"""Process-wide metrics registry: counters, gauges, histograms.

One registry per process (module-level ``REGISTRY``); metrics are
get-or-created by ``(name, labels)`` so every layer that counts the same
thing increments the same object. ``snapshot()`` is the single pane of
glass the scattered per-subsystem counters used to be:

    plan_store.hits / .misses / .writes     core/spmv/plan.py
    opcache.hits / .misses                  core/spmv/opcache.py
    reorder_cache.hits / .misses            core/reorder/api.py
    result_store.hits / .misses / .writes   experiments/store.py
    service.*{service=...}                  serving/spmv_service.py

Metric objects have their own small lock, but callers holding a coarser
lock (e.g. the service condition variable) keep their existing snapshot
atomicity: all service counters are only mutated under ``_cv``, so a
``stats()`` read under ``_cv`` still sees a consistent cut.
"""
from __future__ import annotations

import threading


def _key(name: str, labels: dict) -> tuple:
    return (name, tuple(sorted(labels.items())))


def _fmt(key: tuple) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic-by-convention numeric counter (set() exists for views)."""

    __slots__ = ("_v", "_lock")

    def __init__(self):
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n=1):
        with self._lock:
            self._v += n

    def set(self, v):
        with self._lock:
            self._v = v

    @property
    def value(self):
        return self._v


class Gauge:
    """Point-in-time value, with a max-tracking helper."""

    __slots__ = ("_v", "_lock")

    def __init__(self):
        self._v = 0
        self._lock = threading.Lock()

    def set(self, v):
        with self._lock:
            self._v = v

    def max(self, v):
        with self._lock:
            if v > self._v:
                self._v = v

    @property
    def value(self):
        return self._v


class Histogram:
    """Streaming count/sum/min/max (enough for avg + extremes)."""

    __slots__ = ("count", "sum", "min", "max", "_lock")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None
        self._lock = threading.Lock()

    def observe(self, v):
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            if self.min is None or v < self.min:
                self.min = v
            if self.max is None or v > self.max:
                self.max = v

    def summary(self) -> dict:
        with self._lock:
            return {"count": self.count, "sum": self.sum,
                    "min": self.min, "max": self.max,
                    "avg": (self.sum / self.count) if self.count else None}


class Registry:
    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict = {}
        self._gauges: dict = {}
        self._histograms: dict = {}

    def _get(self, table: dict, cls, name: str, labels: dict):
        key = _key(name, labels)
        m = table.get(key)
        if m is None:
            with self._lock:
                m = table.setdefault(key, cls())
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(self._counters, Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(self._gauges, Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(self._histograms, Histogram, name, labels)

    def total(self, name: str) -> float:
        """Sum of one counter name across all label sets."""
        return sum(c.value for (n, _), c in list(self._counters.items())
                   if n == name)

    def snapshot(self) -> dict:
        """All metrics as plain data: {'counters': {...}, ...}."""
        return {
            "counters": {_fmt(k): c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {_fmt(k): g.value
                       for k, g in sorted(self._gauges.items())},
            "histograms": {_fmt(k): h.summary()
                           for k, h in sorted(self._histograms.items())},
        }

    def reset(self) -> None:
        """Drop every metric (tests only — live handles are invalidated)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


REGISTRY = Registry()


def counter(name: str, **labels) -> Counter:
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    return REGISTRY.histogram(name, **labels)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()
