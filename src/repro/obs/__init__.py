"""repro.obs — zero-dependency observability: spans, metrics, exporters.

Spans (phase-attributed wall time, thread-aware nesting):

    from repro import obs
    with obs.span("plan.probe", engine="sell", k=8):
        ...

Disabled (no sink installed) a span is a shared no-op — safe in hot
paths. Enable for a scope with::

    with obs.tracing() as buf:
        run_campaign(...)
    obs.write_trace("trace.json", buf.flush())   # load in Perfetto

Metrics (process-wide registry; one pane of glass over every cache and
the serving counters)::

    obs.counter("plan_store.hits").inc()
    obs.snapshot()   # {'counters': ..., 'gauges': ..., 'histograms': ...}
"""
from .spans import (Span, TraceBuffer, enabled, install_sink,  # noqa: F401
                    remove_sink, span, tracing)
from .metrics import (REGISTRY, Counter, Gauge, Histogram,  # noqa: F401
                      counter, gauge, histogram, reset, snapshot)
from .export import (to_chrome_trace, validate_chrome_trace,  # noqa: F401
                     write_chrome_trace, write_jsonl, write_trace)

__all__ = [
    "span", "tracing", "enabled", "install_sink", "remove_sink",
    "Span", "TraceBuffer",
    "counter", "gauge", "histogram", "snapshot", "reset",
    "REGISTRY", "Counter", "Gauge", "Histogram",
    "to_chrome_trace", "write_chrome_trace", "write_jsonl",
    "write_trace", "validate_chrome_trace",
]
