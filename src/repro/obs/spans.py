"""Nested, thread-aware spans with a near-zero disabled path.

``span(name, **attrs)`` returns a context manager. With no sink
installed (the default) it returns one shared singleton whose
``__enter__``/``__exit__`` do nothing — a single module-global flag
test, no lock, no allocation — so instrumentation can stay on in hot
paths (per-request dispatch, operator ``__call__``).

With a sink installed (``install_sink`` / the ``tracing()``
contextmanager) spans record wall time via ``perf_counter_ns``, nest
through a thread-local stack (each thread owns its own span tree) and
are exception-safe:

* a span exited by an unwinding exception still records, with an
  ``error`` attribute naming the exception type;
* a child span that was entered but never exited (e.g. a probe that
  raised between ``__enter__`` and manual bookkeeping) is force-closed
  when its enclosing span exits, tagged ``unclosed``.

Timestamps are microseconds on the ``perf_counter_ns`` clock — an
arbitrary but monotonic origin, which is all the Chrome-trace/Perfetto
format needs. ``TraceBuffer.flush()`` returns events in a deterministic
order (ts, tid, id) regardless of which thread emitted first.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from contextlib import contextmanager

_PID = os.getpid()
_sinks: list = []          # sink objects with an .add(event: dict) method
_enabled = False           # fast-path flag, kept in sync with _sinks
_ids = itertools.count(1)  # CPython-atomic span id source
_tls = threading.local()


class _NullSpan:
    """Shared no-op span returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NULL = _NullSpan()


class Span:
    """A live span (tracing enabled). Use as a context manager."""

    __slots__ = ("name", "attrs", "id", "parent", "tid", "thread",
                 "t0", "_open")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self.id = next(_ids)
        self.parent = None
        self.tid = 0
        self.thread = ""
        self.t0 = 0
        self._open = False

    def set(self, **attrs):
        """Attach attributes after entry (e.g. a result computed inside)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        stack = getattr(_tls, "stack", None)
        if stack is None:
            stack = _tls.stack = []
        t = threading.current_thread()
        self.tid = t.ident or 0
        self.thread = t.name
        self.parent = stack[-1].id if stack else None
        stack.append(self)
        self._open = True
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, etype, evalue, tb):
        t1 = time.perf_counter_ns()
        stack = getattr(_tls, "stack", None) or []
        # Force-close any descendants left open by a raise between their
        # __enter__ and __exit__ (they sit above us on the stack).
        while stack and stack[-1] is not self:
            dangling = stack.pop()
            dangling._open = False
            _emit(dangling, t1, unclosed=True)
        if stack and stack[-1] is self:
            stack.pop()
        self._open = False
        _emit(self, t1, error=etype.__name__ if etype else None)
        return False


def _emit(span: Span, t1_ns: int, error=None, unclosed=False) -> None:
    attrs = span.attrs
    if error:
        attrs = dict(attrs, error=error)
    if unclosed:
        attrs = dict(attrs, unclosed=True)
    ev = {
        "name": span.name,
        "ts": span.t0 / 1e3,          # µs, perf_counter origin
        "dur": (t1_ns - span.t0) / 1e3,
        "pid": _PID,
        "tid": span.tid,
        "thread": span.thread,
        "id": span.id,
        "parent": span.parent,
        "args": attrs,
    }
    for sink in list(_sinks):
        sink.add(ev)


def span(name: str, **attrs):
    """Open a span. Near-free when no sink is installed."""
    if not _enabled:
        return _NULL
    return Span(name, attrs)


def enabled() -> bool:
    return _enabled


class TraceBuffer:
    """The default sink: collects events; flush() orders deterministically."""

    def __init__(self):
        self._events: list = []
        self._lock = threading.Lock()

    def add(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def flush(self) -> list:
        """Events sorted by (ts, tid, id) — stable across thread races."""
        with self._lock:
            evs = list(self._events)
        return sorted(evs, key=lambda e: (e["ts"], e["tid"], e["id"]))


def install_sink(sink) -> None:
    global _enabled
    if sink not in _sinks:
        _sinks.append(sink)
    _enabled = True


def remove_sink(sink) -> None:
    global _enabled
    try:
        _sinks.remove(sink)
    except ValueError:
        pass
    _enabled = bool(_sinks)


@contextmanager
def tracing(buffer: TraceBuffer = None):
    """Enable tracing for a scope; yields the TraceBuffer."""
    buf = buffer if buffer is not None else TraceBuffer()
    install_sink(buf)
    try:
        yield buf
    finally:
        remove_sink(buf)
