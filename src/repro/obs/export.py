"""Trace exporters: Chrome-trace/Perfetto JSON and a JSONL event log.

``to_chrome_trace(events)`` converts the span events produced by
``spans.TraceBuffer.flush()`` into the Chrome Trace Event Format that
Perfetto (https://ui.perfetto.dev) and chrome://tracing load directly:
every span becomes a B/E (duration-begin / duration-end) pair on its
originating thread, so nesting falls out of timestamp containment per
tid. ``M`` metadata events name each thread.

``validate_chrome_trace`` is the CI schema gate: every non-metadata
event must be B or E, carry pid/tid, and the B/E events on each
(pid, tid) must balance like parentheses.

Run as a module for the CI check:

    python -m repro.obs.export trace.json
"""
from __future__ import annotations

import json


def to_chrome_trace(events: list) -> dict:
    """Span events (ts/dur in µs) → Chrome-trace JSON object.

    A naive global (ts, phase) sort cannot parenthesize zero-duration
    spans (their B and E share a timestamp), so each thread's sequence is
    built with a stack sweep instead: spans sorted by (ts, -dur, id) —
    parents before the children they contain on start-time ties — with an
    open span's E emitted once the next span starts at-or-after its end
    (the span's recorded parent link keeps a child that starts exactly at
    its parent's end inside it). The result is well-parenthesized per tid
    by construction.
    """
    out = []
    threads = {}
    by_tid: dict = {}
    for ev in events:
        key = (ev["pid"], ev["tid"])
        threads.setdefault(key, ev.get("thread", ""))
        by_tid.setdefault(key, []).append(ev)

    def close(sp):
        out.append({"ph": "E", "pid": sp["pid"], "tid": sp["tid"],
                    "ts": sp["ts"] + sp["dur"]})

    for key in sorted(by_tid):
        spans = sorted(by_tid[key],
                       key=lambda e: (e["ts"], -e["dur"], e["id"]))
        stack: list = []               # open spans, innermost last
        for ev in spans:
            while stack:
                end = stack[-1]["ts"] + stack[-1]["dur"]
                if end < ev["ts"] or (end == ev["ts"]
                                      and stack[-1]["id"] != ev.get("parent")):
                    close(stack.pop())
                else:
                    break
            out.append({"ph": "B", "name": ev["name"], "cat": "repro",
                        "pid": ev["pid"], "tid": ev["tid"], "ts": ev["ts"],
                        "args": dict(ev.get("args") or {})})
            stack.append(ev)
        while stack:
            close(stack.pop())
    meta = [{"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
             "args": {"name": name or f"tid-{tid}"}}
            for (pid, tid), name in sorted(threads.items())]
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, events: list) -> None:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(events), f)


def write_jsonl(path: str, events: list) -> None:
    """One span event per line, raw (ts/dur µs, id/parent links intact)."""
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev) + "\n")


def write_trace(path: str, events: list) -> None:
    """Extension-dispatched: .jsonl → event log, else Chrome-trace JSON."""
    if path.endswith(".jsonl"):
        write_jsonl(path, events)
    else:
        write_chrome_trace(path, events)


def validate_chrome_trace(trace) -> list:
    """Schema-check a Chrome-trace object (or a path to one).

    Returns the trace's duration events on success; raises ValueError
    naming the first violation otherwise.
    """
    if isinstance(trace, str):
        with open(trace) as f:
            trace = json.load(f)
    evs = trace.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        raise ValueError("traceEvents missing or empty")
    stacks: dict = {}
    duration_events = []
    for i, ev in enumerate(evs):
        ph = ev.get("ph")
        if "pid" not in ev or "tid" not in ev:
            raise ValueError(f"event {i} ({ph!r}) lacks pid/tid")
        if ph == "M":
            continue
        if ph not in ("B", "E"):
            raise ValueError(f"event {i} has unexpected ph={ph!r}")
        duration_events.append(ev)
        key = (ev["pid"], ev["tid"])
        stack = stacks.setdefault(key, [])
        if ph == "B":
            if "name" not in ev or "ts" not in ev:
                raise ValueError(f"B event {i} lacks name/ts")
            stack.append(ev)
        else:
            if not stack:
                raise ValueError(f"E event {i} on {key} without open B")
            stack.pop()
    for key, stack in stacks.items():
        if stack:
            raise ValueError(
                f"{len(stack)} unbalanced B event(s) on pid/tid {key}: "
                f"{[e['name'] for e in stack]}")
    return duration_events


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Validate a Chrome-trace JSON file (CI schema gate)")
    ap.add_argument("path")
    ap.add_argument("--require-span", action="append", default=[],
                    help="span name that must appear (repeatable)")
    args = ap.parse_args(argv)
    evs = validate_chrome_trace(args.path)
    names = {e.get("name") for e in evs if e.get("ph") == "B"}
    missing = [s for s in args.require_span if s not in names]
    if missing:
        print(f"FAIL: required spans absent: {missing}")
        print(f"present: {sorted(names)}")
        return 1
    n_b = sum(1 for e in evs if e["ph"] == "B")
    print(f"OK: {n_b} spans, {len(names)} distinct names, B/E balanced")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
