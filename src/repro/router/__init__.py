"""repro.router — multi-shard serving: key -> mesh routing, per-device
memory budgets, non-stalling shard replans, incremental structure deltas.

    from repro.router import MeshSpec, RoutedSpmvService
    from repro.api import Topology

See service.py for the serving contract, table.py for the routing
ledger, placement.py for the policy registry
(@register_placement), and core/spmv/delta.py for StructureDelta.
"""
from .placement import (PLACEMENT_REGISTRY, PlacementSpec,  # noqa: F401
                        estimate_nbytes, get_placement, register_placement)
from .service import RoutedSpmvService  # noqa: F401
from .table import MeshSpec, RoutingTable  # noqa: F401

__all__ = [
    "MeshSpec",
    "PLACEMENT_REGISTRY",
    "PlacementSpec",
    "RoutedSpmvService",
    "RoutingTable",
    "estimate_nbytes",
    "get_placement",
    "register_placement",
]
