"""Placement policies — WHICH mesh a newly routed key lands on.

A policy is a pure function over the routing table's current load state:

    @register_placement("my_policy")
    def my_policy(key, mat, meshes, loads):
        return <mesh name>

`meshes` is the ordered list of MeshSpec candidates, `loads` maps mesh
name -> {"keys", "nnz", "est_bytes"} accumulated from prior assignments
(estimates, not device truth — placement runs BEFORE planning, so it can
only reason from the matrix and the ledger). Returning a name not in
`meshes` is a policy bug and raises at the table.

Built-ins cover the three costs a placement can optimize:

  bin_pack    — best-fit by estimated operator bytes against each mesh's
                total budget (budget_per_device x devices): the mesh with
                the least headroom that still fits, so big keys don't
                strand capacity. Falls back to least-loaded when nothing
                fits — the per-mesh LRU enforces the real budget.
  nnz_balance — argmin of per-device nnz after assignment: equalizes the
                compute (and SpMV memory traffic) each device pays.
  comm_aware  — scores every mesh with the PR 5 plan-time collective cost
                model (core/spmv/topology.comm_model on a uniform row
                split): modelled collective bytes per SpMV on THAT mesh
                shape plus a per-device compute-bytes load penalty, so a
                matrix whose structure gathers badly on a wide mesh is
                co-placed onto a narrower one.

The registry follows core/registry.py: frozen spec, decorator, KeyError
with the sorted known list. This module is numpy-only (plan-time code).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from ..core.sparse.csr import CSRMatrix
from ..core.sparse.partition import static_partition
from ..core.spmv import topology as topology_mod


def estimate_nbytes(mat: CSRMatrix, dtype_size: int = 4) -> int:
    """Pre-plan operator footprint estimate: CSR payload (cols + vals +
    rowptr) at the compute dtype. Engines pad (ELL/SELL/BELL) and sharded
    layouts replicate index maps, so this undershoots — placement treats
    it as a relative load signal; the budgeted LRU enforces truth."""
    m = mat.shape[0]
    return int(mat.nnz * (4 + dtype_size) + (m + 1) * 4)


@dataclasses.dataclass(frozen=True)
class PlacementSpec:
    name: str
    fn: Callable
    description: str = ""


PLACEMENT_REGISTRY: Dict[str, PlacementSpec] = {}


def register_placement(name: str, description: str = "",
                       override: bool = False):
    """Decorator: register `(key, mat, meshes, loads) -> mesh_name`."""

    def deco(fn):
        if name in PLACEMENT_REGISTRY and not override:
            raise ValueError(f"placement {name!r} already registered "
                             f"(pass override=True to replace)")
        PLACEMENT_REGISTRY[name] = PlacementSpec(
            name=name, fn=fn, description=description or (fn.__doc__ or ""))
        return fn

    return deco


def get_placement(name: str) -> PlacementSpec:
    spec = PLACEMENT_REGISTRY.get(name)
    if spec is None:
        raise KeyError(f"unknown placement policy {name!r}; known: "
                       f"{sorted(PLACEMENT_REGISTRY)}")
    return spec


def _least_loaded(meshes, loads) -> str:
    return min(meshes, key=lambda s: loads[s.name]["est_bytes"]).name


@register_placement("bin_pack",
                    "best-fit by estimated bytes against mesh budgets")
def bin_pack(key: str, mat: CSRMatrix, meshes, loads) -> str:
    est = estimate_nbytes(mat)
    best: Optional[str] = None
    best_headroom = None
    for spec in meshes:
        cap = spec.budget_bytes
        if cap is None:
            continue                      # unbounded meshes are fallback
        headroom = cap - loads[spec.name]["est_bytes"] - est
        if headroom < 0:
            continue
        if best_headroom is None or headroom < best_headroom:
            best, best_headroom = spec.name, headroom
    if best is not None:
        return best
    unbounded = [s for s in meshes if s.budget_bytes is None]
    if unbounded:
        return _least_loaded(unbounded, loads)
    return _least_loaded(meshes, loads)   # nothing fits: LRU will evict


@register_placement("nnz_balance",
                    "argmin per-device nnz after assignment")
def nnz_balance(key: str, mat: CSRMatrix, meshes, loads) -> str:
    return min(
        meshes,
        key=lambda s: (loads[s.name]["nnz"] + mat.nnz)
        / max(s.topology.devices, 1),
    ).name


@register_placement("comm_aware",
                    "modelled collective bytes (comm_model) + load penalty")
def comm_aware(key: str, mat: CSRMatrix, meshes, loads) -> str:
    dsize = 4
    best, best_score = None, None
    for spec in meshes:
        topo = spec.topology
        if topo.trivial:
            comm_bytes = 0.0
        else:
            starts = static_partition(mat, topo.row_devices)
            model = topology_mod.comm_model(mat, starts, topo,
                                            dtype_size=dsize, k=1,
                                            block_shape=(8, 128))
            comm_bytes = float(model["bytes_per_spmv"]) * topo.devices
        per_dev_compute = ((loads[spec.name]["nnz"] + mat.nnz)
                           / max(topo.devices, 1)) * (4 + dsize)
        score = comm_bytes + per_dev_compute
        if best_score is None or score < best_score:
            best, best_score = spec.name, score
    assert best is not None
    return best
