"""RoutingTable — the key -> mesh ledger the router dispatches through.

A MeshSpec names one device mesh (a repro Topology) plus its per-device
memory budget; the table owns the authoritative assignment of matrix keys
to meshes, made once at register time by a pluggable placement policy
(placement.py) and stable until the key is removed — SpMV requests must
never migrate mid-flight, so re-placement is an explicit
remove + register, never a side effect.

Every assignment runs under a `router.assign` span and counts
`router.assigned{mesh=...}`; `snapshot()` is the load ledger the policies
score against (estimates — the per-mesh budgeted LRU enforces truth).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

from .. import obs
from ..core.sparse.csr import CSRMatrix
from ..core.spmv import topology as topology_mod
from . import placement as placement_mod


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """One routable device mesh.

    name              — routing label (unique within a table)
    topology          — repro Topology (devices, layout, mesh shape)
    budget_per_device — device-memory budget in bytes for EACH device of
                        this mesh (None = unbounded); the router's
                        per-mesh service enforces it via per-device
                        operator accounting (opcache
                        .operator_nbytes_per_device).
    """

    name: str
    topology: topology_mod.Topology
    budget_per_device: Optional[int] = None

    def __post_init__(self):
        topo = topology_mod.normalize(self.topology) \
            or topology_mod.Topology(devices=1)
        object.__setattr__(self, "topology", topo)
        if self.budget_per_device is not None \
                and int(self.budget_per_device) <= 0:
            raise ValueError("budget_per_device must be positive or None")

    @property
    def budget_bytes(self) -> Optional[int]:
        """Total budget across the mesh (what bin-pack fits against)."""
        if self.budget_per_device is None:
            return None
        return int(self.budget_per_device) * self.topology.devices


class RoutingTable:
    """Thread-safe key -> MeshSpec assignment under one placement policy."""

    def __init__(self, meshes: List[MeshSpec], policy: str = "bin_pack"):
        if not meshes:
            raise ValueError("RoutingTable needs at least one MeshSpec")
        names = [m.name for m in meshes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate mesh names: {names}")
        self.meshes = list(meshes)
        self.policy = placement_mod.get_placement(policy)
        self._by_name = {m.name: m for m in meshes}
        self._assigned: Dict[str, str] = {}        # key -> mesh name
        self._loads = {m.name: {"keys": 0, "nnz": 0, "est_bytes": 0}
                       for m in meshes}
        self._lock = threading.Lock()

    def assign(self, key: str, mat: CSRMatrix,
               mesh: Optional[str] = None) -> MeshSpec:
        """Place `key` (policy-chosen, or pinned with mesh=). Idempotent
        re-assign of a live key is refused — remove() first."""
        with self._lock:
            if key in self._assigned:
                raise ValueError(f"key {key!r} is already routed to "
                                 f"{self._assigned[key]!r}; remove() first")
            with obs.span("router.assign", key=key,
                          policy=self.policy.name) as sp:
                if mesh is not None:
                    if mesh not in self._by_name:
                        raise KeyError(f"unknown mesh {mesh!r}; known: "
                                       f"{sorted(self._by_name)}")
                    name = mesh
                else:
                    name = self.policy.fn(key, mat, self.meshes,
                                          {n: dict(v) for n, v
                                           in self._loads.items()})
                    if name not in self._by_name:
                        raise KeyError(
                            f"placement {self.policy.name!r} returned "
                            f"unknown mesh {name!r}")
                spec = self._by_name[name]
                self._assigned[key] = name
                load = self._loads[name]
                load["keys"] += 1
                load["nnz"] += int(mat.nnz)
                load["est_bytes"] += placement_mod.estimate_nbytes(mat)
                sp.set(mesh=name, est_bytes=load["est_bytes"])
            obs.counter("router.assigned", mesh=name).inc()
            obs.gauge("router.keys", mesh=name).set(load["keys"])
            return spec

    def mesh_of(self, key: str) -> MeshSpec:
        with self._lock:
            name = self._assigned.get(key)
            if name is None:
                raise KeyError(f"key {key!r} is not routed; known keys: "
                               f"{sorted(self._assigned)}")
            return self._by_name[name]

    def remove(self, key: str, mat: Optional[CSRMatrix] = None) -> None:
        with self._lock:
            name = self._assigned.pop(key, None)
            if name is None:
                return
            load = self._loads[name]
            load["keys"] -= 1
            if mat is not None:
                load["nnz"] -= int(mat.nnz)
                load["est_bytes"] -= placement_mod.estimate_nbytes(mat)
            obs.gauge("router.keys", mesh=name).set(load["keys"])

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "policy": self.policy.name,
                "assignments": dict(self._assigned),
                "loads": {n: dict(v) for n, v in self._loads.items()},
            }
