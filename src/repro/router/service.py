"""RoutedSpmvService — one serving front-end over a fleet of device
meshes.

The plain SpmvService serves every key from ONE topology and accounts
device memory as a single global number. This router scales that front
end out: a RoutingTable places each registered key onto one mesh of a
fleet (placement.py policies — bin-pack by estimated bytes, per-device
nnz balance, comm-model-aware co-placement), each mesh is served by its
own `_MeshService` (an SpmvService subclass whose budget bounds EVERY
device via per-device operator accounting), and requests dispatch through
a `router.dispatch` span to the owning mesh.

Updates are where the router earns its subclass: a plain service refuses
sharded-key updates (`RoutedElsewhere`), while `_MeshService` flips
`_allow_sharded_updates` — `update_values` is a sharded `Plan.rebuild`
(frozen partition/panel split/schedule, array repack only) and
`update_structure` replans in the BACKGROUND with a generation-tagged
swap per shard, so sibling keys on the same mesh keep serving the whole
time. Pass `delta=` (core.spmv.delta.StructureDelta) and the replanner
first tries `Plan.apply_delta` — reorder and tuner search skipped
entirely — falling back to a full replan only past the churn/bandwidth
thresholds.

Per-device budget invariant (why `_op_nbytes` is max x devices): the base
LRU tracks Sum_op charge(op) <= budget. With charge(op) =
max_d per_dev(op)[d] * ndev and budget = budget_per_device * ndev,

    Sum_op max_d per_dev(op)[d] <= budget_per_device

and device d's true residency Sum_op per_dev(op)[d] is bounded by the
left side — so NO device ever exceeds budget_per_device, and because
`_install_locked` evicts BEFORE installing, the bound holds even
transiently. `--smoke-route` (benchmarks/run.py) hard-asserts this.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

from .. import obs
from ..core.sparse.csr import CSRMatrix
from ..core.spmv import opcache
from ..serving.errors import UnregisteredKey
from ..serving.spmv_service import SpmvService
from .table import MeshSpec, RoutingTable


class _MeshService(SpmvService):
    """SpmvService for ONE mesh of the fleet: sharded updates allowed,
    memory accounted per device (the budget passed to the base class is
    budget_per_device x devices; see the module docstring invariant)."""

    _allow_sharded_updates = True

    def _op_nbytes(self, op) -> int:
        per = opcache.operator_nbytes_per_device(op)
        return max(per) * len(per)

    def per_device_bytes(self) -> list:
        """Current true per-device residency (sum of each resident
        operator's device slice) — what the budget invariant bounds."""
        with self._cv:
            ops = [ent[1] for ent in self._ops.values()]
        totals: Dict[int, int] = {}
        for op in ops:
            for d, b in enumerate(opcache.operator_nbytes_per_device(op)):
                totals[d] = totals.get(d, 0) + b
        ndev = max(totals) + 1 if totals else 1
        return [totals.get(d, 0) for d in range(ndev)]


class RoutedSpmvService:
    """Route keys across meshes; serve each from its own SpmvService.

    Usage:
        meshes = [MeshSpec("m8", Topology(devices=8),
                           budget_per_device=8 << 20),
                  MeshSpec("m2", Topology(devices=2),
                           budget_per_device=8 << 20)]
        with RoutedSpmvService(meshes, policy="bin_pack",
                               max_batch=8) as router:
            router.register("gnn", mat)              # policy placement
            y = router.submit("gnn", x).result()
            router.update_values("gnn", new_vals)    # sharded rebuild
            fut = router.update_structure("gnn", delta=delta)
            fut.result()                             # replan landed
            print(router.stats()["per_device_ok"])

    Extra **service_kw (max_batch, window_ms, overload, ...) are passed
    to every per-mesh service verbatim.
    """

    def __init__(self, meshes: List[MeshSpec], policy: str = "bin_pack",
                 **service_kw):
        self.table = RoutingTable(meshes, policy=policy)
        service_kw.pop("topology", None)
        service_kw.pop("memory_budget_bytes", None)
        self._services: Dict[str, _MeshService] = {}
        for spec in self.table.meshes:
            budget = (None if spec.budget_per_device is None
                      else int(spec.budget_per_device)
                      * spec.topology.devices)
            self._services[spec.name] = _MeshService(
                topology=spec.topology, memory_budget_bytes=budget,
                **service_kw)
        self._mats: Dict[str, CSRMatrix] = {}
        self._lock = threading.Lock()
        self._closed = False

    # -- key lifecycle -----------------------------------------------------
    def register(self, key: str, mat: CSRMatrix,
                 reorder: Optional[str] = None, priority: int = 0,
                 mesh: Optional[str] = None) -> MeshSpec:
        """Place `key` (policy, or pinned with mesh=) and register it on
        the owning mesh's service. Returns the MeshSpec it landed on."""
        spec = self.table.assign(key, mat, mesh=mesh)
        try:
            self._services[spec.name].register(
                key, mat, reorder=reorder, topology=spec.topology,
                priority=priority)
        except Exception:
            self.table.remove(key, mat)
            raise
        with self._lock:
            self._mats[key] = mat
        return spec

    def _service(self, key: str) -> _MeshService:
        try:
            spec = self.table.mesh_of(key)
        except KeyError:
            raise UnregisteredKey(f"unrouted matrix key {key!r}") from None
        return self._services[spec.name]

    def mesh_of(self, key: str) -> MeshSpec:
        return self.table.mesh_of(key)

    # -- request path ------------------------------------------------------
    def submit(self, key: str, x):
        spec = self.table.mesh_of(key)
        with obs.span("router.dispatch", key=key, mesh=spec.name):
            fut = self._services[spec.name].submit(key, x)
        obs.counter("router.requests", mesh=spec.name).inc()
        return fut

    def operator(self, key: str):
        return self._service(key).operator(key)

    # -- dynamic matrices --------------------------------------------------
    def update_values(self, key: str, vals) -> None:
        """Sharded value swap: Plan.rebuild under the frozen partition —
        array repack only, no replan, siblings unaffected."""
        svc = self._service(key)
        svc.update_values(key, vals)
        obs.counter("router.value_swaps").inc()
        with self._lock:
            mat = self._mats.get(key)
            if mat is not None:
                import numpy as np

                self._mats[key] = CSRMatrix(
                    rowptr=mat.rowptr, cols=mat.cols,
                    vals=np.asarray(vals).astype(mat.vals.dtype,
                                                 copy=False),
                    shape=mat.shape)

    def update_structure(self, key: str, mat: Optional[CSRMatrix] = None,
                         delta=None, staleness_s: Optional[float] = None):
        """Background shard replan (or delta apply): the owning mesh's
        replanner swaps matrix + plan + operator generation-atomically
        while the stale shards — and every sibling key — keep serving.
        Returns the replan Future (resolves to the new generation)."""
        svc = self._service(key)
        fut = svc.update_structure(key, mat=mat, delta=delta,
                                   staleness_s=staleness_s)
        obs.counter("router.replans_requested",
                    delta=str(delta is not None).lower()).inc()
        if mat is not None:
            with self._lock:
                self._mats[key] = mat
        elif delta is not None:
            with self._lock:
                base = self._mats.get(key)
                if base is not None:
                    self._mats[key] = delta.apply_to(base)
        return fut

    # -- lifecycle / observability -----------------------------------------
    def flush(self, timeout: float = 60.0) -> None:
        for svc in self._services.values():
            svc.flush(timeout=timeout)

    def close(self, timeout: float = 60.0) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        err = None
        for svc in self._services.values():
            try:
                svc.close(timeout=timeout)
            except TimeoutError as e:
                err = e
        if err is not None:
            raise err

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def stats(self) -> dict:
        """Fleet snapshot: aggregated counters, per-mesh service stats,
        the routing ledger, and the per-device budget verdict
        (`per_device_ok`: every device of every mesh currently within
        its budget_per_device)."""
        per_mesh = {}
        agg = {k: 0 for k in ("requests", "results", "errors", "sheds",
                              "rejected", "replans", "replan_errors",
                              "value_swaps", "evictions",
                              "budget_overruns", "pending")}
        per_device_ok = True
        for spec in self.table.meshes:
            svc = self._services[spec.name]
            s = svc.stats()
            per_dev = svc.per_device_bytes()
            budget = spec.budget_per_device
            ok = budget is None or all(b <= budget for b in per_dev)
            per_device_ok = per_device_ok and ok
            per_mesh[spec.name] = {
                "service": s,
                "devices": spec.topology.devices,
                "budget_per_device": budget,
                "per_device_bytes": per_dev,
                "per_device_ok": ok,
            }
            for k in agg:
                agg[k] += int(s.get(k, 0))
        return {**agg, "per_mesh": per_mesh,
                "per_device_ok": per_device_ok,
                "routing": self.table.snapshot()}
