"""Jit'd wrapper: full-sequence SSD using the fused chunk kernel
(lax.scan over chunks, kernel per step). Forward-only — serving/prefill."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_chunk
from .ref import ssd_chunk_ref


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel"))
def ssd_scan(la, xw, b_mat, c_mat, state0, chunk: int = 128,
             use_kernel: str = "ref"):
    """la [B,S,H]; xw [B,S,H,P]; b/c [B,S,N]; state0 [B,H,N,P].
    Returns (y [B,S,H,P], final state). S must divide by `chunk`."""
    bsz, s, h = la.shape
    nc = s // chunk

    def rc(t_):
        return jnp.moveaxis(t_.reshape(bsz, nc, chunk, *t_.shape[2:]), 1, 0)

    fn = {
        "pallas": lambda *a: ssd_chunk(*a),
        "interpret": lambda *a: ssd_chunk(*a, interpret=True),
        "ref": ssd_chunk_ref,
    }[use_kernel]

    def body(state, inp):
        la_i, xw_i, b_i, c_i = inp
        y, new_state = fn(la_i, xw_i, b_i, c_i, state)
        return new_state, y

    final, ys = jax.lax.scan(body, state0,
                             (rc(la), rc(xw), rc(b_mat), rc(c_mat)))
    return jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, xw.shape[-1]), final
