"""Pure-jnp oracle for the SSD chunk kernel (mirrors the scan body of
models/layers/mamba2._ssd_chunked for ONE chunk)."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_chunk_ref(la, xw, b_mat, c_mat, state):
    """Same contract as kernel.ssd_chunk."""
    la = la.astype(jnp.float32)
    cum = jnp.cumsum(la, axis=1)                        # [B,T,H]
    t = la.shape[1]
    tri = jnp.tril(jnp.ones((t, t), bool))
    expo = cum[:, :, None, :] - cum[:, None, :, :]      # [B,T,T,H]
    expo = jnp.where(tri[None, :, :, None], expo, -1e30)
    dec = jnp.exp(expo)
    cb = jnp.einsum("btn,bin->bti", c_mat, b_mat)
    xwf = xw.astype(jnp.float32)
    y = jnp.einsum("bti,btih,bihp->bthp", cb, dec, xwf)
    y += jnp.einsum("btn,bth,bhnp->bthp", c_mat,
                    jnp.exp(cum), state.astype(jnp.float32))
    dec_end = jnp.exp(cum[:, -1:, :] - cum)             # [B,T,H]
    sout = state.astype(jnp.float32) * \
        jnp.exp(cum[:, -1, :])[..., None, None] + \
        jnp.einsum("btn,bth,bthp->bhnp", b_mat, dec_end, xwf)
    return y.astype(xw.dtype), sout.astype(state.dtype)
