"""Pallas TPU kernel: one Mamba2/SSD chunk step (fused).

Motivation (EXPERIMENTS §Roofline): the zamba2 prefill/train cells are the
most memory-bound in the table because the pure-JAX SSD chunk materializes
the [T, T, H] decay tensor and the [T, T] score matrix in HBM for every
chunk. This kernel fuses the whole chunk — cumsum, decay, scores, intra/
inter terms, and the state update — in VMEM; HBM traffic drops to the
chunk's inputs + outputs + state (~T*(2P+2N) floats per (batch, head)
instead of ~T^2).

Grid: (batch, heads) — each program owns one (b, h) slice: T<=256, P, N
all fit VMEM ([T,T] f32 at T=128 is 64 KiB).

Forward-only (no custom_vjp): used on the inference paths (prefill/decode);
training keeps the jnp path whose AD is exercised by the smoke tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(la_ref, xw_ref, b_ref, c_ref, s_ref, y_ref, sout_ref):
    la = la_ref[0, :, 0].astype(jnp.float32)           # [T]
    xw = xw_ref[0, :, 0].astype(jnp.float32)           # [T, P]
    bm = b_ref[0].astype(jnp.float32)                  # [T, N]
    cm = c_ref[0].astype(jnp.float32)                  # [T, N]
    state = s_ref[0, 0].astype(jnp.float32)            # [N, P]

    t = la.shape[0]
    cum = jnp.cumsum(la)                               # [T]
    # decay(t,i) = exp(cum_t - cum_i) for i<=t; mask exponent pre-exp
    expo = cum[:, None] - cum[None, :]
    tri = jnp.tril(jnp.ones((t, t), jnp.bool_))
    dec = jnp.exp(jnp.where(tri, expo, -1e30))
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)   # [T, T]
    y = jnp.dot(cb * dec, xw, preferred_element_type=jnp.float32)
    # inter-chunk: y_t += exp(cum_t) * (c_t . state)
    y += jnp.exp(cum)[:, None] * jnp.dot(cm, state,
                                         preferred_element_type=jnp.float32)
    # state update
    dec_end = jnp.exp(cum[-1] - cum)                   # [T]
    sout = state * jnp.exp(cum[-1]) + jnp.dot(
        (bm * dec_end[:, None]).T, xw, preferred_element_type=jnp.float32)

    y_ref[0, :, 0] = y.astype(y_ref.dtype)
    sout_ref[0, 0] = sout.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(la, xw, b_mat, c_mat, state, interpret: bool = False):
    """One SSD chunk for all (batch, head) pairs.

    la:    [B, T, H]    log decay (negative)
    xw:    [B, T, H, P] discretized input (x * dt)
    b_mat: [B, T, N]
    c_mat: [B, T, N]
    state: [B, H, N, P] incoming state
    Returns (y [B, T, H, P], state_out [B, H, N, P]).
    """
    bsz, t, h = la.shape
    p = xw.shape[-1]
    n = b_mat.shape[-1]
    grid = (bsz, h)
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, 1), lambda b, hh: (b, 0, hh)),
            pl.BlockSpec((1, t, 1, p), lambda b, hh: (b, 0, hh, 0)),
            pl.BlockSpec((1, t, n), lambda b, hh: (b, 0, 0)),
            pl.BlockSpec((1, t, n), lambda b, hh: (b, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b, hh: (b, hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, 1, p), lambda b, hh: (b, 0, hh, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b, hh: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, h, p), xw.dtype),
            jax.ShapeDtypeStruct((bsz, h, n, p), state.dtype),
        ],
        interpret=interpret,
    )(la, xw, b_mat, c_mat, state)
