"""Pallas TPU kernel: SELL-C-σ multi-vector SpMM (k-tiled flattened-chunk grid).

The SpMV kernel (kernels/sell_spmv/kernel.py) already accepts x of shape
[n_pad, nv], but it carries ALL nv vectors through every grid step — fine
for nv = 1, wasteful when a serving batch or block solver brings k = 32
right-hand sides (the y tile and the gathered x grow k-fold in VMEM).

This kernel tiles the dense block X[n_pad, k_pad] into lane-aligned vector
blocks of width KB and makes the k-tile the OUTER grid axis:

    grid = (k_pad // KB, num_chunks)

* Inner axis g streams the flattened [C, W] SELL chunks exactly like the
  SpMV kernel, so consecutive chunks of one slice accumulate into the same
  resident y tile (the revisit-consecutive reduction contract holds per
  k-tile).
* Each chunk block loaded for step (kt, g) multiplies the full KB-wide
  x tile — the matrix stream is amortized over KB vectors per pass, and the
  whole matrix is streamed ceil(k / KB) times instead of k times. This is
  the data-movement win the k-aware tuner (core/spmv/tune.py) models.
* The x k-tile's block index depends only on kt (the outer axis), so it
  stays resident in VMEM across all chunks of one pass.

Correctness on CPU is exercised through interpret mode (tests force it);
ref.py holds the jnp oracle used as the non-TPU fallback engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sell_spmm_kernel(chunk_slice_ref, cols_ref, vals_ref, x_ref, y_ref, *,
                      acc_dtype):
    g = pl.program_id(1)                     # chunk index (inner axis)
    sl = chunk_slice_ref[g]
    prev = chunk_slice_ref[jnp.maximum(g - 1, 0)]
    is_first = jnp.logical_or(g == 0, sl != prev)

    @pl.when(is_first)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    cols = cols_ref[0]                       # [C, W] int32
    vals = vals_ref[0].astype(acc_dtype)     # [C, W]
    xg = x_ref[cols].astype(acc_dtype)       # on-chip gather: [C, W, KB]
    part = jnp.sum(vals[..., None] * xg, axis=1)        # [C, KB]
    y_ref[0] += part.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_slices", "kb", "interpret"))
def sell_spmm_ktiled(chunk_vals: jax.Array, chunk_cols: jax.Array,
                     chunk_slice: jax.Array, x: jax.Array, num_slices: int,
                     kb: int, interpret: bool = False) -> jax.Array:
    """y[S, C, k_pad] = SELL(chunk_*) @ x[n_pad, k_pad], k-tiled by kb.

    chunk_vals: [T, C, W] (padding slots are 0)
    chunk_cols: [T, C, W] int32 (padding -> 0, result-neutral via zero vals)
    chunk_slice: int32[T], nondecreasing, covering every slice in [0, S)
    x: [n_pad, k_pad] with k_pad a multiple of kb
    """
    t, c, w = chunk_vals.shape
    n_pad, k_pad = x.shape
    assert k_pad % kb == 0, (k_pad, kb)
    nkt = k_pad // kb
    # accumulate at >= the operator dtype (f32 floor): an f64 operator's
    # matmul keeps f64 accuracy, same contract as ref.spmm_ell
    acc_dtype = jnp.promote_types(x.dtype, jnp.float32)

    return pl.pallas_call(
        functools.partial(_sell_spmm_kernel, acc_dtype=acc_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nkt, t),
            in_specs=[
                pl.BlockSpec((1, c, w), lambda kt, g, cs: (g, 0, 0)),
                pl.BlockSpec((1, c, w), lambda kt, g, cs: (g, 0, 0)),
                pl.BlockSpec((n_pad, kb), lambda kt, g, cs: (0, kt)),
            ],
            out_specs=pl.BlockSpec((1, c, kb),
                                   lambda kt, g, cs: (cs[g], 0, kt)),
        ),
        out_shape=jax.ShapeDtypeStruct((num_slices, c, k_pad), x.dtype),
        interpret=interpret,
    )(chunk_slice, chunk_cols, chunk_vals, x)
