"""Public wrapper for SELL-C-σ SpMM: dense RHS block in, dense block out.

`sell_matmul(op, x)` is what `SellOperator.matmul` dispatches to for 2-D
x — it handles k padding to the lane-aligned k-tile, the n padding, the
σ-sort un-permute, and the pallas / interpret / jnp-ref engine choice,
mirroring kernels/sell_spmv/ops.py for the single-vector path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ... import obs
from .kernel import sell_spmm_ktiled
from .ref import sell_spmm_ref

LANES = 128


def pick_k_tile(k: int, lanes: int = LANES) -> int:
    """k-tile width: smallest power of two >= k, clipped to [8, lanes].

    Small batches keep the tile narrow (padding scales with KB); anything
    past one lane row is split into multiple passes over the matrix.
    """
    kb = 8
    while kb < min(max(k, 1), lanes):
        kb *= 2
    return min(kb, lanes)


def sell_matmul(op, x: jax.Array) -> jax.Array:
    """y[m, k] = A @ x[n, k] for a SellOperator `op` (kernels/sell_spmv).

    Only the kernel paths pad k to the lane-aligned k-tile; the jnp-ref
    path needs no alignment and runs on the exact k columns (small service
    batches would otherwise pay up to the tile floor in wasted flops).
    """
    n, k = x.shape
    with obs.span("kernel.spmm", engine="sell", k=int(k),
                  use_kernel=op.use_kernel) as sp:
        if op.use_kernel in ("pallas", "interpret"):
            kb = pick_k_tile(k)
            sp.set(k_tile=int(kb))
            k_pad = ((k + kb - 1) // kb) * kb
            xp = jnp.pad(x, ((0, op.n_pad - n), (0, k_pad - k)))
            y = sell_spmm_ktiled(op.chunk_vals, op.chunk_cols,
                                 op.chunk_slice, xp, op.num_slices, kb,
                                 interpret=(op.use_kernel == "interpret"))
        else:
            xp = jnp.pad(x, ((0, op.n_pad - n), (0, 0)))
            y = sell_spmm_ref(op.chunk_vals, op.chunk_cols, op.chunk_slice,
                              xp, op.num_slices)
        # y is in slice order; inv_perm[r] = slice position of original
        # row r
        y = y.reshape(-1, y.shape[-1])[op.inv_perm]
        return y[:, :k]
