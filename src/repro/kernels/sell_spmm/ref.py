"""jnp oracle for the k-tiled SELL SpMM kernel.

The SpMV oracle already handles a trailing vector axis, so the SpMM oracle
IS the one the sell_spmv package exposes — re-exported here (not copied)
so both kernels are tested against a single implementation.
"""
from __future__ import annotations

from ..sell_spmv.ref import sell_spmm_ref  # noqa: F401
