"""Jit'd public wrapper for BCSR SpMV: host format in, vector out."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from ...core.sparse.bell import BCSR
from .kernel import bcsr_spmm
from .ref import bcsr_spmm_ref


def pad_empty_rows(host: BCSR) -> BCSR:
    """Ensure every block row has >= 1 block (kernel contract): insert an
    explicit zero block (col 0) for each empty block row."""
    counts = np.diff(host.block_rowptr.astype(np.int64))
    empty = np.flatnonzero(counts == 0)
    if empty.size == 0:
        return host
    bm, bn = host.block_shape
    add_blocks = np.zeros((empty.size, bm, bn), dtype=host.blocks.dtype)
    rows = np.concatenate([host.block_rows, empty.astype(np.int32)])
    cols = np.concatenate([host.block_cols, np.zeros(empty.size, np.int32)])
    blocks = np.concatenate([host.blocks, add_blocks], axis=0)
    order = np.argsort(rows, kind="stable")
    rowptr = np.zeros(host.num_block_rows + 1, dtype=np.int64)
    np.add.at(rowptr, rows.astype(np.int64) + 1, 1)
    return BCSR(blocks=blocks[order], block_rows=rows[order],
                block_cols=cols[order],
                block_rowptr=np.cumsum(rowptr).astype(np.int32),
                shape=host.shape, block_shape=host.block_shape)


class BcsrOperator:
    """Device-resident BCSR operator: y = A @ x."""

    def __init__(self, host: BCSR, dtype=jnp.float32, use_kernel: str = "auto"):
        host = pad_empty_rows(host)
        self.block_shape = host.block_shape
        self.shape = host.shape
        self.nbr = host.num_block_rows
        bm, bn = host.block_shape
        self.ncb = (host.shape[1] + bn - 1) // bn
        self.blocks = jnp.asarray(host.blocks, dtype=dtype)
        self.block_rows = jnp.asarray(host.block_rows, dtype=jnp.int32)
        self.block_cols = jnp.asarray(host.block_cols, dtype=jnp.int32)
        if use_kernel == "auto":
            use_kernel = "pallas" if jax.default_backend() == "tpu" else "ref"
        self.use_kernel = use_kernel

    def __call__(self, x: jax.Array) -> jax.Array:
        with obs.span("kernel.spmv", engine="bcsr",
                      use_kernel=self.use_kernel):
            squeeze = x.ndim == 1
            if squeeze:
                x = x[:, None]
            n, nv = x.shape
            bm, bn = self.block_shape
            x2d = jnp.pad(x, ((0, self.ncb * bn - n), (0, 0))) \
                .reshape(self.ncb, bn, nv)
            if self.use_kernel == "pallas":
                y = bcsr_spmm(self.blocks, self.block_rows, self.block_cols,
                              x2d, self.nbr)
            elif self.use_kernel == "interpret":
                y = bcsr_spmm(self.blocks, self.block_rows, self.block_cols,
                              x2d, self.nbr, interpret=True)
            else:
                y = bcsr_spmm_ref(self.blocks, self.block_rows,
                                  self.block_cols, x2d, self.nbr)
            y = y.reshape(-1, nv)[: self.shape[0]]
            return y[:, 0] if squeeze else y

    def matmul(self, x: jax.Array) -> jax.Array:
        """x: [n, k] -> y: [m, k] (vectorized __call__: one stream of the
        flattened block list serves all k vectors)."""
        return self(x)

    def flops(self) -> int:
        t, bm, bn = self.blocks.shape
        return 2 * t * bm * bn

    # -- operator-cache protocol (core/spmv/opcache.py) --------------------
    def state(self):
        meta = {"shape": list(self.shape),
                "block_shape": list(self.block_shape),
                "nbr": self.nbr, "ncb": self.ncb,
                "use_kernel": self.use_kernel}
        return meta, {"blocks": np.asarray(self.blocks),
                      "block_rows": np.asarray(self.block_rows),
                      "block_cols": np.asarray(self.block_cols)}

    @classmethod
    def from_state(cls, meta, arrays, dtype=jnp.float32):
        op = object.__new__(cls)
        op.shape = tuple(meta["shape"])
        op.block_shape = tuple(meta["block_shape"])
        op.nbr, op.ncb = meta["nbr"], meta["ncb"]
        op.use_kernel = meta["use_kernel"]
        op.blocks = jnp.asarray(arrays["blocks"], dtype=dtype)
        op.block_rows = jnp.asarray(arrays["block_rows"])
        op.block_cols = jnp.asarray(arrays["block_cols"])
        return op
