"""Pure-jnp oracle for the BCSR kernel (same signature as kernel.py)."""
from __future__ import annotations

import jax

from ...core.spmv.ref import spmv_bcsr


def bcsr_spmm_ref(blocks: jax.Array, block_rows: jax.Array, block_cols: jax.Array,
                  x2d: jax.Array, num_block_rows: int) -> jax.Array:
    return spmv_bcsr(blocks, block_rows, block_cols, x2d, num_block_rows)
