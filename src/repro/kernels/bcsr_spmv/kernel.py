"""Pallas TPU kernel: BCSR SpMV/SpMM (flattened-grid variant).

Block-ELL pays K_max grid steps for *every* block row — ruinous for
power-law matrices whose block-count distribution is skewed (the paper's
load-imbalance story at tile granularity). This kernel walks the true block
list instead: grid = (total_blocks,), with scalar-prefetched block_rows /
block_cols driving the BlockSpec index_maps. The output tile for a block
row stays in VMEM across its (consecutive, row-sorted) blocks and is
flushed when the row id changes — the same revisit-consecutive reduction
contract Pallas flash-attention uses.

Requirement: every block row has >= 1 block (builder pads empty rows with an
explicit zero block) so each output tile is written at least once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bcsr_kernel(block_rows_ref, block_cols_ref, blocks_ref, x_ref, y_ref, *,
                 acc_dtype):
    g = pl.program_id(0)
    row = block_rows_ref[g]
    prev = block_rows_ref[jnp.maximum(g - 1, 0)]
    is_first = jnp.logical_or(g == 0, row != prev)

    @pl.when(is_first)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    a = blocks_ref[0]          # [bm, bn]
    xv = x_ref[0]              # [bn, nv]
    y_ref[0] += jnp.dot(a, xv, preferred_element_type=acc_dtype).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_block_rows", "interpret"))
def bcsr_spmm(blocks: jax.Array, block_rows: jax.Array, block_cols: jax.Array,
              x2d: jax.Array, num_block_rows: int,
              interpret: bool = False) -> jax.Array:
    """y[nbr, bm, nv] = BCSR @ x2d[ncb, bn, nv].

    blocks: [T, bm, bn]; block_rows: int32[T] nondecreasing, covering every
    row id in [0, nbr); block_cols: int32[T].
    """
    t, bm, bn = blocks.shape
    ncb, bn2, nv = x2d.shape
    assert bn2 == bn
    acc_dtype = jnp.float32

    return pl.pallas_call(
        functools.partial(_bcsr_kernel, acc_dtype=acc_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(t,),
            in_specs=[
                pl.BlockSpec((1, bm, bn), lambda g, br, bc: (g, 0, 0)),
                pl.BlockSpec((1, bn, nv), lambda g, br, bc: (bc[g], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, bm, nv), lambda g, br, bc: (br[g], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((num_block_rows, bm, nv), x2d.dtype),
        interpret=interpret,
    )(block_rows, block_cols, blocks, x2d)
