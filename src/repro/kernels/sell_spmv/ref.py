"""Pure-jnp oracle for the SELL-C-σ kernel (same signature as kernel.py)."""
from __future__ import annotations

import jax

from ...core.spmv.ref import spmv_sell


def sell_spmm_ref(chunk_vals: jax.Array, chunk_cols: jax.Array,
                  chunk_slice: jax.Array, x: jax.Array,
                  num_slices: int) -> jax.Array:
    return spmv_sell(chunk_vals, chunk_cols, chunk_slice, x, num_slices)
