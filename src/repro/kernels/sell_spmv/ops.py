"""Jit'd public wrapper for SELL-C-σ SpMV: host format in, vector out.

Handles x padding, the σ-sort un-permute (inv_perm gather) and the
pallas / interpret / jnp-ref engine choice, mirroring bell_spmv/ops.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from ...core.sparse.sell import SellCS
from .kernel import sell_spmm
from .ref import sell_spmm_ref


class SellOperator:
    """Device-resident SELL-C-σ operator: y = A @ x."""

    def __init__(self, host: SellCS, dtype=jnp.float32, use_kernel: str = "auto"):
        self.shape = host.shape
        self.c = host.c
        self.sigma = host.sigma
        self.w = host.w
        self.num_slices = host.num_slices
        # pad x to a lane multiple (gather indices all < n, so padding is
        # never read; it only keeps the VMEM buffer tile-aligned)
        self.n_pad = ((host.shape[1] + 127) // 128) * 128
        self.chunk_vals = jnp.asarray(host.chunk_vals, dtype=dtype)
        self.chunk_cols = jnp.asarray(host.chunk_cols, dtype=jnp.int32)
        self.chunk_slice = jnp.asarray(host.chunk_slice, dtype=jnp.int32)
        self.inv_perm = jnp.asarray(host.inv_perm, dtype=jnp.int32)
        if use_kernel == "auto":
            use_kernel = "pallas" if jax.default_backend() == "tpu" else "ref"
        self.use_kernel = use_kernel

    def __call__(self, x: jax.Array) -> jax.Array:
        """x: [n] or [n, nv] -> y: [m] or [m, nv]."""
        with obs.span("kernel.spmv", engine="sell",
                      use_kernel=self.use_kernel):
            squeeze = x.ndim == 1
            if squeeze:
                x = x[:, None]
            n, nv = x.shape
            xp = jnp.pad(x, ((0, self.n_pad - n), (0, 0)))
            if self.use_kernel == "pallas":
                y = sell_spmm(self.chunk_vals, self.chunk_cols,
                              self.chunk_slice, xp, self.num_slices)
            elif self.use_kernel == "interpret":
                y = sell_spmm(self.chunk_vals, self.chunk_cols,
                              self.chunk_slice, xp, self.num_slices,
                              interpret=True)
            else:
                y = sell_spmm_ref(self.chunk_vals, self.chunk_cols,
                                  self.chunk_slice, xp, self.num_slices)
            # y is in slice order; inv_perm[r] = slice position of
            # original row r
            y = y.reshape(-1, nv)[self.inv_perm]
            return y[:, 0] if squeeze else y

    def matmul(self, x: jax.Array) -> jax.Array:
        """x: [n, k] -> y: [m, k] via the k-tiled SpMM kernel
        (kernels/sell_spmm): one matrix stream amortized over a lane-aligned
        k-tile of vectors, instead of nv riding along every chunk."""
        if x.ndim == 1:
            return self(x)
        from ..sell_spmm.ops import sell_matmul

        return sell_matmul(self, x)

    # -- operator-cache protocol (core/spmv/opcache.py) --------------------
    def state(self):
        meta = {"shape": list(self.shape), "c": self.c, "sigma": self.sigma,
                "w": self.w, "num_slices": self.num_slices,
                "n_pad": self.n_pad, "use_kernel": self.use_kernel}
        arrays = {"chunk_vals": np.asarray(self.chunk_vals),
                  "chunk_cols": np.asarray(self.chunk_cols),
                  "chunk_slice": np.asarray(self.chunk_slice),
                  "inv_perm": np.asarray(self.inv_perm)}
        return meta, arrays

    @classmethod
    def from_state(cls, meta, arrays, dtype=jnp.float32):
        op = object.__new__(cls)
        op.shape = tuple(meta["shape"])
        op.c, op.sigma, op.w = meta["c"], meta["sigma"], meta["w"]
        op.num_slices, op.n_pad = meta["num_slices"], meta["n_pad"]
        op.use_kernel = meta["use_kernel"]
        op.chunk_vals = jnp.asarray(arrays["chunk_vals"], dtype=dtype)
        op.chunk_cols = jnp.asarray(arrays["chunk_cols"])
        op.chunk_slice = jnp.asarray(arrays["chunk_slice"])
        op.inv_perm = jnp.asarray(arrays["inv_perm"])
        return op

    @property
    def padded_nnz(self) -> int:
        """Stored element count — the format's work/footprint measure."""
        return int(np.prod(self.chunk_vals.shape))

    def flops(self) -> int:
        """VPU flops per SpMV (2 * stored elements, padding included)."""
        return 2 * self.padded_nnz
