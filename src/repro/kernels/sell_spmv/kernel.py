"""Pallas TPU kernel: SELL-C-σ SpMV/SpMM (flattened-chunk grid).

Storage (core/sparse/sell.py): the matrix is a flat list of [C, W] chunks —
C rows of one slice x W lane-aligned element slots — with a scalar-prefetched
`chunk_slice` map naming the slice each chunk belongs to. Slices with few
nonzeros contribute few chunks, so power-law matrices do O(nnz) grid steps
instead of Block-ELL's O(slices * K_max).

Per grid step the VPU does an elementwise multiply of the chunk's values
against the gathered x elements and a lane reduction into the slice's y
tile. The y tile stays resident in VMEM across the (consecutive) chunks of
one slice and is re-initialized when `chunk_slice` changes — the same
revisit-consecutive reduction contract as the BCSR kernel.

x stays whole in VMEM (the corpus vectors are <= a few hundred KB) and the
per-element x[col] gather happens on-chip; this is the TPU translation of
the CPU SELL kernel's gather loads. The gather is exercised through
interpret mode on CPU (tests force it); the jnp oracle in ref.py is the
non-TPU fallback engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sell_kernel(chunk_slice_ref, cols_ref, vals_ref, x_ref, y_ref, *,
                 acc_dtype):
    g = pl.program_id(0)
    sl = chunk_slice_ref[g]
    prev = chunk_slice_ref[jnp.maximum(g - 1, 0)]
    is_first = jnp.logical_or(g == 0, sl != prev)

    @pl.when(is_first)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    cols = cols_ref[0]                       # [C, W] int32
    vals = vals_ref[0].astype(acc_dtype)     # [C, W]
    xg = x_ref[cols].astype(acc_dtype)       # on-chip gather: [C, W, nv]
    part = jnp.sum(vals[..., None] * xg, axis=1)        # [C, nv]
    y_ref[0] += part.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_slices", "interpret"))
def sell_spmm(chunk_vals: jax.Array, chunk_cols: jax.Array,
              chunk_slice: jax.Array, x: jax.Array, num_slices: int,
              interpret: bool = False) -> jax.Array:
    """y[S, C, nv] = SELL(chunk_vals, chunk_cols, chunk_slice) @ x[n_pad, nv].

    chunk_vals: [T, C, W] (padding slots are 0)
    chunk_cols: [T, C, W] int32 (padding -> 0, result-neutral via zero vals)
    chunk_slice: int32[T], nondecreasing, covering every slice in [0, S)
    """
    t, c, w = chunk_vals.shape
    n_pad, nv = x.shape
    acc_dtype = jnp.float32

    return pl.pallas_call(
        functools.partial(_sell_kernel, acc_dtype=acc_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(t,),
            in_specs=[
                pl.BlockSpec((1, c, w), lambda g, cs: (g, 0, 0)),
                pl.BlockSpec((1, c, w), lambda g, cs: (g, 0, 0)),
                pl.BlockSpec((n_pad, nv), lambda g, cs: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, c, nv), lambda g, cs: (cs[g], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((num_slices, c, nv), x.dtype),
        interpret=interpret,
    )(chunk_slice, chunk_cols, chunk_vals, x)
