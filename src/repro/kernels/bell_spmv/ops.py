"""Jit'd public wrapper for Block-ELL SpMV: host-format in, vector out.

Handles padding/reshaping between the logical (m, n) world and the kernel's
tiled [nbr, K, bm, bn] world, and falls back to the jnp reference on
non-TPU backends unless interpret mode is forced (tests force it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from ...core.sparse.bell import BlockELL
from .kernel import bell_spmm
from .ref import bell_spmm_ref


class BellOperator:
    """Device-resident Block-ELL operator: y = A @ x."""

    def __init__(self, host: BlockELL, dtype=jnp.float32, use_kernel: str = "auto"):
        self.block_shape = host.block_shape
        self.shape = host.shape
        bm, bn = host.block_shape
        self.ncb = (host.shape[1] + bn - 1) // bn
        self.blocks = jnp.asarray(host.blocks, dtype=dtype)
        self.block_cols = jnp.asarray(host.block_cols, dtype=jnp.int32)
        if use_kernel == "auto":
            use_kernel = "pallas" if jax.default_backend() == "tpu" else "ref"
        self.use_kernel = use_kernel

    def __call__(self, x: jax.Array) -> jax.Array:
        """x: [n] or [n, nv] -> y: [m] or [m, nv]."""
        with obs.span("kernel.spmv", engine="bell",
                      use_kernel=self.use_kernel):
            squeeze = x.ndim == 1
            if squeeze:
                x = x[:, None]
            n, nv = x.shape
            bm, bn = self.block_shape
            pad_n = self.ncb * bn - n
            x2d = jnp.pad(x, ((0, pad_n), (0, 0))).reshape(self.ncb, bn, nv)
            if self.use_kernel == "pallas":
                y = bell_spmm(self.blocks, self.block_cols, x2d)
            elif self.use_kernel == "interpret":
                y = bell_spmm(self.blocks, self.block_cols, x2d,
                              interpret=True)
            else:
                y = bell_spmm_ref(self.blocks, self.block_cols, x2d)
            y = y.reshape(-1, nv)[: self.shape[0]]
            return y[:, 0] if squeeze else y

    def matmul(self, x: jax.Array) -> jax.Array:
        """x: [n, k] -> y: [m, k] — the block layout already carries a
        trailing vector axis through the MXU contraction, so the batched
        path IS the vectorized __call__ (each dense brick is streamed once
        for all k vectors)."""
        return self(x)

    def flops(self) -> int:
        """MXU flops per SpMV (2 * padded block volume)."""
        nbr, k, bm, bn = self.blocks.shape
        return 2 * nbr * k * bm * bn

    # -- operator-cache protocol (core/spmv/opcache.py) --------------------
    def state(self):
        meta = {"shape": list(self.shape),
                "block_shape": list(self.block_shape),
                "ncb": self.ncb, "use_kernel": self.use_kernel}
        return meta, {"blocks": np.asarray(self.blocks),
                      "block_cols": np.asarray(self.block_cols)}

    @classmethod
    def from_state(cls, meta, arrays, dtype=jnp.float32):
        op = object.__new__(cls)
        op.shape = tuple(meta["shape"])
        op.block_shape = tuple(meta["block_shape"])
        op.ncb = meta["ncb"]
        op.use_kernel = meta["use_kernel"]
        op.blocks = jnp.asarray(arrays["blocks"], dtype=dtype)
        op.block_cols = jnp.asarray(arrays["block_cols"])
        return op
