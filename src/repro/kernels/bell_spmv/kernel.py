"""Pallas TPU kernel: Block-ELL SpMV/SpMM.

TPU-native SpMV (DESIGN.md §3): the matrix is stored as dense (bm x bn) MXU
bricks at the nonempty block positions; the x tile each brick needs is
gathered HBM->VMEM by the *pipeline itself* via a scalar-prefetched
block-column index feeding the BlockSpec index_map — the TPU idiom replacing
the CPU's per-element x[col] gather.

Grid = (num_block_rows, K): the second axis walks the (padded) blocks of one
block row, accumulating into the y tile that stays resident in VMEM (output
revisiting is consecutive => Pallas keeps it on-chip until the row is done).
Reordering quality shows up here exactly as in the paper: fewer/denser
blocks => fewer grid steps and fewer distinct x tiles fetched.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bell_kernel(block_cols_ref, blocks_ref, x_ref, y_ref, *, acc_dtype):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    a = blocks_ref[0, 0]      # [bm, bn]
    xv = x_ref[0]             # [bn, nv]
    y_ref[0] += jnp.dot(a, xv, preferred_element_type=acc_dtype).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bell_spmm(blocks: jax.Array, block_cols: jax.Array, x2d: jax.Array,
              interpret: bool = False) -> jax.Array:
    """y[nbr, bm, nv] = BlockELL(blocks, block_cols) @ x2d[ncb, bn, nv].

    blocks: [nbr, K, bm, bn] (zero padding blocks)
    block_cols: [nbr, K] int32 (padding -> any valid block, typically 0)
    """
    nbr, kk, bm, bn = blocks.shape
    ncb, bn2, nv = x2d.shape
    assert bn2 == bn, (bn2, bn)
    acc_dtype = jnp.float32

    grid = (nbr, kk)
    return pl.pallas_call(
        functools.partial(_bell_kernel, acc_dtype=acc_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bm, bn), lambda i, k, bc: (i, k, 0, 0)),
                pl.BlockSpec((1, bn, nv), lambda i, k, bc: (bc[i, k], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, bm, nv), lambda i, k, bc: (i, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((nbr, bm, nv), x2d.dtype),
        interpret=interpret,
    )(block_cols, blocks, x2d)
