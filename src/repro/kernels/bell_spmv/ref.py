"""Pure-jnp oracle for the Block-ELL kernel (same signature as kernel.py)."""
from __future__ import annotations

import jax

from ...core.spmv.ref import spmv_bell


def bell_spmm_ref(blocks: jax.Array, block_cols: jax.Array, x2d: jax.Array) -> jax.Array:
    return spmv_bell(blocks, block_cols, x2d)
