"""RWKV6 "Finch" block: time-mix (WKV6 linear attention with data-dependent
per-channel decay) + channel-mix FFN [arXiv:2404.05892].

Chunked parallel form: within a chunk the decay products are expressed with
cumulative log-decay differences (an attention-like [T,T] matrix per head);
the running state [B,H,K,V] is carried across chunks by lax.scan — same
structure as the Mamba2 SSD scan, so train/prefill are MXU matmuls, decode
is an O(1) state update.

Faithful simplifications (documented in DESIGN.md): static token-shift mix
coefficients (full RWKV6 uses a data-dependent LoRA lerp); decay LoRA and
the per-head bonus u are kept, as they define WKV6.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import init_linear, init_rmsnorm, linear, rmsnorm


def init_rwkv6(key, d_model, rwkv_cfg, d_ff, dtype=jnp.float32):
    hd = rwkv_cfg.head_dim
    h = d_model // hd
    lora = rwkv_cfg.decay_lora
    ks = jax.random.split(key, 12)
    scale = float(1.0 / np.sqrt(d_model))
    return {
        # time-mix
        "mix_r": 0.5 * jnp.ones((d_model,), dtype),
        "mix_k": 0.5 * jnp.ones((d_model,), dtype),
        "mix_v": 0.5 * jnp.ones((d_model,), dtype),
        "mix_w": 0.5 * jnp.ones((d_model,), dtype),
        "mix_g": 0.5 * jnp.ones((d_model,), dtype),
        "wr": init_linear(ks[0], d_model, d_model, False, dtype),
        "wk": init_linear(ks[1], d_model, d_model, False, dtype),
        "wv": init_linear(ks[2], d_model, d_model, False, dtype),
        "wg": init_linear(ks[3], d_model, d_model, False, dtype),
        "wo": init_linear(ks[4], d_model, d_model, False, dtype),
        # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d_model,), -4.0, dtype),
        "w_lora_a": scale * jax.random.normal(ks[5], (d_model, lora), dtype),
        "w_lora_b": float(1.0 / np.sqrt(lora)) * jax.random.normal(ks[6], (lora, d_model), dtype),
        "u_bonus": 0.1 * jax.random.normal(ks[7], (h, hd), dtype),
        "ln_x": init_rmsnorm(d_model, dtype),  # per-head group norm approx
        # channel-mix
        "cmix_k": 0.5 * jnp.ones((d_model,), dtype),
        "wck": init_linear(ks[8], d_model, d_ff, False, dtype),
        "wcv": init_linear(ks[9], d_ff, d_model, False, dtype),
    }


def _token_shift(x, mix, last=None):
    """lerp(x_{t-1}, x_t, mix). last: [B,1,d] carry for decode."""
    if last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([last, x], axis=1)[:, :-1]
    return x * mix + prev * (1 - mix)


def _wkv6_chunked(r, k, v, log_w, u, chunk, init_state=None):
    """r,k,v: [B,S,H,D]; log_w: [B,S,H,D] (log decay, <0); u: [H,D].
    Returns (y [B,S,H,D], state [B,H,D,D]).  state[k_dim, v_dim]."""
    b, s, h, d = r.shape
    nc = s // chunk

    def rc(t):
        return jnp.moveaxis(t.reshape(b, nc, chunk, h, d), 1, 0)

    tri_lo = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strictly lower

    def scan_fn(state, inp):
        r_i, k_i, v_i, lw_i = inp                    # [B,T,H,D]
        cum = jnp.cumsum(lw_i, axis=1)               # [B,T,H,D]
        # within-chunk: y_t = sum_{i<t} (r_t . (k_i * prod_{j in (i,t]} w_j)) v_i
        #             + (r_t . (k_t * u)) v_t
        # decay(t,i) = exp(cum_{t-1}... ) careful: prod over j=i+1..t-1? WKV6:
        # y_t = sum_{i<t} r_t·(diag(prod_{i<j<t} w_j) k_i) v_i + r_t·(u k_t) v_t
        # use D(t,i) = exp(cum_{t-1} - cum_i) for i < t (w applied after read)
        cum_shift = jnp.pad(cum, ((0, 0), (1, 0), (0, 0), (0, 0)))[:, :-1]
        # scores[t,i] = sum_d r[t,d] k[i,d] exp(cum_shift_t - cum_i)[d], i<t.
        # Direct (unfactored) decay: the exponent cum_shift_t - cum_i <= 0
        # for i < t, so this never overflows — the factored a*bk form does
        # (exp(-cum_i) is unbounded for fast-decay channels). The [T,T,D]
        # intermediate is why the chunk is small (fla-style tiling).
        expo = cum_shift[:, :, None] - cum[:, None]           # [B,T,T,H,D]
        expo = jnp.where(tri_lo[None, :, :, None, None], expo, -1e30)
        dec = jnp.exp(expo)  # exponent masked BEFORE exp: 0*inf NaN guard
        scores = jnp.einsum("bthd,btihd->bhti", r_i, k_i[:, None] * dec)
        y_i = jnp.einsum("bhti,bihd->bthd", scores, v_i)
        # diagonal (bonus) term: (r_t . (u * k_t)) v_t
        y_i += jnp.sum(r_i * k_i * u[None, None], axis=-1, keepdims=True) * v_i
        # cross-chunk: y_t += (r_t * exp(cum_shift_t)) @ state   (exp <= 1)
        a = r_i * jnp.exp(cum_shift)
        y_i += jnp.einsum("bthd,bhde->bthe", a, state)
        # state' = diag(exp(cum_T)) state + sum_i exp(cum_T - cum_i) k_i v_i^T
        dec_end = jnp.exp(cum[:, -1:] - cum)        # [B,T,H,D], exponent <= 0
        w_all = jnp.exp(cum[:, -1])                 # [B,H,D]
        state = state * w_all[..., None] + \
            jnp.einsum("bihd,bihe->bhde", k_i * dec_end, v_i)
        return state, y_i

    s0 = (jnp.zeros((b, h, d, d), r.dtype) if init_state is None
          else init_state.astype(r.dtype))
    final, ys = jax.lax.scan(scan_fn, s0, (rc(r), rc(k), rc(v), rc(log_w)))
    return jnp.moveaxis(ys, 0, 1).reshape(b, s, h, d), final


def rwkv6_time_mix(params, x, rwkv_cfg, cache=None):
    """x [B,S,d]. cache: None or {shift [B,1,d], wkv [B,H,D,D]}."""
    b, s, d = x.shape
    hd = rwkv_cfg.head_dim
    h = d // hd
    last = cache["shift_t"] if cache is not None else None
    xr = _token_shift(x, params["mix_r"], last)
    xk = _token_shift(x, params["mix_k"], last)
    xv = _token_shift(x, params["mix_v"], last)
    xw = _token_shift(x, params["mix_w"], last)
    xg = _token_shift(x, params["mix_g"], last)
    r = linear(params["wr"], xr).reshape(b, s, h, hd)
    k = linear(params["wk"], xk).reshape(b, s, h, hd)
    v = linear(params["wv"], xv).reshape(b, s, h, hd)
    g = jax.nn.silu(linear(params["wg"], xg))
    log_w = -jnp.exp(params["w0"] + jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"])
    log_w = log_w.reshape(b, s, h, hd)

    if cache is None:
        pad = (-s) % rwkv_cfg.chunk
        if pad:
            r, k, v, log_w = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                              for t in (r, k, v, log_w))
        y, state = _wkv6_chunked(r, k, v, log_w, params["u_bonus"],
                                 rwkv_cfg.chunk)
        y = y[:, :s]
        new_cache = None
    else:
        state = cache["wkv"]
        # one-step: y = r . (u k v^T + state); state' = diag(w) state + k v^T
        kv = jnp.einsum("bhd,bhe->bhde", k[:, 0], v[:, 0])
        y = jnp.einsum("bhd,bhde->bhe", r[:, 0],
                       params["u_bonus"][None, :, :, None] * kv + state)[:, None]
        state = state * jnp.exp(log_w[:, 0])[..., None] + kv
        new_cache = {"shift_t": x[:, -1:], "wkv": state}
    y = y.reshape(b, s, d)
    y = rmsnorm(params["ln_x"], y) * g
    return linear(params["wo"], y), new_cache


def rwkv6_channel_mix(params, x, cache_last=None):
    xk = _token_shift(x, params["cmix_k"], cache_last)
    k = jnp.square(jax.nn.relu(linear(params["wck"], xk)))
    return linear(params["wcv"], k)


def init_rwkv6_cache(batch, d_model, rwkv_cfg, dtype=jnp.float32):
    hd = rwkv_cfg.head_dim
    h = d_model // hd
    return {
        "shift_t": jnp.zeros((batch, 1, d_model), dtype),
        "shift_c": jnp.zeros((batch, 1, d_model), dtype),
        "wkv": jnp.zeros((batch, h, hd, hd), dtype),
    }
