"""Dense MLP blocks: SwiGLU (llama-family) used by every dense arch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import init_linear, linear


def init_mlp(key, d_model, d_ff, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": init_linear(ks[0], d_model, d_ff, False, dtype),
        "w_up": init_linear(ks[1], d_model, d_ff, False, dtype),
        "w_down": init_linear(ks[2], d_ff, d_model, False, dtype),
    }


def mlp(params, x, activation=jax.nn.silu):
    return linear(params["w_down"],
                  activation(linear(params["w_gate"], x)) * linear(params["w_up"], x))
