"""Mamba2 (SSD) block — chunked state-space dual form [Dao & Gu 2024].

Train/prefill: sequence split into chunks of `chunk`; within-chunk term is
a small quadratic matmul (MXU-friendly — the "duality"), cross-chunk states
carried by a sequential lax.scan over chunks (NC = S/chunk steps).
Decode: O(1) recurrent state update.

Faithful simplifications (documented): single B/C group (n_groups=1),
scalar-per-head A (as in Mamba2), causal conv width 4, no dt softplus floor
tweaks. State cache = (conv_state [B, W-1, d_conv_ch], ssm_state [B, H, N, P]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import init_linear, init_rmsnorm, linear, rmsnorm


def init_mamba2(key, d_model, ssm_cfg, dtype=jnp.float32):
    d_inner = ssm_cfg.expand * d_model
    n, p = ssm_cfg.d_state, ssm_cfg.head_dim
    h = d_inner // p
    ks = jax.random.split(key, 5)
    conv_ch = d_inner + 2 * n  # conv over [x, B, C]
    return {
        # in_proj -> [z, x, B, C, dt]
        "in_proj": init_linear(ks[0], d_model, 2 * d_inner + 2 * n + h, False, dtype),
        "conv_w": 0.1 * jax.random.normal(ks[1], (ssm_cfg.conv_width, conv_ch), dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(dtype)),
        "dt_bias": jnp.zeros((h,), dtype),
        "d_skip": jnp.ones((h,), dtype),
        "norm": init_rmsnorm(d_inner, dtype),
        "out_proj": init_linear(ks[4], d_inner, d_model, False, dtype),
    }


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """xbc [B,S,C]; depthwise causal conv width W. Returns (y, new_state)."""
    w = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xbc], axis=1)  # [B, S+W-1, C]
    y = sum(xp[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(w)) + conv_b
    new_state = xp[:, -(w - 1):]
    return jax.nn.silu(y), new_state


def _ssd_chunked(xh, dt, a_log, b_mat, c_mat, chunk, init_state=None):
    """SSD scan. xh [B,S,H,P], dt [B,S,H], b/c [B,S,N].
    Returns (y [B,S,H,P], final_state [B,H,N,P]).

    One lax.scan over chunks computes the within-chunk quadratic term AND
    the cross-chunk state pass per step, so live memory is one chunk's
    [B,T,T,H] decay tensor, not NC of them."""
    b, s, h, p = xh.shape
    n = b_mat.shape[-1]
    nc = s // chunk
    la = -jnp.exp(a_log.astype(jnp.float32)) * dt.astype(jnp.float32)  # log decay [B,S,H]
    xw = xh * dt[..., None].astype(xh.dtype)                            # discretized input

    def reshape_c(t):  # [B,S,...] -> [NC,B,T,...] (scan leading axis)
        return jnp.moveaxis(t.reshape(b, nc, chunk, *t.shape[2:]), 1, 0)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def scan_fn(state, inp):
        la_i, xw_i, b_i, c_i = inp            # [B,T,H],[B,T,H,P],[B,T,N],[B,T,N]
        cum = jnp.cumsum(la_i, axis=1)        # [B,T,H]
        # within-chunk: scores[t,i] = exp(cum_t - cum_i) * (c_t . b_i), i<=t
        # mask the EXPONENT (upper triangle is exp(+large) -> inf, and
        # 0*inf = NaN in the where() backward), then exp.
        expo = cum[:, :, None, :] - cum[:, None, :, :]                  # [B,T,T,H]
        expo = jnp.where(tri[None, :, :, None], expo, -1e30)
        dec = jnp.exp(expo)
        cb = jnp.einsum("btn,bin->bti", c_i, b_i)                       # [B,T,T]
        y_i = jnp.einsum("bti,btih,bihp->bthp", cb, dec.astype(xw_i.dtype), xw_i)
        # cross-chunk: y_t += (c_t . state_in) * exp(cum_t)
        y_i += jnp.einsum("btn,bth,bhnp->bthp", c_i,
                          jnp.exp(cum).astype(xw_i.dtype), state)
        # state update
        dec_end = jnp.exp(cum[:, -1:, :] - cum)                         # [B,T,H]
        new_state = state * jnp.exp(cum[:, -1, :])[..., None, None].astype(state.dtype) \
            + jnp.einsum("btn,bth,bthp->bhnp", b_i, dec_end.astype(xw_i.dtype), xw_i)
        return new_state, y_i

    s0 = (jnp.zeros((b, h, n, p), xh.dtype) if init_state is None
          else init_state.astype(xh.dtype))
    final, ys = jax.lax.scan(
        scan_fn, s0, (reshape_c(la), reshape_c(xw), reshape_c(b_mat),
                      reshape_c(c_mat)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y, final


def mamba2_block(params, x, ssm_cfg, cache=None):
    """x [B,S,d]. cache None (train/prefill from zero state) or dict
    {conv, ssm} for decode. Returns (y, new_cache_or_None)."""
    b, s, d = x.shape
    d_inner = ssm_cfg.expand * d
    n, p = ssm_cfg.d_state, ssm_cfg.head_dim
    h = d_inner // p

    zxbcdt = linear(params["in_proj"], x)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"])                        # [B,S,H]

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    xs, b_mat, c_mat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xh = xs.reshape(b, s, h, p)

    if cache is None:
        pad = (-s) % ssm_cfg.chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
            c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        y, final = _ssd_chunked(xh, dt, params["a_log"], b_mat, c_mat,
                                ssm_cfg.chunk)
        y = y[:, :s]
        new_cache = None
    else:
        # decode: s == 1 single step, state update
        a = jnp.exp(-jnp.exp(params["a_log"].astype(jnp.float32))
                    * dt[:, 0].astype(jnp.float32))                     # [B,H]
        xw = xh[:, 0] * dt[:, 0, :, None].astype(xh.dtype)              # [B,H,P]
        state = cache["ssm"]
        state = state * a[..., None, None].astype(state.dtype) + \
            jnp.einsum("bn,bhp->bhnp", b_mat[:, 0], xw)
        y = jnp.einsum("bn,bhnp->bhp", c_mat[:, 0], state)[:, None]     # [B,1,H,P]
        final = state
        new_cache = {"conv": new_conv, "ssm": final}

    y = y + xh[:, :s] * params["d_skip"][None, None, :, None]           # D skip
    y = y.reshape(b, s, d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))                     # gated norm
    return linear(params["out_proj"], y), new_cache


def init_mamba2_cache(batch, d_model, ssm_cfg, dtype=jnp.float32):
    d_inner = ssm_cfg.expand * d_model
    n, p = ssm_cfg.d_state, ssm_cfg.head_dim
    h = d_inner // p
    conv_ch = d_inner + 2 * n
    return {
        "conv": jnp.zeros((batch, ssm_cfg.conv_width - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, h, n, p), dtype),
    }
