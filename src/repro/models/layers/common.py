"""Shared model primitives: norms, rotary, embedding, initializers.

Parameters are plain nested dicts of jnp arrays (pytrees); every init_*
returns such a dict. Sharding is attached OUTSIDE the model code by
path-based logical-axis rules (distributed/sharding.py), so the layer code
stays mesh-agnostic and the dry-run can re-shard without touching models.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    # float(scale): np.float64 scalars are STRONGLY typed and silently
    # promote bf16 params to f32; python floats are weak.
    return float(scale) * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def init_linear(key, d_in, d_out, bias=False, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": truncated_normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_rmsnorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def init_embedding(key, vocab, d, dtype=jnp.float32):
    # 0.02-std (gpt/llama convention); with tied unembedding this keeps
    # random-init CE near ln(vocab).
    return {"table": truncated_normal(key, (vocab, d), 0.02, dtype)}


def embed(p, tokens):
    return p["table"][tokens]


def unembed(p, x, softcap=None):
    """Tied unembedding. Logits in f32 (loss numerics)."""
    logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                        p["table"].astype(jnp.float32))
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def softcap_fn(x, cap):
    return cap * jnp.tanh(x / cap) if cap is not None else x


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S] int."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                         # [D/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                     # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_cross_entropy(logits, labels):
    """logits [..., V] f32, labels [...] int -> mean CE over all positions."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
