"""Mixture-of-Experts with SORT-BASED (reordered) dispatch.

Paper tie-in (DESIGN.md §5): the token->expert routing matrix is a sparse
matrix. This layer applies the paper's machinery to it:
  * `sorted` dispatch — tokens are PERMUTED by expert id (argsort): the
    reordering. Contiguous expert segments = dense blocks, exactly the
    block-locality argument of §4 applied to expert compute on the MXU.
  * capacity clipping — per-expert slot count C is the nnz-balanced
    schedule (paper Listing 5): every expert (processor) gets the same
    number of slots (nnz); overflow tokens are dropped like the paper's
    balanced panels bound max_load.
  * the nnz load-imbalance metric LI = max_load/fair_load (§6.1) is
    computed on the raw routing every step and returned as a metric.
  * `onehot` dispatch — the unreordered baseline (GShard-style dense
    one-hot einsum) for the ablation in benchmarks/moe_dispatch.

Expert parallelism: experts sharded over `ep_axis` (mesh "model"); tokens
arrive sequence-sharded over the same axis; dispatch buffers move through
one all_to_all each way. Single-device path (smoke tests) runs the same
body with no collectives.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import init_linear


def init_moe(key, d_model, cfg, dtype=jnp.float32):
    """cfg: MoEConfig. Expert weights stacked on a leading E axis."""
    ks = jax.random.split(key, 4)
    e, dff = cfg.num_experts, cfg.d_ff_expert
    scale = float(1.0 / np.sqrt(d_model))
    return {
        "router": init_linear(ks[0], d_model, e, False, dtype),
        "w_gate": scale * jax.random.truncated_normal(ks[1], -2, 2, (e, d_model, dff), dtype),
        "w_up": scale * jax.random.truncated_normal(ks[2], -2, 2, (e, d_model, dff), dtype),
        "w_down": float(1.0 / np.sqrt(dff)) * jax.random.truncated_normal(
            ks[3], -2, 2, (e, dff, d_model), dtype),
    }


def _route(params, x_flat, num_experts, top_k):
    """Returns (gates [n,k], experts [n,k], probs [n,E])."""
    logits = (x_flat.astype(jnp.float32) @ params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, experts, probs


# public alias: repro.workloads lowers this routing decision to sparse
# dispatch/combine matrices (workloads/sources.py mirrors it in numpy)
route = _route


def _aux_loss(probs, experts, num_experts):
    """Switch-style load-balancing loss + the paper's LI metric."""
    n, _ = probs.shape
    onehot = jax.nn.one_hot(experts[:, 0], num_experts)  # primary expert
    f = onehot.mean(0)                                   # fraction per expert
    p = probs.mean(0)
    aux = num_experts * jnp.sum(f * p)
    counts = jnp.zeros((num_experts,), jnp.float32).at[experts.reshape(-1)].add(1.0)
    li = counts.max() / jnp.maximum(counts.mean(), 1e-9)  # paper §6.1
    return aux, li, counts


def _expert_ffn(buf, w_gate, w_up, w_down):
    """buf [E_loc, C, d] -> [E_loc, C, d] (SwiGLU per expert)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", buf, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _moe_body(params, x, *, moe_cfg, ep_axis: Optional[str], ep_size: int,
              fsdp_axis: Optional[str] = None, fsdp_size: int = 1):
    """x: [b, s, d] LOCAL tokens. Returns (y, metrics).

    Expert weights arrive FSDP-sharded on their d_model/d_ff dim over
    `fsdp_axis` and are all-gathered on use (grads reduce-scatter back via
    AD) — same memory/comm pattern as the dense layers' FSDP."""
    if fsdp_axis is not None and fsdp_size > 1:
        params = dict(params,
                      w_gate=jax.lax.all_gather(params["w_gate"], fsdp_axis,
                                                axis=1, tiled=True),
                      w_up=jax.lax.all_gather(params["w_up"], fsdp_axis,
                                              axis=1, tiled=True),
                      w_down=jax.lax.all_gather(params["w_down"], fsdp_axis,
                                                axis=2, tiled=True))
    b, s, d = x.shape
    e, k = moe_cfg.num_experts, moe_cfg.top_k
    n = b * s
    x_flat = x.reshape(n, d)
    gates, experts, probs = _route(params, x_flat, e, k)
    aux, li, counts = _aux_loss(probs, experts, e)

    # capacity = nnz-balanced schedule (paper Listing 5 analogue)
    cap = int(np.ceil(n * k * moe_cfg.capacity_factor / e / 8)) * 8

    # ---- sorted (reordered) dispatch ----
    ef = experts.reshape(-1)                       # [n*k]
    tok = jnp.repeat(jnp.arange(n), k)
    gf = gates.reshape(-1)
    if moe_cfg.dispatch == "sorted":
        order = jnp.argsort(ef)                    # the reordering permutation
        ef_s, tok_s, gf_s = ef[order], tok[order], gf[order]
        # rank within expert segment
        seg_start = jnp.searchsorted(ef_s, ef_s, side="left")
        rank = jnp.arange(n * k) - seg_start
    else:  # onehot baseline: rank via cumsum over unsorted assignments
        onehot_full = jax.nn.one_hot(ef, e, dtype=jnp.int32)
        rank = (jnp.cumsum(onehot_full, axis=0) - 1)[jnp.arange(n * k), ef]
        ef_s, tok_s, gf_s = ef, tok, gf
    keep = rank < cap
    slot = jnp.where(keep, ef_s * cap + rank, e * cap)   # drop -> scratch row
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(x_flat[tok_s])
    buf = buf[:-1].reshape(e, cap, d)

    if ep_axis is not None and ep_size > 1:
        # [E, C, d] -> [E/M, M*C, d]: each rank keeps its experts' slots
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1,
                                 tiled=True)
    y_buf = _expert_ffn(buf, params["w_gate"], params["w_up"], params["w_down"])
    if ep_axis is not None and ep_size > 1:
        y_buf = jax.lax.all_to_all(y_buf, ep_axis, split_axis=1, concat_axis=0,
                                   tiled=True)

    # combine: gather each assignment's slot output, weight, sum over k
    y_flat = jnp.concatenate([y_buf.reshape(e * cap, d),
                              jnp.zeros((1, d), y_buf.dtype)])  # scratch row
    contrib = y_flat[slot] * (gf_s * keep)[:, None]
    y = jnp.zeros((n, d), x.dtype).at[tok_s].add(contrib.astype(x.dtype))

    drop_frac = 1.0 - keep.mean()
    metrics = {"aux_loss": aux, "router_li": li, "drop_frac": drop_frac}
    return y.reshape(b, s, d), metrics


def moe_layer(params, x, moe_cfg, mesh=None, ep_axis="model",
              dp_axes=("data",)):
    """x: [B, S, d] GLOBAL (under jit+mesh) or local (mesh=None).

    With a mesh: shard_map over (dp_axes x ep_axis); tokens are
    sequence-sharded over ep_axis when S divides, giving each device
    n = B_l * S/M tokens to route (DESIGN.md §4).
    """
    if mesh is None:
        return _moe_body(params, x, moe_cfg=moe_cfg, ep_axis=None, ep_size=1)

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from ...distributed import sharding as _SH

    ep_size = mesh.shape[ep_axis]
    fsdp_axis = ("data" if (_SH.MOE_FSDP and "data" in mesh.axis_names)
                 else None)
    fsdp_size = mesh.shape[fsdp_axis] if fsdp_axis else 1
    s = x.shape[1]
    seq_shard = (s % ep_size == 0) and (s // ep_size >= 1) and s > 1
    xspec = P(dp_axes, ep_axis if seq_shard else None, None)
    wspec = {"router": {"w": P()},
             "w_gate": P(ep_axis, fsdp_axis, None),
             "w_up": P(ep_axis, fsdp_axis, None),
             "w_down": P(ep_axis, None, fsdp_axis)}

    def body(p, xl):
        y, metrics = _moe_body(p, xl, moe_cfg=moe_cfg, ep_axis=ep_axis,
                               ep_size=ep_size, fsdp_axis=fsdp_axis,
                               fsdp_size=fsdp_size)
        # metrics are per-shard; average over the whole mesh
        metrics = {k: jax.lax.pmean(jax.lax.pmean(v, ep_axis), dp_axes)
                   for k, v in metrics.items()}
        return y, metrics

    f = shard_map(body, mesh=mesh,
                  in_specs=(wspec, xspec),
                  out_specs=(xspec, {"aux_loss": P(), "router_li": P(),
                                     "drop_frac": P()}),
                  check_rep=False)
    return f(params, x)
