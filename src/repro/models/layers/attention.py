"""Attention: flash-style chunked (online softmax over KV blocks) with GQA,
causal/bidirectional, sliding-window, softcap, and cross-attention; plus the
single-token decode path over a KV cache.

Chunking over KV bounds the live score tensor to [B, H, Sq, kv_chunk] so the
32k-prefill cells compile with bounded memory (DESIGN.md §4); XLA fuses the
scan body. Sliding-window layers (gemma2 local) skip KV chunks entirely
outside the window at trace time — chunks are a static loop count, so the
skip costs nothing when it cannot apply.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import apply_rope, init_linear, linear, softcap_fn

NEG_INF = -1e30


def init_attention(key, d_model, n_heads, kv_heads, head_dim, qkv_bias=False,
                   dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d_model, n_heads * head_dim, qkv_bias, dtype),
        "wk": init_linear(ks[1], d_model, kv_heads * head_dim, qkv_bias, dtype),
        "wv": init_linear(ks[2], d_model, kv_heads * head_dim, qkv_bias, dtype),
        "wo": init_linear(ks[3], n_heads * head_dim, d_model, False, dtype),
    }


def _split_heads(x, n, d):
    return x.reshape(*x.shape[:-1], n, d)


def flash_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                    softcap: Optional[float] = None, kv_chunk: int = 1024,
                    q_offset: int = 0):
    """q [B,Sq,H,D]; k,v [B,Sk,KVH,D] -> [B,Sq,H,D].

    GQA via head grouping. q_offset: absolute position of q[0] relative to
    k[0] (prefill: 0; not used for decode — see decode_attention).
    """
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    scale = 1.0 / np.sqrt(d)
    kv_chunk = min(kv_chunk, sk)
    nchunks = (sk + kv_chunk - 1) // kv_chunk
    pad = nchunks * kv_chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunks, kv_chunk, kvh, d)
    vc = v.reshape(b, nchunks, kv_chunk, kvh, d)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inputs):
        m, l, acc = carry
        idx, kci, vci = inputs
        # scores: [b, kvh, g, sq, ck]
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kci,
                       preferred_element_type=jnp.float32) * scale
        s = softcap_fn(s, softcap)
        k_pos = idx * kv_chunk + jnp.arange(kv_chunk)
        mask = k_pos[None, :] < sk  # padding
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vci.dtype), vci,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, kvh, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.arange(nchunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, -2, 1).reshape(b, sq, h, d)  # [b,kvh,g,sq,d]->[b,sq,h,d]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None,
                     softcap=None):
    """One-token decode: q [B,1,H,D]; caches [B,Smax,KVH,D]; cache_len []
    (current valid length, the new token's position = cache_len - 1
    AFTER insertion)."""
    b, _, h, d = q.shape
    _, smax, kvh, _ = k_cache.shape
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) / np.sqrt(d)
    s = softcap_fn(s, softcap)
    k_pos = jnp.arange(smax)
    mask = k_pos < cache_len
    if window is not None:
        mask = mask & (k_pos >= cache_len - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def attention_block(params, x, *, n_heads, kv_heads, head_dim, rope_theta,
                    causal=True, window=None, softcap=None, kv_chunk=1024,
                    positions=None, cache=None, cross_kv=None):
    """Full attention sub-block: proj -> rope -> (flash | decode) -> out proj.

    cache: None (train/prefill, returns (y, new_kv) with new_kv=(k,v) full)
           or dict {k, v, len} for decode (returns (y, updated cache)).
    cross_kv: [B, T, d] encoder states for cross-attention (no rope/causal).
    """
    b, s, _ = x.shape
    q = _split_heads(linear(params["wq"], x), n_heads, head_dim)
    kv_src = cross_kv if cross_kv is not None else x
    k = _split_heads(linear(params["wk"], kv_src), kv_heads, head_dim)
    v = _split_heads(linear(params["wv"], kv_src), kv_heads, head_dim)

    if cross_kv is None:
        if positions is None:
            base = 0 if cache is None else cache["len"]
            positions = base + jnp.arange(s)
            positions = jnp.broadcast_to(positions, (b, s))
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)

    if cache is not None and cross_kv is None:
        # insert the new token at position cache["len"]
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache["len"], 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache["len"], 1)
        new_len = cache["len"] + s
        y = decode_attention(q, k_cache, v_cache, new_len, window=window,
                             softcap=softcap)
        new_cache = {"k": k_cache, "v": v_cache, "len": new_len}
    else:
        y = flash_attention(q, k, v, causal=causal and cross_kv is None,
                            window=window, softcap=softcap, kv_chunk=kv_chunk)
        new_cache = None
    y = y.reshape(b, s, n_heads * head_dim)
    return linear(params["wo"], y), new_cache
