"""Composable LM: one implementation consuming ModelConfig for all 10
assigned architectures (DESIGN.md §5).

Families map to scan bodies:
  dense                 — scan over L identical (attn + SwiGLU) layers
  gemma2 local/global   — scan over L/2 (local, global) PAIRS (static window)
  moe                   — scan over L (attn + MoE) layers (EP shard_map inside)
  rwkv6                 — scan over L (time-mix + channel-mix) layers
  zamba2 hybrid         — scan over groups of `hybrid_attn_period` Mamba2
                          layers, one SHARED attention block between groups
  vlm                   — scan over groups of (period-1) self layers + 1
                          gated cross-attn layer
  hubert                — dense with causal=False, frame embeddings in,
                          classifier head out

Weights are stacked on a leading layer axis; every scan body is wrapped in
jax.checkpoint (remat) during training. Caches (KV / SSM / conv / shift)
are stacked the same way and threaded through the scans for decode.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .layers import attention as A
from .layers import mamba2 as M
from .layers import moe as MOE
from .layers import mlp as MLP
from .layers import rwkv6 as R
from .layers.common import (embed, init_embedding, init_linear, init_rmsnorm,
                            linear, rmsnorm, softmax_cross_entropy, unembed)


def make_hint(mesh, dp_axes, seq_shard=True):
    """Activation sharding hint at embed / layer-scan boundaries:
    batch over dp axes AND sequence over "model" (Megatron-style sequence
    parallelism) when the seq dim divides. Two jobs:
      * propagation alone may replicate the batch dim (observed: XLA
        sharded d_model instead — 16x activation memory);
      * the layer-scan backward stacks [L, B, S, d] residuals — seq
        sharding cuts that stack by the TP degree (104B train: 41 -> ~7GiB).
    Attention/MLP internals re-gather the sequence as needed."""
    if mesh is None:
        return lambda x: x
    from jax.sharding import NamedSharding, PartitionSpec as P

    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)

    def hint(x):
        seq = ("model" if (seq_shard and x.ndim == 3 and x.shape[1] > 1
                           and x.shape[1] % msize == 0) else None)
        spec = P(dp_axes, seq, *([None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return hint


def make_wconstrain(mesh):
    """Per-layer weight sharding constraint (see sharding.constrain_tree).
    Identity without a mesh (single-device tests)."""
    if mesh is None:
        return lambda lp: lp
    from ..distributed.sharding import constrain_tree

    return lambda lp: constrain_tree(lp, mesh)


def _stack_init(init_fn, key, n, *args, **kw):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(k, *args, **kw))(keys)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict[str, Any]:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"final_norm": init_rmsnorm(cfg.d_model, dtype)}
    if cfg.embed_inputs:
        p["embed"] = init_embedding(ks[0], cfg.padded_vocab, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["head"] = init_linear(ks[1], cfg.d_model, cfg.padded_vocab, False, dtype)

    def attn_args():
        return (cfg.d_model, cfg.n_heads, cfg.kv_heads, hd)

    if cfg.rwkv is not None:
        p["layers"] = _stack_init(R.init_rwkv6, ks[2], cfg.n_layers,
                                  cfg.d_model, cfg.rwkv, cfg.d_ff, dtype)
    elif cfg.ssm is not None:  # zamba2 hybrid
        period = cfg.hybrid_attn_period
        groups, rem = divmod(cfg.n_layers, period) if period else (0, cfg.n_layers)
        p["layers"] = _stack_init(M.init_mamba2, ks[2], groups * period,
                                  cfg.d_model, cfg.ssm, dtype)
        if rem:
            p["tail_layers"] = _stack_init(M.init_mamba2, ks[3], rem,
                                           cfg.d_model, cfg.ssm, dtype)
        if period:
            kk = jax.random.split(ks[4], 3)
            p["shared_attn"] = {
                "norm": init_rmsnorm(cfg.d_model, dtype),
                "attn": A.init_attention(kk[0], *attn_args(), cfg.qkv_bias, dtype),
                "mlp_norm": init_rmsnorm(cfg.d_model, dtype),
                "mlp": MLP.init_mlp(kk[1], cfg.d_model, cfg.d_ff, dtype),
            }
    elif cfg.cross_attn_period:  # vlm
        period = cfg.cross_attn_period
        groups = cfg.n_layers // period
        p["layers"] = _stack_init(_init_dense_layer, ks[2],
                                  groups * (period - 1), cfg, dtype)
        p["cross_layers"] = _stack_init(_init_cross_layer, ks[3], groups, cfg, dtype)
    elif cfg.moe is not None:
        p["layers"] = _stack_init(_init_moe_layer, ks[2], cfg.n_layers, cfg, dtype)
    elif cfg.local_global_period:  # gemma2: pairs
        p["layers"] = _stack_init(_init_dense_pair, ks[2],
                                  cfg.n_layers // 2, cfg, dtype)
    else:
        p["layers"] = _stack_init(_init_dense_layer, ks[2], cfg.n_layers, cfg, dtype)
    return p


def _init_dense_layer(key, cfg, dtype):
    hd = cfg.resolved_head_dim
    kk = jax.random.split(key, 2)
    d = {
        "attn_norm": init_rmsnorm(cfg.d_model, dtype),
        "attn": A.init_attention(kk[0], cfg.d_model, cfg.n_heads, cfg.kv_heads,
                                 hd, cfg.qkv_bias, dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model, dtype),
        "mlp": MLP.init_mlp(kk[1], cfg.d_model, cfg.d_ff, dtype),
    }
    if cfg.post_block_norm:
        d["attn_post_norm"] = init_rmsnorm(cfg.d_model, dtype)
        d["mlp_post_norm"] = init_rmsnorm(cfg.d_model, dtype)
    return d


def _init_dense_pair(key, cfg, dtype):
    kk = jax.random.split(key, 2)
    return {"local": _init_dense_layer(kk[0], cfg, dtype),
            "global": _init_dense_layer(kk[1], cfg, dtype)}


def _init_moe_layer(key, cfg, dtype):
    hd = cfg.resolved_head_dim
    kk = jax.random.split(key, 2)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model, dtype),
        "attn": A.init_attention(kk[0], cfg.d_model, cfg.n_heads, cfg.kv_heads,
                                 hd, cfg.qkv_bias, dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model, dtype),
        "moe": MOE.init_moe(kk[1], cfg.d_model, cfg.moe, dtype),
    }


def _init_cross_layer(key, cfg, dtype):
    hd = cfg.resolved_head_dim
    kk = jax.random.split(key, 2)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model, dtype),
        "cross_attn": A.init_attention(kk[0], cfg.d_model, cfg.n_heads,
                                       cfg.kv_heads, hd, False, dtype),
        "gate": jnp.zeros((), dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model, dtype),
        "mlp": MLP.init_mlp(kk[1], cfg.d_model, cfg.d_ff, dtype),
    }


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------
def _dense_layer(lp, x, cfg, *, window, cache=None, kv_chunk=1024):
    hd = cfg.resolved_head_dim
    h = rmsnorm(lp["attn_norm"], x, cfg.rmsnorm_eps)
    y, new_cache = A.attention_block(
        lp["attn"], h, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads, head_dim=hd,
        rope_theta=cfg.rope_theta, causal=not cfg.encoder_only, window=window,
        softcap=cfg.attn_softcap, kv_chunk=kv_chunk, cache=cache)
    if "attn_post_norm" in lp:
        y = rmsnorm(lp["attn_post_norm"], y, cfg.rmsnorm_eps)
    x = x + y
    h = rmsnorm(lp["mlp_norm"], x, cfg.rmsnorm_eps)
    y = MLP.mlp(lp["mlp"], h)
    if "mlp_post_norm" in lp:
        y = rmsnorm(lp["mlp_post_norm"], y, cfg.rmsnorm_eps)
    return x + y, new_cache


def _moe_dense_layer(lp, x, cfg, mesh, dp_axes, *, cache=None, kv_chunk=1024):
    hd = cfg.resolved_head_dim
    h = rmsnorm(lp["attn_norm"], x, cfg.rmsnorm_eps)
    y, new_cache = A.attention_block(
        lp["attn"], h, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads, head_dim=hd,
        rope_theta=cfg.rope_theta, causal=True, kv_chunk=kv_chunk, cache=cache)
    x = x + y
    h = rmsnorm(lp["mlp_norm"], x, cfg.rmsnorm_eps)
    y, moe_metrics = MOE.moe_layer(lp["moe"], h, cfg.moe, mesh=mesh,
                                   dp_axes=dp_axes)
    return x + y, new_cache, moe_metrics


def _rwkv_layer_impl(lp, x, cfg, cache=None):
    cache_tm = None if cache is None else {"shift_t": cache["shift_t"],
                                           "wkv": cache["wkv"]}
    y, new_tm = R.rwkv6_time_mix(lp, x, cfg.rwkv, cache_tm)
    x = x + y
    last_c = None if cache is None else cache["shift_c"]
    y = R.rwkv6_channel_mix(lp, x, last_c)
    new_cache = None
    if cache is not None:
        new_cache = {"shift_t": new_tm["shift_t"], "wkv": new_tm["wkv"],
                     "shift_c": x[:, -1:]}
    return x + y, new_cache


def _shared_attn_block(sp, x, cfg, cache=None, kv_chunk=1024):
    hd = cfg.resolved_head_dim
    h = rmsnorm(sp["norm"], x, cfg.rmsnorm_eps)
    y, new_cache = A.attention_block(
        sp["attn"], h, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads, head_dim=hd,
        rope_theta=cfg.rope_theta, causal=True, kv_chunk=kv_chunk, cache=cache)
    x = x + y
    h = rmsnorm(sp["mlp_norm"], x, cfg.rmsnorm_eps)
    return x + MLP.mlp(sp["mlp"], h), new_cache


def _cross_layer(lp, x, img, cfg):
    hd = cfg.resolved_head_dim
    h = rmsnorm(lp["attn_norm"], x, cfg.rmsnorm_eps)
    y, _ = A.attention_block(
        lp["cross_attn"], h, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
        head_dim=hd, rope_theta=cfg.rope_theta, cross_kv=img)
    x = x + jnp.tanh(lp["gate"]) * y
    h = rmsnorm(lp["mlp_norm"], x, cfg.rmsnorm_eps)
    return x + MLP.mlp(lp["mlp"], h)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def forward(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            mesh=None, dp_axes=("data",), cache=None, train=False,
            kv_chunk: int = 1024, constrain_weights: bool = True):
    """Returns (logits, new_cache, metrics).

    batch: {"tokens": [B,S]} or {"embeds": [B,S,d]} (+ "image_embeds").
    cache: None (train/prefill) or the arch's stacked cache pytree (decode).
    """
    hint = make_hint(mesh, dp_axes)
    wcon = make_wconstrain(mesh if constrain_weights else None)
    if cfg.embed_inputs:
        x = embed(params["embed"], batch["tokens"])
        if cfg.name.startswith("gemma"):
            x = x * float(np.sqrt(cfg.d_model))
    else:
        x = batch["embeds"]
    x = hint(x)
    metrics: Dict[str, jax.Array] = {}

    remat = jax.checkpoint if train else (lambda f: f)

    if cfg.rwkv is not None:
        def body(carry, xs):
            lp, lcache = xs
            y, new_cache = _rwkv_layer_impl(wcon(lp), hint(carry), cfg, lcache)
            return hint(y), new_cache
        x, new_caches = jax.lax.scan(remat(body), x, (params["layers"], cache))
    elif cfg.ssm is not None:
        x, new_caches, metrics = _zamba_forward(params, x, cfg, cache, remat,
                                                kv_chunk, hint, wcon)
    elif cfg.cross_attn_period:
        x, new_caches = _vlm_forward(params, x, batch["image_embeds"], cfg,
                                     cache, remat, kv_chunk, hint, wcon)
    elif cfg.moe is not None:
        aux0 = {"aux_loss": jnp.zeros(()), "router_li": jnp.zeros(()),
                "drop_frac": jnp.zeros(())}

        def body(carry, xs):
            h, acc = carry
            lp, lcache = xs
            y, new_cache, mm = _moe_dense_layer(wcon(lp), hint(h), cfg, mesh,
                                                dp_axes, cache=lcache,
                                                kv_chunk=kv_chunk)
            acc = {k: acc[k] + mm[k] for k in acc}
            return (hint(y), acc), new_cache
        (x, aux), new_caches = jax.lax.scan(remat(body), (x, aux0),
                                            (params["layers"], cache))
        metrics = {k: v / cfg.n_layers for k, v in aux.items()}
    elif cfg.local_global_period:
        def body(carry, xs):
            lp, lcache = xs
            lc = None if lcache is None else lcache["local"]
            gc = None if lcache is None else lcache["global"]
            lp = wcon(lp)
            h, nl = _dense_layer(lp["local"], hint(carry), cfg,
                                 window=cfg.sliding_window, cache=lc,
                                 kv_chunk=kv_chunk)
            h, ng = _dense_layer(lp["global"], h, cfg, window=None, cache=gc,
                                 kv_chunk=kv_chunk)
            out_cache = None if lcache is None else {"local": nl, "global": ng}
            return hint(h), out_cache
        x, new_caches = jax.lax.scan(remat(body), x, (params["layers"], cache))
    else:
        def body(carry, xs):
            lp, lcache = xs
            y, new_cache = _dense_layer(wcon(lp), hint(carry), cfg,
                                        window=None, cache=lcache,
                                        kv_chunk=kv_chunk)
            return hint(y), new_cache
        x, new_caches = jax.lax.scan(remat(body), x, (params["layers"], cache))

    x = rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    if cfg.tie_embeddings and cfg.embed_inputs:
        logits = unembed(params["embed"], x, cfg.final_softcap)
    else:
        logits = linear(params["head"], x).astype(jnp.float32)
    return logits, new_caches, metrics


def _zamba_forward(params, x, cfg, cache, remat, kv_chunk,
                   hint=lambda x: x, wcon=lambda p: p):
    period = cfg.hybrid_attn_period
    groups = params["layers"]["in_proj"]["w"].shape[0] // period
    metrics: Dict[str, jax.Array] = {}

    # reshape stacked mamba params to [groups, period, ...]
    grouped = jax.tree_util.tree_map(
        lambda t: t.reshape(groups, period, *t.shape[1:]), params["layers"])
    mamba_cache = None if cache is None else cache["mamba"]
    attn_cache = None if cache is None else cache["shared_attn"]
    sp = params["shared_attn"]

    def group_body(carry, xs):
        gp, gcache, acache = xs

        def inner(c, ys):
            lp, lc = ys
            y, nc = M.mamba2_block(wcon(lp), hint(c), cfg.ssm, lc)
            return hint(y), nc
        h, new_mcache = jax.lax.scan(inner, carry, (gp, gcache))
        h, new_acache = _shared_attn_block(sp, h, cfg, acache, kv_chunk)
        return hint(h), (new_mcache, new_acache)

    x, (new_mc, new_ac) = jax.lax.scan(
        remat(group_body), x, (grouped, mamba_cache, attn_cache))

    new_tail = None
    if "tail_layers" in params:
        tail_cache = None if cache is None else cache["tail"]

        def tail_body(carry, xs):
            lp, lc = xs
            y, nc = M.mamba2_block(lp, carry, cfg.ssm, lc)
            return y, nc
        x, new_tail = jax.lax.scan(remat(tail_body), x,
                                   (params["tail_layers"], tail_cache))
    new_cache = None
    if cache is not None:
        new_cache = {"mamba": new_mc, "shared_attn": new_ac, "tail": new_tail}
    return x, new_cache, metrics


def _vlm_forward(params, x, img, cfg, cache, remat, kv_chunk,
                 hint=lambda x: x, wcon=lambda p: p):
    period = cfg.cross_attn_period
    groups = params["cross_layers"]["gate"].shape[0]
    self_grouped = jax.tree_util.tree_map(
        lambda t: t.reshape(groups, period - 1, *t.shape[1:]), params["layers"])
    self_cache = None if cache is None else cache["self"]

    def group_body(carry, xs):
        gp, cp, gcache = xs

        def inner(c, ys):
            lp, lc = ys
            y, nc = _dense_layer(wcon(lp), hint(c), cfg, window=None,
                                 cache=lc, kv_chunk=kv_chunk)
            return hint(y), nc
        h, new_scache = jax.lax.scan(inner, carry, (gp, gcache))
        h = _cross_layer(cp, h, img, cfg)
        return hint(h), new_scache

    x, new_sc = jax.lax.scan(remat(group_body), x,
                             (self_grouped, params["cross_layers"], self_cache))
    new_cache = None if cache is None else {"self": new_sc}
    return x, new_cache


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked decode cache for the arch (leading axis = scan layers)."""
    hd = cfg.resolved_head_dim

    def kv(n):
        return {"k": jnp.zeros((n, batch, max_len, cfg.kv_heads, hd), dtype),
                "v": jnp.zeros((n, batch, max_len, cfg.kv_heads, hd), dtype),
                "len": jnp.zeros((n,), jnp.int32)}

    if cfg.rwkv is not None:
        base = R.init_rwkv6_cache(batch, cfg.d_model, cfg.rwkv, dtype)
        return jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t, (cfg.n_layers, *t.shape)), base)
    if cfg.ssm is not None:
        period = cfg.hybrid_attn_period
        groups, rem = divmod(cfg.n_layers, period)
        mc = M.init_mamba2_cache(batch, cfg.d_model, cfg.ssm, dtype)
        out = {"mamba": jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t, (groups, period, *t.shape)), mc),
            "shared_attn": kv(groups)}
        out["tail"] = (jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t, (rem, *t.shape)), mc) if rem else None)
        return out
    if cfg.cross_attn_period:
        period = cfg.cross_attn_period
        groups = cfg.n_layers // period
        return {"self": jax.tree_util.tree_map(
            lambda t: t.reshape(groups, period - 1, *t.shape[1:]),
            kv(groups * (period - 1)))}
    if cfg.local_global_period:
        return {"local": kv(cfg.n_layers // 2), "global": kv(cfg.n_layers // 2)}
    return kv(cfg.n_layers)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def loss_fn(params, batch, cfg: ModelConfig, mesh=None, dp_axes=("data",),
            train=True):
    logits, _, metrics = forward(params, batch, cfg, mesh=mesh,
                                 dp_axes=dp_axes, cache=None, train=train)
    if cfg.encoder_only:
        loss = softmax_cross_entropy(logits, batch["labels"])
    else:
        loss = softmax_cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])
    if cfg.moe is not None and "aux_loss" in metrics:
        loss = loss + cfg.moe.router_aux_weight * metrics["aux_loss"]
    metrics["ce_loss"] = loss
    return loss, metrics
