"""minicpm-2b [dense]: llama-like MHA, WSD schedule [arXiv:2404.06395; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, kv_heads=36,
    d_ff=5760, vocab=122753, head_dim=64,
    wsd_schedule=True,
)
