"""rwkv6-7b (Finch) [ssm]: attention-free, data-dependent decay
[arXiv:2404.05892; hf]."""
from .base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, kv_heads=64,
    d_ff=14336, vocab=65536, head_dim=64,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, chunk=32),
)
