"""hubert-xlarge [audio]: encoder-only; frontend is a stub — input_specs()
provides precomputed frame embeddings [arXiv:2106.07447; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, kv_heads=16,
    d_ff=5120, vocab=504, head_dim=80,
    encoder_only=True, embed_inputs=False, tie_embeddings=False,
)
