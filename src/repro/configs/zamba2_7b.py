"""zamba2-7b [hybrid]: 81 Mamba2 layers + shared attention block every 6
[arXiv:2411.15242; unverified]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, kv_heads=32,
    d_ff=14336, vocab=32000, head_dim=112,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=128),
    hybrid_attn_period=6,
)
