"""Architecture registry: --arch <id> -> ModelConfig.

The paper's own workload (distributed SpMV/CG) is registered as `spmv`
and handled by launch/dryrun.py separately from the LM path.
"""
from . import (command_r_plus_104b, gemma2_27b, hubert_xlarge,
               llama32_vision_11b, minicpm_2b, phi35_moe_42b_a66b,
               qwen2_7b, qwen3_moe_30b_a3b, rwkv6_7b, zamba2_7b)
from .base import SHAPES, ModelConfig, ShapeConfig, smoke_config

ARCHS = {
    "zamba2-7b": zamba2_7b.CONFIG,
    "qwen2-7b": qwen2_7b.CONFIG,
    "command-r-plus-104b": command_r_plus_104b.CONFIG,
    "gemma2-27b": gemma2_27b.CONFIG,
    "minicpm-2b": minicpm_2b.CONFIG,
    "rwkv6-7b": rwkv6_7b.CONFIG,
    "hubert-xlarge": hubert_xlarge.CONFIG,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b_a66b.CONFIG,
    "llama-3.2-vision-11b": llama32_vision_11b.CONFIG,
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def runnable_cells():
    """The 40 assigned (arch x shape) cells minus documented skips
    (DESIGN.md §5): returns list of (arch, shape, runnable, reason)."""
    out = []
    for arch, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            runnable, reason = True, ""
            if cfg.encoder_only and shape.kind == "decode":
                runnable, reason = False, "encoder-only: no decode step"
            elif sname == "long_500k" and not cfg.sub_quadratic:
                runnable, reason = False, "full attention: long_500k needs sub-quadratic"
            out.append((arch, sname, runnable, reason))
    return out
