"""llama-3.2-vision-11b [vlm]: cross-attn image layers every 5; vision
frontend is a stub — input_specs() provides patch embeddings
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, kv_heads=8,
    d_ff=14336, vocab=128256, head_dim=128, rope_theta=5e5,
    cross_attn_period=5, num_image_tokens=1600,
)
