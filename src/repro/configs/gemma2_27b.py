"""gemma2-27b [dense]: local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, kv_heads=16,
    d_ff=36864, vocab=256000, head_dim=128,
    attn_softcap=50.0, final_softcap=30.0,
    sliding_window=4096, local_global_period=2,  # even layers local
    post_block_norm=True,
)
