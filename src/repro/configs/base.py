"""Model/run configuration system.

ModelConfig captures everything the 10 assigned architectures need as data
(no per-arch model code): attention flavour (GQA/sliding/softcap/cross),
MoE, SSM (Mamba2), RWKV6, hybrid interleaving, encoder-only. One composable
decoder implementation in models/ consumes it.

ShapeConfig captures the four assigned input-shape cells. RunConfig binds
(arch, shape, mesh, precision, optimizer) for the launcher/dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # paper tie-in (DESIGN.md §5): sort-based (reordered) dispatch vs
    # one-hot; load-imbalance metric reported either way.
    dispatch: str = "sorted"  # "sorted" | "onehot"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block."""
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    chunk: int = 32  # small: the WKV6 chunk materializes [T,T,D] per head


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rmsnorm_eps: float = 1e-5
    tie_embeddings: bool = True
    encoder_only: bool = False       # hubert: bidirectional, no decode
    embed_inputs: bool = True        # False: frontend stub feeds embeddings
    # gemma2
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    local_global_period: int = 0     # >0: every k-th layer is GLOBAL, rest local
    post_block_norm: bool = False    # gemma2 extra norms
    # moe
    moe: Optional[MoEConfig] = None
    moe_every: int = 1               # every k-th layer is MoE (1 = all)
    # ssm / hybrid
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    hybrid_attn_period: int = 0      # zamba2: shared attn block every k layers
    # vlm
    cross_attn_period: int = 0       # every k-th layer cross-attends
    num_image_tokens: int = 0
    # training
    wsd_schedule: bool = False       # minicpm warmup-stable-decay

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a multiple of 256 so the embedding/logit dim
        shards over the model axis (MaxText-style padding; only minicpm's
        122753 is affected among the assigned archs)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def attention_free(self) -> bool:
        return self.rwkv is not None or (
            self.ssm is not None and self.hybrid_attn_period == 0)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (decode-time state/cache is O(1) or the
        arch is hybrid with O(S) decode attention)."""
        return self.ssm is not None or self.rwkv is not None

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, l = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        total = self.vocab * d  # embed (tied)
        attn = d * hd * self.n_heads + 2 * d * hd * self.kv_heads + hd * self.n_heads * d
        ffn_dense = 3 * d * self.d_ff
        for i in range(l):
            if self.ssm is not None and not self._is_hybrid_attn_layer(i):
                di = self.ssm.expand * d
                total += 2 * d * di + di * d + d * self.ssm.d_state * 2
                continue
            if self.rwkv is not None:
                # 5 square mats (r,k,v,g,o) + decay LoRA + 2-mat channel-mix
                total += 5 * d * d + 2 * d * self.rwkv.decay_lora + 2 * d * self.d_ff
                continue
            total += attn
            if self.moe is not None and (i % self.moe_every == 0):
                total += self.moe.num_experts * 3 * d * self.moe.d_ff_expert
                total += d * self.moe.num_experts
            else:
                total += ffn_dense
        if self.hybrid_attn_period:
            total += attn + ffn_dense  # one shared block
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        moe_layers = len([i for i in range(self.n_layers) if i % self.moe_every == 0])
        all_exp = moe_layers * self.moe.num_experts * 3 * d * self.moe.d_ff_expert
        act_exp = moe_layers * self.moe.top_k * 3 * d * self.moe.d_ff_expert
        return total - all_exp + act_exp

    def _is_hybrid_attn_layer(self, i: int) -> bool:
        return bool(self.hybrid_attn_period) and (i + 1) % self.hybrid_attn_period == 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    changes = dict(
        n_layers=min(cfg.n_layers, 4 if not cfg.hybrid_attn_period else 5),
        d_model=128,
        n_heads=4,
        kv_heads=min(cfg.kv_heads, 4) if cfg.kv_heads < cfg.n_heads else 4,
        d_ff=256,
        vocab=512,
        head_dim=32,
        num_image_tokens=16 if cfg.cross_attn_period else 0,
        sliding_window=64 if cfg.sliding_window else None,
    )
    if cfg.moe:
        changes["moe"] = MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                                   dispatch=cfg.moe.dispatch)
    if cfg.ssm:
        changes["ssm"] = SSMConfig(d_state=16, head_dim=32, chunk=16)
    if cfg.rwkv:
        changes["rwkv"] = RWKVConfig(head_dim=32, decay_lora=16, chunk=16)
    if cfg.hybrid_attn_period:
        changes["hybrid_attn_period"] = 3
    if cfg.cross_attn_period:
        changes["cross_attn_period"] = 2
    if cfg.local_global_period:
        changes["local_global_period"] = 2
    return dataclasses.replace(cfg, **changes)
