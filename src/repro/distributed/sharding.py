"""Sharding rules: parameter-path regex -> PartitionSpec (t5x-style logical
rules, applied OUTSIDE model code).

Strategy (DESIGN.md §4): 2-D FSDP x TP.
  * "model" axis: TP on heads / d_ff / vocab / experts / SSM channels.
  * "data" axis: FSDP on the other big dim of each weight (all-gathered
    per layer inside the scan by XLA SPMD).
  * "pod" axis (multi-pod): pure data parallelism (batch), params replicated
    across pods — gradients all-reduce over pod+data.
Optimizer state inherits the param specs. Stacked layer params get a None
prepended for the layer axis.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex on path, spec for the UNSTACKED param). First match wins.
_RULES = [
    # embeddings / heads
    (r"embed/table", P("model", "data")),
    (r"head/w", P("data", "model")),
    # attention
    (r"attn/w[qkv]/w", P("data", "model")),
    (r"attn/w[qkv]/b", P("model")),
    (r"attn/wo/w", P("model", "data")),
    (r"cross_attn/w[qkv]/w", P("data", "model")),
    (r"cross_attn/wo/w", P("model", "data")),
    # dense mlp
    (r"mlp/w_(gate|up)/w", P("data", "model")),
    (r"mlp/w_down/w", P("model", "data")),
    # moe (experts on model = EP, FSDP over data on d_model/d_ff;
    # must match moe_layer's shard_map wspec). See MOE_FSDP below.
    (r"moe/router/w", P()),
    # mamba2
    (r"in_proj/w", P("data", "model")),
    (r"out_proj/w", P("model", "data")),
    (r"conv_w", P(None, "model")),
    (r"conv_b", P("model")),
    (r"(a_log|dt_bias|d_skip)", P("model")),
    (r"layers/norm/scale", P("model")),  # mamba gated-norm over d_inner
    # rwkv6
    (r"w[rkvg]/w", P("data", "model")),
    (r"wo/w", P("model", "data")),
    (r"w_lora_a", P("data", None)),
    (r"w_lora_b", P(None, "model")),
    (r"u_bonus", P("model", None)),
    (r"wck/w", P("data", "model")),
    (r"wcv/w", P("model", "data")),
    (r"(w0|mix_[rkvwg]|cmix_k)", P()),
    # norms & scalars
    (r"(norm|ln_x)/scale", P()),
    (r"gate", P()),
]

_STACKED_PREFIXES = ("layers", "tail_layers", "cross_layers")

# §Perf Cell B switch: False = EP-stationary experts (resident TP-sharded on
# the model axis, no FSDP gather per layer/microbatch; qwen3: 3.6 GiB/dev).
MOE_FSDP = True


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def param_spec(path, leaf) -> P:
    s = _path_str(path)
    spec = None
    if re.search(r"moe/w_(gate|up)", s):
        spec = P("model", "data", None) if MOE_FSDP else P("model", None, None)
    elif re.search(r"moe/w_down", s):
        spec = P("model", None, "data") if MOE_FSDP else P("model", None, None)
    else:
        for pat, sp in _RULES:
            if re.search(pat, s):
                spec = sp
                break
    if spec is None:
        spec = P()  # replicate by default (small tensors)
    stacked = s.startswith(_STACKED_PREFIXES)
    ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    want = len(spec) + (1 if stacked else 0)
    # pad spec with None up to rank; prepend None for the stacked layer axis
    parts = ([None] if stacked else []) + list(spec)
    parts += [None] * (ndim - len(parts))
    if len(parts) != ndim:  # over-specified (e.g. scalar gate): trim
        parts = parts[:ndim]
    return P(*parts)


def param_specs(params) -> Any:
    """Pytree of PartitionSpec matching `params` (arrays or ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(param_spec, params)


def named_shardings(tree_specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def batch_spec(shape_kind: str, dp_axes) -> Any:
    """Input batch specs: tokens/labels [B, S] batch-sharded."""
    return P(dp_axes, None)


def divisible(n: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    return n % size == 0


def constrain_tree(tree, mesh: Mesh):
    """Apply the param rules as with_sharding_constraint on an arbitrary
    (sub)tree — used INSIDE the layer scan on the per-layer weight slice.

    Two effects (§Perf iteration 1): the forward all-gather of FSDP shards
    happens on the bf16 copies (not f32), and — because the VJP of
    with_sharding_constraint constrains the cotangent identically — the
    per-layer weight GRADS are pinned to their shard inside the loop, so
    XLA emits reduce-scatter instead of full-tensor all-reduce."""
    specs = param_specs(tree)
    specs = validate_specs(tree, specs, mesh)
    return jax.tree_util.tree_map(
        lambda t, sp: jax.lax.with_sharding_constraint(
            t, NamedSharding(mesh, sp)), tree, specs)


def validate_specs(params, specs, mesh: Mesh):
    """Drop (replace with None) any spec axis that does not divide the dim —
    keeps the dry-run legal for every arch (e.g. odd head counts)."""
    def fix(path, leaf, spec):
        shape = leaf.shape
        parts = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for dim, ax in zip(shape, parts):
            out.append(ax if divisible(dim, mesh, ax) else None)
        return P(*out)
    return jax.tree_util.tree_map_with_path(
        lambda p, l, s: fix(p, l, s), params, specs)
