"""Gradient compression for cross-pod all-reduce (DESIGN.md §4).

Under SPMD the all-reduce is inserted by XLA where grads cross the
pod/data axes; compressing the gradient VALUES to bf16 (or int8 with
stochastic rounding) before the optimizer means the collective moves half
(quarter) the bytes. bf16 is lossless enough for Adam (which re-normalizes
by sqrt(nu)); int8 uses per-tensor scale + stochastic rounding so the
expectation is unbiased.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_bf16(grads):
    return jax.tree_util.tree_map(
        lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)


def compress_int8_stochastic(grads, key):
    """Quantize-dequantize with stochastic rounding (unbiased)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))

    def one(g, k):
        scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
        x = g / scale
        lo = jnp.floor(x)
        p = x - lo
        r = lo + (jax.random.uniform(k, g.shape) < p)
        return jnp.clip(r, -127, 127) * scale

    return treedef.unflatten([one(g, k) for g, k in zip(leaves, keys)])
