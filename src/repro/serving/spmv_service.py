"""Micro-batching SpMV service — the streaming operator front-end.

The ROADMAP north star ("serve heavy traffic from millions of users") means
concurrent `y = A @ x` requests against a small set of cached operators.
Running them one SpMV at a time streams the matrix once per request; this
service coalesces concurrent same-matrix requests into ONE SpMM call

    Y[:, 0..b) = A @ [x_0 | x_1 | ... | x_{b-1}]

so the matrix bytes are paid once per batch — the same amortization the
k-aware tuner (core/spmv/tune.py) models and the SELL SpMM kernel
(kernels/sell_spmm) implements.

Policy (classic micro-batching, cf. serving/decode.py's decode batching):
  * Requests enqueue per matrix key; a dispatcher thread always serves the
    key holding the OLDEST pending request (FIFO fairness across matrices).
  * A batch closes when it reaches `max_batch` requests OR `window_ms` has
    elapsed since its oldest request — bounded latency, opportunistic width.
  * Operators resolve once per key through the pipeline facade
    (repro.api.plan + Plan.build, persistent plan store) with a
    k=max_batch-specialized plan.
  * The service may reorder a matrix internally (`reorder=` scheme, per
    service or per register() call) — the planned operators carry their
    permutation, so requests and responses stay in the ORIGINAL index
    space; no caller ever sees the reordered numbering.

Equivalence guarantee: request j of a coalesced batch receives column j of
`op.matmul(X)`, which matches the unbatched `op(x_j)` to fp32 accumulation
tolerance (the batched kernels stream the same matrix elements in the same
per-column order; only the vector axis is widened). Tested in
tests/test_spmm_batch.py.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Dict, Optional

import numpy as np

from ..core.sparse.csr import CSRMatrix


@dataclasses.dataclass
class _Request:
    key: str
    x: np.ndarray
    future: Future
    t_submit: float


class SpmvService:
    """Queue + coalesce concurrent (matrix_key, x) requests into SpMM calls.

    Usage:
        svc = SpmvService(max_batch=8, window_ms=2.0)
        svc.register("mesh", mat)
        fut = svc.submit("mesh", x)          # -> concurrent.futures.Future
        y = fut.result()
        svc.close()

    Also usable as a context manager (close() on exit).
    """

    def __init__(self, engine: str = "auto", max_batch: int = 32,
                 window_ms: float = 2.0, use_kernel: str = "auto",
                 dtype=None, cache: bool = True, probe: bool = False,
                 max_queue: int = 1024, reorder: str = "baseline",
                 topology=None, partition: str = "auto"):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.engine = engine
        self.reorder = reorder
        self.topology = topology
        self.partition = partition
        self.max_batch = int(max_batch)
        self.max_queue = int(max_queue)
        self.window_s = float(window_ms) * 1e-3
        self.use_kernel = use_kernel
        self.cache = cache
        self.probe = probe
        self._dtype = dtype
        self._matrices: Dict[str, CSRMatrix] = {}
        self._schemes: Dict[str, str] = {}
        self._topologies: Dict[str, object] = {}
        self._gen: collections.Counter = collections.Counter()
        self._ops: Dict[str, tuple] = {}          # key -> (gen, operator)
        self._build_info: Dict[str, dict] = {}
        self._queues: Dict[str, collections.deque] = {}
        self._cv = threading.Condition()
        self._op_lock = threading.Lock()
        self._stop = False
        self._inflight = 0
        self._key_inflight: collections.Counter = collections.Counter()
        self._current_batch: Optional[list] = None
        self._stats = {"requests": 0, "batches": 0, "dispatches": 0,
                       "errors": 0, "batch_size_sum": 0, "batch_size_max": 0,
                       "wait_ms_sum": 0.0,
                       "batch_hist": collections.Counter()}
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="spmv-service-dispatch")
        self._worker.start()

    # -- registry ----------------------------------------------------------
    def register(self, key: str, mat: CSRMatrix,
                 reorder: Optional[str] = None, topology=None) -> None:
        """Make `key` servable. Operator build is lazy (first batch).

        reorder overrides the service-wide scheme for this key, and
        topology (a repro.api.Topology) overrides the service-wide
        topology — a SHARDED key: its operator is the topology-aware
        plan's ShardedOperator, dispatching each coalesced SpMM across
        the device mesh (or its single-device simulation). Requests stay
        in the original index space either way (the operator carries its
        permutation and panel maps).

        Re-registering a key drops its memoized operator, and is REFUSED
        while the key has queued or in-flight requests — a request
        validated against matrix A must never be answered from matrix B
        (flush() first to swap safely)."""
        with self._cv:
            if key in self._matrices and (self._queues[key]
                                          or self._key_inflight[key]):
                raise RuntimeError(
                    f"cannot re-register {key!r} with pending requests; "
                    f"flush() first")
            self._matrices[key] = mat
            self._schemes[key] = self.reorder if reorder is None else reorder
            self._topologies[key] = (self.topology if topology is None
                                     else topology)
            # bumping the generation under _cv invalidates any memoized
            # operator atomically with the matrix swap — operator() only
            # trusts an entry whose generation matches the matrix it read
            self._gen[key] += 1
            self._queues.setdefault(key, collections.deque())

    def operator(self, key: str):
        """Resolve (and memoize) the operator for `key` via the pipeline
        facade, tuned for this service's max batch width. The returned
        operator accepts original-index-space vectors (it carries the
        permutation of this key's reordering scheme)."""
        with self._cv:
            mat = self._matrices[key]
            scheme = self._schemes[key]
            topology = self._topologies.get(key)
            gen = self._gen[key]
        with self._op_lock:
            ent = self._ops.get(key)
            if ent is not None and ent[0] == gen:
                return ent[1]
            from ..api import SpmvProblem, plan as make_plan

            pl = make_plan(
                SpmvProblem(mat, k=self.max_batch, dtype=self._dtype,
                            hints={"use_kernel": self.use_kernel}),
                reorder=scheme, engine=self.engine, probe=self.probe,
                cache=self.cache, topology=topology,
                partition=self.partition)
            op = pl.build(cache=self.cache)
            self._ops[key] = (gen, op)
            self._build_info[key] = op.build_info
        return op

    # -- request path ------------------------------------------------------
    def submit(self, key: str, x) -> Future:
        """Enqueue one y = A_key @ x request; returns a Future of np [m]."""
        x = np.asarray(x)
        with self._cv:
            if self._stop:
                raise RuntimeError("service is closed")
            if key not in self._matrices:
                raise KeyError(f"unregistered matrix key {key!r}")
            n = self._matrices[key].shape[1]
            # reject malformed requests HERE: a bad x inside a coalesced
            # batch would otherwise fail every well-formed neighbour
            if x.shape != (n,):
                raise ValueError(
                    f"x for {key!r} must have shape ({n},), got {x.shape}")
            # backpressure: bounded per-key queue — reject loudly instead
            # of letting a fast producer grow pending vectors unboundedly
            if len(self._queues[key]) >= self.max_queue:
                raise RuntimeError(
                    f"backpressure: queue for {key!r} is full "
                    f"({self.max_queue} pending)")
            fut: Future = Future()
            self._queues[key].append(
                _Request(key, x, fut, time.monotonic()))
            self._stats["requests"] += 1
            self._cv.notify_all()
        return fut

    def flush(self, timeout: float = 60.0) -> None:
        """Block until every queued request has been dispatched & resolved."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while (any(self._queues.values()) or self._inflight) \
                    and time.monotonic() < deadline:
                self._cv.wait(0.02)
            if any(self._queues.values()) or self._inflight:
                raise TimeoutError("flush timed out")

    def close(self, timeout: float = 60.0) -> None:
        """Drain outstanding work (up to timeout), then stop the
        dispatcher. The service ALWAYS stops — if draining times out the
        TimeoutError is re-raised after shutdown, never before it — and
        any request still queued (or stuck in a wedged dispatch) gets its
        Future failed, so no caller blocked in result() hangs forever."""
        err = None
        try:
            self.flush(timeout=timeout)
        except TimeoutError as e:
            err = e
        with self._cv:
            self._stop = True
            leftovers = [r for q in self._queues.values() for r in q]
            for q in self._queues.values():
                q.clear()
            self._cv.notify_all()
        self._worker.join(timeout=10.0)
        if self._worker.is_alive():
            # dispatch wedged in device code: fail its batch best-effort
            # (the zombie daemon thread's late set_result is swallowed by
            # _dispatch's InvalidStateError guard)
            with self._cv:
                leftovers.extend(self._current_batch or [])
        for r in leftovers:
            if not r.future.done():
                r.future.set_exception(
                    RuntimeError("service closed before dispatch"))
        if err is not None:
            raise err

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def stats(self) -> dict:
        with self._cv:
            s = dict(self._stats)
            s["batch_hist"] = dict(self._stats["batch_hist"])
        with self._op_lock:      # _build_info is written under _op_lock
            op_hits = {k: v["cache_hit"] for k, v in self._build_info.items()}
        b = max(s["batches"], 1)
        s["avg_batch"] = s["batch_size_sum"] / b       # dispatched reqs/batch
        s["avg_wait_ms"] = s["wait_ms_sum"] / b
        # DISPATCHED requests per dispatch (error batches included) — the
        # amortization the service exists for; computed from completed
        # work only, so a mid-stream snapshot is not inflated by requests
        # still sitting in the queues
        s["coalesce_ratio"] = (s["batch_size_sum"] + s["errors"]) \
            / max(s["dispatches"], 1)
        s["op_cache_hits"] = op_hits
        return s

    # -- dispatcher --------------------------------------------------------
    def _pick_key(self) -> Optional[str]:
        """Next key to serve (None if all queues are empty).

        Priority: (1) the oldest request whose batch window already
        expired — the latency bound always wins; (2) any key with a FULL
        batch ready — no reason to sleep out another key's window while a
        dispatchable batch waits (cross-key head-of-line blocking);
        (3) the oldest pending request.
        """
        oldest, oldest_t, full = None, None, None
        for key, q in self._queues.items():
            if not q:
                continue
            if oldest_t is None or q[0].t_submit < oldest_t:
                oldest, oldest_t = key, q[0].t_submit
            if full is None and len(q) >= self.max_batch:
                full = key
        if oldest is not None and \
                time.monotonic() >= oldest_t + self.window_s:
            return oldest
        return full if full is not None else oldest

    def _run(self) -> None:
        while True:
            with self._cv:
                key = self._pick_key()
                while key is None and not self._stop:
                    self._cv.wait(0.05)
                    key = self._pick_key()
                if key is None and self._stop:
                    return
                # batch window: wait for more same-key arrivals, bounded by
                # the oldest request's deadline and the batch size cap —
                # re-evaluating the pick each wake so a key that becomes
                # dispatchable (full batch / expired window) preempts
                q = self._queues[key]
                deadline = q[0].t_submit + self.window_s
                while (len(q) < self.max_batch and not self._stop
                       and time.monotonic() < deadline):
                    self._cv.wait(
                        max(min(deadline - time.monotonic(), 0.05), 1e-4))
                    nk = self._pick_key()
                    if nk is not None and nk != key:
                        key, q = nk, self._queues[nk]
                        deadline = q[0].t_submit + self.window_s
                batch = [q.popleft()
                         for _ in range(min(self.max_batch, len(q)))]
                # defensive: the queue can be emptied externally while we
                # waited (forced shutdown paths clear it under _cv)
                if not batch:
                    continue
                self._inflight += 1
                self._key_inflight[key] += 1
                self._current_batch = batch
            try:
                self._dispatch(key, batch)
            finally:
                with self._cv:
                    self._inflight -= 1
                    self._key_inflight[key] -= 1
                    self._current_batch = None
                    self._cv.notify_all()

    def _dispatch(self, key: str, batch: list) -> None:
        import jax.numpy as jnp

        t0 = time.monotonic()
        try:
            op = self.operator(key)
            dt = jnp.float32 if self._dtype is None else self._dtype
            if len(batch) == 1:
                # a lone request takes the SpMV path: matmul's k-tile
                # padding would do tile-width times the work for 1 column
                y = np.asarray(op(jnp.asarray(batch[0].x, dt)))[:, None]
            else:
                # assemble on host, ONE device put per batch
                x_block = jnp.asarray(
                    np.stack([r.x for r in batch], axis=1), dt)
                y = np.asarray(op.matmul(x_block))
        except Exception as e:                       # pragma: no cover
            with self._cv:
                self._stats["dispatches"] += 1
                self._stats["errors"] += len(batch)
            for r in batch:
                try:
                    r.future.set_exception(e)
                except Exception:    # already failed by a wedged close()
                    pass
            return
        with self._cv:
            self._stats["dispatches"] += 1
            self._stats["batches"] += 1
            self._stats["batch_size_sum"] += len(batch)
            self._stats["batch_size_max"] = max(
                self._stats["batch_size_max"], len(batch))
            self._stats["batch_hist"][len(batch)] += 1
            self._stats["wait_ms_sum"] += (t0 - batch[0].t_submit) * 1e3
        for j, r in enumerate(batch):
            try:
                r.future.set_result(y[:, j])
            except Exception:        # already failed by a wedged close()
                pass
