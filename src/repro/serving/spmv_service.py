"""Micro-batching SpMV service — the production-hardened operator front-end.

The ROADMAP north star ("serve heavy traffic from millions of users") means
concurrent `y = A @ x` requests against a set of planned operators. This
service coalesces concurrent same-matrix requests into ONE SpMM call

    Y[:, 0..b) = A @ [x_0 | x_1 | ... | x_{b-1}]

so the matrix bytes are paid once per batch — the amortization the k-aware
tuner (core/spmv/tune.py) models — and hardens that front-end for real
traffic along four axes (DESIGN.md "Serving & degradation"):

* **Bounded residency.** Resident operators live in a memory-budgeted LRU
  (`memory_budget_bytes`): device bytes are accounted per operator
  (opcache.operator_nbytes) and the least-recently-used operators are
  evicted past the budget. Eviction drops device arrays ONLY — the plan
  survives in the content-addressed plan store, so an evicted key reloads
  with zero re-tune on its next request.
* **Admission control + QoS.** Per-key (`max_queue`) and global
  (`max_queue_global` requests / `max_queue_bytes` payload bytes) queue
  limits; overload surfaces as TYPED retryable errors (serving/errors.py)
  under one of three policies — `"reject"` (refuse the newcomer with
  `QueueFull.retry_after_ms`), `"shed-oldest"` (fail the oldest queued
  request of the lowest-priority key with `RequestShed` and admit the
  newcomer), `"degrade-to-k1"` (admit, and above the half-full watermark
  the dispatcher stops waiting out batch windows — latency-optimal
  coalescing degrades, possibly to singleton batches, so the backlog
  drains at maximum rate). Keys carry priority classes
  (`register(priority=)`); the dispatcher serves the highest class first
  and sheds from the lowest.
* **Dynamic matrices.** `update_values(key, vals)` swaps values under an
  UNCHANGED structure hash: the plan is kept (`Plan.rebuild` — permute +
  convert under the frozen scheme/engine decision, no replan, no re-tune)
  and the operator is swapped atomically. `update_structure(key, mat)`
  keeps serving the STALE operator while a background thread replans the
  new structure, then swaps matrix + plan + operator atomically; a
  staleness bound (`max_staleness_s`) gates dispatch once exceeded until
  the replan lands.
* **SLO observability.** `stats()` is one self-consistent snapshot (taken
  under the service lock): p50/p95/p99 end-to-end latency from a bounded
  reservoir, throughput, shed/eviction rates, coalesce ratio, resident
  bytes vs budget, and counters that balance —
  requests == results + sheds + errors + pending.

The dispatcher sleeps on genuine condition-variable wakeups (notify on
enqueue / drain / replan) — a quiescent service performs ZERO wakeups
(`stats()["wakeups"]` is the regression counter), where the pre-hardening
dispatcher polled every 50 ms.

Policy (classic micro-batching): requests enqueue per matrix key; the
dispatcher serves the highest-priority class first, and within it the key
whose batch window expired, else a full batch, else the oldest request. A
batch closes at `max_batch` requests or `window_ms` after its oldest
request. Operators resolve once per key through the pipeline facade
(repro.api.plan + Plan.build, persistent plan store) with a
k=max_batch-specialized plan; the service may reorder internally
(`reorder=`) — operators carry their permutation, so requests and
responses stay in the ORIGINAL index space.

Equivalence guarantee: request j of a coalesced batch receives column j of
`op.matmul(X)`, which matches the unbatched `op(x_j)` to fp32 accumulation
tolerance. Tested in tests/test_spmm_batch.py; the hardening invariants in
tests/test_serving_hardened.py; the open-loop load harness is
serving/traffic.py.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future
from typing import Dict, Optional, Tuple

import numpy as np

from .. import obs
from ..core.sparse.csr import CSRMatrix
from ..core.spmv import delta as delta_mod
from ..core.spmv import opcache
from ..core.spmv import plan as plan_mod
from .errors import (BadRequest, KeyBusy, QueueFull, RequestShed,
                     RoutedElsewhere, ServiceClosed, UnregisteredKey)

OVERLOAD_POLICIES = ("reject", "shed-oldest", "degrade-to-k1")

_RESERVOIR_SIZE = 2048
_SERVICE_IDS = itertools.count(1)

# every legacy integer/float counter of SpmvService.stats(); each backs
# onto a process-wide obs counter `service.<key>{service=<sid>}`
_STAT_KEYS = ("requests", "batches", "dispatches", "errors", "results",
              "sheds", "rejected", "batch_size_sum", "batch_size_max",
              "wait_ms_sum", "wakeups", "op_builds", "op_reloads",
              "evictions", "budget_overruns", "value_swaps",
              "replans", "replan_errors")


class _RegistryStats:
    """Dict-like stats view backed by the obs metrics registry.

    Every legacy counter key reads/writes a per-service labelled counter
    in `repro.obs` — `SpmvService.stats()` is therefore a *view* over the
    registry (obs.snapshot() shows the same numbers) while every existing
    `self._stats["x"] += 1` mutation site keeps working verbatim.

    Lock discipline is unchanged: all mutation happens under the
    service's `_cv`, so a `stats()` read under `_cv` is still one atomic
    cut across all counters (the per-metric locks are redundant here but
    harmless). `batch_hist` stays a local Counter — it is a dict-valued
    legacy key, not a scalar metric.
    """

    def __init__(self, sid: str):
        self.sid = sid
        self._c = {k: obs.counter(f"service.{k}", service=sid)
                   for k in _STAT_KEYS}
        self.batch_hist: collections.Counter = collections.Counter()

    def __getitem__(self, key):
        if key == "batch_hist":
            return self.batch_hist
        return self._c[key].value

    def __setitem__(self, key, value):
        if key == "batch_hist":
            self.batch_hist = value
        else:
            self._c[key].set(value)

    def as_dict(self) -> dict:
        d = {k: c.value for k, c in self._c.items()}
        d["batch_hist"] = dict(self.batch_hist)
        return d


@dataclasses.dataclass
class _Request:
    key: str
    x: np.ndarray
    future: Future
    t_submit: float


class _Reservoir:
    """Bounded latency reservoir (Vitter's Algorithm R): a uniform sample
    of all observations in O(size) memory, so p50/p95/p99 stay meaningful
    over unbounded request streams. Deterministic per service (seeded)."""

    def __init__(self, size: int = _RESERVOIR_SIZE, seed: int = 0):
        self.size = int(size)
        self.count = 0
        self._buf: list = []
        self._rng = np.random.default_rng(seed)

    def add(self, value: float) -> None:
        self.count += 1
        if len(self._buf) < self.size:
            self._buf.append(float(value))
        else:
            j = int(self._rng.integers(self.count))
            if j < self.size:
                self._buf[j] = float(value)

    def snapshot(self) -> list:
        return list(self._buf)


def _percentile(sorted_vals: list, q: float) -> float:
    """Nearest-rank percentile of an ascending list (NaN when empty)."""
    if not sorted_vals:
        return float("nan")
    i = max(0, min(len(sorted_vals) - 1,
                   int(np.ceil(q / 100.0 * len(sorted_vals))) - 1))
    return sorted_vals[i]


class SpmvService:
    """Queue + coalesce concurrent (matrix_key, x) requests into SpMM calls.

    Usage:
        svc = SpmvService(max_batch=8, window_ms=2.0,
                          memory_budget_bytes=64 << 20, overload="reject")
        svc.register("mesh", mat, priority=1)
        fut = svc.submit("mesh", x)          # -> concurrent.futures.Future
        y = fut.result()                      # typed errors: serving.errors
        svc.update_values("mesh", new_vals)   # same structure: no replan
        svc.update_structure("mesh", mat2)    # background replan, stale ok
        print(svc.stats()["slo"])             # p50/p95/p99, shed rate, ...
        svc.close()

    Also usable as a context manager (close() on exit).
    """

    # Sharded keys refuse update_values/update_structure on a PLAIN
    # service (RoutedElsewhere): the per-shard replan lifecycle belongs
    # to the multi-shard router, whose per-mesh service flips this.
    _allow_sharded_updates = False

    def __init__(self, engine: str = "auto", max_batch: int = 32,
                 window_ms: float = 2.0, use_kernel: str = "auto",
                 dtype=None, cache: bool = True, probe: bool = False,
                 max_queue: int = 1024, reorder: str = "baseline",
                 topology=None, partition: str = "auto",
                 memory_budget_bytes: Optional[int] = None,
                 overload: str = "reject",
                 max_queue_global: Optional[int] = None,
                 max_queue_bytes: Optional[int] = None,
                 max_staleness_s: Optional[float] = None,
                 reservoir_size: int = _RESERVOIR_SIZE):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if overload not in OVERLOAD_POLICIES:
            raise ValueError(f"overload must be one of {OVERLOAD_POLICIES}, "
                             f"got {overload!r}")
        if memory_budget_bytes is not None and memory_budget_bytes <= 0:
            raise ValueError("memory_budget_bytes must be positive or None")
        self.engine = engine
        self.reorder = reorder
        self.topology = topology
        self.partition = partition
        self.max_batch = int(max_batch)
        self.max_queue = int(max_queue)
        self.max_queue_global = (None if max_queue_global is None
                                 else int(max_queue_global))
        self.max_queue_bytes = (None if max_queue_bytes is None
                                else int(max_queue_bytes))
        self.memory_budget_bytes = (None if memory_budget_bytes is None
                                    else int(memory_budget_bytes))
        self.overload = overload
        self.max_staleness_s = max_staleness_s
        self.window_s = float(window_ms) * 1e-3
        self.use_kernel = use_kernel
        self.cache = cache
        self.probe = probe
        self._dtype = dtype
        self._matrices: Dict[str, CSRMatrix] = {}
        self._schemes: Dict[str, str] = {}
        self._topologies: Dict[str, object] = {}
        self._priorities: Dict[str, int] = {}
        self._gen: collections.Counter = collections.Counter()
        # key -> (gen, operator, nbytes); insertion order IS the LRU order
        # (move_to_end on every touch, evict from the front)
        self._ops: "collections.OrderedDict[str, tuple]" = \
            collections.OrderedDict()
        self._resident_bytes = 0
        self._resident_bytes_max = 0
        # key -> (structure_key, scheme, Plan): the frozen decision the
        # dynamic-matrix path rebuilds from without replanning
        self._plans: Dict[str, tuple] = {}
        self._dirty: Dict[str, bool] = {}   # values diverged from plan store
        self._build_info: Dict[str, dict] = {}
        self._queues: Dict[str, collections.deque] = {}
        self._queued = 0                    # total queued requests
        self._queued_bytes = 0              # total queued payload bytes
        self._cv = threading.Condition()
        self._op_lock = threading.Lock()    # serializes operator builds;
        # ordering discipline: _op_lock may be taken first and _cv inside
        # it, NEVER the reverse
        self._stop = False
        self._inflight = 0                  # dispatching batches
        self._inflight_reqs = 0             # requests inside those batches
        self._key_inflight: collections.Counter = collections.Counter()
        self._current_batch: Optional[list] = None
        self._replan_pending: Dict[str, dict] = {}
        self._replan_q: collections.deque = collections.deque()
        self._replanner: Optional[threading.Thread] = None
        self._latency = _Reservoir(reservoir_size)
        self._t_start = time.monotonic()
        self.sid = f"svc{next(_SERVICE_IDS)}"
        self._stats = _RegistryStats(self.sid)
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="spmv-service-dispatch")
        self._worker.start()

    # -- registry ----------------------------------------------------------
    def register(self, key: str, mat: CSRMatrix,
                 reorder: Optional[str] = None, topology=None,
                 priority: int = 0) -> None:
        """Make `key` servable. Operator build is lazy (first batch).

        reorder overrides the service-wide scheme for this key, topology
        (a repro.api.Topology) overrides the service-wide topology (a
        SHARDED key serves through a ShardedOperator), and priority is
        the key's QoS class: the dispatcher serves higher classes first
        and the shed-oldest policy sheds from the lowest class. Requests
        stay in the original index space either way.

        Re-registering a key drops its memoized operator — but when the
        new matrix has the SAME structure hash, the kept plan makes the
        next resolve a value-swap rebuild, not a replan. Re-registration
        is REFUSED (KeyBusy) while the key has queued/in-flight requests
        or a structure replan in flight — a request validated against
        matrix A must never be answered from matrix B (flush() first)."""
        with self._cv:
            if self._stop:
                raise ServiceClosed("service is closed")
            if key in self._matrices and (self._queues[key]
                                          or self._key_inflight[key]
                                          or key in self._replan_pending):
                raise KeyBusy(
                    f"cannot re-register {key!r} with pending requests; "
                    f"flush() first (or update_values/update_structure)")
            self._matrices[key] = mat
            self._schemes[key] = self.reorder if reorder is None else reorder
            self._topologies[key] = (self.topology if topology is None
                                     else topology)
            self._priorities[key] = int(priority)
            # bumping the generation under _cv invalidates any memoized
            # operator atomically with the matrix swap — operator() only
            # trusts an entry whose generation matches the matrix it read
            self._gen[key] += 1
            self._evict_locked(key)
            hint = self._plans.get(key)
            if hint is not None:
                if (hint[0] == plan_mod.structure_key(mat)
                        and hint[1] == self._schemes[key]):
                    self._dirty[key] = True    # same structure: value swap
                else:
                    del self._plans[key]       # new structure: fresh plan
                    self._dirty.pop(key, None)
            self._queues.setdefault(key, collections.deque())

    # -- operator residency (memory-budgeted LRU) --------------------------
    def _evict_locked(self, key: str) -> None:
        """Drop `key`'s resident operator (if any), adjusting the gauge."""
        ent = self._ops.pop(key, None)
        if ent is not None:
            self._resident_bytes -= ent[2]
            self._sync_lru_gauges_locked()

    def _sync_lru_gauges_locked(self) -> None:
        obs.gauge("service.resident_bytes", service=self.sid).set(
            self._resident_bytes)
        obs.gauge("service.resident_ops", service=self.sid).set(
            len(self._ops))

    def _op_nbytes(self, op) -> int:
        """Bytes an operator is charged against the memory budget.
        The router's per-mesh service overrides this with per-device
        accounting (max device share x devices), so its budget bounds
        EVERY device, not just the global sum."""
        return opcache.operator_nbytes(op)

    def _install_locked(self, key: str, gen: int, op, nbytes: int):
        """Install a freshly built operator under the memory budget:
        evict LRU-first residents until the newcomer fits, so the
        resident-bytes gauge NEVER exceeds the budget. An operator that
        alone exceeds the budget is served transiently (never tracked as
        resident) and counted as a budget overrun."""
        self._evict_locked(key)
        budget = self.memory_budget_bytes
        if budget is not None and nbytes > budget:
            self._stats["evictions"] += 1
            self._stats["budget_overruns"] += 1
            return
        if budget is not None:
            while self._resident_bytes + nbytes > budget and self._ops:
                k2, (_, _, b2) = next(iter(self._ops.items()))
                del self._ops[k2]
                self._resident_bytes -= b2
                self._stats["evictions"] += 1
        self._ops[key] = (gen, op, nbytes)
        self._resident_bytes += nbytes
        self._resident_bytes_max = max(self._resident_bytes_max,
                                       self._resident_bytes)
        self._sync_lru_gauges_locked()

    def operator(self, key: str):
        """Resolve (and memoize, budget permitting) the operator for
        `key` via the pipeline facade, tuned for this service's max batch
        width. An evicted key resolves through the plan store (device
        arrays reload, zero re-tune); a key whose values were swapped
        since its plan was stored rebuilds from the kept plan (format
        conversion only, no replan). The returned operator accepts
        original-index-space vectors."""
        while True:
            with self._cv:
                if key not in self._matrices:
                    raise UnregisteredKey(f"unregistered matrix key {key!r}")
                ent = self._ops.get(key)
                gen = self._gen[key]
                if ent is not None and ent[0] == gen:
                    self._ops.move_to_end(key)
                    return ent[1]
            with self._op_lock:
                with self._cv:
                    ent = self._ops.get(key)
                    gen = self._gen[key]
                    if ent is not None and ent[0] == gen:
                        self._ops.move_to_end(key)
                        return ent[1]
                    mat = self._matrices[key]
                    scheme = self._schemes[key]
                    topology = self._topologies.get(key)
                    hint = self._plans.get(key)
                    dirty = self._dirty.get(key, False)
                op, pl, info = self._build_operator(mat, scheme, topology,
                                                    hint, dirty)
                nb = self._op_nbytes(op)
                with self._cv:
                    if self._gen[key] != gen:
                        continue       # superseded mid-build: resolve again
                    self._plans[key] = (plan_mod.structure_key(mat),
                                        scheme, pl)
                    self._build_info[key] = info
                    self._stats["op_builds"] += 1
                    if info.get("cache_hit"):
                        self._stats["op_reloads"] += 1
                    self._install_locked(key, gen, op, nb)
                    return op

    def _build_operator(self, mat, scheme, topology, hint, dirty):
        """Build outside the service lock. Returns (op, plan, build_info).

        When the key's values have diverged from the plan store (dirty)
        and the kept plan still matches the structure + scheme, rebuild
        under the frozen decision — plan() would otherwise replan from
        scratch because its content key hashes the values. Sharded plans
        take the same shortcut: Plan.rebuild repacks the frozen layout
        (partition, panel split, schedule all kept)."""
        if (dirty and hint is not None
                and hint[0] == plan_mod.structure_key(mat)
                and hint[1] == scheme):
            op = hint[2].rebuild(mat, use_kernel=self.use_kernel)
            return op, hint[2], op.build_info
        from ..api import SpmvProblem, plan as make_plan

        pl = make_plan(
            SpmvProblem(mat, k=self.max_batch, dtype=self._dtype,
                        hints={"use_kernel": self.use_kernel}),
            reorder=scheme, engine=self.engine, probe=self.probe,
            cache=self.cache, topology=topology, partition=self.partition)
        op = pl.build(cache=self.cache)
        return op, pl, op.build_info

    # -- dynamic matrices --------------------------------------------------
    def update_values(self, key: str, vals) -> None:
        """Swap `key`'s numeric values in place — the structure hash is
        unchanged by construction, so the plan is KEPT: the new operator
        is a `Plan.rebuild` (permute + format conversion under the frozen
        scheme/engine decision; zero reorder, zero re-tune, no replan)
        and is swapped in atomically. In-flight batches complete against
        the old values; later dispatches see the new ones."""
        vals = np.asarray(vals)
        with self._cv:
            if self._stop:
                raise ServiceClosed("service is closed")
            if key not in self._matrices:
                raise UnregisteredKey(f"unregistered matrix key {key!r}")
            if (not self._allow_sharded_updates
                    and plan_mod.topology_mod.normalize(
                        self._topologies.get(key)) is not None):
                raise RoutedElsewhere(
                    f"update_values on sharded key {key!r}: per-shard "
                    f"swaps belong to the router — register the key "
                    f"through repro.router.RoutedSpmvService")
            if key in self._replan_pending:
                raise KeyBusy(f"structure replan in flight for {key!r}")
            mat = self._matrices[key]
            if vals.shape != mat.vals.shape:
                raise BadRequest(
                    f"vals for {key!r} must have shape {mat.vals.shape}, "
                    f"got {vals.shape}")
            new_mat = CSRMatrix(rowptr=mat.rowptr, cols=mat.cols,
                                vals=vals.astype(mat.vals.dtype, copy=False),
                                shape=mat.shape)
            gen = self._gen[key] + 1
            self._gen[key] = gen
            self._matrices[key] = new_mat
            self._dirty[key] = True
            hint = self._plans.get(key)
            scheme = self._schemes[key]
        if hint is None or hint[1] != scheme:
            return          # no operator planned yet: first dispatch plans
        # rebuild OUTSIDE the lock — the old operator keeps serving
        op = hint[2].rebuild(new_mat, use_kernel=self.use_kernel)
        nb = self._op_nbytes(op)
        with self._cv:
            if self._gen[key] == gen and not self._stop:
                self._build_info[key] = op.build_info
                self._install_locked(key, gen, op, nb)
                self._stats["value_swaps"] += 1
                self._cv.notify_all()

    def update_structure(self, key: str, mat: Optional[CSRMatrix] = None,
                         staleness_s: Optional[float] = None,
                         delta=None) -> Future:
        """Replace `key`'s matrix with one of a DIFFERENT structure. The
        stale operator keeps serving while a background thread replans
        (reorder + tune on the new structure); matrix, plan and operator
        then swap atomically. Returns a Future resolving to the new
        generation (or the replan error — the stale operator keeps
        serving on failure).

        Either pass the full replacement matrix (`mat=`) or an
        incremental `delta=` (core.spmv.delta.StructureDelta) describing
        the edit against the CURRENT matrix; with a delta the background
        worker first tries `Plan.apply_delta` (reuse the frozen tuning
        decision + permutation, skip reorder and re-tune entirely) and
        only falls back to a full replan when the delta is over the
        churn/bandwidth thresholds (DeltaTooLarge).

        staleness_s (default: the service's max_staleness_s) bounds how
        long the stale operator may keep answering: once exceeded, the
        key's dispatch GATES on the replan instead of serving staler
        results. The matrix shape must be unchanged (queued requests were
        validated against it)."""
        if (mat is None) == (delta is None):
            raise BadRequest("update_structure takes exactly one of "
                             "mat= or delta=")
        with self._cv:
            if self._stop:
                raise ServiceClosed("service is closed")
            if key not in self._matrices:
                raise UnregisteredKey(f"unregistered matrix key {key!r}")
            if (not self._allow_sharded_updates
                    and plan_mod.topology_mod.normalize(
                        self._topologies.get(key)) is not None):
                raise RoutedElsewhere(
                    f"update_structure on sharded key {key!r}: the "
                    f"per-shard replan lifecycle belongs to the router — "
                    f"register the key through "
                    f"repro.router.RoutedSpmvService")
            if key in self._replan_pending:
                raise KeyBusy(f"structure replan already in flight for "
                              f"{key!r}")
            if delta is not None:
                # materialize eagerly so malformed deltas (BadDelta, a
                # ValueError) surface at the call site, not in the Future
                mat = delta.apply_to(self._matrices[key])
            if tuple(mat.shape) != tuple(self._matrices[key].shape):
                raise BadRequest(
                    f"update_structure must keep the shape "
                    f"{tuple(self._matrices[key].shape)}, got "
                    f"{tuple(mat.shape)} (queued x would be malformed)")
            bound = self.max_staleness_s if staleness_s is None \
                else staleness_s
            now = time.monotonic()
            fut: Future = Future()
            self._replan_pending[key] = {
                "mat": mat, "delta": delta, "t_req": now, "future": fut,
                "deadline": (float("inf") if bound is None
                             else now + float(bound)),
            }
            self._replan_q.append(key)
            if self._replanner is None or not self._replanner.is_alive():
                self._replanner = threading.Thread(
                    target=self._replan_loop, daemon=True,
                    name="spmv-service-replan")
                self._replanner.start()
            self._cv.notify_all()
        return fut

    def _replan_loop(self) -> None:
        while True:
            with self._cv:
                while not self._replan_q and not self._stop:
                    self._cv.wait()
                if self._stop:
                    return
                key = self._replan_q.popleft()
                ent = self._replan_pending.get(key)
                if ent is None:
                    continue
                mat, scheme = ent["mat"], self._schemes[key]
                topology = self._topologies.get(key)
                hint = self._plans.get(key)
                dirty = self._dirty.get(key, False)
                delta = ent.get("delta")
                skey_cur = plan_mod.structure_key(self._matrices[key])
            try:
                with obs.span("serve.replan", key=key,
                              delta=delta is not None):
                    op = pl = info = None
                    if (delta is not None and hint is not None
                            and hint[1] == scheme and hint[0] == skey_cur):
                        # incremental path: keep the frozen tuning
                        # decision + perm, skip reorder/tune entirely;
                        # refuse -> full replan below
                        try:
                            pl = hint[2].apply_delta(delta)
                            op = (pl.rebuild(mat,
                                             use_kernel=self.use_kernel)
                                  if dirty else pl.build(cache=self.cache))
                            info = op.build_info
                        except delta_mod.DeltaTooLarge:
                            op = pl = info = None
                    if op is None:
                        op, pl, info = self._build_operator(
                            mat, scheme, topology, None, False)
                    nb = self._op_nbytes(op)
            except Exception as e:
                with self._cv:
                    if self._replan_pending.get(key) is ent:
                        del self._replan_pending[key]
                    self._stats["replan_errors"] += 1
                    self._cv.notify_all()
                ent["future"].set_exception(e)
                continue
            with self._cv:
                ok = (not self._stop and key in self._matrices
                      and self._replan_pending.get(key) is ent)
                if ok:
                    gen = self._gen[key] + 1
                    self._gen[key] = gen
                    self._matrices[key] = mat
                    self._plans[key] = (plan_mod.structure_key(mat),
                                        scheme, pl)
                    self._dirty[key] = False
                    self._build_info[key] = info
                    self._install_locked(key, gen, op, nb)
                    del self._replan_pending[key]
                    self._stats["replans"] += 1
                    self._cv.notify_all()
            if ok:
                ent["future"].set_result(gen)
            else:
                ent["future"].set_exception(
                    ServiceClosed("service closed before replan landed"))

    # -- request path ------------------------------------------------------
    def _retry_after_ms_locked(self) -> float:
        """Backlog drain-time estimate: batches queued x batch window."""
        window = max(self.window_s * 1e3, 0.5)
        return window * (1.0 + self._queued / max(self.max_batch, 1))

    def _over_limit_locked(self, key: str,
                           nbytes: int) -> Optional[Tuple[str, str]]:
        """(reason, scope) of the first violated admission limit, or
        None. scope is "key" (only shedding from `key`'s own queue can
        relieve it) or "global"."""
        if len(self._queues[key]) >= self.max_queue:
            return (f"queue for {key!r} is full ({self.max_queue} "
                    f"pending)", "key")
        if (self.max_queue_global is not None
                and self._queued >= self.max_queue_global):
            return (f"global queue is full ({self.max_queue_global} "
                    f"pending)", "global")
        if (self.max_queue_bytes is not None and self._queued
                and self._queued_bytes + nbytes > self.max_queue_bytes):
            return (f"global queue payload is full "
                    f"({self._queued_bytes} of {self.max_queue_bytes} B)",
                    "global")
        return None

    def _shed_oldest_locked(self, incoming_key: str, scope: str) -> bool:
        """Fail one queued request with RequestShed to make room. The
        victim is scoped to the violated limit: a full PER-KEY queue can
        only be relieved from that key's own queue (oldest first —
        classic drop-oldest; shedding other keys would drain unrelated
        work without freeing a slot), a GLOBAL limit from the oldest
        request of the lowest-priority key. Returns False when nothing
        may be shed (every eligible request outranks the newcomer)."""
        victim_key, victim_prio = None, None
        candidates = ([incoming_key] if scope == "key"
                      else list(self._queues))
        for k in candidates:
            q = self._queues[k]
            if not q:
                continue
            p = self._priorities.get(k, 0)
            if victim_prio is None or p < victim_prio or \
                    (p == victim_prio
                     and q[0].t_submit < self._queues[victim_key][0].t_submit):
                victim_key, victim_prio = k, p
        if victim_key is None \
                or victim_prio > self._priorities.get(incoming_key, 0):
            return False
        r = self._queues[victim_key].popleft()
        self._queued -= 1
        self._queued_bytes -= r.x.nbytes
        self._stats["sheds"] += 1
        try:
            r.future.set_exception(RequestShed(
                f"shed to admit newer work (overload policy shed-oldest)",
                retry_after_ms=self._retry_after_ms_locked()))
        except Exception:       # already failed by a wedged close()
            pass
        return True

    def submit(self, key: str, x) -> Future:
        """Enqueue one y = A_key @ x request; returns a Future of np [m].

        Raises (serving/errors.py — all keep their legacy builtin bases):
          ServiceClosed    after close()
          UnregisteredKey  unknown key
          BadRequest       x has the wrong shape
          QueueFull        admission refused (retryable; retry_after_ms)
        Under overload="shed-oldest" the newcomer is admitted and the
        oldest lowest-priority queued request fails with RequestShed."""
        x = np.asarray(x)
        with obs.span("serve.submit", key=key), self._cv:
            if self._stop:
                raise ServiceClosed("service is closed")
            if key not in self._matrices:
                raise UnregisteredKey(f"unregistered matrix key {key!r}")
            n = self._matrices[key].shape[1]
            # reject malformed requests HERE: a bad x inside a coalesced
            # batch would otherwise fail every well-formed neighbour
            if x.shape != (n,):
                raise BadRequest(
                    f"x for {key!r} must have shape ({n},), got {x.shape}")
            # admission control: bounded queues — shed or reject loudly
            # instead of letting a fast producer grow pending vectors
            # unboundedly
            limit = self._over_limit_locked(key, x.nbytes)
            while limit is not None and self.overload == "shed-oldest":
                if not self._shed_oldest_locked(key, limit[1]):
                    break
                limit = self._over_limit_locked(key, x.nbytes)
            if limit is not None:
                self._stats["rejected"] += 1
                raise QueueFull(
                    f"backpressure: {limit[0]}",
                    retry_after_ms=self._retry_after_ms_locked())
            fut: Future = Future()
            self._queues[key].append(
                _Request(key, x, fut, time.monotonic()))
            self._queued += 1
            self._queued_bytes += x.nbytes
            self._stats["requests"] += 1
            self._cv.notify_all()
        return fut

    def flush(self, timeout: float = 60.0) -> None:
        """Block until every queued request has been dispatched & resolved.
        Event-driven: woken by the dispatcher's drain notifies, no
        polling loop."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while any(self._queues.values()) or self._inflight:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError("flush timed out")
                self._cv.wait(remaining)

    def close(self, timeout: float = 60.0) -> None:
        """Drain outstanding work (up to timeout), then stop the
        dispatcher and replanner. The service ALWAYS stops — if draining
        times out the TimeoutError is re-raised after shutdown, never
        before it — and any request still queued (or stuck in a wedged
        dispatch) gets its Future failed with ServiceClosed, so no caller
        blocked in result() hangs forever."""
        err = None
        try:
            self.flush(timeout=timeout)
        except TimeoutError as e:
            err = e
        with self._cv:
            self._stop = True
            leftovers = [r for q in self._queues.values() for r in q]
            dropped = len(leftovers)
            for q in self._queues.values():
                q.clear()
            self._queued = 0
            self._queued_bytes = 0
            self._stats["errors"] += dropped
            pending_replans = list(self._replan_pending.values())
            self._replan_pending.clear()
            self._replan_q.clear()
            self._cv.notify_all()
        self._worker.join(timeout=10.0)
        if self._replanner is not None:
            self._replanner.join(timeout=10.0)
        if self._worker.is_alive():
            # dispatch wedged in device code: fail its batch best-effort
            # (the zombie daemon thread's late set_result is swallowed by
            # _dispatch's InvalidStateError guard)
            with self._cv:
                wedged = list(self._current_batch or [])
                self._stats["errors"] += len(wedged)
                leftovers.extend(wedged)
        for r in leftovers:
            if not r.future.done():
                r.future.set_exception(
                    ServiceClosed("service closed before dispatch"))
        for ent in pending_replans:
            if not ent["future"].done():
                ent["future"].set_exception(
                    ServiceClosed("service closed before replan landed"))
        if err is not None:
            raise err

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- observability -----------------------------------------------------
    def stats(self) -> dict:
        """One self-consistent snapshot: every counter, gauge and the
        latency reservoir are read under a single lock acquisition, so
        the invariant requests == results + sheds + errors + pending
        holds in ANY snapshot, not just at quiescence.

        Since the obs layer landed this is a VIEW over the process-wide
        metrics registry: each legacy key reads the per-service counter
        `service.<key>{service=<sid>}` that obs.snapshot() also reports
        (all mutation still happens under `_cv`, preserving snapshot
        atomicity)."""
        with self._cv:
            s = self._stats.as_dict()
            s["queued"] = self._queued
            s["queued_bytes"] = self._queued_bytes
            s["inflight_requests"] = self._inflight_reqs
            s["pending"] = self._queued + self._inflight_reqs
            s["resident_bytes"] = self._resident_bytes
            s["resident_bytes_max"] = self._resident_bytes_max
            s["resident_ops"] = len(self._ops)
            s["memory_budget_bytes"] = self.memory_budget_bytes
            s["replans_pending"] = len(self._replan_pending)
            op_hits = {k: v["cache_hit"] for k, v in self._build_info.items()}
            lat = sorted(self._latency.snapshot())
            lat_count = self._latency.count
            elapsed = max(time.monotonic() - self._t_start, 1e-9)
        b = max(s["batches"], 1)
        s["avg_batch"] = s["batch_size_sum"] / b       # dispatched reqs/batch
        s["avg_wait_ms"] = s["wait_ms_sum"] / b
        # DISPATCHED requests per dispatch (error batches included) — the
        # amortization the service exists for; computed from completed
        # work only, so a mid-stream snapshot is not inflated by requests
        # still sitting in the queues
        s["coalesce_ratio"] = (s["batch_size_sum"] + s["errors"]) \
            / max(s["dispatches"], 1)
        s["op_cache_hits"] = op_hits
        s["slo"] = {
            "p50_ms": _percentile(lat, 50.0),
            "p95_ms": _percentile(lat, 95.0),
            "p99_ms": _percentile(lat, 99.0),
            "latency_samples": lat_count,
            "throughput_rps": s["results"] / elapsed,
            "shed_rate": s["sheds"] / max(s["requests"], 1),
            "reject_rate": s["rejected"] / max(s["requests"]
                                               + s["rejected"], 1),
            "eviction_rate": s["evictions"] / max(s["op_builds"], 1),
            "coalesce_ratio": s["coalesce_ratio"],
        }
        return s

    # -- dispatcher --------------------------------------------------------
    def _gated_locked(self, key: str, now: float) -> bool:
        """True when `key` must not dispatch: its structure replan has
        exceeded the staleness bound, so serving the stale operator any
        longer would violate it. The replanner's completion notify lifts
        the gate (replan failure also lifts it — best-effort bound)."""
        ent = self._replan_pending.get(key)
        return ent is not None and now > ent["deadline"]

    def _drain_locked(self) -> bool:
        """degrade-to-k1 overload mode: above the half-full watermark the
        dispatcher stops waiting out batch windows and drains whatever is
        queued immediately (coalescing degrades, possibly to k=1)."""
        if self.overload != "degrade-to-k1":
            return False
        if (self.max_queue_global is not None
                and self._queued >= max(1, self.max_queue_global // 2)):
            return True
        wm = max(1, self.max_queue // 2)
        return any(len(q) >= wm for q in self._queues.values())

    def _pick_key(self) -> Optional[str]:
        """Next key to serve (None if nothing is dispatchable).

        QoS first: only the highest-priority class with pending requests
        is considered (strict classes — shedding policies, not the
        scheduler, protect low classes under sustained load). Within the
        class: (1) the oldest request whose batch window already expired
        — the latency bound always wins; (2) any key with a FULL batch
        ready; (3) the oldest pending request. Staleness-gated keys are
        skipped entirely (their replan notify re-wakes the dispatcher).
        """
        now = time.monotonic()
        cands = []                    # (prio, t_oldest, full, key)
        for key, q in self._queues.items():
            if not q or self._gated_locked(key, now):
                continue
            cands.append((self._priorities.get(key, 0), q[0].t_submit,
                          len(q) >= self.max_batch, key))
        if not cands:
            return None
        top = max(c[0] for c in cands)
        cands = [c for c in cands if c[0] == top]
        expired = [c for c in cands if now >= c[1] + self.window_s]
        pool = expired or [c for c in cands if c[2]] or cands
        return min(pool, key=lambda c: c[1])[3]

    def _run(self) -> None:
        while True:
            with self._cv:
                key = self._pick_key()
                while key is None and not self._stop:
                    # pure condition-variable sleep: a quiescent service
                    # performs ZERO wakeups (tests assert on the counter);
                    # submit/update/replan/close all notify
                    self._cv.wait()
                    self._stats["wakeups"] += 1
                    key = self._pick_key()
                if key is None and self._stop:
                    return
                # batch window: wait for more same-key arrivals, bounded by
                # the oldest request's deadline and the batch size cap —
                # re-evaluating the pick each wake so a key that becomes
                # dispatchable (full batch / expired window) preempts. The
                # wait is EXACTLY the remaining window (no poll cap): each
                # wake is an enqueue notify or the single deadline expiry.
                q = self._queues[key]
                deadline = q[0].t_submit + self.window_s if q else 0.0
                while (q and len(q) < self.max_batch and not self._stop
                       and not self._drain_locked()
                       and time.monotonic() < deadline):
                    self._cv.wait(max(deadline - time.monotonic(), 1e-4))
                    self._stats["wakeups"] += 1
                    nk = self._pick_key()
                    if nk is None:
                        q = self._queues[key]   # emptied externally
                        break
                    if nk != key:
                        key = nk
                    q = self._queues[key]
                    deadline = q[0].t_submit + self.window_s if q else 0.0
                batch = [q.popleft()
                         for _ in range(min(self.max_batch, len(q)))]
                # defensive: the queue can be emptied externally while we
                # waited (forced shutdown paths clear it under _cv)
                if not batch:
                    continue
                self._queued -= len(batch)
                self._queued_bytes -= sum(r.x.nbytes for r in batch)
                self._inflight += 1
                self._inflight_reqs += len(batch)
                self._key_inflight[key] += 1
                self._current_batch = batch
            try:
                self._dispatch(key, batch)
            finally:
                with self._cv:
                    self._inflight -= 1
                    self._inflight_reqs -= len(batch)
                    self._key_inflight[key] -= 1
                    self._current_batch = None
                    self._cv.notify_all()

    def _dispatch(self, key: str, batch: list) -> None:
        import jax.numpy as jnp

        t0 = time.monotonic()
        try:
            with obs.span("serve.dispatch", key=key,
                          batch_size=len(batch)):
                op = self.operator(key)
                dt = jnp.float32 if self._dtype is None else self._dtype
                with obs.span("serve.execute", key=key,
                              batch_size=len(batch)):
                    if len(batch) == 1:
                        # a lone request takes the SpMV path: matmul's
                        # k-tile padding would do tile-width times the
                        # work for 1 column
                        y = np.asarray(
                            op(jnp.asarray(batch[0].x, dt)))[:, None]
                    else:
                        # assemble on host, ONE device put per batch
                        x_block = jnp.asarray(
                            np.stack([r.x for r in batch], axis=1), dt)
                        y = np.asarray(op.matmul(x_block))
        except Exception as e:                       # pragma: no cover
            with self._cv:
                self._stats["dispatches"] += 1
                self._stats["errors"] += len(batch)
            for r in batch:
                try:
                    r.future.set_exception(e)
                except Exception:    # already failed by a wedged close()
                    pass
            return
        done = time.monotonic()
        with self._cv:
            self._stats["dispatches"] += 1
            self._stats["batches"] += 1
            self._stats["batch_size_sum"] += len(batch)
            self._stats["batch_size_max"] = max(
                self._stats["batch_size_max"], len(batch))
            self._stats["batch_hist"][len(batch)] += 1
            self._stats["wait_ms_sum"] += (t0 - batch[0].t_submit) * 1e3
            self._stats["results"] += len(batch)
            for r in batch:
                self._latency.add((done - r.t_submit) * 1e3)
        for j, r in enumerate(batch):
            try:
                r.future.set_result(y[:, j])
            except Exception:        # already failed by a wedged close()
                pass
