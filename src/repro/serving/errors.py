"""Typed serving exceptions — callers must be able to tell retryable
overload apart from programming errors.

Every class keeps the pre-hardening builtin it replaces as a BASE, so
existing `except RuntimeError` / `except KeyError` / `except ValueError`
call sites (and tests) keep working unchanged:

    ServiceError                      common base (mix-in, never raised)
      ServiceClosed (RuntimeError)    submit()/update after close()
      QueueFull     (RuntimeError)    admission refused — RETRYABLE; carries
                                      retry_after_ms (drain-time estimate)
        RequestShed (QueueFull)       an ADMITTED request was shed by the
                                      shed-oldest overload policy — same
                                      retryable contract, delivered through
                                      the request's Future
      KeyBusy       (RuntimeError)    register() on a key with pending work
      UnregisteredKey (KeyError)      submit()/update on an unknown key
      BadRequest    (ValueError)      malformed x / vals / matrix argument
        RoutedElsewhere (BadRequest)  a sharded-key update on a PLAIN
                                      SpmvService — the multi-shard
                                      router (repro.router) owns that
                                      lifecycle

Retry discipline: `isinstance(e, QueueFull)` (which covers RequestShed)
means "back off retry_after_ms and resend the same request"; everything
else is terminal for that request.
"""
from __future__ import annotations


class ServiceError(Exception):
    """Mix-in base for every typed serving error."""


class ServiceClosed(ServiceError, RuntimeError):
    """The service has been close()d; no further work is accepted."""


class QueueFull(ServiceError, RuntimeError):
    """Admission control refused the request (overload) — retryable.

    retry_after_ms is the service's estimate of when capacity frees up
    (queue depth over dispatch rate, floored at one batch window).
    """

    def __init__(self, msg: str, retry_after_ms: float = 0.0):
        super().__init__(msg)
        self.retry_after_ms = float(retry_after_ms)


class RequestShed(QueueFull):
    """An admitted request was evicted by the shed-oldest policy to make
    room for newer work. Delivered through the shed request's Future."""


class KeyBusy(ServiceError, RuntimeError):
    """register() refused: the key has queued or in-flight requests."""


class UnregisteredKey(ServiceError, KeyError):
    """The request names a matrix key that was never register()ed."""

    def __str__(self) -> str:  # KeyError.__str__ repr()s the message
        return self.args[0] if self.args else ""


class BadRequest(ServiceError, ValueError):
    """Malformed request payload (wrong shape/nnz/dtype) — a programming
    error at the call site, never retryable."""


class RoutedElsewhere(BadRequest):
    """update_values/update_structure on a SHARDED key of a plain
    SpmvService: the per-shard replan lifecycle (generation-tagged swap
    per shard, siblings keep serving) lives in the multi-shard router —
    register the key through repro.router.RoutedSpmvService instead.
    Subclasses BadRequest, so pre-router `except ValueError` /
    `except BadRequest` call sites keep working unchanged."""
