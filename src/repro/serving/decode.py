"""Serving: serve_step (one decode token for a batch over a KV/state cache)
and a simple batched greedy generation loop.

serve_step is the function the decode_32k / long_500k dry-run cells lower:
one new token against a cache of `seq_len` (DESIGN.md §5)."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import model as MDL


def make_serve_step(cfg: ModelConfig, mesh=None, dp_axes=("data",),
                    compute_dtype=jnp.bfloat16, constrain_weights=False):
    """Returns serve_step(params, batch, cache) -> (next_tokens, cache).

    constrain_weights=False: serving keeps weights wherever the caller
    sharded them (weight-stationary TP) — re-constraining to the training
    FSDP spec inside the layer scan would reshard every layer."""

    def serve_step(params, batch, cache):
        params_c = jax.tree_util.tree_map(
            lambda t: t.astype(compute_dtype)
            if jnp.issubdtype(t.dtype, jnp.floating) else t, params)
        logits, new_cache, _ = MDL.forward(params_c, batch, cfg, mesh=mesh,
                                           dp_axes=dp_axes, cache=cache,
                                           train=False,
                                           constrain_weights=constrain_weights)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tokens, new_cache

    return serve_step


def generate(cfg: ModelConfig, params, prompt_tokens, max_new: int,
             cache_len: int, image_embeds=None):
    """Greedy generation (CPU example path): token-by-token prefill then
    decode — exercises the same cache code the dry-run lowers."""
    b, s = prompt_tokens.shape
    cache = MDL.init_cache(cfg, b, cache_len, dtype=jnp.float32)
    step = jax.jit(make_serve_step(cfg, compute_dtype=jnp.float32))
    tok = None
    for t in range(s):
        batch = {"tokens": prompt_tokens[:, t:t + 1]}
        if image_embeds is not None:
            batch["image_embeds"] = image_embeds
        tok, cache = step(params, batch, cache)
    out = [tok]
    for _ in range(max_new - 1):
        batch = {"tokens": out[-1][:, None]}
        if image_embeds is not None:
            batch["image_embeds"] = image_embeds
        tok, cache = step(params, batch, cache)
        out.append(tok)
    return jnp.stack(out, axis=1)
