"""Open-loop traffic simulator for SpmvService — SLO-vs-load curves.

The paper's amortization argument (reorder/tune cost is only worth paying
over many executions) becomes measurable only under traffic: this module
generates OPEN-LOOP request streams — arrivals fire on their own schedule
whether or not the service keeps up, which is what makes overload visible
(a closed loop self-throttles and can never push the service past
capacity) — and drives a service instance, classifying every outcome.

Three pieces:

  * TrafficPattern      — declarative load shape: arrival process
                          (poisson / uniform / bursty), offered rate,
                          request count, Zipf hot-key skew over n_keys,
                          and a value-update mix (update_frac of arrivals
                          are update_values calls, exercising the
                          no-replan value-swap path under load).
  * arrival_times / zipf_keys / update_mask
                        — the deterministic (seeded) schedule pieces,
                          unit-testable without a service.
  * run_open_loop(svc, mats, pattern)
                        — drive a service, resolve EVERY future, return a
                          summary: outcome counts (ok / shed / rejected /
                          errors / unresolved), achieved vs offered rate,
                          budget compliance, and the service's stats()
                          snapshot. `unresolved` > 0 means a Future never
                          resolved — the invariant the soak test asserts
                          to zero.

The `"serve"` experiment cell kind (experiments/cells.py) wraps this so
SLO-vs-load curves flow through ExperimentSpec → ResultStore → Report
like every other measurement; `benchmarks/run.py --smoke-serve` is the
CI-sized campaign.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Dict, Optional

import numpy as np

from ..core.sparse.csr import CSRMatrix
from .errors import KeyBusy, QueueFull, RequestShed

ARRIVALS = ("poisson", "uniform", "bursty")


@dataclasses.dataclass(frozen=True)
class TrafficPattern:
    """One load shape, fully deterministic given `seed`.

    arrival      — "poisson" (memoryless, the open-loop default),
                   "uniform" (evenly spaced — isolates queueing from
                   arrival variance), or "bursty" (on/off modulated
                   Poisson: burst_factor x the mean rate for burst_duty
                   of each burst_period, starved in between — same mean
                   rate, much worse tail).
    rate_rps     — MEAN offered arrival rate, requests per second.
    requests     — total arrivals (submits + value updates).
    n_keys       — distinct matrix keys; requests pick keys Zipf(zipf_s)
                   -skewed (key 0 hottest). More keys than the memory
                   budget fits is the LRU-thrash scenario.
    zipf_s       — Zipf exponent (0 = uniform over keys).
    update_frac  — fraction of arrivals that are update_values() calls
                   instead of submits (the dynamic-values mix).
    structure_frac — fraction of arrivals that are update_structure()
                   calls carrying a small deletion-only StructureDelta
                   (always churn/bandwidth-legal, so the delta-apply
                   path — not the full-replan fallback — is what soaks).
                   Takes precedence over update_frac on an arrival
                   masked by both. The mid-soak replan scenario the
                   router's sibling-p99 assert runs on.
    """

    arrival: str = "poisson"
    rate_rps: float = 200.0
    requests: int = 200
    n_keys: int = 1
    zipf_s: float = 1.1
    update_frac: float = 0.0
    structure_frac: float = 0.0
    burst_factor: float = 4.0
    burst_duty: float = 0.2
    burst_period_s: float = 0.05
    seed: int = 0

    def __post_init__(self):
        if self.arrival not in ARRIVALS:
            raise ValueError(f"arrival must be one of {ARRIVALS}, "
                             f"got {self.arrival!r}")
        if self.rate_rps <= 0 or self.requests < 1 or self.n_keys < 1:
            raise ValueError("rate_rps must be > 0, requests and n_keys "
                             ">= 1")
        if not 0.0 <= self.update_frac < 1.0:
            raise ValueError("update_frac must be in [0, 1)")
        if not 0.0 <= self.structure_frac < 1.0:
            raise ValueError("structure_frac must be in [0, 1)")
        if not (self.burst_factor > 1.0 and 0.0 < self.burst_duty < 1.0
                and self.burst_period_s > 0.0):
            raise ValueError("burst_factor must be > 1, burst_duty in "
                             "(0, 1), burst_period_s > 0")


def arrival_times(pattern: TrafficPattern) -> np.ndarray:
    """Offsets (seconds, ascending, starting after 0) of each arrival."""
    rng = np.random.default_rng(pattern.seed)
    n, rate = pattern.requests, pattern.rate_rps
    if pattern.arrival == "uniform":
        return (np.arange(1, n + 1) / rate).astype(np.float64)
    if pattern.arrival == "poisson":
        return np.cumsum(rng.exponential(1.0 / rate, size=n))
    # bursty: piecewise-Poisson, rate modulated by an on/off square wave
    # with the SAME mean rate (lo = (1 - duty*factor)/(1 - duty) * rate,
    # floored at 1% of rate — with the defaults duty*factor <= 1 so the
    # floor never engages and the mean is exact). Generated by
    # Lewis-Shedler thinning: candidate gaps at the hi rate, accepted
    # with probability r(t)/hi — naively sampling at the CURRENT phase
    # rate is wrong (a long off-phase gap leaps over later bursts
    # entirely, collapsing the realized mean rate).
    duty, factor, period = (pattern.burst_duty, pattern.burst_factor,
                            pattern.burst_period_s)
    hi = rate * factor
    lo = max(rate * (1.0 - duty * factor) / max(1.0 - duty, 1e-9),
             rate * 0.01)
    out = np.empty(n, np.float64)
    t = 0.0
    for i in range(n):
        while True:
            t += float(rng.exponential(1.0 / hi))
            r = hi if (t % period) / period < duty else lo
            if rng.random() * hi < r:
                break
        out[i] = t
    return out


def zipf_keys(pattern: TrafficPattern) -> np.ndarray:
    """Key index per arrival, Zipf(zipf_s)-skewed (index 0 hottest)."""
    rng = np.random.default_rng(pattern.seed + 1)
    w = 1.0 / np.arange(1, pattern.n_keys + 1) ** pattern.zipf_s
    return rng.choice(pattern.n_keys, size=pattern.requests, p=w / w.sum())


def update_mask(pattern: TrafficPattern) -> np.ndarray:
    """Boolean per arrival: True = update_values() instead of submit()."""
    rng = np.random.default_rng(pattern.seed + 2)
    return rng.random(pattern.requests) < pattern.update_frac


def structure_mask(pattern: TrafficPattern) -> np.ndarray:
    """Boolean per arrival: True = update_structure() with a small
    deletion delta. Wins over update_mask on a doubly masked arrival."""
    rng = np.random.default_rng(pattern.seed + 4)
    return rng.random(pattern.requests) < pattern.structure_frac


def _deletion_delta(mat: CSRMatrix, rng, frac: float = 0.005):
    """A small always-legal StructureDelta: delete ~frac of the entries
    (floored at 1). Deletions never grow bandwidth and the churn stays
    far under delta.MAX_CHURN, so Plan.apply_delta accepts it."""
    from ..core.spmv.delta import StructureDelta

    nnz = mat.nnz
    k = max(1, int(round(frac * nnz)))
    pick = np.sort(rng.choice(nnz, size=min(k, nnz), replace=False))
    rows = np.repeat(np.arange(mat.shape[0], dtype=np.int64),
                     np.diff(mat.rowptr.astype(np.int64)))
    return StructureDelta(del_rows=rows[pick],
                          del_cols=mat.cols.astype(np.int64)[pick])


def run_open_loop(svc, mats: Dict[str, CSRMatrix],
                  pattern: TrafficPattern,
                  result_timeout_s: float = 60.0,
                  speedup: float = 1.0, prewarm: bool = True) -> dict:
    """Drive `svc` with the pattern over the (already registered) keys in
    `mats`, open-loop: each arrival fires at its scheduled offset whether
    or not earlier requests completed (late = fire immediately, never
    skipped). Every submitted Future is then resolved and classified.

    prewarm resolves every key's operator BEFORE the clock starts (the
    production warm-up step): the stream then measures steady-state
    serving, not first-build latency, and value updates hit existing
    plans (the eager no-replan swap path) instead of keys that were
    never planned. Under a memory budget the prewarm itself already
    exercises LRU eviction. speedup > 1 compresses the schedule (CI
    knob: same arrival sequence, shorter wall time). Returns the summary
    dict (see module docstring); `svc` is NOT closed — the caller owns
    its lifecycle (flush() before reading svc.stats() if quiescent
    counters are wanted).
    """
    if len(mats) < pattern.n_keys:
        raise ValueError(f"pattern wants {pattern.n_keys} keys, "
                         f"got {len(mats)} matrices")
    keys = list(mats)[:pattern.n_keys]
    if prewarm:
        for k in keys:
            svc.operator(k)
    rng = np.random.default_rng(pattern.seed + 3)
    xs = {k: rng.standard_normal(mats[k].shape[1]) for k in keys}

    times = arrival_times(pattern) / float(speedup)
    kidx = zipf_keys(pattern)
    is_update = update_mask(pattern)
    is_structure = structure_mask(pattern)
    cur = dict(mats)          # tracks structure as deltas land
    drng = np.random.default_rng(pattern.seed + 5)

    futures = []
    replan_futures = []
    submitted = rejected = updates = update_conflicts = update_errors = 0
    structure_updates = structure_conflicts = structure_errors = 0
    retry_after_positive = True
    t0 = time.monotonic()
    for i in range(pattern.requests):
        delay = t0 + times[i] - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        key = keys[kidx[i]]
        if is_structure[i]:
            try:
                d = _deletion_delta(cur[key], drng)
                replan_futures.append(
                    svc.update_structure(key, delta=d))
                cur[key] = d.apply_to(cur[key])
                structure_updates += 1
            except KeyBusy:
                structure_conflicts += 1   # replan already in flight
            except Exception:
                structure_errors += 1
        elif is_update[i]:
            try:
                svc.update_values(key, cur[key].vals * (1.0 + 0.01 * i))
                updates += 1
            except KeyBusy:
                update_conflicts += 1   # replan in flight: benign race
            except Exception:
                update_errors += 1
        else:
            try:
                futures.append(svc.submit(key, xs[key]))
                submitted += 1
            except QueueFull as e:
                rejected += 1
                if e.retry_after_ms <= 0:
                    retry_after_positive = False
    wall_submit_s = time.monotonic() - t0

    ok = shed = errors = unresolved = 0
    for fut in futures:
        try:
            fut.result(timeout=result_timeout_s)
            ok += 1
        except RequestShed:
            shed += 1
        except FutureTimeout:
            unresolved += 1             # the no-silent-drops violation
        except Exception:
            errors += 1
    replans_landed = replan_errors = replan_unresolved = 0
    for fut in replan_futures:
        try:
            fut.result(timeout=result_timeout_s)
            replans_landed += 1
        except FutureTimeout:
            replan_unresolved += 1
        except Exception:
            replan_errors += 1
    wall_s = time.monotonic() - t0

    stats = svc.stats()
    budget = stats.get("memory_budget_bytes")
    budget_ok = (budget is None
                 or stats.get("resident_bytes_max", 0) <= budget)
    if "per_device_ok" in stats:        # routed fleet: per-device verdict
        budget_ok = budget_ok and bool(stats["per_device_ok"])
    return {
        "pattern": dataclasses.asdict(pattern),
        "offered": int(pattern.requests),
        "submitted": int(submitted),
        "ok": int(ok),
        "shed": int(shed),
        "rejected": int(rejected),
        "errors": int(errors),
        "unresolved": int(unresolved),
        "updates": int(updates),
        "update_conflicts": int(update_conflicts),
        "update_errors": int(update_errors),
        "structure_updates": int(structure_updates),
        "structure_conflicts": int(structure_conflicts),
        "structure_errors": int(structure_errors),
        "replans_landed": int(replans_landed),
        "replan_errors": int(replan_errors),
        "replan_unresolved": int(replan_unresolved),
        "retry_after_positive": bool(retry_after_positive),
        "offered_rps": pattern.requests / max(wall_submit_s, 1e-9),
        "achieved_rps": ok / max(wall_s, 1e-9),
        "wall_s": float(wall_s),
        "budget_ok": bool(budget_ok),
        "stats": stats,
    }
