"""Train-step factory: builds the pjit'd step for an (arch, shape, mesh).

make_train_step returns (step_fn, state_shardings, batch_shardings):
  state = {params, opt}   — params FSDP x TP sharded (distributed/sharding),
  step(state, batch) -> (state, metrics)

Features: mixed precision (bf16 compute / f32 master+Adam), microbatched
gradient accumulation (lax.scan over microbatches), optional bf16 gradient
compression for the cross-pod all-reduce (distributed/compression), remat
inside the model scan.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..distributed import sharding as SH
from ..models import model as MDL
from . import optimizer as OPT


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda t: t.astype(dtype) if jnp.issubdtype(t.dtype, jnp.floating) else t,
        tree)


def make_train_step(cfg: ModelConfig, opt_cfg: OPT.OptConfig, mesh: Mesh,
                    dp_axes: Tuple[str, ...] = ("data",),
                    microbatches: int = 1,
                    compute_dtype=jnp.bfloat16,
                    grad_compression: Optional[str] = None):
    """Returns (step_fn, make_state_shardings, batch_spec)."""

    def loss_for(params_c, batch):
        return MDL.loss_fn(params_c, batch, cfg, mesh=mesh, dp_axes=dp_axes,
                           train=True)

    def _constrain_like_params(tree, params):
        """Pin gradient-accumulator sharding to the param sharding (without
        this the compiler may replicate the f32 accumulators — hundreds of
        GiB for multi-B-param models)."""
        specs = SH.validate_specs(params, SH.param_specs(params), mesh)
        return jax.tree_util.tree_map(
            lambda t, sp: jax.lax.with_sharding_constraint(
                t, NamedSharding(mesh, sp)), tree, specs)

    def step(state, batch):
        params = state["params"]
        params_c = cast_tree(params, compute_dtype)

        if microbatches > 1:
            def micro(carry, mb):
                gacc, lacc = carry
                (l, metrics), g = jax.value_and_grad(loss_for, has_aux=True)(
                    params_c, mb)
                g = _constrain_like_params(g, params_c)
                gacc = jax.tree_util.tree_map(jnp.add, gacc, g)
                gacc = _constrain_like_params(gacc, params_c)
                return (gacc, lacc + l), metrics
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params_c)
            zeros = _constrain_like_params(zeros, params_c)

            def to_micro(t):
                t = t.reshape(microbatches, t.shape[0] // microbatches,
                              *t.shape[1:])
                # keep the PER-MICROBATCH batch dim sharded over dp — the
                # reshape otherwise drops batch sharding and every
                # activation downstream replicates across the data axis.
                spec = P(None, dp_axes, *([None] * (t.ndim - 2)))
                return jax.lax.with_sharding_constraint(
                    t, NamedSharding(mesh, spec))
            mbs = jax.tree_util.tree_map(to_micro, batch)
            (grads, loss), metrics = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_for, has_aux=True)(
                params_c, batch)

        if grad_compression == "bf16":
            from ..distributed.compression import compress_bf16
            grads = compress_bf16(grads)

        new_params, new_opt, opt_metrics = OPT.adamw_update(
            opt_cfg, params, grads, state["opt"])
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    def state_shardings(params_shape):
        """params_shape: pytree of ShapeDtypeStruct (from eval_shape)."""
        specs = SH.param_specs(params_shape)
        specs = SH.validate_specs(params_shape, specs, mesh)
        pshard = SH.named_shardings(specs, mesh)
        return {
            "params": pshard,
            "opt": {"step": NamedSharding(mesh, P()),
                    "mu": pshard, "nu": pshard},
        }

    batch_spec = P(dp_axes, None)
    return step, state_shardings, batch_spec


def init_state(cfg: ModelConfig, key, param_dtype=jnp.float32):
    params = MDL.init_params(cfg, key, param_dtype)
    return {"params": params, "opt": OPT.init_opt_state(params)}


def init_state_shape(cfg: ModelConfig, param_dtype=jnp.float32):
    """ShapeDtypeStruct pytree of the state — for AOT sharding/lowering."""
    return jax.eval_shape(
        functools.partial(init_state, cfg, param_dtype=param_dtype),
        jax.random.PRNGKey(0))
