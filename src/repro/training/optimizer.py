"""AdamW with cosine / WSD (warmup-stable-decay, MiniCPM) schedules.

Self-contained (no optax offline): state = {step, mu, nu} pytree sharded
like the params, so the update is fully elementwise — no optimizer
collectives (ZeRO-style via the FSDP param sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"          # "cosine" | "wsd"
    wsd_decay_frac: float = 0.1       # last 10% of steps decay (WSD)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_at(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    if cfg.schedule == "wsd":
        decay_start = cfg.total_steps * (1.0 - cfg.wsd_decay_frac)
        frac = (step - decay_start) / jnp.maximum(
            cfg.total_steps - decay_start, 1.0)
        frac = jnp.clip(frac, 0.0, 1.0)
        main = cfg.peak_lr * (1.0 - (1.0 - cfg.min_lr_frac) * frac)
    else:
        t = jnp.clip((step - cfg.warmup_steps) /
                     jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        main = cfg.peak_lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                              * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, main)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {"step": jnp.zeros((), jnp.int32),
            "mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params)}


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: OptConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        step_ = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norm scales/bias exempt)
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"step": step, "mu": new_mu, "nu": new_nu}, metrics
