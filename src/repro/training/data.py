"""Deterministic synthetic token pipeline.

Produces reproducible batches from a (seed, step) pair — the pipeline state
is just the step counter, so the checkpoint stores one integer and restart
resumes mid-epoch exactly (fault-tolerance requirement, DESIGN.md §4).

The token stream is a mixture of Zipf-distributed unigrams and short
repeated motifs so the CE loss has learnable structure (examples/train
shows loss decreasing; pure uniform tokens would pin loss at ln(V))."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_prob: float = 0.5


class SyntheticLM:
    """Stateless batch generator: batch(step) is pure in (config, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self.probs = probs / probs.sum()
        # fixed motif table: 64 motifs of motif_len tokens
        self.motifs = rng.integers(0, cfg.vocab,
                                   size=(64, cfg.motif_len)).astype(np.int32)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        toks = rng.choice(cfg.vocab, p=self.probs,
                          size=(cfg.global_batch, cfg.seq_len)).astype(np.int32)
        # paste motifs at random positions (learnable bigram structure)
        n_paste = int(cfg.motif_prob * cfg.global_batch * cfg.seq_len
                      / cfg.motif_len / 4)
        rows = rng.integers(0, cfg.global_batch, n_paste)
        cols = rng.integers(0, max(cfg.seq_len - cfg.motif_len, 1), n_paste)
        ids = rng.integers(0, 64, n_paste)
        for r, c, i in zip(rows, cols, ids):
            toks[r, c:c + cfg.motif_len] = self.motifs[i]
        return {"tokens": toks}

    def batch_for_model(self, step: int, model_cfg) -> dict:
        """Adds frontend-stub / label fields the arch needs."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, 1))
        out = self.batch(step)
        if not model_cfg.embed_inputs:  # hubert: frame embeddings + labels
            out = {
                "embeds": rng.standard_normal(
                    (cfg.global_batch, cfg.seq_len, model_cfg.d_model)
                ).astype(np.float32),
                "labels": out["tokens"] % model_cfg.vocab,
            }
        if model_cfg.cross_attn_period:
            out["image_embeds"] = rng.standard_normal(
                (cfg.global_batch, model_cfg.num_image_tokens,
                 model_cfg.d_model)).astype(np.float32)
        return out
