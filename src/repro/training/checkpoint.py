"""Step-atomic checkpointing with async write and auto-resume.

Layout:  <dir>/step_<N>/
             manifest.json   (step, config hash, leaf index, status)
             arr_<i>.npy     (one file per leaf, host-gathered)
         <dir>/step_<N>.tmp/ during write; os.replace() commits (atomic on
         POSIX), so a crash mid-write never corrupts the latest checkpoint.

Restore picks the newest COMMITTED step; partial .tmp dirs are ignored and
garbage-collected. Async mode runs the save on a worker thread — training
continues; save() blocks only if a previous save is still in flight
(back-pressure rather than unbounded queue).

At multi-pod scale each host saves its own shard set (addressable-shards
loop below); here (single host) that degenerates to full arrays.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), leaf) for p, leaf in flat]


def config_hash(obj: Any) -> str:
    return hashlib.sha1(repr(obj).encode()).hexdigest()[:12]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        self._gc_tmp()

    # -- save --------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None,
             cfg_hash: str = "") -> None:
        if self._thread is not None:
            self._thread.join()  # back-pressure: one save in flight
            self._thread = None
        # device -> host copy happens sync (cheap vs write); write async
        host = jax.tree_util.tree_map(np.asarray, tree)
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}, cfg_hash))
            self._thread.start()
        else:
            self._write(step, host, extra or {}, cfg_hash)

    def _write(self, step: int, host_tree, extra: dict, cfg_hash: str):
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        index = []
        for i, (path, leaf) in enumerate(_leaf_paths(host_tree)):
            np.save(os.path.join(tmp, f"arr_{i}.npy"), leaf)
            index.append(path)
        manifest = {"step": step, "cfg_hash": cfg_hash, "index": index,
                    "extra": extra, "time": time.time(), "status": "complete"}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore -----------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                mf = os.path.join(self.dir, name, "manifest.json")
                if os.path.exists(mf):
                    steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, step: int, like: Any, cfg_hash: str = "") -> tuple[Any, dict]:
        """Restores into the structure of `like` (validates leaf count &
        config hash). Returns (tree, extra)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if cfg_hash and manifest["cfg_hash"] and manifest["cfg_hash"] != cfg_hash:
            raise ValueError(
                f"checkpoint config hash {manifest['cfg_hash']} != {cfg_hash}: "
                "refusing to restore across incompatible configs")
        flat, treedef = jax.tree_util.tree_flatten(like)
        n = len(manifest["index"])
        if n != len(flat):
            raise ValueError(f"leaf count mismatch: ckpt {n} vs model {len(flat)}")
        leaves = [np.load(os.path.join(d, f"arr_{i}.npy")) for i in range(n)]
        restored = treedef.unflatten(leaves)
        return restored, manifest.get("extra", {})

    def restore_latest(self, like: Any, cfg_hash: str = ""):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, like, cfg_hash)
        return step, tree, extra

    # -- gc ----------------------------------------------------------------
    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def _gc_tmp(self):
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)
