"""The benchmark corpus — a seeded, named, structurally diverse matrix suite.

Analogue of the paper's 559 symmetric >=10k-row SuiteSparse selection,
sized for a 1-core CPU container (DESIGN.md §7). Three tiers:

  * SMOKE  — tiny, for unit tests (seconds).
  * BENCH  — the default corpus for benchmarks/fig* (~60 matrices,
             10k-66k rows) satisfying the paper's >=10k-row filter.
  * LARGE  — a few 100k+ row matrices incl. the Fig. 1 pair.

Each entry is (name, thunk). Matrices are deterministic in their seed and
cached on disk (npz) after first build so repeated benchmark runs are fast.
"""
from __future__ import annotations

import os
from typing import Callable, Dict

import numpy as np

from ..core.sparse.csr import CSRMatrix
from . import generators as G

_CACHE_DIR = os.environ.get("REPRO_MATRIX_CACHE", "/tmp/repro_matrices")


def _cached(name: str, thunk: Callable[[], CSRMatrix]) -> CSRMatrix:
    os.makedirs(_CACHE_DIR, exist_ok=True)
    path = os.path.join(_CACHE_DIR, name + ".npz")
    if os.path.exists(path):
        z = np.load(path)
        return CSRMatrix(rowptr=z["rowptr"], cols=z["cols"], vals=z["vals"],
                         shape=tuple(int(v) for v in z["shape"]))
    mat = thunk()
    np.savez(path, rowptr=mat.rowptr, cols=mat.cols, vals=mat.vals,
             shape=np.asarray(mat.shape))
    return mat


def _bench_defs() -> Dict[str, Callable[[], CSRMatrix]]:
    defs: Dict[str, Callable[[], CSRMatrix]] = {}
    # banded family (RCM's home turf) + shuffled twins (Fig. 1 regime)
    for i, (m, bw) in enumerate([(16384, 8), (16384, 32), (32768, 16),
                                 (32768, 63), (65536, 8), (65536, 24)]):
        defs[f"banded_m{m}_bw{bw}"] = (lambda m=m, bw=bw, i=i: G.banded(m, bw, seed=i))
        defs[f"banded_shuf_m{m}_bw{bw}"] = (
            lambda m=m, bw=bw, i=i: G.shuffle(G.banded(m, bw, seed=i), seed=100 + i))
    # 2-D/3-D stencils (+ shuffled: hidden locality that RCM can recover)
    for i, nx in enumerate([128, 181, 256]):
        defs[f"stencil2d_{nx}"] = lambda nx=nx, i=i: G.stencil_2d(nx, seed=i)
        defs[f"stencil2d_shuf_{nx}"] = (
            lambda nx=nx, i=i: G.shuffle(G.stencil_2d(nx, seed=i), seed=200 + i))
    for i, nx in enumerate([24, 32]):
        defs[f"stencil3d_{nx}"] = lambda nx=nx, i=i: G.stencil_3d(nx, seed=i)
        defs[f"stencil3d_shuf_{nx}"] = (
            lambda nx=nx, i=i: G.shuffle(G.stencil_3d(nx, seed=i), seed=300 + i))
    # power-law graphs (load-imbalance stressors)
    for i, (scale, ef) in enumerate([(14, 8), (14, 16), (15, 8), (16, 6)]):
        defs[f"rmat_s{scale}_e{ef}"] = lambda s=scale, e=ef, i=i: G.rmat(s, e, seed=i)
    # community graphs (Louvain/METIS home turf), shuffled so structure is hidden
    for i, (m, k, pin) in enumerate([(16384, 16, 0.004), (32768, 32, 0.002),
                                     (16384, 8, 0.006), (32768, 64, 0.004)]):
        defs[f"sbm_m{m}_k{k}"] = (
            lambda m=m, k=k, pin=pin, i=i:
            G.shuffle(G.sbm(m, k, pin, 8.0 / m / m * 4, seed=i), seed=400 + i))
    # small world
    for i, (m, k, beta) in enumerate([(16384, 6, 0.05), (32768, 8, 0.1),
                                      (65536, 6, 0.02)]):
        defs[f"smallworld_m{m}_k{k}"] = (
            lambda m=m, k=k, b=beta, i=i: G.small_world(m, k, b, seed=i))
    # kronecker
    for i, (bm, p) in enumerate([(11, 4), (26, 3)]):
        defs[f"kron_b{bm}_p{p}"] = lambda b=bm, p=p, i=i: G.kron_graph(b, p, seed=i)
    # uniform random (no structure to find — reordering should not help)
    for i, (m, d) in enumerate([(16384, 8), (32768, 12), (65536, 6)]):
        defs[f"uniform_m{m}_d{d}"] = lambda m=m, d=d, i=i: G.random_uniform(m, d, seed=i)
    # explicit power-law row skew (hub rows; padded-ELL worst case, the
    # regime the SELL-C-σ engine and the autotuner exist for)
    for i, (m, a) in enumerate([(16384, 2.1), (32768, 1.9), (16384, 1.7)]):
        defs[f"powerlaw_m{m}_a{round(a * 10)}"] = (
            lambda m=m, a=a, i=i: G.power_law(m, alpha=a, seed=i))
    return defs


def bench_names() -> list[str]:
    return sorted(_bench_defs().keys())


def get(name: str) -> CSRMatrix:
    defs = _bench_defs()
    defs.update(_large_defs())
    defs.update(_smoke_defs())
    defs.update(_locality_defs())
    if name not in defs:
        raise KeyError(f"unknown matrix {name!r}; known: {sorted(defs)[:10]}...")
    return _cached(name, defs[name])


def _large_defs() -> Dict[str, Callable[[], CSRMatrix]]:
    return {
        # the Fig. 1 pair (1M x 1M so x spills this host's 2 MiB L2 —
        # the paper's 128K matrices spill the smaller caches of its hosts)
        "fig1_banded": lambda: G.banded(1048576, 15, seed=7),
        "fig1_shuffled": lambda: G.shuffle(G.banded(1048576, 15, seed=7), seed=8),
    }


# LOCALITY tier: ~520k rows — x (2+ MiB) spills L2, so sequential
# data-movement effects (the paper's §4 sequential story) are physically
# measurable on this host (DESIGN.md §7). Shuffled variants hide structure
# that reordering can recover.
def _locality_defs() -> Dict[str, Callable[[], CSRMatrix]]:
    M = 524288
    return {
        "loc_banded_bw8": lambda: G.banded(M, 8, seed=20),
        "loc_banded_shuf_bw8": lambda: G.shuffle(G.banded(M, 8, seed=20), seed=21),
        "loc_banded_shuf_bw24": lambda: G.shuffle(G.banded(M, 24, seed=22), seed=23),
        "loc_stencil2d_shuf": lambda: G.shuffle(G.stencil_2d(724, seed=24), seed=25),
        "loc_stencil3d_shuf": lambda: G.shuffle(G.stencil_3d(80, seed=26), seed=27),
        "loc_sbm_k64": lambda: G.shuffle(
            G.sbm(M, 64, 0.0008, 1.0 / M / 64, seed=28), seed=29),
        "loc_smallworld_k8": lambda: G.small_world(M, 8, 0.05, seed=30),
        "loc_rmat_s19": lambda: G.rmat(19, 8, seed=31),
        "loc_uniform_d8": lambda: G.random_uniform(M, 8, seed=32),
        # NATURALLY-ordered matrices (the regime where the paper observes
        # slowdowns: baseline ordering is already near-optimal, so most
        # reorderings can only destroy incidental locality)
        "loc_stencil2d_nat": lambda: G.stencil_2d(724, seed=33),
        "loc_stencil3d_nat": lambda: G.stencil_3d(80, seed=34),
        "loc_banded_bw24_nat": lambda: G.banded(M, 24, seed=35),
        "loc_banded_bw3_nat": lambda: G.banded(M, 3, seed=36),
    }


def locality_names() -> list[str]:
    return sorted(_locality_defs().keys())


def _smoke_defs() -> Dict[str, Callable[[], CSRMatrix]]:
    return {
        "smoke_banded": lambda: G.banded(256, 4, seed=1),
        "smoke_stencil": lambda: G.stencil_2d(20, seed=2),
        "smoke_rmat": lambda: G.rmat(8, 4, seed=3),
        "smoke_sbm": lambda: G.shuffle(G.sbm(512, 8, 0.08, 0.002, seed=4), seed=5),
        "smoke_powerlaw": lambda: G.power_law(1024, alpha=1.9, seed=6),
    }


def smoke_names() -> list[str]:
    return sorted(_smoke_defs().keys())


def large_names() -> list[str]:
    return sorted(_large_defs().keys())
