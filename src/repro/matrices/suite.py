"""The benchmark corpus — a seeded, named, structurally diverse matrix suite.

Analogue of the paper's 559 symmetric >=10k-row SuiteSparse selection,
sized for a 1-core CPU container (DESIGN.md §7). One registered catalog,
queried by tier:

  * SMOKE    — tiny, for unit tests (seconds).
  * BENCH    — the default corpus for benchmarks/fig* (~60 matrices,
               10k-66k rows) satisfying the paper's >=10k-row filter.
  * LARGE    — a few 100k+ row matrices incl. the Fig. 1 pair.
  * LOCALITY — ~520k rows, x spills L2 (sequential locality tier).
  * CORPUS   — real SuiteSparse matrices (or offline stand-ins) resolved
               through repro.corpus; names carry the `corpus://` prefix.
  * WORKLOAD — dynamic model-layer sparsity streams (repro.workloads);
               names carry the `workload://` prefix and resolve to the
               stream's step-0 representative matrix (the full stream is
               the "workload" cell kind's business).

Every name — synthetic, `corpus://`, or `workload://` — resolves through
the same `get(name)`. Synthetic entries are deterministic in their seed and cached
on disk (npz) after first build; corpus entries resolve through the
content-addressed `.csrz` artifact store. Third parties can add entries
with `register_matrix`.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, Optional

import numpy as np

from ..core.sparse.csr import CSRMatrix
from . import generators as G

TIERS = ("smoke", "bench", "large", "locality", "corpus", "workload")


@dataclasses.dataclass(frozen=True)
class MatrixDef:
    """One catalog entry: a named, tiered thunk producing a CSRMatrix."""

    name: str
    tier: str
    thunk: Callable[[], CSRMatrix]
    cached: bool = True              # persist to the npz matrix cache


_CATALOG: Dict[str, MatrixDef] = {}


def register_matrix(name: str, tier: str, thunk: Callable[[], CSRMatrix],
                    cached: bool = True, override: bool = False) -> None:
    if tier not in TIERS:
        raise ValueError(f"unknown tier {tier!r}; known: {TIERS}")
    if name in _CATALOG and not override:
        raise ValueError(f"matrix {name!r} already registered")
    _CATALOG[name] = MatrixDef(name=name, tier=tier, thunk=thunk,
                               cached=cached)


def _cache_dir() -> str:
    return os.environ.get("REPRO_MATRIX_CACHE", "/tmp/repro_matrices")


def _cached(name: str, thunk: Callable[[], CSRMatrix]) -> CSRMatrix:
    root = _cache_dir()
    os.makedirs(root, exist_ok=True)
    path = os.path.join(root, name + ".npz")
    if os.path.exists(path):
        z = np.load(path)
        return CSRMatrix(rowptr=z["rowptr"], cols=z["cols"], vals=z["vals"],
                         shape=tuple(int(v) for v in z["shape"]))
    mat = thunk()
    np.savez(path, rowptr=mat.rowptr, cols=mat.cols, vals=mat.vals,
             shape=np.asarray(mat.shape))
    return mat


def names(tier: Optional[str] = None) -> list:
    """Catalog names, optionally restricted to one tier (sorted)."""
    if tier is None:
        return sorted(_CATALOG)
    if tier not in TIERS:
        raise ValueError(f"unknown tier {tier!r}; known: {TIERS}")
    return sorted(n for n, d in _CATALOG.items() if d.tier == tier)


def get(name: str) -> CSRMatrix:
    """Resolve any catalog name — synthetic, corpus://, or registered."""
    if name.startswith("corpus://"):
        from ..corpus import manifest as corpus_manifest

        return corpus_manifest.resolve(name)
    if name.startswith("workload://"):
        from ..workloads import sources as workload_sources

        return workload_sources.representative(name)
    if name not in _CATALOG:
        raise KeyError(f"unknown matrix {name!r}; known: "
                       f"{sorted(_CATALOG)[:10]}... (or a corpus:// / "
                       f"workload:// name)")
    d = _CATALOG[name]
    return _cached(name, d.thunk) if d.cached else d.thunk()


def bench_names() -> list:
    return names("bench")


def smoke_names() -> list:
    return names("smoke")


def large_names() -> list:
    return names("large")


def locality_names() -> list:
    return names("locality")


def corpus_names() -> list:
    """Qualified corpus:// names from the corpus manifest."""
    from ..corpus import manifest as corpus_manifest

    return corpus_manifest.corpus_names()


def workload_names() -> list:
    """Canonical workload:// preset names (any parameterization of the
    repro.workloads name grammar resolves too)."""
    from ..workloads import sources as workload_sources

    return workload_sources.preset_names()


# --------------------------------------------------------------------------
# built-in catalog
# --------------------------------------------------------------------------
def _register_bench() -> None:
    # banded family (RCM's home turf) + shuffled twins (Fig. 1 regime)
    for i, (m, bw) in enumerate([(16384, 8), (16384, 32), (32768, 16),
                                 (32768, 63), (65536, 8), (65536, 24)]):
        register_matrix(f"banded_m{m}_bw{bw}", "bench",
                        lambda m=m, bw=bw, i=i: G.banded(m, bw, seed=i))
        register_matrix(f"banded_shuf_m{m}_bw{bw}", "bench",
                        lambda m=m, bw=bw, i=i:
                        G.shuffle(G.banded(m, bw, seed=i), seed=100 + i))
    # 2-D/3-D stencils (+ shuffled: hidden locality that RCM can recover)
    for i, nx in enumerate([128, 181, 256]):
        register_matrix(f"stencil2d_{nx}", "bench",
                        lambda nx=nx, i=i: G.stencil_2d(nx, seed=i))
        register_matrix(f"stencil2d_shuf_{nx}", "bench",
                        lambda nx=nx, i=i:
                        G.shuffle(G.stencil_2d(nx, seed=i), seed=200 + i))
    for i, nx in enumerate([24, 32]):
        register_matrix(f"stencil3d_{nx}", "bench",
                        lambda nx=nx, i=i: G.stencil_3d(nx, seed=i))
        register_matrix(f"stencil3d_shuf_{nx}", "bench",
                        lambda nx=nx, i=i:
                        G.shuffle(G.stencil_3d(nx, seed=i), seed=300 + i))
    # power-law graphs (load-imbalance stressors)
    for i, (scale, ef) in enumerate([(14, 8), (14, 16), (15, 8), (16, 6)]):
        register_matrix(f"rmat_s{scale}_e{ef}", "bench",
                        lambda s=scale, e=ef, i=i: G.rmat(s, e, seed=i))
    # community graphs (Louvain/METIS home turf), shuffled to hide structure
    for i, (m, k, pin) in enumerate([(16384, 16, 0.004), (32768, 32, 0.002),
                                     (16384, 8, 0.006), (32768, 64, 0.004)]):
        register_matrix(f"sbm_m{m}_k{k}", "bench",
                        lambda m=m, k=k, pin=pin, i=i:
                        G.shuffle(G.sbm(m, k, pin, 8.0 / m / m * 4, seed=i),
                                  seed=400 + i))
    # small world
    for i, (m, k, beta) in enumerate([(16384, 6, 0.05), (32768, 8, 0.1),
                                      (65536, 6, 0.02)]):
        register_matrix(f"smallworld_m{m}_k{k}", "bench",
                        lambda m=m, k=k, b=beta, i=i:
                        G.small_world(m, k, b, seed=i))
    # kronecker
    for i, (bm, p) in enumerate([(11, 4), (26, 3)]):
        register_matrix(f"kron_b{bm}_p{p}", "bench",
                        lambda b=bm, p=p, i=i: G.kron_graph(b, p, seed=i))
    # uniform random (no structure to find — reordering should not help)
    for i, (m, d) in enumerate([(16384, 8), (32768, 12), (65536, 6)]):
        register_matrix(f"uniform_m{m}_d{d}", "bench",
                        lambda m=m, d=d, i=i: G.random_uniform(m, d, seed=i))
    # explicit power-law row skew (hub rows; padded-ELL worst case, the
    # regime the SELL-C-σ engine and the autotuner exist for)
    for i, (m, a) in enumerate([(16384, 2.1), (32768, 1.9), (16384, 1.7)]):
        register_matrix(f"powerlaw_m{m}_a{round(a * 10)}", "bench",
                        lambda m=m, a=a, i=i: G.power_law(m, alpha=a, seed=i))


def _register_large() -> None:
    # the Fig. 1 pair (1M x 1M so x spills this host's 2 MiB L2 —
    # the paper's 128K matrices spill the smaller caches of its hosts)
    register_matrix("fig1_banded", "large",
                    lambda: G.banded(1048576, 15, seed=7))
    register_matrix("fig1_shuffled", "large",
                    lambda: G.shuffle(G.banded(1048576, 15, seed=7), seed=8))


def _register_locality() -> None:
    # LOCALITY tier: ~520k rows — x (2+ MiB) spills L2, so sequential
    # data-movement effects (the paper's §4 sequential story) are
    # physically measurable on this host (DESIGN.md §7). Shuffled
    # variants hide structure that reordering can recover.
    M = 524288
    defs = {
        "loc_banded_bw8": lambda: G.banded(M, 8, seed=20),
        "loc_banded_shuf_bw8":
            lambda: G.shuffle(G.banded(M, 8, seed=20), seed=21),
        "loc_banded_shuf_bw24":
            lambda: G.shuffle(G.banded(M, 24, seed=22), seed=23),
        "loc_stencil2d_shuf":
            lambda: G.shuffle(G.stencil_2d(724, seed=24), seed=25),
        "loc_stencil3d_shuf":
            lambda: G.shuffle(G.stencil_3d(80, seed=26), seed=27),
        "loc_sbm_k64": lambda: G.shuffle(
            G.sbm(M, 64, 0.0008, 1.0 / M / 64, seed=28), seed=29),
        "loc_smallworld_k8": lambda: G.small_world(M, 8, 0.05, seed=30),
        "loc_rmat_s19": lambda: G.rmat(19, 8, seed=31),
        "loc_uniform_d8": lambda: G.random_uniform(M, 8, seed=32),
        # NATURALLY-ordered matrices (the regime where the paper observes
        # slowdowns: baseline ordering is already near-optimal, so most
        # reorderings can only destroy incidental locality)
        "loc_stencil2d_nat": lambda: G.stencil_2d(724, seed=33),
        "loc_stencil3d_nat": lambda: G.stencil_3d(80, seed=34),
        "loc_banded_bw24_nat": lambda: G.banded(M, 24, seed=35),
        "loc_banded_bw3_nat": lambda: G.banded(M, 3, seed=36),
    }
    for name, thunk in defs.items():
        register_matrix(name, "locality", thunk)


def _register_smoke() -> None:
    defs = {
        "smoke_banded": lambda: G.banded(256, 4, seed=1),
        "smoke_stencil": lambda: G.stencil_2d(20, seed=2),
        "smoke_rmat": lambda: G.rmat(8, 4, seed=3),
        "smoke_sbm":
            lambda: G.shuffle(G.sbm(512, 8, 0.08, 0.002, seed=4), seed=5),
        "smoke_powerlaw": lambda: G.power_law(1024, alpha=1.9, seed=6),
    }
    for name, thunk in defs.items():
        register_matrix(name, "smoke", thunk)


_register_bench()
_register_large()
_register_locality()
_register_smoke()
