"""MatrixMarket I/O so real SuiteSparse .mtx files drop in when available."""
from __future__ import annotations

import numpy as np

from ..core.sparse.csr import CSRMatrix


def read_mtx(path: str) -> CSRMatrix:
    with open(path, "r") as f:
        header = f.readline()
        if not header.startswith("%%MatrixMarket"):
            raise ValueError("not a MatrixMarket file")
        toks = header.lower().split()
        symmetric = "symmetric" in toks
        pattern = "pattern" in toks
        line = f.readline()
        while line.startswith("%"):
            line = f.readline()
        m, n, nnz = (int(t) for t in line.split())
        data = np.loadtxt(f, ndmin=2)
    r0 = data[:, 0].astype(np.int64) - 1
    c0 = data[:, 1].astype(np.int64) - 1
    v0 = np.ones(r0.size) if pattern else data[:, 2]
    if symmetric:  # stored lower triangle only; mirror the off-diagonal
        off = r0 != c0
        rows = np.concatenate([r0, c0[off]])
        cols = np.concatenate([c0, r0[off]])
        vals = np.concatenate([v0, v0[off]])
    else:
        rows, cols, vals = r0, c0, v0
    return CSRMatrix.from_coo(rows, cols, vals, (m, n))


def write_mtx(path: str, mat: CSRMatrix) -> None:
    r = np.repeat(np.arange(mat.m), mat.row_nnz())
    with open(path, "w") as f:
        f.write("%%MatrixMarket matrix coordinate real general\n")
        f.write(f"{mat.m} {mat.n} {mat.nnz}\n")
        for i in range(mat.nnz):
            f.write(f"{r[i] + 1} {mat.cols[i] + 1} {mat.vals[i]:.17g}\n")
