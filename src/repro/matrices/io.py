"""MatrixMarket I/O so real SuiteSparse .mtx files drop in when available.

`read_mtx` is a thin veneer over the corpus streaming parser
(`repro.corpus.mtxstream`): chunked two-pass ingestion with peak parser
memory bounded by the chunk size, `real`/`integer`/`pattern` fields,
`general`/`symmetric` symmetry, and clear rejection of `complex`/
`hermitian`/`skew-symmetric` files (the old whole-file reader silently
mis-parsed them). For cached, content-addressed ingestion use
`repro.corpus.ingest_path` — it wraps the same parser behind the `.csrz`
artifact store so a file is parsed once, ever.

`write_mtx` batches formatting through np.savetxt (the old per-nnz
Python loop was the slowest line in the repo for big matrices) and emits
the exact same `%.17g` general/real encoding, so round-trips through
either reader are value-exact.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.sparse.csr import CSRMatrix
from ..corpus import mtxstream


def read_mtx(path: str, chunk_nnz: Optional[int] = None) -> CSRMatrix:
    """Parse a MatrixMarket coordinate file into CSR (streaming)."""
    return mtxstream.read_mtx(path, chunk_nnz=chunk_nnz)


def write_mtx(path: str, mat: CSRMatrix) -> None:
    r = np.repeat(np.arange(1, mat.m + 1, dtype=np.int64), mat.row_nnz())
    c = mat.cols.astype(np.int64) + 1
    with open(path, "w") as f:
        f.write("%%MatrixMarket matrix coordinate real general\n")
        f.write(f"{mat.m} {mat.n} {mat.nnz}\n")
        np.savetxt(f, np.column_stack([r, c, mat.vals]),
                   fmt=("%d", "%d", "%.17g"))
