"""Synthetic sparse-matrix corpus (SuiteSparse substitute — DESIGN.md §6).

Every generator returns a *symmetric* CSRMatrix (the paper filters to
symmetric matrices because METIS requires them). Seeded and deterministic.

Families span the structural regimes of the paper's 559-matrix corpus:
  banded          — paper Fig. 1 left (bandwidth-limited, FEM 1-D)
  stencil_2d/3d   — 5/7-point Laplacians (regular FEM / CFD meshes)
  rmat            — power-law graphs (web/social; worst-case skew)
  sbm             — stochastic block model (community structure;
                    the regime Louvain/METIS target)
  small_world     — Watts-Strogatz ring + random rewires
  kron            — Kronecker product structure (recursive self-similarity)
  random_uniform  — Erdos-Renyi (paper Fig. 1 right after shuffle)
plus `shuffle()` which applies the paper's random symmetric permutation.
"""
from __future__ import annotations

import numpy as np

from ..core.sparse.csr import CSRMatrix


def _symmetrize_coo(rows, cols, m, rng, weights=None):
    """Build symmetric CSR from an edge list: A = B + B^T with unit/random
    weights and a diagonal added (keeps CG-compatible SPD-ish structure)."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    r = np.concatenate([rows, cols, np.arange(m)])
    c = np.concatenate([cols, rows, np.arange(m)])
    if weights is None:
        v = rng.uniform(0.1, 1.0, size=rows.size)
    else:
        v = weights[keep]
    # duplicate edges collapse via from_coo's dedup (sums); that retains
    # symmetry since both directions receive identical sums.
    vals = np.concatenate([v, v, np.full(m, float(m))])
    return CSRMatrix.from_coo(r, c, vals, (m, m))


def banded(m: int, half_bw: int, seed: int = 0) -> CSRMatrix:
    """Symmetric banded matrix, half-bandwidth `half_bw` (Fig. 1 left)."""
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for d in range(1, half_bw + 1):
        i = np.arange(m - d)
        rows.append(i)
        cols.append(i + d)
    rows = np.concatenate(rows) if rows else np.empty(0, dtype=np.int64)
    cols = np.concatenate(cols) if cols else np.empty(0, dtype=np.int64)
    return _symmetrize_coo(rows, cols, m, rng)


def stencil_2d(nx: int, ny: int | None = None, seed: int = 0) -> CSRMatrix:
    """5-point Laplacian on an nx x ny grid (natural row-major ordering)."""
    ny = ny or nx
    rng = np.random.default_rng(seed)
    idx = np.arange(nx * ny).reshape(nx, ny)
    rows, cols = [], []
    rows.append(idx[:, :-1].ravel()); cols.append(idx[:, 1:].ravel())
    rows.append(idx[:-1, :].ravel()); cols.append(idx[1:, :].ravel())
    return _symmetrize_coo(np.concatenate(rows), np.concatenate(cols), nx * ny, rng)


def stencil_3d(nx: int, ny: int | None = None, nz: int | None = None, seed: int = 0) -> CSRMatrix:
    ny = ny or nx
    nz = nz or nx
    rng = np.random.default_rng(seed)
    idx = np.arange(nx * ny * nz).reshape(nx, ny, nz)
    rows, cols = [], []
    rows.append(idx[:, :, :-1].ravel()); cols.append(idx[:, :, 1:].ravel())
    rows.append(idx[:, :-1, :].ravel()); cols.append(idx[:, 1:, :].ravel())
    rows.append(idx[:-1, :, :].ravel()); cols.append(idx[1:, :, :].ravel())
    return _symmetrize_coo(np.concatenate(rows), np.concatenate(cols), nx * ny * nz, rng)


def rmat(scale: int, edge_factor: int = 8, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> CSRMatrix:
    """R-MAT power-law graph, 2^scale vertices (Graph500-style)."""
    rng = np.random.default_rng(seed)
    m = 1 << scale
    ne = m * edge_factor
    rows = np.zeros(ne, dtype=np.int64)
    cols = np.zeros(ne, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(ne)
        # quadrant probabilities (a, b, c, d)
        row_bit = (r >= a + b).astype(np.int64) * ((r < a + b + c).astype(np.int64) * 0 + 1)
        row_bit = (r >= a + b).astype(np.int64)
        col_bit = ((r >= a) & (r < a + b)).astype(np.int64) | (r >= a + b + c).astype(np.int64)
        rows |= row_bit << bit
        cols |= col_bit << bit
    return _symmetrize_coo(rows, cols, m, rng)


def sbm(m: int, communities: int, p_in: float, p_out: float, seed: int = 0) -> CSRMatrix:
    """Stochastic block model with a hidden (shuffled) community layout."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, communities, size=m)
    # expected degrees: d_in = (m/communities)*p_in, d_out = m*p_out
    n_in = int(m * (m / communities) * p_in / 2)
    n_out = int(m * m * p_out / 2)
    ri = rng.integers(0, m, size=2 * n_in)
    # sample within-community edges by matching labels via sort trick
    order = np.argsort(labels[ri], kind="stable")
    ri = ri[order]
    rows_in = ri[0::2][: n_in]
    cols_in = ri[1::2][: n_in]
    same = labels[rows_in] == labels[cols_in]
    rows_in, cols_in = rows_in[same], cols_in[same]
    rows_out = rng.integers(0, m, size=n_out)
    cols_out = rng.integers(0, m, size=n_out)
    rows = np.concatenate([rows_in, rows_out])
    cols = np.concatenate([cols_in, cols_out])
    return _symmetrize_coo(rows, cols, m, rng)


def small_world(m: int, k: int = 6, beta: float = 0.1, seed: int = 0) -> CSRMatrix:
    """Watts-Strogatz: ring lattice with k/2 neighbours each side, random
    rewiring with probability beta."""
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for d in range(1, k // 2 + 1):
        i = np.arange(m)
        j = (i + d) % m
        rewire = rng.random(m) < beta
        j = np.where(rewire, rng.integers(0, m, size=m), j)
        rows.append(i)
        cols.append(j)
    return _symmetrize_coo(np.concatenate(rows), np.concatenate(cols), m, rng)


def kron_graph(base_m: int, power: int, density: float = 0.3, seed: int = 0) -> CSRMatrix:
    """Kronecker power of a random base adjacency (recursive structure)."""
    rng = np.random.default_rng(seed)
    base = (rng.random((base_m, base_m)) < density).astype(np.float64)
    base = np.maximum(base, base.T)
    g = base
    for _ in range(power - 1):
        g = np.kron(g, base)
    np.fill_diagonal(g, 0)
    r, c = np.nonzero(g)
    return _symmetrize_coo(r, c, g.shape[0], rng)


def power_law(m: int, alpha: float = 2.1, max_deg: int | None = None,
              seed: int = 0) -> CSRMatrix:
    """Configuration-model graph with zipf(alpha) row degrees.

    The explicit row-skew stressor for the SELL-vs-ELL comparison: a few
    hub rows carry O(max_deg) nonzeros while the bulk stay at 1-3, so
    padded-ELL storage explodes (m * max_deg) while SELL-C-σ stays O(nnz).
    Lower alpha = heavier tail. Degrees are capped at max_deg
    (default m // 4) to keep the matrix buildable.
    """
    rng = np.random.default_rng(seed)
    cap = m // 4 if max_deg is None else max_deg
    deg = np.minimum(rng.zipf(alpha, size=m).astype(np.int64), max(cap, 1))
    # configuration model: pair stubs uniformly (hubs attract edges in
    # proportion to their degree, preserving the skew after symmetrization)
    stubs = np.repeat(np.arange(m, dtype=np.int64), deg)
    return _symmetrize_coo(stubs, rng.permutation(stubs), m, rng)


def random_uniform(m: int, avg_deg: int, seed: int = 0) -> CSRMatrix:
    """Erdos-Renyi-ish uniform random (Fig. 1 right regime)."""
    rng = np.random.default_rng(seed)
    ne = m * avg_deg // 2
    return _symmetrize_coo(rng.integers(0, m, ne), rng.integers(0, m, ne), m, rng)


def shuffle(mat: CSRMatrix, seed: int = 0) -> CSRMatrix:
    """The paper's Fig. 1 experiment: random symmetric row/col permutation."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(mat.m)
    return mat.permute(perm)
