"""Streaming MatrixMarket ingestion — chunked parsing with bounded memory.

The seed's reader slurped the whole file through one ``np.loadtxt`` call:
the text, the token list, and the full COO triplet were all resident at
once — several times the matrix's own footprint at peak. SuiteSparse-scale
files (10^7–10^8 coordinate lines) need the streaming discipline of the
OSKI-enhancement work instead: parse fixed-size coordinate blocks and
assemble CSR directly, so the parser's working set is bounded by the
chunk size while the only O(nnz) allocations are the output arrays
themselves.

Two streaming passes over the data section:

  pass 1 — row occupancy: each chunk contributes per-row counts
           (symmetric files also count the mirrored off-diagonal
           entries); the exclusive scan of the counts is the final
           rowptr. Peak: one chunk's buffers + int64[m+1].
  pass 2 — placement: each chunk's entries land at per-row fill cursors
           (stable within-chunk ordering via one argsort per chunk), so
           cols/vals are written once, in place — no global COO sort of
           3x nnz temporary arrays.

A final per-row column ordering (one lexsort over the output arrays) and
a duplicate merge (the format forbids duplicates but assembled files ship
them; scipy semantics: sum) finish the build.

Supported: ``coordinate`` x ``real``/``integer``/``pattern`` x
``general``/``symmetric``. ``complex``/``hermitian``/``skew-symmetric``
fields and the dense ``array`` format are rejected with a clear error —
the seed reader silently mis-parsed them (a complex file's imaginary
column was read as the value of the *next* entry).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterator, Optional, Tuple

import numpy as np

from .. import obs
from ..core.sparse.csr import CSRMatrix

# Coordinate lines parsed per block. 2^18 lines is ~8 MB of text and
# ~6 MB of parsed buffers — invisible next to any matrix worth streaming,
# large enough that per-chunk overhead (seek bookkeeping, argsort setup)
# amortizes away.
DEFAULT_CHUNK_NNZ = 1 << 18

_FIELDS = ("real", "integer", "pattern")
_SYMMETRIES = ("general", "symmetric")


@dataclasses.dataclass(frozen=True)
class MtxHeader:
    """Validated MatrixMarket banner + size line."""

    field: str      # real | integer | pattern
    symmetry: str   # general | symmetric
    m: int
    n: int
    nnz: int        # declared entry count (stored entries, pre-mirror)
    data_offset: int  # stream position of the first data line

    @property
    def ncols(self) -> int:
        return 2 if self.field == "pattern" else 3

    @property
    def symmetric(self) -> bool:
        return self.symmetry == "symmetric"


def read_header(path: str) -> MtxHeader:
    with open(path, "r") as f:
        return _parse_header(f, path)


def _parse_header(f, path: str) -> MtxHeader:
    banner = f.readline()
    if not banner.startswith("%%MatrixMarket"):
        raise ValueError(
            f"{path}: not a MatrixMarket file (banner starts {banner[:40]!r})")
    toks = banner.split()
    if len(toks) < 5:
        raise ValueError(
            f"{path}: malformed MatrixMarket banner {banner.strip()!r} "
            "(need '%%MatrixMarket object format field symmetry')")
    obj, fmt, field, sym = (t.lower() for t in toks[1:5])
    if obj != "matrix":
        raise ValueError(f"{path}: MatrixMarket object {obj!r} is not supported "
                         "(only 'matrix')")
    if fmt != "coordinate":
        raise ValueError(
            f"{path}: MatrixMarket format {fmt!r} is not supported — only "
            "sparse 'coordinate' files can be ingested (dense 'array' files "
            "have no sparse structure)")
    if field == "complex":
        raise ValueError(
            f"{path}: complex-valued MatrixMarket files are not supported — "
            "the SpMV pipeline is real-valued; extract the real part (or the "
            "magnitude) upstream and re-export as field 'real'")
    if field not in _FIELDS:
        raise ValueError(f"{path}: MatrixMarket field {field!r} is not supported "
                         f"(one of {_FIELDS})")
    if sym in ("hermitian", "skew-symmetric"):
        raise ValueError(
            f"{path}: MatrixMarket symmetry {sym!r} is not supported — only "
            f"{_SYMMETRIES}; re-export with the full (or lower-triangle "
            "symmetric) pattern")
    if sym not in _SYMMETRIES:
        raise ValueError(f"{path}: MatrixMarket symmetry {sym!r} is not supported "
                         f"(one of {_SYMMETRIES})")
    line = f.readline()
    while line and (line.startswith("%") or not line.strip()):
        line = f.readline()
    parts = line.split()
    if len(parts) != 3:
        raise ValueError(f"{path}: malformed MatrixMarket size line "
                         f"{line.strip()!r} (need 'm n nnz')")
    try:
        m, n, nnz = (int(p) for p in parts)
    except ValueError:
        raise ValueError(f"{path}: malformed MatrixMarket size line "
                         f"{line.strip()!r} (need three integers)") from None
    if m < 0 or n < 0 or nnz < 0:
        raise ValueError(f"{path}: negative dimension in size line {line.strip()!r}")
    if sym == "symmetric" and m != n:
        raise ValueError(f"{path}: symmetric MatrixMarket file must be square, "
                         f"got {m}x{n}")
    return MtxHeader(field=field, symmetry=sym, m=m, n=n, nnz=nnz,
                     data_offset=f.tell())


def _parse_chunk(lines, hdr: MtxHeader, lineno: int, path: str):
    """Parse one block of coordinate lines → (rows0, cols0, vals) 0-based."""
    nc = hdr.ncols
    toks = "".join(lines).split()
    if len(toks) != nc * len(lines):
        raise ValueError(
            f"{path}: malformed MatrixMarket data near line {lineno}: expected "
            f"{nc} whitespace-separated columns per entry for field "
            f"{hdr.field!r}")
    try:
        arr = np.asarray(toks, dtype=np.float64)
    except ValueError:
        raise ValueError(
            f"{path}: malformed MatrixMarket data near line {lineno}: "
            "non-numeric token") from None
    arr = arr.reshape(-1, nc)
    rc = arr[:, :2]
    if not np.all(rc == np.floor(rc)):
        raise ValueError(
            f"{path}: non-integer row/column index near line {lineno}")
    r = rc[:, 0].astype(np.int64) - 1
    c = rc[:, 1].astype(np.int64) - 1
    if r.size:
        if (int(r.min()) < 0 or int(c.min()) < 0
                or int(r.max()) >= hdr.m or int(c.max()) >= hdr.n):
            raise ValueError(
                f"{path}: coordinate out of range near line {lineno}: indices "
                f"are 1-based in [1, {hdr.m}] x [1, {hdr.n}]")
    if nc == 2:
        v = np.ones(r.size, dtype=np.float64)
    else:
        v = np.ascontiguousarray(arr[:, 2])
    return r, c, v


def _iter_chunks(path: str, hdr: MtxHeader, chunk_nnz: int,
                 stats: dict) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Yield parsed coordinate blocks of at most `chunk_nnz` entries.

    Enforces the declared entry count: raises on truncated files (fewer
    data lines than `nnz`) and on trailing non-blank garbage.
    """
    with open(path, "r") as f:
        f.seek(hdr.data_offset)
        consumed = 0
        while consumed < hdr.nnz:
            want = min(chunk_nnz, hdr.nnz - consumed)
            lines = []
            while len(lines) < want:
                line = f.readline()
                if not line:
                    raise ValueError(
                        f"{path}: truncated MatrixMarket file: header declares "
                        f"{hdr.nnz} entries, found {consumed + len(lines)}")
                if not line.strip():
                    continue
                lines.append(line)
            lineno = consumed + 1  # 1-based data line of the chunk start
            chunk = _parse_chunk(lines, hdr, lineno, path)
            consumed += len(lines)
            stats["chunks"] += 1
            stats["max_chunk_elems"] = max(stats["max_chunk_elems"], len(lines))
            yield chunk
        for line in f:
            if line.strip():
                raise ValueError(
                    f"{path}: MatrixMarket file has data beyond the declared "
                    f"{hdr.nnz} entries")


def _place(cursors: np.ndarray, r: np.ndarray, c: np.ndarray, v: np.ndarray,
           cols: np.ndarray, vals: np.ndarray) -> None:
    """Scatter one chunk into the output arrays at per-row fill cursors."""
    if r.size == 0:
        return
    order = np.argsort(r, kind="stable")
    rs = r[order]
    first = np.flatnonzero(np.r_[True, rs[1:] != rs[:-1]])
    runlen = np.diff(np.r_[first, rs.size])
    within = np.arange(rs.size, dtype=np.int64) - np.repeat(first, runlen)
    pos = cursors[rs] + within
    cols[pos] = c[order]
    vals[pos] = v[order]
    cursors[rs[first]] += runlen


def _mirror(r, c, v):
    """Append the transposed off-diagonal entries (symmetric expansion)."""
    off = r != c
    return (np.concatenate([r, c[off]]),
            np.concatenate([c, r[off]]),
            np.concatenate([v, v[off]]))


def parse_mtx(path: str, chunk_nnz: Optional[int] = None) -> Tuple[CSRMatrix, dict]:
    """Stream-parse a MatrixMarket file into CSR with bounded peak memory.

    Returns (matrix, stats). `stats["chunks"]` counts chunk parses across
    both passes (per-pass count = chunks // 2) and `stats["max_chunk_elems"]`
    never exceeds `chunk_nnz` — the chunk-count accounting that pins peak
    parser memory to the chunk size rather than the file size.
    """
    chunk_nnz = int(chunk_nnz if chunk_nnz is not None else DEFAULT_CHUNK_NNZ)
    if chunk_nnz < 1:
        raise ValueError(f"chunk_nnz must be >= 1, got {chunk_nnz}")
    hdr = read_header(path)
    stats = {"chunks": 0, "max_chunk_elems": 0, "passes": 2,
             "chunk_nnz": chunk_nnz, "declared_nnz": hdr.nnz,
             "field": hdr.field, "symmetry": hdr.symmetry,
             "duplicates_merged": 0}
    with obs.span("corpus.parse", path=os.path.basename(path), m=hdr.m,
                  n=hdr.n, declared_nnz=hdr.nnz, chunk_nnz=chunk_nnz,
                  field=hdr.field, symmetry=hdr.symmetry) as sp:
        # pass 1: row occupancy
        counts = np.zeros(hdr.m, dtype=np.int64)
        for r, c, _ in _iter_chunks(path, hdr, chunk_nnz, stats):
            counts += np.bincount(r, minlength=hdr.m)
            if hdr.symmetric:
                off = r != c
                counts += np.bincount(c[off], minlength=hdr.m)
        rowptr = np.zeros(hdr.m + 1, dtype=np.int64)
        np.cumsum(counts, out=rowptr[1:])
        total = int(rowptr[-1])
        cols = np.empty(total, dtype=np.int64)
        vals = np.empty(total, dtype=np.float64)
        # pass 2: placement at per-row cursors
        cursors = rowptr[:-1].copy()
        for r, c, v in _iter_chunks(path, hdr, chunk_nnz, stats):
            if hdr.symmetric:
                r, c, v = _mirror(r, c, v)
            _place(cursors, r, c, v, cols, vals)
        sp.set(chunks=stats["chunks"], max_chunk_elems=stats["max_chunk_elems"])

    with obs.span("corpus.build", m=hdr.m, n=hdr.n, nnz=total) as sp:
        # rows are already contiguous by construction; one stable lexsort
        # orders columns within each row.
        row_ids = np.repeat(np.arange(hdr.m, dtype=np.int64), np.diff(rowptr))
        order = np.lexsort((cols, row_ids))
        cols = cols[order]
        vals = vals[order]
        if total:
            key = row_ids * np.int64(max(hdr.n, 1)) + cols
            starts = np.flatnonzero(np.r_[True, key[1:] != key[:-1]])
            if starts.size != total:
                # duplicate coordinates: sum, matching the seed reader's
                # from_coo semantics (and scipy's mmread).
                vals = np.add.reduceat(vals, starts)
                cols = cols[starts]
                row_ids = row_ids[starts]
                stats["duplicates_merged"] = total - int(starts.size)
                counts = np.bincount(row_ids, minlength=hdr.m)
                rowptr = np.zeros(hdr.m + 1, dtype=np.int64)
                np.cumsum(counts, out=rowptr[1:])
                total = int(starts.size)
        mat = CSRMatrix(rowptr=rowptr.astype(np.int32),
                        cols=cols.astype(np.int32),
                        vals=np.ascontiguousarray(vals),
                        shape=(hdr.m, hdr.n))
        sp.set(nnz=mat.nnz, duplicates_merged=stats["duplicates_merged"])
    obs.counter("corpus.parses").inc()
    stats.update(m=hdr.m, n=hdr.n, nnz=mat.nnz)
    return mat, stats


def read_mtx(path: str, chunk_nnz: Optional[int] = None) -> CSRMatrix:
    """Chunked replacement for the seed's whole-file reader."""
    return parse_mtx(path, chunk_nnz=chunk_nnz)[0]
