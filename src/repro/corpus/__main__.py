"""`python -m repro.corpus` — corpus acquisition/ingestion CLI.

    python -m repro.corpus list
    python -m repro.corpus ingest --fixtures [--chunk-nnz N] [--trace t.json]
    python -m repro.corpus ingest corpus://bcsstk17 [--expect-cached]
    python -m repro.corpus verify --all

`--trace` wraps the run in obs.tracing() and writes a Perfetto-loadable
Chrome trace, so ingestion shows up as `corpus.parse` / `corpus.build`
spans next to the planner's. `--expect-cached` turns the run into an
assertion that *zero* parsing happened (every matrix resolved from its
`.csrz` artifact) — the CI corpus-smoke job uses it to prove re-ingest
is a 100% cache hit.
"""
from __future__ import annotations

import argparse
import json
import sys

from .. import obs
from . import artifact, manifest


def _select(args) -> list:
    entries = manifest.load_manifest()
    if getattr(args, "all", False):
        return sorted(entries)
    if getattr(args, "fixtures", False):
        return sorted(n for n, e in entries.items() if e.fixture)
    names = [n[len(manifest.CORPUS_PREFIX):]
             if n.startswith(manifest.CORPUS_PREFIX) else n
             for n in (args.names or [])]
    if not names:
        raise SystemExit("no matrices selected: pass names, --fixtures, "
                         "or --all")
    for n in names:
        manifest.get_entry(n)  # fail fast with the known-names message
    return names


def _cmd_list(args) -> int:
    entries = manifest.load_manifest()
    rows = []
    for name in sorted(entries):
        e = entries[name]
        src = "fixture" if e.fixture else (e.url or "?")
        rows.append({"name": e.qualified, "m": e.m, "n": e.n, "nnz": e.nnz,
                     "symmetric": e.symmetric, "kind": e.kind, "source": src})
    if args.json:
        print(json.dumps(rows, indent=2))
    else:
        w = max(len(r["name"]) for r in rows)
        for r in rows:
            print(f"{r['name']:<{w}}  {r['m']:>9} x {r['n']:>9}  "
                  f"nnz {r['nnz']:>10}  {r['kind']:<8} {r['source']}")
    return 0


def _cmd_ingest(args) -> int:
    names = _select(args)
    before = obs.snapshot()["counters"].get("corpus.parses", 0)
    failures = 0
    for name in names:
        try:
            res = manifest.ensure(name, chunk_nnz=args.chunk_nnz,
                                  allow_download=not args.offline)
        except (ValueError, OSError, KeyError) as e:
            print(f"INGEST FAIL {name}: {e}", file=sys.stderr)
            failures += 1
            continue
        how = "cache-hit" if res.cache_hit else (
            "stand-in" if res.meta.get("standin") else "parsed")
        extra = ""
        if res.parse_stats:
            extra = (f"  chunks={res.parse_stats['chunks']}"
                     f" chunk_nnz={res.parse_stats['chunk_nnz']}")
        print(f"{manifest.CORPUS_PREFIX}{name}: {how}  "
              f"{res.mat.m}x{res.mat.n} nnz={res.mat.nnz}  "
              f"artifact={res.artifact or '-'}{extra}")
    parses = obs.snapshot()["counters"].get("corpus.parses", 0) - before
    print(f"ingest: {len(names) - failures}/{len(names)} ok, "
          f"{parses} parse(s)")
    if args.expect_cached and parses:
        print(f"EXPECT-CACHED FAILED: {parses} matrices were re-parsed "
              "instead of resolving from .csrz artifacts", file=sys.stderr)
        return 1
    return 1 if failures else 0


def _cmd_verify(args) -> int:
    names = _select(args)
    failures = 0
    for name in names:
        try:
            rep = manifest.verify_entry(name)
        except (ValueError, OSError, KeyError) as e:
            print(f"VERIFY FAIL {name}: {e}", file=sys.stderr)
            failures += 1
            continue
        tag = "ok" if rep["ok"] else "FAIL"
        kind = " (stand-in)" if rep["standin"] else ""
        print(f"{manifest.CORPUS_PREFIX}{name}: {tag}{kind}")
        for p in rep["problems"]:
            print(f"  - {p}", file=sys.stderr)
        failures += 0 if rep["ok"] else 1
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.corpus",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace of the run")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("list", help="print the corpus manifest")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_list)

    for cmd, fn, hlp in (("ingest", _cmd_ingest,
                          "parse matrices into .csrz artifacts"),
                         ("verify", _cmd_verify,
                          "check artifacts against manifest + sidecars")):
        p = sub.add_parser(cmd, help=hlp)
        p.add_argument("names", nargs="*", help="corpus names "
                       "(corpus:// prefix optional)")
        p.add_argument("--fixtures", action="store_true",
                       help="select the bundled fixtures")
        p.add_argument("--all", action="store_true",
                       help="select every manifest entry")
        p.add_argument("--offline", action="store_true",
                       help="never download (stand-ins for remote entries)")
        if cmd == "ingest":
            p.add_argument("--chunk-nnz", type=int, default=None,
                           help="coordinate lines per parse block")
            p.add_argument("--expect-cached", action="store_true",
                           help="fail if any matrix had to be parsed")
        p.set_defaults(fn=fn)

    args = ap.parse_args(argv)
    if args.trace:
        with obs.tracing() as buf:
            try:
                rc = args.fn(args)
            finally:
                obs.write_trace(args.trace, buf.flush())
                print(f"trace written to {args.trace}")
        return rc
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
