"""repro.corpus — real-matrix corpus: streaming ingestion, `.csrz`
artifact cache, declarative manifest, and the cross-campaign learned
tuner (DESIGN.md "Corpus & learned tuning").

    from repro import corpus

    mat = corpus.resolve("corpus://bcsstk17")   # fetch|fixture|stand-in
    res = corpus.ingest_path("matrix.mtx")      # chunked parse, cached
    corpus.corpus_names()                       # manifest listing

`corpus://` names also resolve through `repro.matrices.suite.get`, so
experiment specs consume the corpus like any synthetic matrix. The
learned tuner lives in `corpus.advisor` and is reached implicitly via
`plan(problem, probe="learned")`.

CLI: `python -m repro.corpus {list,ingest,verify} [--trace PATH]`.
"""
from __future__ import annotations

from .artifact import (IngestResult, cache_dir, file_sha256, ingest_path,
                       load_csrz, save_csrz, structural_meta)
from .manifest import (CORPUS_PREFIX, CorpusEntry, corpus_names, ensure,
                       get_entry, load_manifest, offline, resolve,
                       verify_entry)
from .mtxstream import (DEFAULT_CHUNK_NNZ, MtxHeader, parse_mtx, read_header,
                        read_mtx)

__all__ = [
    "CORPUS_PREFIX", "CorpusEntry", "DEFAULT_CHUNK_NNZ", "IngestResult",
    "MtxHeader", "TuneAdvisor", "cache_dir", "corpus_names", "ensure",
    "file_sha256", "get_entry", "ingest_path", "load_csrz", "load_manifest",
    "offline", "parse_mtx", "read_header", "read_mtx", "resolve",
    "save_csrz", "structural_meta", "verify_entry",
]


def __getattr__(name):
    # TuneAdvisor pulls in the experiments layer; keep that import out of
    # the ingestion path (matrices/io.py imports this package).
    if name == "TuneAdvisor":
        from .advisor import TuneAdvisor
        return TuneAdvisor
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
