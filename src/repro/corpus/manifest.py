"""Corpus manifest: declarative registry of real matrices + acquisition.

`manifest.json` pins ~10 well-known SuiteSparse matrices (URL, sha256,
expected dims) plus the bundled tiny fixtures under `fixtures/`. Every
entry resolves through one funnel:

    corpus://<name>  →  ensure(name)  →  IngestResult (.csrz artifact)

Acquisition ladder, first rung that works wins:
  1. bundled fixture         — checked-in .mtx, content-hash ingest
  2. already-downloaded .mtx — under <cache>/mtx/, content-hash ingest
  3. download                — resumable (HTTP Range on a .part file),
                               sha256-verified when the manifest pins one,
                               SuiteSparse .tar.gz unpacked in-stream
  4. offline stand-in        — deterministic synthetic matrix at the
                               entry's scale (exact m, approximate nnz),
                               cached as a first-class .csrz artifact

Offline mode (`REPRO_CORPUS_OFFLINE=1`, or any download failure) skips
straight to rung 4, so campaigns — including the ≥100k-row scale
campaign — run with zero network while keeping real-matrix shapes. A
stand-in's sidecar carries `"standin": true` so reports can never pass
synthetic numbers off as the real matrix.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tarfile
import tempfile
import warnings
import zlib
from typing import Dict, Optional

from .. import obs
from ..core.sparse.csr import CSRMatrix
from . import artifact as artifact_mod

CORPUS_PREFIX = "corpus://"
MANIFEST_PATH = os.path.join(os.path.dirname(__file__), "manifest.json")
FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures")

_STANDIN_VERSION = 1  # bump to invalidate cached stand-in artifacts

_KINDS = ("mesh", "graph", "web", "fixture")


@dataclasses.dataclass(frozen=True)
class CorpusEntry:
    """One manifest row. `sha256=None` means "not pinned yet": the
    downloader records the observed hash in the artifact sidecar instead
    of failing."""

    name: str
    group: str
    m: int
    n: int
    nnz: int                 # expected nnz of the ASSEMBLED CSR (post-mirror)
    symmetric: bool
    kind: str                # mesh | graph | web | fixture (stand-in family)
    url: Optional[str] = None
    sha256: Optional[str] = None
    fixture: Optional[str] = None
    tags: tuple = ()

    @property
    def qualified(self) -> str:
        return CORPUS_PREFIX + self.name


def offline() -> bool:
    return os.environ.get("REPRO_CORPUS_OFFLINE", "").strip().lower() in (
        "1", "true", "yes", "on")


def load_manifest(path: Optional[str] = None) -> Dict[str, CorpusEntry]:
    path = path or MANIFEST_PATH
    with open(path) as f:
        raw = json.load(f)
    entries: Dict[str, CorpusEntry] = {}
    for rec in raw["matrices"]:
        e = CorpusEntry(name=rec["name"], group=rec.get("group", ""),
                        m=int(rec["m"]), n=int(rec["n"]), nnz=int(rec["nnz"]),
                        symmetric=bool(rec["symmetric"]), kind=rec["kind"],
                        url=rec.get("url"), sha256=rec.get("sha256"),
                        fixture=rec.get("fixture"),
                        tags=tuple(rec.get("tags", ())))
        if e.name in entries:
            raise ValueError(f"{path}: duplicate corpus entry {e.name!r}")
        if e.kind not in _KINDS:
            raise ValueError(f"{path}: entry {e.name!r} has unknown kind "
                             f"{e.kind!r} (one of {_KINDS})")
        if e.url is None and e.fixture is None:
            raise ValueError(f"{path}: entry {e.name!r} has neither url nor "
                             "fixture — unresolvable")
        if e.m <= 0 or e.n <= 0 or e.nnz <= 0:
            raise ValueError(f"{path}: entry {e.name!r} has non-positive dims")
        entries[e.name] = e
    return entries


def get_entry(name: str) -> CorpusEntry:
    if name.startswith(CORPUS_PREFIX):
        name = name[len(CORPUS_PREFIX):]
    entries = load_manifest()
    try:
        return entries[name]
    except KeyError:
        known = ", ".join(sorted(entries))
        raise KeyError(f"unknown corpus matrix {name!r}; manifest has: "
                       f"{known}") from None


def corpus_names() -> list:
    """Qualified corpus:// names, the form the suite registry exposes."""
    return [CORPUS_PREFIX + n for n in sorted(load_manifest())]


# -- acquisition -----------------------------------------------------------

def _mtx_dir() -> str:
    return os.path.join(artifact_mod.cache_dir(), "mtx")


def _local_mtx_path(entry: CorpusEntry) -> str:
    if entry.fixture:
        return os.path.join(FIXTURE_DIR, entry.fixture)
    return os.path.join(_mtx_dir(), f"{entry.name}.mtx")


def _download(url: str, dest: str, timeout: float = 60.0) -> None:
    """Resumable download: append to `dest + '.part'` with an HTTP Range
    request when a partial file exists, then atomic-rename into place."""
    import urllib.request

    part = dest + ".part"
    os.makedirs(os.path.dirname(dest), exist_ok=True)
    have = os.path.getsize(part) if os.path.exists(part) else 0
    req = urllib.request.Request(url)
    if have:
        req.add_header("Range", f"bytes={have}-")
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        if have and resp.status != 206:
            have = 0  # server ignored Range: restart from scratch
        mode = "ab" if have else "wb"
        with open(part, mode) as f:
            while True:
                block = resp.read(1 << 20)
                if not block:
                    break
                f.write(block)
    os.replace(part, dest)


def fetch(entry: CorpusEntry, timeout: float = 60.0) -> str:
    """Materialize the entry's .mtx file locally; returns its path.

    SuiteSparse ships MatrixMarket as `<group>/<name>.tar.gz` containing
    `<name>/<name>.mtx`; plain `.mtx` URLs are stored as-is. Verifies the
    manifest sha256 (of the downloaded archive/file) when pinned.
    """
    mtx = _local_mtx_path(entry)
    if os.path.exists(mtx):
        return mtx
    if entry.url is None:
        raise ValueError(f"corpus entry {entry.name!r} has no url "
                         "(fixture-only) and no local file")
    is_tar = entry.url.endswith((".tar.gz", ".tgz"))
    dl = os.path.join(_mtx_dir(),
                      f"{entry.name}.tar.gz" if is_tar else f"{entry.name}.mtx")
    with obs.span("corpus.fetch", matrix=entry.name, url=entry.url):
        _download(entry.url, dl, timeout=timeout)
        if entry.sha256:
            got = artifact_mod.file_sha256(dl)
            if got != entry.sha256:
                os.remove(dl)
                raise ValueError(
                    f"corpus entry {entry.name!r}: sha256 mismatch "
                    f"(manifest {entry.sha256[:12]}…, downloaded {got[:12]}…)")
        if is_tar:
            member = f"{entry.name}/{entry.name}.mtx"
            with tarfile.open(dl, "r:gz") as tf:
                src = tf.extractfile(member)
                if src is None:
                    raise ValueError(f"{dl}: member {member!r} missing")
                fd, tmp = tempfile.mkstemp(dir=_mtx_dir(),
                                           prefix=f".{entry.name}.")
                with os.fdopen(fd, "wb") as out:
                    while True:
                        block = src.read(1 << 20)
                        if not block:
                            break
                        out.write(block)
            os.replace(tmp, mtx)
            os.remove(dl)
    return mtx


# -- offline stand-ins -----------------------------------------------------

def _standin_key(entry: CorpusEntry) -> str:
    import hashlib

    sig = f"standin:v{_STANDIN_VERSION}:{entry.name}:{entry.m}:{entry.n}:" \
          f"{entry.nnz}:{entry.kind}"
    return hashlib.sha256(sig.encode()).hexdigest()


def standin(entry: CorpusEntry) -> CSRMatrix:
    """Deterministic synthetic matrix at the entry's scale: exact m (the
    quantity the scale stamp keys on), nnz matched to the entry's average
    degree, structural family matched to `kind`."""
    from ..matrices import generators

    seed = zlib.crc32(entry.name.encode()) & 0x7FFFFFFF
    deg = max(1, round(entry.nnz / max(entry.m, 1)))
    if entry.kind in ("mesh", "fixture"):
        half_bw = max(1, (deg - 1) // 2)
        return generators.banded(entry.m, half_bw, seed=seed)
    if entry.kind == "graph":
        return generators.random_uniform(entry.m, deg, seed=seed)
    # web: the row-skew regime
    return generators.power_law(entry.m, alpha=2.1, seed=seed)


def _ensure_standin(entry: CorpusEntry) -> artifact_mod.IngestResult:
    key = _standin_key(entry)
    use_cache = artifact_mod.cache_enabled()
    zpath = artifact_mod.artifact_paths(key)[0] if use_cache else ""
    if use_cache:
        hit = artifact_mod.load_csrz(zpath)
        if hit is not None:
            obs.counter("corpus.artifact_hits").inc()
            mat, meta = hit
            return artifact_mod.IngestResult(mat=mat, meta=meta, key=key,
                                             artifact=zpath, cache_hit=True,
                                             parse_stats=None)
        obs.counter("corpus.artifact_misses").inc()
    with obs.span("corpus.standin", matrix=entry.name, m=entry.m,
                  kind=entry.kind):
        mat = standin(entry)
        meta = artifact_mod.structural_meta(mat)
        meta["standin"] = True
        meta["source"] = {"name": entry.name, "kind": entry.kind,
                          "target_nnz": entry.nnz,
                          "version": _STANDIN_VERSION}
        if use_cache:
            artifact_mod.save_csrz(zpath, mat, meta)
    obs.counter("corpus.standins").inc()
    return artifact_mod.IngestResult(mat=mat, meta=meta, key=key,
                                     artifact=zpath, cache_hit=False,
                                     parse_stats=None)


# -- the resolution funnel -------------------------------------------------

def _check_dims(entry: CorpusEntry, res: artifact_mod.IngestResult) -> None:
    got = (res.mat.m, res.mat.n, res.mat.nnz)
    want = (entry.m, entry.n, entry.nnz)
    if got != want:
        raise ValueError(
            f"corpus entry {entry.name!r}: manifest expects m/n/nnz {want}, "
            f"ingested file has {got} — stale manifest or wrong file")


def ensure(name: str, chunk_nnz: Optional[int] = None,
           allow_download: bool = True) -> artifact_mod.IngestResult:
    """Resolve a corpus name to an ingested artifact (the funnel above)."""
    entry = get_entry(name)
    mtx = _local_mtx_path(entry)
    if os.path.exists(mtx):
        res = artifact_mod.ingest_path(mtx, chunk_nnz=chunk_nnz)
        _check_dims(entry, res)
        return res
    if entry.fixture:
        raise FileNotFoundError(
            f"corpus entry {entry.name!r}: bundled fixture {mtx} is missing")
    if offline() or not allow_download:
        return _ensure_standin(entry)
    try:
        mtx = fetch(entry)
    except Exception as e:  # network/extract failure → stand-in, loudly
        obs.counter("corpus.fetch_failures").inc()
        warnings.warn(f"corpus: fetch of {entry.name!r} failed ({e!r}); "
                      "falling back to a synthetic stand-in", RuntimeWarning,
                      stacklevel=2)
        return _ensure_standin(entry)
    res = artifact_mod.ingest_path(mtx, chunk_nnz=chunk_nnz)
    _check_dims(entry, res)
    return res


def resolve(name: str, chunk_nnz: Optional[int] = None) -> CSRMatrix:
    """corpus://<name> → CSRMatrix (what `matrices.suite.get` delegates to)."""
    return ensure(name, chunk_nnz=chunk_nnz).mat


def verify_entry(name: str) -> dict:
    """Consistency report for one entry: artifact present? sidecar matches
    a recomputed structural summary? dims match the manifest?"""
    entry = get_entry(name)
    report = {"name": entry.name, "ok": True, "problems": [], "artifact": None,
              "standin": None}
    res = ensure(name)
    report["artifact"] = res.artifact
    report["standin"] = bool(res.meta.get("standin"))
    fresh = artifact_mod.structural_meta(res.mat)
    for fld in ("m", "n", "nnz"):
        if fresh[fld] != res.meta.get(fld):
            report["problems"].append(
                f"sidecar {fld}={res.meta.get(fld)} != recomputed {fresh[fld]}")
    if not report["standin"]:
        want = (entry.m, entry.n, entry.nnz)
        got = (fresh["m"], fresh["n"], fresh["nnz"])
        if want != got:
            report["problems"].append(f"manifest dims {want} != artifact {got}")
    elif fresh["m"] != entry.m or fresh["n"] != entry.n:
        report["problems"].append(
            f"stand-in shape {(fresh['m'], fresh['n'])} != manifest "
            f"{(entry.m, entry.n)}")
    report["ok"] = not report["problems"]
    return report
