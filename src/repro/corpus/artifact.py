"""Device-ready `.csrz` artifact cache — a real matrix is parsed once, ever.

A `.csrz` artifact is the compact binary form of an ingested matrix:

    <key>.csrz       — compressed npz: indptr / indices / values / shape
                       (the exact CSRMatrix arrays, bit-identical on load)
    <key>.csrz.json  — structural-metrics sidecar: dims, density, the
                       tuner feature vector, locality summary, provenance
                       (source path + sha256 + parse accounting)

`key` is the streamed sha256 of the *source file bytes*, so re-ingesting
the same MatrixMarket file — any path, any process — resolves to the
cached artifact without touching the parser (`corpus.artifact_hits` vs
`corpus.parses` counters make this auditable). Writes follow the repo's
cache convention (plan.py / opcache.py): tmp + atomic rename, npz first,
sidecar json LAST so a reader never sees a torn artifact; loads are
tolerant (any corruption → None → re-parse).

Cache root: $REPRO_CORPUS_CACHE (default /tmp/repro_corpus; "off"/"0"/
"none" disables, same convention as the other REPRO_* caches).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from typing import Optional, Tuple

import numpy as np

from .. import obs
from ..core.sparse import metrics
from ..core.sparse.csr import CSRMatrix
from . import mtxstream

CSRZ_SCHEMA = 1

_OFF = ("off", "0", "none", "")


def cache_dir() -> str:
    return os.environ.get("REPRO_CORPUS_CACHE", "/tmp/repro_corpus")


def cache_enabled() -> bool:
    return cache_dir().strip().lower() not in _OFF


def file_sha256(path: str, block_bytes: int = 1 << 20) -> str:
    """Streamed content hash of the source file — the artifact key."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            b = f.read(block_bytes)
            if not b:
                break
            h.update(b)
    return h.hexdigest()


def artifact_paths(key: str, root: Optional[str] = None) -> Tuple[str, str]:
    base = os.path.join(root or cache_dir(), key)
    return base + ".csrz", base + ".csrz.json"


def structural_meta(mat: CSRMatrix) -> dict:
    """The sidecar: everything the advisor/reporting layers read without
    ever loading the arrays."""
    from ..core.spmv.tune import matrix_features

    feat = matrix_features(mat)
    m, n = mat.shape
    return {
        "m": int(m),
        "n": int(n),
        "nnz": int(mat.nnz),
        "dtype": str(mat.vals.dtype),
        "density": float(mat.nnz) / max(float(m) * float(n), 1.0),
        "features": feat,
        "locality": metrics.summary(mat),
    }


def save_csrz(path: str, mat: CSRMatrix, meta: Optional[dict] = None) -> str:
    """Atomically write `<base>.csrz` + `<base>.csrz.json`; returns the
    npz path. `path` may be given with or without the .csrz suffix."""
    base = path[:-5] if path.endswith(".csrz") else path
    zpath, jpath = base + ".csrz", base + ".csrz.json"
    d = os.path.dirname(zpath)
    if d:
        os.makedirs(d, exist_ok=True)
    if meta is None:
        meta = structural_meta(mat)
    tag = f"{os.getpid()}.{threading.get_ident()}"
    ztmp, jtmp = f"{zpath}.{tag}.tmp", f"{jpath}.{tag}.tmp"
    try:
        with open(ztmp, "wb") as f:
            np.savez_compressed(f, indptr=mat.rowptr, indices=mat.cols,
                                values=mat.vals,
                                shape=np.asarray(mat.shape, dtype=np.int64))
        os.replace(ztmp, zpath)
        with open(jtmp, "w") as f:
            json.dump({"schema": CSRZ_SCHEMA, "meta": meta}, f)
        os.replace(jtmp, jpath)  # json lands LAST: it gates reads
    except OSError:
        for t in (ztmp, jtmp):
            try:
                os.remove(t)
            except OSError:
                pass
        raise
    obs.counter("corpus.artifact_writes").inc()
    return zpath


def load_csrz(path: str) -> Optional[Tuple[CSRMatrix, dict]]:
    """Tolerant artifact load: (matrix, meta) or None on any miss or
    corruption (caller re-parses)."""
    base = path[:-5] if path.endswith(".csrz") else path
    zpath, jpath = base + ".csrz", base + ".csrz.json"
    try:
        with open(jpath) as f:
            rec = json.load(f)
        if rec.get("schema") != CSRZ_SCHEMA:
            return None
        with np.load(zpath) as z:
            mat = CSRMatrix(rowptr=np.ascontiguousarray(z["indptr"]),
                            cols=np.ascontiguousarray(z["indices"]),
                            vals=np.ascontiguousarray(z["values"]),
                            shape=tuple(int(s) for s in z["shape"]))
        if mat.rowptr.shape[0] != mat.shape[0] + 1:
            return None
        return mat, rec.get("meta", {})
    except Exception:
        return None


@dataclasses.dataclass
class IngestResult:
    mat: CSRMatrix
    meta: dict
    key: str             # content hash (or stand-in key) of the source
    artifact: str        # npz path ("" when caching is disabled)
    cache_hit: bool
    parse_stats: Optional[dict]  # None on a cache hit — nothing was parsed


def ingest_path(path: str, chunk_nnz: Optional[int] = None,
                cache: bool = True) -> IngestResult:
    """Ingest a MatrixMarket file through the artifact cache.

    Hit: zero parse work (the `corpus.parses` counter does not move).
    Miss: chunked parse (`corpus.parse`/`corpus.build` spans) + artifact
    write, keyed by the source file's sha256.
    """
    key = file_sha256(path)
    use_cache = cache and cache_enabled()
    zpath = artifact_paths(key)[0] if use_cache else ""
    if use_cache:
        hit = load_csrz(zpath)
        if hit is not None:
            obs.counter("corpus.artifact_hits").inc()
            mat, meta = hit
            return IngestResult(mat=mat, meta=meta, key=key, artifact=zpath,
                                cache_hit=True, parse_stats=None)
        obs.counter("corpus.artifact_misses").inc()
    mat, stats = mtxstream.parse_mtx(path, chunk_nnz=chunk_nnz)
    meta = structural_meta(mat)
    meta["source"] = {
        "path": os.path.abspath(path),
        "sha256": key,
        "field": stats["field"],
        "symmetry": stats["symmetry"],
        "parse": {k: stats[k] for k in
                  ("chunks", "chunk_nnz", "max_chunk_elems", "passes",
                   "duplicates_merged")},
    }
    if use_cache:
        save_csrz(zpath, mat, meta)
    return IngestResult(mat=mat, meta=meta, key=key, artifact=zpath,
                        cache_hit=False, parse_stats=stats)
