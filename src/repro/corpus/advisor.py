"""TuneAdvisor — the closed-loop learned tuner (`plan(probe="learned")`).

OSKI re-probes every matrix from scratch; the OSKI-enhancement line of
work (Akbudak, Kayaaslan & Aykanat) shows the structural metrics that
*predict* which storage/engine wins. The ResultStore already holds
measured cells — each records the tuner's feature vector, the decision
that was probed, and the throughput it achieved. The advisor closes the
loop:

    embed(features)  — normalize the structural metrics into a feature
                       space: log-scale size/density, row-nnz CV,
                       relative bandwidth + profile, block fill, distinct
                       col blocks per block row
    knowledge base   — mined lazily from prior ResultStore cells
                       (spmv cells carrying "features"+"tuner_decision")
    shortlist()      — nearest-neighbor match (z-normalized euclidean,
                       k=3 neighbors), map the neighbors' decisions onto
                       the current candidate grid, return a top-k ranked
                       shortlist + a confidence in (0, 1]

`tune(probe="learned")` then times only the shortlist instead of the
model's top-3 or the exhaustive grid, and records agreement as obs
counters: `advisor.hits` (the prediction won the probe), `advisor.misses`
(a probed alternative won), `advisor.fallbacks` (empty knowledge base →
model ranking). The chosen plan carries `advisor_confidence` so reports
can condition on how much the decision was trusted.
"""
from __future__ import annotations

import math
import threading
from typing import Optional

import numpy as np

from ..experiments.store import ResultStore
from ..core.spmv.tune import PROBE_TOP_K, _label

# feature-space axes, in order (documented in DESIGN.md)
FEATURE_AXES = (
    "log_m",            # problem size decade
    "log_nnz",
    "row_nnz_mean",
    "row_nnz_cv",       # skew — the SELL-vs-ELL axis
    "rel_bandwidth",    # avg row bandwidth / n — RCM's objective, normalized
    "rel_profile",      # envelope per row / n
    "block_fill",       # MXU-brick usefulness
    "blocks_per_row",   # distinct col blocks per block row (x-tile traffic)
    "log_density",      # density bucket (log10 nnz/(m*n))
)

_EPS = 1e-9


def embed(feat: dict) -> np.ndarray:
    """Project a tuner feature dict (tune.matrix_features) onto FEATURE_AXES.
    Missing keys (records from older schemas) default to 0."""
    m = max(int(feat.get("m", 1)), 1)
    n = max(int(feat.get("n", 1)), 1)
    nnz = max(int(feat.get("nnz", 1)), 1)
    nbr = max(int(feat.get("num_block_rows", 1)), 1)
    return np.array([
        math.log10(m),
        math.log10(nnz),
        nnz / m,
        float(feat.get("row_nnz_cv", 0.0)),
        float(feat.get("avg_row_bandwidth", 0.0)) / n,
        float(feat.get("profile_per_row", 0.0)) / n,
        float(feat.get("block_fill", 0.0)),
        float(feat.get("nonempty_blocks", 0)) / nbr,
        math.log10(max(nnz / (float(m) * float(n)), _EPS)),
    ], dtype=np.float64)


def _mine_record(record: dict) -> Optional[dict]:
    """One KB row from one stored cell record, or None if the record
    predates the learned-tuner schema."""
    feat = record.get("features")
    dec = record.get("tuner_decision")
    if not isinstance(feat, dict) or not isinstance(dec, dict):
        return None
    gflops = record.get("seq_ios_gflops") or record.get("gflops") or 0.0
    return {
        "vec": embed(feat),
        "decision": dec,
        "gflops": float(gflops),
        "matrix": record.get("matrix", "?"),
    }


class TuneAdvisor:
    """Feature-space nearest-neighbor over prior campaign decisions."""

    def __init__(self, store: Optional[ResultStore] = None,
                 k_neighbors: int = 3, top_k: int = 2):
        self.store = store or ResultStore()
        self.k_neighbors = max(int(k_neighbors), 1)
        # top_k < PROBE_TOP_K by design: the learned mode must probe
        # strictly fewer candidates than both probe modes
        self.top_k = max(int(top_k), 1)
        self._lock = threading.Lock()
        self._kb = None          # list of KB rows
        self._mat = None         # stacked feature matrix
        self._mean = None
        self._std = None

    # -- knowledge base ----------------------------------------------------
    def refresh(self) -> int:
        """(Re-)mine the ResultStore; returns the knowledge-base size."""
        rows = []
        for _key, entry in self.store.entries():
            row = _mine_record(entry.get("record", {}))
            if row is not None:
                rows.append(row)
        with self._lock:
            self._kb = rows
            if rows:
                self._mat = np.stack([r["vec"] for r in rows])
                self._mean = self._mat.mean(axis=0)
                std = self._mat.std(axis=0)
                self._std = np.where(std > _EPS, std, 1.0)
            else:
                self._mat = self._mean = self._std = None
        return len(rows)

    def knowledge_size(self) -> int:
        if self._kb is None:
            self.refresh()
        return len(self._kb)

    # -- matching ----------------------------------------------------------
    def _match(self, decision: dict, cands: list) -> Optional[dict]:
        """Map a mined decision onto the current candidate grid: exact
        (engine, block_shape, sigma) first, then (engine, block_shape),
        then cheapest same-engine candidate; None if the engine is gone."""
        eng = decision.get("engine")
        shape = tuple(decision.get("block_shape") or ())
        sigma = decision.get("sell_sigma")
        same_eng = [cd for cd in cands if cd["engine"] == eng]
        if not same_eng:
            return None
        for cd in same_eng:
            if tuple(cd["block_shape"]) == shape and cd["sigma"] == sigma:
                return cd
        for cd in same_eng:
            if tuple(cd["block_shape"]) == shape:
                return cd
        return same_eng[0]  # cands arrive model-ranked: cheapest first

    def shortlist(self, feat: dict, ranked_cands: list):
        """(shortlist, confidence, predicted_label) for a feature dict and
        a model-ranked candidate list. Empty shortlist = no usable
        knowledge (caller falls back to the model ranking)."""
        if self._kb is None:
            self.refresh()
        if not self._kb:
            return [], 0.0, None
        q = (embed(feat) - self._mean) / self._std
        d = np.linalg.norm((self._mat - self._mean) / self._std - q, axis=1)
        order = np.argsort(d, kind="stable")[:self.k_neighbors]
        picks, seen = [], set()
        for i in order:
            cd = self._match(self._kb[int(i)]["decision"], ranked_cands)
            if cd is None:
                continue
            lab = _label(cd["engine"], cd["block_shape"], cd["sigma"])
            if lab not in seen:
                seen.add(lab)
                picks.append(cd)
        if not picks:
            return [], 0.0, None
        predicted = _label(picks[0]["engine"], picks[0]["block_shape"],
                           picks[0]["sigma"])
        # pad with the model ranking so a lone neighbor still gets a
        # sanity-check competitor (but never reach PROBE_TOP_K width)
        for cd in ranked_cands:
            if len(picks) >= self.top_k:
                break
            lab = _label(cd["engine"], cd["block_shape"], cd["sigma"])
            if lab not in seen:
                seen.add(lab)
                picks.append(cd)
        confidence = float(1.0 / (1.0 + float(d[order[0]])))
        return picks[:self.top_k], confidence, predicted


# -- default advisor (what tune() reaches for) -----------------------------
# One advisor per store root: the KB is mined lazily on first use and
# shared across plans in the process; call refresh() (or advisor_reset())
# after seeding new measurements mid-process.
_DEFAULTS = {}
_DEFAULTS_LOCK = threading.Lock()


def default_advisor() -> TuneAdvisor:
    store = ResultStore()
    with _DEFAULTS_LOCK:
        adv = _DEFAULTS.get(store.root)
        if adv is None:
            adv = TuneAdvisor(store=store)
            _DEFAULTS[store.root] = adv
        return adv


def advisor_reset() -> None:
    """Drop memoized advisors (tests / after reseeding a store)."""
    with _DEFAULTS_LOCK:
        _DEFAULTS.clear()
