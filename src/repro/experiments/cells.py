"""Cell measurement kinds — what the Runner executes on a store miss.

A kind is a function `(cell, mat) -> record` registered in CELL_KINDS.
Built-ins:

  * "spmv"     — the paper's full per-cell protocol through the
                 Problem→Plan→Operator facade: plan (reorder + tune) once,
                 then any subset of {IOS, YAX, instrumented CG,
                 modelled-parallel static/nnz-balanced, analytic structural
                 metrics} per the cell's resolved policy. k > 1 times the
                 SpMM path (`op.matmul`) and reports amortized per-vector
                 time. Plan-time fields (reorder_ms/tune_ms/build_ms) are
                 recorded separately from run-time fields — the paper's
                 §3 accounting rule.
  * "schedule" — the scheduling-policy sweep (paper Fig. 4 adapted):
                 variant names pick the policy — "static_default",
                 "static_c<chunk>" (strided chunked-cyclic panels, each
                 timed on its own gathered submatrix), "nnz_balanced".
  * "parallel" — topology-aware cells (figs 4, 9–11 as campaigns): the
                 variant is "<layout>:<partitioner>" (e.g.
                 "1d_rows:nnz_balanced", "1d_rows:chunked_cyclic_c16",
                 "2d_panels:metis_cut"); the cell plans through
                 plan(topology=Topology(devices=p, layout=...)) and
                 records the partition-quality metrics (LI, cut volume,
                 halo width), the modelled collective bytes/schedule, the
                 calibrated modelled-parallel timing on the plan's
                 panels, and (verify=True) the ShardedOperator's
                 original-index-space oracle check.
  * "workload" — one dynamic-sparsity stream (repro.workloads): the cell
                 matrix is a `workload://` name, the variant the
                 scenario ("static" value-only / "drift" per-step
                 structure change / "shift1" one mid-stream change). The
                 whole stream runs through a `WorkloadSession`
                 (plan/replan/rebuild/reuse amortization policy) and the
                 record is the stream summary: per-step LI, drop_frac,
                 reuse rate, plan-cost share, sparse-vs-reference
                 (sorted-vs-onehot for moe) speedup, verification.
  * "serve"    — one open-loop traffic-sim run against a hardened
                 SpmvService (serving/traffic.py): the variant encodes
                 the load shape + service limits (`serve_variant(...)`),
                 cell.k is the service's max_batch, and the record is
                 the SLO summary — outcome counts (ok/shed/rejected/
                 errors/unresolved), p50/p95/p99 latency, throughput,
                 eviction + value-swap counters, and budget compliance.
  * "route"    — one traffic run against a RoutedSpmvService FLEET
                 (repro.router): the variant encodes load + fleet shape
                 (`route_variant(...)` — meshes, devices per mesh,
                 placement policy, per-device budget, structure-delta
                 mix) and the record adds the router verdicts:
                 per_device_ok, replans landed vs delta applies, and the
                 key→mesh assignment.

Third-party kinds register with @register_cell_kind and become one spec
line (`ExperimentSpec(kind=...)`) like everything else.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

CELL_KINDS: Dict[str, Callable] = {}


def register_cell_kind(name: str, override: bool = False) -> Callable:
    def deco(fn: Callable) -> Callable:
        if name in CELL_KINDS and not override:
            raise ValueError(f"cell kind {name!r} already registered")
        CELL_KINDS[name] = fn
        return fn

    return deco


def get_cell_kind(name: str) -> Callable:
    try:
        return CELL_KINDS[name]
    except KeyError:
        raise KeyError(f"unknown cell kind {name!r}; known: "
                       f"{sorted(CELL_KINDS)}") from None


def _median_ios(op, x0, k, n, dtype, pol) -> float:
    """Median IOS milliseconds over `repeats` independent runs."""
    from ..core.measure import ios

    samples = []
    for r in range(int(pol["repeats"])):
        if k <= 1:
            t = ios.run_ios(op, x0, iters=pol["iters"], warmup=pol["warmup"])
        else:
            t = ios.run_ios_batched(op, n, k, iters=pol["iters"],
                                    warmup=pol["warmup"], dtype=dtype,
                                    seed=pol["seed"] + r)
        samples.append(np.asarray(t))
    return float(np.median(np.concatenate(samples)))


def _verify_original_space(op_full, mat, k, dtype, tol, seed) -> float:
    """Max relative error of the permutation-carrying operator against the
    numpy oracle in the ORIGINAL index space (exercises perm/iperm)."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    if k <= 1:
        x = rng.standard_normal(mat.n)
        got = np.asarray(op_full(jnp.asarray(x, dtype)))
        want = mat.spmv(x)
    else:
        x = rng.standard_normal((mat.n, k))
        got = np.asarray(op_full.matmul(jnp.asarray(x, dtype)))
        want = np.stack([mat.spmv(x[:, j]) for j in range(k)], axis=1)
    scale = float(np.abs(want).max()) + 1e-9
    err = float(np.abs(got - want).max()) / scale
    if err > tol:
        raise AssertionError(
            f"verify failed: rel_err={err:.3e} > {tol:.1e} "
            f"({mat.m}x{mat.n} matrix, k={k})")
    return err


@register_cell_kind("spmv")
def measure_spmv_cell(cell, mat) -> dict:
    """All measurements for one (matrix, scheme, machine point, k) cell."""
    import jax.numpy as jnp

    from ..api import SpmvProblem, plan
    from ..core.measure import cg, ios, parallel_model
    from ..core.sparse import metrics, partition

    pol = cell.policy_dict()
    dtype = jnp.dtype(cell.dtype)
    hints = {"seed": pol["seed"]}
    if pol["use_kernel"] != "auto":
        hints["use_kernel"] = pol["use_kernel"]
    # one plan() + build() through the pipeline facade: repeat campaigns
    # reload plan + device arrays from the plan store (plan time -> ~0)
    pl = plan(SpmvProblem(mat, k=cell.k, dtype=cell.dtype, hints=hints),
              reorder=cell.scheme, engine=cell.engine, probe=pol["probe"])
    rmat = pl.reordered_matrix()
    rec = {
        "m": int(mat.m), "n": int(mat.n), "nnz": int(rmat.nnz),
        # plan-time accounting (paper methodology: preprocessing is
        # reported separately from SpMV run-time, never folded in)
        "resolved_scheme": pl.scheme,
        "tuner_choice": pl.tune.engine,
        "plan_label": pl.tune.label(),
        "reorder_ms": pl.reorder_ms,
        "tune_ms": pl.tune_ms,
        "plan_ms": pl.plan_ms,
        "plan_store_hit": bool(pl.cache_hit),
    }
    # tuner knowledge for the cross-campaign advisor (repro.corpus.advisor
    # mines these pairs out of the store): the structural feature vector
    # and the decision the tuner landed on, plus probe accounting so
    # learned-vs-exhaustive campaigns can compare probe effort.
    if pl.tune.features:
        rec["features"] = {k: float(v) for k, v in pl.tune.features.items()}
    rec["tuner_decision"] = {
        "engine": pl.tune.engine,
        "block_shape": list(pl.tune.block_shape),
        "sell_sigma": (None if pl.tune.sell_sigma is None
                       else int(pl.tune.sell_sigma)),
    }
    rec["advisor_confidence"] = float(pl.advisor_confidence)
    rec["probed_candidates"] = len(pl.tune.probe_ms or {})
    rec["tuner_candidates"] = len(pl.tune.costs)
    if cell.engine == "auto":
        rec["tuner_label"] = pl.tune.label()
        rec["tuner_cost_bytes"] = pl.tune.cost_bytes

    need_op = pol["time_spmv"] or pol["with_yax"] or pol["with_cg"] \
        or pol["verify"]
    panel_engine = cell.engine
    if need_op:
        op_full = pl.build()
        build_info = op_full.build_info
        op = op_full.unwrap()     # measurements run in the reordered space
        rec.update({
            "engine": build_info["engine"],
            "format_build_ms": build_info["build_ms"],
            "op_cache_hit": build_info["cache_hit"],
            "op_load_ms": build_info["load_ms"],
        })
        # panels use the CONCRETE engine the tuner chose for the whole
        # matrix (never "auto": re-tuning per panel would time the tuner)
        panel_engine = build_info["engine"] if cell.engine == "auto" \
            else cell.engine
        if pol["verify"]:
            rec["verify_rel_err"] = _verify_original_space(
                op_full, mat, cell.k, dtype, pol.get("verify_tol", 1e-4),
                pol["seed"])
        rng = np.random.default_rng(pol["seed"])
        x0 = jnp.asarray(rng.standard_normal(rmat.n), dtype)
        if pol["time_spmv"]:
            ms = _median_ios(op, x0, cell.k, rmat.n, dtype, pol)
            if cell.k <= 1:
                rec["seq_ios_ms"] = ms
                rec["seq_ios_gflops"] = float(
                    ios.gflops(rmat.nnz, np.array([ms]))[0])
                # aliases so k is a uniform axis in SpMM-shaped reports
                rec["spmm_ms"] = ms
                rec["per_vector_ms"] = ms
            else:
                rec["spmm_ms"] = ms
                rec["per_vector_ms"] = ms / cell.k
                rec["spmm_gflops"] = float(
                    ios.gflops(rmat.nnz * cell.k, np.array([ms]))[0])
        if pol["with_yax"] and cell.k <= 1:
            yax = float(np.median(ios.run_yax(
                op, x0, iters=pol["iters"], warmup=pol["warmup"])))
            rec["seq_yax_ms"] = yax
            rec["seq_yax_gflops"] = float(
                ios.gflops(rmat.nnz, np.array([yax]))[0])
        if pol["with_cg"] and cell.k <= 1:
            cg_ms = float(np.median(cg.cg_measured(
                op, x0, iters=pol["iters"], warmup=pol["warmup"])))
            rec["cg_ms"] = cg_ms
            rec["cg_gflops"] = float(
                ios.gflops(rmat.nnz, np.array([cg_ms]))[0])

    if pol["with_parallel"]:
        for sched in ("static", "nnz_balanced"):
            ms = parallel_model.modelled_parallel_ms(
                rmat, cell.p, panel_engine, schedule=sched,
                iters=max(6, pol["iters"] // 2))
            rec[f"par_{sched}_ms"] = ms
            rec[f"par_{sched}_gflops"] = float(
                ios.gflops(rmat.nnz, np.array([ms]))[0])
    if pol["with_metrics"]:
        # structural metrics (analytic, exact) at this cell's p
        panels_s = partition.static_partition(rmat, cell.p)
        panels_b = partition.nnz_balanced_partition(rmat, cell.p)
        rec["li_static"] = metrics.load_imbalance(rmat, panels_s)
        rec["li_nnz_balanced"] = metrics.load_imbalance(rmat, panels_b)
        rec["bandwidth"] = metrics.bandwidth(rmat)
        rec["avg_row_bandwidth"] = metrics.avg_row_bandwidth(rmat)
        rec["cut_volume"] = metrics.cut_volume(rmat, panels_s)
        rec["block_fill_8x128"] = metrics.block_fill_ratio(rmat, 8, 128)
    return rec


# --------------------------------------------------------------------------
# topology-aware cells (figs 4, 9-11 as campaigns over sharded plans)
# --------------------------------------------------------------------------
def parallel_variant(layout: str, partitioner: str) -> str:
    """The variants-axis encoding of one (layout, partitioner) point."""
    return f"{layout}:{partitioner}"


def _parse_parallel_variant(variant: str):
    from ..core.spmv.topology import LAYOUTS

    layout, _, part = (variant or "").partition(":")
    if not part:
        if layout in LAYOUTS:            # bare layout -> default partition
            part = "nnz_balanced"
        else:                            # bare partitioner -> default layout
            layout, part = "1d_rows", layout or "nnz_balanced"
    return layout, part


@register_cell_kind("parallel")
def measure_parallel_cell(cell, mat) -> dict:
    """One (matrix, scheme, machine point, layout x partitioner) cell of a
    distributed campaign, through the topology-aware facade."""
    import jax.numpy as jnp

    from ..api import SpmvProblem, Topology, plan
    from ..core.measure import ios, parallel_model

    pol = cell.policy_dict()
    if cell.p < 2:
        raise ValueError(
            f"'parallel' cells need p >= 2 devices, got p={cell.p} "
            f"(a 1-device topology is the single-device pipeline — "
            f"use the 'spmv' kind)")
    layout, part = _parse_parallel_variant(cell.variant)
    topo = Topology(devices=cell.p, layout=layout)
    dtype = jnp.dtype(cell.dtype)
    hints = {"seed": pol["seed"]}
    pl = plan(SpmvProblem(mat, k=cell.k, dtype=cell.dtype, hints=hints),
              reorder=cell.scheme, engine=cell.engine, topology=topo,
              partition=part)
    rmat = pl.reordered_matrix()
    comm = pl.comm
    rec = {
        "m": int(mat.m), "n": int(mat.n), "nnz": int(rmat.nnz),
        "devices": int(cell.p), "layout": layout,
        "partitioner": pl.partitioner,
        "resolved_scheme": pl.scheme,
        "engine": pl.tune.engine,
        "plan_label": pl.label(),
        "reorder_ms": pl.reorder_ms,
        "tune_ms": pl.tune_ms,
        "plan_ms": pl.plan_ms,
        "plan_store_hit": bool(pl.cache_hit),
        # partition quality (the paper's parallel-execution story):
        "li": comm.get("li"),
        "cut_volume": comm.get("cut_volume"),
        "halo_width": comm.get("halo_width"),
        "comm_schedule": comm.get("schedule"),
        "comm_bytes_per_spmv": comm.get("bytes_per_spmv"),
        "gather_bytes": comm.get("gather_bytes"),
        "halo_bytes": comm.get("halo_bytes"),
        "h_pad": comm.get("h_pad"),
    }
    if pol["verify"]:
        op = pl.build()
        rec.update({
            "op_cache_hit": op.build_info.get("cache_hit", False),
            "op_load_ms": op.build_info.get("load_ms", 0.0),
            "format_build_ms": op.build_info.get("build_ms", 0.0),
            "simulated": bool(op.simulated),
        })
        rec["verify_rel_err"] = _verify_original_space(
            op, mat, cell.k, dtype, pol.get("verify_tol", 1e-4),
            pol["seed"])
    if pol["time_spmv"]:
        # calibrated per-panel model on the plan's own panels — the same
        # protocol as the "schedule" kind, so figs 4/11 stay comparable
        ms = parallel_model.modelled_parallel_ms(
            rmat, topo.row_devices, pl.tune.engine,
            panels=pl.panel_starts, iters=pol["iters"],
            rng_seed=pol["seed"])
        rec["modelled_par_ms"] = ms
        rec["gflops"] = float(ios.gflops(rmat.nnz, np.array([ms]))[0])
    return rec


# --------------------------------------------------------------------------
# scheduling-policy cells (paper Fig. 4 adapted)
# --------------------------------------------------------------------------
def _rows_submatrix(mat, rows: np.ndarray):
    from ..core.sparse.csr import CSRMatrix

    rp = mat.rowptr.astype(np.int64)
    counts = rp[rows + 1] - rp[rows]
    idx = np.concatenate([np.arange(rp[r], rp[r + 1]) for r in rows]) \
        if rows.size else np.empty(0, np.int64)
    rowptr = np.zeros(rows.size + 1, dtype=np.int64)
    rowptr[1:] = np.cumsum(counts)
    return CSRMatrix(rowptr=rowptr.astype(np.int32), cols=mat.cols[idx],
                     vals=mat.vals[idx], shape=(rows.size, mat.n))


def _chunked_static_ms(mat, p: int, chunk: int, iters: int,
                       seed: int) -> float:
    """Modelled parallel time under static,chunk scheduling: each thread's
    rows are a strided set; its time is measured on its own gathered
    submatrix (includes the locality loss of striding). IOS semantics: the
    panel's output refreshes x at ITS OWN row positions (x stays full-size —
    feeding the short y back as x would silently clamp gather indices)."""
    import time as _time

    import jax.numpy as jnp

    from ..core.measure import parallel_model
    from ..core.sparse import partition
    from ..core.spmv.ops import make_engine

    panels = partition.chunked_cyclic_panels(mat.m, p, chunk)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(mat.n), jnp.float32)
    worst = 0.0
    for rows in panels:
        sub = _rows_submatrix(mat, rows)
        op = make_engine(sub, "csr", nnz_bucket=4096)
        rows_dev = jnp.asarray(rows)
        xi = x
        times = []
        for i in range(iters + 2):
            t0 = _time.perf_counter()
            y = op(xi)
            y.block_until_ready()
            if i >= 2:
                times.append((_time.perf_counter() - t0) * 1e3)
            xi = xi.at[rows_dev].set(y[: rows.size])
        worst = max(worst, float(np.median(times)))
    return worst + parallel_model.ALPHA_SYNC_MS


@register_cell_kind("schedule")
def measure_schedule_cell(cell, mat) -> dict:
    """One (matrix, scheme, scheduling-policy) point; the policy is the
    variant. The scheme axis is honored like everywhere else (the matrix
    is permuted before panels are cut), so a schemes x variants schedule
    spec measures what it claims."""
    from ..core.measure import ios, parallel_model
    from ..core.reorder import api as reorder_api

    pol = cell.policy_dict()
    if cell.scheme != "baseline":
        mat = mat.permute(reorder_api.reorder(mat, cell.scheme,
                                              pol["seed"]))
    var = cell.variant
    if var == "static_default":
        ms = parallel_model.modelled_parallel_ms(
            mat, cell.p, cell.engine, schedule="static", iters=pol["iters"])
    elif var == "nnz_balanced":
        ms = parallel_model.modelled_parallel_ms(
            mat, cell.p, cell.engine, schedule="nnz_balanced",
            iters=pol["iters"])
    elif var.startswith("static_c"):
        ms = _chunked_static_ms(mat, cell.p, int(var[len("static_c"):]),
                                pol["iters"], pol["seed"])
    else:
        raise ValueError(f"unknown scheduling variant {var!r}")
    return {
        "m": int(mat.m), "n": int(mat.n), "nnz": int(mat.nnz),
        "modelled_par_ms": ms,
        "gflops": float(ios.gflops(mat.nnz, np.array([ms]))[0]),
    }


# --------------------------------------------------------------------------
# workload cells (dynamic model-layer sparsity streams, ISSUE 9)
# --------------------------------------------------------------------------
@register_cell_kind("workload")
def measure_workload_cell(cell, mat) -> dict:
    """One workload stream: cell.matrix is a `workload://` name, the
    variant is the scenario. The resolved suite matrix (step-0
    representative) is ignored — the stream regenerates every step from
    the cell's seed, so the cell stays content-addressed on
    (name, scenario, scheme, engine, policy)."""
    from ..workloads import DynamicSparseProblem, WorkloadSession, run_stream

    pol = cell.policy_dict()
    scenario = cell.variant or "drift"
    problem = DynamicSparseProblem(cell.matrix, scenario=scenario,
                                   seed=pol["seed"], dtype=cell.dtype)
    if problem.wdef.kind == "moe" and cell.scheme != "baseline":
        raise ValueError(
            f"moe workloads have rectangular dispatch/combine matrices; "
            f"symmetric reordering scheme {cell.scheme!r} does not apply "
            f"(the dispatch IS the reordering) — use scheme='baseline'")
    session = WorkloadSession(problem, reorder=cell.scheme,
                              engine=cell.engine, probe=pol["probe"])
    rec = run_stream(problem, session, iters=max(int(pol["iters"]), 2),
                     compare_dense=pol["time_spmv"], verify=pol["verify"])
    if problem.wdef.kind == "moe":
        # the seed benchmark's vocabulary: sparse chain == sorted
        # dispatch, reference == onehot baseline
        rec["sorted_ms"] = rec["sparse_ms"]
        if "ref_ms" in rec:
            rec["onehot_ms"] = rec["ref_ms"]
            rec["sorted_vs_onehot_speedup"] = rec["speedup_vs_ref"]
        if "verify_ok" in rec:
            rec["dispatch_agree"] = rec["verify_ok"]
    return rec


# --------------------------------------------------------------------------
# serving cells (open-loop traffic sim -> SLO summary, ISSUE 6)
# --------------------------------------------------------------------------
_SERVE_DEFAULTS = {
    "arrival": "poisson", "rate_rps": 300.0, "requests": 200,
    "n_keys": 1, "zipf_s": 1.1, "update_frac": 0.0,
    "budget_mb": 0.0,            # 0 = unbudgeted
    "max_queue": 64, "window_ms": 2.0, "overload": "reject",
}


def serve_variant(arrival: str = "poisson", rate_rps: float = 300.0,
                  requests: int = 200, n_keys: int = 1,
                  zipf_s: float = 1.1, update_frac: float = 0.0,
                  budget_mb: float = 0.0, max_queue: int = 64,
                  window_ms: float = 2.0,
                  overload: str = "reject") -> str:
    """The variants-axis encoding of one traffic scenario: the arrival
    kind followed by single-letter-prefixed tokens (r=rate_rps,
    n=requests, K=n_keys, z=zipf_s, u=update_frac, m=budget_mb [0=none],
    q=max_queue, w=window_ms, o=overload policy). Defaults are elided so
    equal scenarios always encode to the SAME string (cell identity)."""
    toks = [arrival]
    for tag, name, val in (("r", "rate_rps", rate_rps),
                           ("n", "requests", requests),
                           ("K", "n_keys", n_keys),
                           ("z", "zipf_s", zipf_s),
                           ("u", "update_frac", update_frac),
                           ("m", "budget_mb", budget_mb),
                           ("q", "max_queue", max_queue),
                           ("w", "window_ms", window_ms),
                           ("o", "overload", overload)):
        if val != _SERVE_DEFAULTS[name]:
            toks.append(f"{tag}{val:g}" if isinstance(val, float)
                        else f"{tag}{val}")
    return ",".join(toks)


def _parse_serve_variant(variant: str) -> dict:
    from ..serving.traffic import ARRIVALS

    cfg = dict(_SERVE_DEFAULTS)
    toks = [t for t in (variant or "").split(",") if t]
    if toks and toks[0] in ARRIVALS:
        cfg["arrival"] = toks.pop(0)
    casts = {"r": ("rate_rps", float), "n": ("requests", int),
             "K": ("n_keys", int), "z": ("zipf_s", float),
             "u": ("update_frac", float), "m": ("budget_mb", float),
             "q": ("max_queue", int), "w": ("window_ms", float),
             "o": ("overload", str)}
    for t in toks:
        if t[0] not in casts:
            raise ValueError(f"unknown serve-variant token {t!r} in "
                             f"{variant!r} (known: {sorted(casts)})")
        name, cast = casts[t[0]]
        cfg[name] = cast(t[1:])
    return cfg


@register_cell_kind("serve")
def measure_serve_cell(cell, mat) -> dict:
    """One open-loop traffic run: cell.k is the service's max_batch, the
    variant the scenario. The matrix is registered under n_keys distinct
    service keys (Zipf-skewed traffic over them), so the memory budget
    sees n_keys resident operators while the content-addressed plan
    store holds ONE entry — evictions reload zero-re-tune, which is the
    LRU pillar this cell measures."""
    import jax.numpy as jnp

    from ..serving import traffic
    from ..serving.spmv_service import SpmvService

    pol = cell.policy_dict()
    cfg = _parse_serve_variant(cell.variant)
    pattern = traffic.TrafficPattern(
        arrival=cfg["arrival"], rate_rps=cfg["rate_rps"],
        requests=cfg["requests"], n_keys=cfg["n_keys"],
        zipf_s=cfg["zipf_s"], update_frac=cfg["update_frac"],
        seed=pol["seed"])
    budget = (None if cfg["budget_mb"] <= 0
              else int(cfg["budget_mb"] * (1 << 20)))
    svc = SpmvService(
        engine=cell.engine, max_batch=max(int(cell.k), 1),
        window_ms=cfg["window_ms"], use_kernel=pol["use_kernel"],
        dtype=jnp.dtype(cell.dtype), max_queue=cfg["max_queue"],
        reorder=cell.scheme, memory_budget_bytes=budget,
        overload=cfg["overload"])
    try:
        for i in range(cfg["n_keys"]):
            svc.register(f"{cell.matrix}#{i}", mat)
        summary = traffic.run_open_loop(
            svc, {f"{cell.matrix}#{i}": mat for i in range(cfg["n_keys"])},
            pattern)
        svc.flush()
        stats = svc.stats()       # quiescent: counters fully balanced
    finally:
        svc.close()
    slo = stats["slo"]
    return {
        "m": int(mat.m), "n": int(mat.n), "nnz": int(mat.nnz),
        "offered": summary["offered"], "submitted": summary["submitted"],
        "ok": summary["ok"], "shed": summary["shed"],
        "rejected": summary["rejected"], "errors": summary["errors"],
        "unresolved": summary["unresolved"],
        "updates": summary["updates"],
        "update_conflicts": summary["update_conflicts"],
        "retry_after_positive": bool(summary["retry_after_positive"]),
        "offered_rps": float(summary["offered_rps"]),
        "achieved_rps": float(summary["achieved_rps"]),
        "wall_s": float(summary["wall_s"]),
        "p50_ms": float(slo["p50_ms"]), "p95_ms": float(slo["p95_ms"]),
        "p99_ms": float(slo["p99_ms"]),
        "throughput_rps": float(slo["throughput_rps"]),
        "shed_rate": float(slo["shed_rate"]),
        "reject_rate": float(slo["reject_rate"]),
        "eviction_rate": float(slo["eviction_rate"]),
        "coalesce_ratio": float(stats["coalesce_ratio"]),
        "avg_batch": float(stats["avg_batch"]),
        "batch_size_max": int(stats["batch_size_max"]),
        "op_builds": int(stats["op_builds"]),
        "op_reloads": int(stats["op_reloads"]),
        "evictions": int(stats["evictions"]),
        "value_swaps": int(stats["value_swaps"]),
        "replans": int(stats["replans"]),
        "wakeups": int(stats["wakeups"]),
        "resident_bytes_max": int(stats["resident_bytes_max"]),
        "memory_budget_bytes": int(budget or 0),
        "budget_ok": bool(summary["budget_ok"]),
        # the no-silent-drops invariant, checked at quiescence: every
        # admitted request is accounted a result, a shed, or an error
        "counters_balanced": bool(
            stats["requests"] == stats["results"] + stats["sheds"]
            + stats["errors"] and stats["pending"] == 0),
    }


# --------------------------------------------------------------------------
# routed serving cells (multi-shard fleet traffic, ISSUE 10)
# --------------------------------------------------------------------------
_ROUTE_DEFAULTS = {
    "arrival": "poisson", "rate_rps": 300.0, "requests": 200,
    "n_keys": 2, "zipf_s": 1.1, "update_frac": 0.0,
    "structure_frac": 0.0,
    "devices": 2,                # devices per mesh
    "meshes": 2,                 # fleet size
    "layout": "1d_rows",
    "policy": "bin_pack",        # placement policy
    "budget_mb": 0.0,            # per-DEVICE budget (0 = unbudgeted)
    "window_ms": 2.0,
}


def route_variant(arrival: str = "poisson", rate_rps: float = 300.0,
                  requests: int = 200, n_keys: int = 2,
                  zipf_s: float = 1.1, update_frac: float = 0.0,
                  structure_frac: float = 0.0, devices: int = 2,
                  meshes: int = 2, layout: str = "1d_rows",
                  policy: str = "bin_pack", budget_mb: float = 0.0,
                  window_ms: float = 2.0) -> str:
    """Variants-axis encoding of one routed-fleet scenario (the serve
    kind's convention: arrival first, then single-letter tokens with
    defaults elided — r=rate_rps, n=requests, K=n_keys, z=zipf_s,
    u=update_frac, s=structure_frac, d=devices per mesh, M=meshes,
    L=layout, P=placement policy, m=per-device budget_mb, w=window_ms)."""
    toks = [arrival]
    for tag, name, val in (("r", "rate_rps", rate_rps),
                           ("n", "requests", requests),
                           ("K", "n_keys", n_keys),
                           ("z", "zipf_s", zipf_s),
                           ("u", "update_frac", update_frac),
                           ("s", "structure_frac", structure_frac),
                           ("d", "devices", devices),
                           ("M", "meshes", meshes),
                           ("L", "layout", layout),
                           ("P", "policy", policy),
                           ("m", "budget_mb", budget_mb),
                           ("w", "window_ms", window_ms)):
        if val != _ROUTE_DEFAULTS[name]:
            toks.append(f"{tag}{val:g}" if isinstance(val, float)
                        else f"{tag}{val}")
    return ",".join(toks)


def _parse_route_variant(variant: str) -> dict:
    from ..serving.traffic import ARRIVALS

    cfg = dict(_ROUTE_DEFAULTS)
    toks = [t for t in (variant or "").split(",") if t]
    if toks and toks[0] in ARRIVALS:
        cfg["arrival"] = toks.pop(0)
    casts = {"r": ("rate_rps", float), "n": ("requests", int),
             "K": ("n_keys", int), "z": ("zipf_s", float),
             "u": ("update_frac", float), "s": ("structure_frac", float),
             "d": ("devices", int), "M": ("meshes", int),
             "L": ("layout", str), "P": ("policy", str),
             "m": ("budget_mb", float), "w": ("window_ms", float)}
    for t in toks:
        if t[0] not in casts:
            raise ValueError(f"unknown route-variant token {t!r} in "
                             f"{variant!r} (known: {sorted(casts)})")
        name, cast = casts[t[0]]
        cfg[name] = cast(t[1:])
    return cfg


@register_cell_kind("route")
def measure_route_cell(cell, mat) -> dict:
    """One open-loop traffic run against a RoutedSpmvService fleet: the
    variant encodes load shape + fleet shape (`route_variant(...)`),
    cell.k is each mesh service's max_batch. The matrix registers under
    n_keys distinct keys routed across the meshes by the placement
    policy; traffic mixes submits with value swaps and small deletion
    StructureDeltas (the delta-apply shard-replan path). The record adds
    the router's verdicts — per_device_ok, replans landed, the
    key→mesh assignment — to the serve-kind SLO summary."""
    import jax.numpy as jnp

    from ..core.spmv.topology import Topology
    from ..router import MeshSpec, RoutedSpmvService
    from ..serving import traffic

    pol = cell.policy_dict()
    cfg = _parse_route_variant(cell.variant)
    pattern = traffic.TrafficPattern(
        arrival=cfg["arrival"], rate_rps=cfg["rate_rps"],
        requests=cfg["requests"], n_keys=cfg["n_keys"],
        zipf_s=cfg["zipf_s"], update_frac=cfg["update_frac"],
        structure_frac=cfg["structure_frac"], seed=pol["seed"])
    budget = (None if cfg["budget_mb"] <= 0
              else int(cfg["budget_mb"] * (1 << 20)))
    meshes = [MeshSpec(f"mesh{i}",
                       Topology(devices=cfg["devices"],
                                layout=cfg["layout"]),
                       budget_per_device=budget)
              for i in range(cfg["meshes"])]
    svc = RoutedSpmvService(
        meshes, policy=cfg["policy"], engine=cell.engine,
        max_batch=max(int(cell.k), 1), window_ms=cfg["window_ms"],
        use_kernel=pol["use_kernel"], dtype=jnp.dtype(cell.dtype),
        reorder=cell.scheme)
    try:
        mats = {f"{cell.matrix}#{i}": mat for i in range(cfg["n_keys"])}
        for k, m in mats.items():
            svc.register(k, m)
        summary = traffic.run_open_loop(svc, mats, pattern)
        svc.flush()
        stats = svc.stats()       # quiescent: counters fully balanced
    finally:
        svc.close()
    return {
        "m": int(mat.m), "n": int(mat.n), "nnz": int(mat.nnz),
        "offered": summary["offered"], "submitted": summary["submitted"],
        "ok": summary["ok"], "shed": summary["shed"],
        "rejected": summary["rejected"], "errors": summary["errors"],
        "unresolved": summary["unresolved"],
        "updates": summary["updates"],
        "update_conflicts": summary["update_conflicts"],
        "structure_updates": summary["structure_updates"],
        "structure_conflicts": summary["structure_conflicts"],
        "replans_landed": summary["replans_landed"],
        "replan_errors": summary["replan_errors"],
        "replan_unresolved": summary["replan_unresolved"],
        "offered_rps": float(summary["offered_rps"]),
        "achieved_rps": float(summary["achieved_rps"]),
        "wall_s": float(summary["wall_s"]),
        "devices": int(cfg["devices"]), "meshes": int(cfg["meshes"]),
        "layout": cfg["layout"], "placement": cfg["policy"],
        "budget_per_device": int(budget or 0),
        "per_device_ok": bool(stats["per_device_ok"]),
        "budget_ok": bool(summary["budget_ok"]),
        "replans": int(stats["replans"]),
        "value_swaps": int(stats["value_swaps"]),
        "evictions": int(stats["evictions"]),
        "assignments": dict(stats["routing"]["assignments"]),
        "counters_balanced": bool(
            stats["requests"] == stats["results"] + stats["sheds"]
            + stats["errors"] and stats["pending"] == 0),
    }
