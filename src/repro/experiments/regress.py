"""Perf-regression gate over BENCH_spmv.json summaries.

`compare(baseline, current)` diffs two bench summaries (the dicts
`Report.bench_summary()` emits) with noise-aware relative thresholds and
returns a verdict dict; `main()` is the CLI `benchmarks/regress.py`
delegates to. Exit codes:

    0 — comparable, no regression
    1 — comparable, at least one regression beyond tolerance
    2 — NOT comparable (scale stamps differ, missing/corrupt file) —
        cross-scale comparison is refused, never silently passed,
        because smoke-scale numbers (scale.representative == false) do
        not transfer to paper-scale matrices and vice versa.

What is gated (each against `rel_tol`, default 0.35 — smoke-scale runs
under interpret-mode kernels are noisy; CI pins the threshold it wants):

* per-scheme geomean GFLOPs      — lower bound (throughput must not drop)
* per-scheme speedup_vs_baseline — lower bound
* plan_run.median_run_ms         — upper bound (run time must not grow)

Phase medians (reorder/tune/build/load) are reported informationally but
do NOT gate: plan-time is one-off, dominated by cold caches, and the
paper's methodology (§3) keeps it out of SpMV time.

``--portable`` gates only the machine-normalized speedup ratios — the
mode for CI runners comparing against a baseline committed from another
machine, where absolute interpret-mode GFLOPs do not transfer.
"""
from __future__ import annotations

import argparse
import json
from typing import Optional

DEFAULT_REL_TOL = 0.35

# scale-stamp fields that must match for two summaries to be comparable
_SCALE_KEYS = ("matrices", "max_m", "iters", "warmup", "use_kernel",
               "representative")


def _fmt(v) -> str:
    return f"{v:.4f}" if isinstance(v, float) else str(v)


def scale_mismatches(baseline: dict, current: dict) -> list:
    """Human-readable list of scale-stamp differences ([] = comparable).
    A summary with no scale stamp (pre-gate schema) is incomparable."""
    bs, cs = baseline.get("scale"), current.get("scale")
    if not isinstance(bs, dict) or not isinstance(cs, dict):
        missing = "baseline" if not isinstance(bs, dict) else "current"
        return [f"{missing} summary has no scale stamp "
                f"(re-run the bench to stamp it)"]
    out = []
    for k in _SCALE_KEYS:
        if bs.get(k) != cs.get(k):
            out.append(f"scale.{k}: baseline={bs.get(k)!r} "
                       f"current={cs.get(k)!r}")
    if baseline.get("field") != current.get("field"):
        out.append(f"field: baseline={baseline.get('field')!r} "
                   f"current={current.get('field')!r}")
    return out


def compare(baseline: dict, current: dict,
            rel_tol: float = DEFAULT_REL_TOL,
            portable: bool = False) -> dict:
    """Diff two bench summaries. Returns
    {comparable, scale_mismatch, checks, regressions, improvements,
    notes} — see module docstring for the gate set and exit semantics.

    portable=True gates only machine-normalized quantities (the
    speedup_vs_baseline ratios) and demotes the absolute ones (geomean
    GFLOPs, median_run_ms) to notes — the mode for comparing against a
    baseline committed from a DIFFERENT machine, where absolute
    interpret-mode throughput does not transfer. Same-machine gating
    (the default) checks everything."""
    mism = scale_mismatches(baseline, current)
    if mism:
        return {"comparable": False, "scale_mismatch": mism,
                "checks": 0, "regressions": [], "improvements": [],
                "notes": []}
    regressions, improvements, notes = [], [], []
    checks = 0

    def gate(name, base, cur, lower_bound, machine_bound=False):
        """lower_bound=True: cur must stay >= base*(1-tol); else cur must
        stay <= base*(1+tol). machine_bound metrics are demoted to notes
        under portable=True."""
        nonlocal checks
        if base is None or cur is None:
            return
        if portable and machine_bound:
            notes.append(f"{name}: baseline={_fmt(base)} "
                         f"current={_fmt(cur)} (machine-bound, not gated "
                         f"in --portable mode)")
            return
        checks += 1
        if lower_bound:
            limit = base * (1.0 - rel_tol)
            bad = cur < limit
            better = cur > base
        else:
            limit = base * (1.0 + rel_tol)
            bad = cur > limit
            better = cur < base
        line = (f"{name}: baseline={_fmt(base)} current={_fmt(cur)} "
                f"limit={_fmt(limit)} (rel_tol={rel_tol:g})")
        if bad:
            regressions.append(line)
        elif better:
            improvements.append(line)

    bg, cg = baseline.get("geomean", {}), current.get("geomean", {})
    for scheme in sorted(set(bg) & set(cg)):
        gate(f"geomean[{scheme}]", bg[scheme], cg[scheme],
             lower_bound=True, machine_bound=True)
    for scheme in sorted(set(bg) ^ set(cg)):
        notes.append(f"geomean[{scheme}] present in only one summary "
                     f"— not gated")
    bs = baseline.get("speedup_vs_baseline", {})
    cs = current.get("speedup_vs_baseline", {})
    for scheme in sorted(set(bs) & set(cs)):
        gate(f"speedup_vs_baseline[{scheme}]", bs[scheme], cs[scheme],
             lower_bound=True)
    bp = baseline.get("plan_run", {}) or {}
    cp = current.get("plan_run", {}) or {}
    gate("plan_run.median_run_ms", bp.get("median_run_ms"),
         cp.get("median_run_ms"), lower_bound=False, machine_bound=True)
    bph, cph = baseline.get("phases", {}) or {}, current.get("phases", {}) or {}
    for k in sorted(set(bph) & set(cph)):
        notes.append(f"phases.{k}: baseline={_fmt(bph[k])} "
                     f"current={_fmt(cph[k])} (informational, not gated)")
    return {"comparable": True, "scale_mismatch": [], "checks": checks,
            "regressions": regressions, "improvements": improvements,
            "notes": notes}


def load_summary(path: str) -> Optional[dict]:
    try:
        with open(path) as f:
            out = json.load(f)
        return out if isinstance(out, dict) else None
    except (OSError, ValueError):
        return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate the current BENCH_spmv.json against a committed "
                    "baseline (exit 0 pass / 1 regression / 2 incomparable)")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline summary JSON")
    ap.add_argument("--current", required=True,
                    help="freshly produced summary JSON")
    ap.add_argument("--rel-tol", type=float, default=DEFAULT_REL_TOL,
                    help=f"relative noise tolerance "
                         f"(default {DEFAULT_REL_TOL})")
    ap.add_argument("--portable", action="store_true",
                    help="gate only machine-normalized ratios (speedups); "
                         "use when the baseline was committed from a "
                         "different machine")
    args = ap.parse_args(argv)
    base = load_summary(args.baseline)
    cur = load_summary(args.current)
    if base is None or cur is None:
        which = args.baseline if base is None else args.current
        print(f"REGRESS INCOMPARABLE: cannot read summary {which!r}")
        return 2
    res = compare(base, cur, rel_tol=args.rel_tol, portable=args.portable)
    for line in res["notes"]:
        print(f"  note: {line}")
    for line in res["improvements"]:
        print(f"  improvement: {line}")
    if not res["comparable"]:
        print("REGRESS INCOMPARABLE: scale stamps differ — refusing the "
              "cross-scale comparison:")
        for line in res["scale_mismatch"]:
            print(f"  {line}")
        return 2
    if res["regressions"]:
        print(f"REGRESS FAIL: {len(res['regressions'])} regression(s) "
              f"beyond tolerance:")
        for line in res["regressions"]:
            print(f"  {line}")
        return 1
    print(f"REGRESS OK: {res['checks']} checks within rel_tol="
          f"{args.rel_tol:g} ({len(res['improvements'])} improved)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
