"""Report — the typed view over a campaign's measured cells.

Replaces the ad-hoc `grid()`/baseline-index lookups of the legacy
benchmarks: accessors are STRICT (a missing cell or field raises
MissingCellError naming the exact cell, instead of silently yielding the
NaN speedups that used to skew consistency statistics), grids come back
as [scheme, matrix] arrays ready for measure/profiles.py, and the
standard paper statistics (Dolan-Moré profiles, speedup buckets,
pairwise win rates, cross-machine consistency) are one call each.

Amortization accounting (paper §3): `plan_run_split()` spreads each
cell's one-off plan time over the policy's `amortize_iters` SpMV calls;
`break_even()` reports, per (matrix, scheme), how many SpMV calls the
measured run-time saving needs to repay the plan time — the
"is reordering worth it for THIS solve length" number.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Iterable, Optional

import numpy as np

from ..core.measure import profiles as profile_stats

BENCH_SCHEMA_VERSION = 1


class MissingCellError(KeyError):
    """A report was asked for a cell (or a field of a cell) that was never
    measured. Carries the exact coordinates so the fix is obvious."""

    def __init__(self, coords: dict, field: Optional[str] = None,
                 hint: str = ""):
        self.coords = dict(coords)
        self.field = field
        what = (f"field {field!r} missing from cell" if field
                else "no measured cell for")
        msg = f"{what} {self.coords}"
        if hint:
            msg += f" ({hint})"
        super().__init__(msg)

    def __str__(self) -> str:  # KeyError would repr() the message
        return self.args[0]


class Report:
    def __init__(self, spec, entries, measured: int = 0, reused: int = 0,
                 failures: Optional[list] = None, store=None):
        self.spec = spec
        self.measured = measured
        self.reused = reused
        self.failures = failures or []
        self.store = store
        self.records = []
        self._buckets: dict = {}      # (matrix, scheme) -> [records]
        for entry in entries:
            cell, rec = entry[0], entry[1]
            merged = dict(rec)
            merged.update({
                "matrix": cell.matrix, "scheme": cell.scheme,
                "profile": cell.profile, "engine_request": cell.engine,
                "dtype": cell.dtype, "p": cell.p, "k": cell.k,
                "variant": cell.variant, "cell_key": cell.key(),
                # runner provenance (not persisted in the store record):
                # was THIS run's copy served from the store, and how long
                # did the measurement take if not
                "store_reused": bool(entry[2]) if len(entry) > 2 else False,
                "runner_wall_s": float(entry[3]) if len(entry) > 3 else 0.0,
            })
            self.records.append(merged)
            self._buckets.setdefault((cell.matrix, cell.scheme),
                                     []).append(merged)

    # -- cell/value accessors ---------------------------------------------
    def _resolve(self, matrix: str, scheme: str, profile: Optional[str],
                 engine: Optional[str], dtype: Optional[str],
                 p: Optional[int], k: Optional[int],
                 variant: Optional[str]) -> dict:
        """Match on every coordinate the caller pinned; unpinned axes must
        be unambiguous across the report's cells."""
        want = {"matrix": matrix, "scheme": scheme}
        for name, v in (("profile", profile), ("engine_request", engine),
                        ("dtype", dtype), ("p", p), ("k", k),
                        ("variant", variant)):
            if v is not None:
                want[name] = v
        bucket = self._buckets.get((matrix, scheme), ())
        hits = [r for r in bucket
                if all(r[f] == v for f, v in want.items())]
        if len(hits) == 1:
            return hits[0]
        if not hits:
            raise MissingCellError(want, hint=f"campaign {self.spec.name!r} "
                                   f"holds {len(self.records)} cells")
        raise MissingCellError(
            want, hint=f"{len(hits)} cells match — pin more axes "
            f"(profile/engine/k/variant)")

    def cell(self, matrix: str, scheme: str, profile: Optional[str] = None,
             engine: Optional[str] = None, dtype: Optional[str] = None,
             p: Optional[int] = None, k: Optional[int] = None,
             variant: Optional[str] = None) -> dict:
        return self._resolve(matrix, scheme, profile, engine, dtype, p, k,
                             variant)

    def value(self, field: str, matrix: str, scheme: str, **coords) -> float:
        rec = self.cell(matrix, scheme, **coords)
        if field not in rec:
            raise MissingCellError(
                {"matrix": matrix, "scheme": scheme, **coords}, field=field,
                hint="the cell exists but its policy never measured this")
        return rec[field]

    # -- grids -------------------------------------------------------------
    def grid(self, field: str, matrices: Iterable[str],
             schemes: Iterable[str], **coords) -> np.ndarray:
        """[scheme, matrix] array of `field` — STRICT (MissingCellError on
        any absent cell/field; no NaN placeholders)."""
        matrices, schemes = list(matrices), list(schemes)
        out = np.empty((len(schemes), len(matrices)), dtype=np.float64)
        for i, s in enumerate(schemes):
            for j, m in enumerate(matrices):
                out[i, j] = self.value(field, m, s, **coords)
        return out

    def speedup(self, field: str, matrices: Iterable[str],
                schemes: Iterable[str], baseline: str = "baseline",
                **coords) -> np.ndarray:
        """[scheme, matrix] speedup of `field` (higher-is-better) relative
        to the baseline scheme on the same (matrix, machine point)."""
        matrices, schemes = list(matrices), list(schemes)
        g = self.grid(field, matrices, schemes, **coords)
        base = self.grid(field, matrices, [baseline], **coords)[0]
        return g / base

    # -- paper statistics (measure/profiles.py) ---------------------------
    def performance_profile(self, field: str, matrices, schemes,
                            taus: np.ndarray, **coords) -> np.ndarray:
        return profile_stats.performance_profile(
            self.grid(field, matrices, schemes, **coords), np.asarray(taus))

    def speedup_buckets(self, field: str, matrices, schemes,
                        baseline: str = "baseline", **coords) -> np.ndarray:
        return profile_stats.speedup_buckets(
            self.speedup(field, matrices, schemes, baseline, **coords))

    def pairwise_win_rates(self, field: str, matrices, schemes,
                           **coords) -> np.ndarray:
        return profile_stats.pairwise_win_rates(
            self.grid(field, matrices, schemes, **coords))

    def consistency(self, field: str, matrices, scheme: str,
                    machine_profiles: Iterable[str], tau,
                    baseline: str = "baseline", **coords):
        """Cross-machine Consistent% (paper Eq. 1) of one scheme's
        speedups over the given profiles. `tau` may be a scalar
        (returns (consistent, |CCS|)) or a sequence (returns one tuple
        per tau — the [machines, matrices] stack is built once)."""
        sp = np.stack([
            self.speedup(field, matrices, [scheme], baseline,
                         profile=prof, **coords)[0]
            for prof in machine_profiles])
        if np.iterable(tau):
            return [profile_stats.consistency_ratio(sp, t) for t in tau]
        return profile_stats.consistency_ratio(sp, tau)

    # -- amortization accounting (paper §3) --------------------------------
    @staticmethod
    def _plan_ms(rec: dict) -> float:
        """One-off plan-time this run actually paid: reorder excluded (the
        paper never times it), plan-store hits count zero (that is the
        store's purpose)."""
        if rec.get("plan_store_hit") or rec.get("op_cache_hit"):
            return 0.0
        return rec.get("tune_ms", 0.0) + rec.get("format_build_ms", 0.0)

    def plan_run_split(self, field: str = "seq_ios_ms",
                       iters_to_amortize: Optional[int] = None) -> dict:
        """Per-cell plan-time vs run-time split + amortized run time (run
        time with the plan cost spread over `iters_to_amortize` calls —
        default: the spec policy's amortize_iters, a CG-length solve)."""
        iters = (self.spec.policy.amortize_iters
                 if iters_to_amortize is None else iters_to_amortize)
        out = {}
        for rec in self.records:
            if field not in rec:
                continue
            plan_ms, run_ms = self._plan_ms(rec), rec[field]
            out[rec["cell_key"]] = {
                "matrix": rec["matrix"], "scheme": rec["scheme"],
                "profile": rec["profile"],
                "plan_ms": plan_ms, "run_ms": run_ms,
                "tuner_choice": rec.get("tuner_choice",
                                        rec.get("engine", "csr")),
                "op_cache_hit": bool(rec.get("op_cache_hit", False)),
                "plan_over_run": plan_ms / max(run_ms, 1e-9),
                "amortized_ms": run_ms + plan_ms / max(iters, 1),
            }
        return out

    def break_even(self, field: str = "seq_ios_ms",
                   baseline: str = "baseline", **coords) -> list:
        """Per non-baseline cell: SpMV calls needed before the scheme's
        one-off plan time (reorder + tune + convert, as paid this run) is
        repaid by its per-call run-time saving vs the baseline cell at
        the SAME machine point / k / variant. inf when the scheme does
        not beat baseline at all. Returns one dict per cell (full
        coordinates included — a multi-profile campaign yields one entry
        per machine); cells whose baseline was never measured are
        skipped, any other lookup problem propagates."""
        fieldmap = {"engine": "engine_request"}
        out = []
        for rec in self.records:
            if rec["scheme"] == baseline or field not in rec:
                continue
            if any(rec.get(fieldmap.get(f, f)) != v
                   for f, v in coords.items()):
                continue
            try:
                # every axis pinned -> the lookup can miss but never be
                # ambiguous (ambiguity would be a harness bug, not data)
                base = self.value(field, rec["matrix"], baseline,
                                  profile=rec["profile"],
                                  engine=rec["engine_request"],
                                  dtype=rec["dtype"], p=rec["p"],
                                  k=rec["k"], variant=rec["variant"])
            except MissingCellError as e:
                if e.field is not None:
                    raise       # baseline cell exists but wasn't timed
                continue        # baseline cell genuinely absent
            saving = base - rec[field]
            plan_ms = self._plan_ms(rec) + rec.get("reorder_ms", 0.0)
            out.append({
                "matrix": rec["matrix"], "scheme": rec["scheme"],
                "profile": rec["profile"], "k": rec["k"],
                "variant": rec["variant"],
                "saving_ms_per_call": saving,
                "plan_ms": plan_ms,
                "break_even_iters": (plan_ms / saving if saving > 1e-12
                                     else float("inf")),
            })
        return out

    # -- emission ----------------------------------------------------------
    def write_csv(self, path: str, header: list, rows: list) -> None:
        write_csv(path, header, rows)

    def bench_summary(self, field: str = "seq_ios_gflops",
                      baseline: str = "baseline") -> dict:
        """The trajectory summary BENCH_spmv.json carries: per-scheme
        geomean GFLOPs + speedup over baseline, store-reuse counters, and
        the plan/run amortization medians."""
        by_scheme: dict = {}
        for rec in self.records:
            if field in rec:
                by_scheme.setdefault(rec["scheme"], []).append(rec[field])
        geo = {s: round(profile_stats.geomean(np.asarray(v)), 4)
               for s, v in by_scheme.items()}
        summary = {
            "schema": BENCH_SCHEMA_VERSION,
            "campaign": self.spec.name,
            "kind": self.spec.kind,
            "cells": len(self.records),
            "measured": self.measured,
            "reused": self.reused,
            "failures": len(self.failures),
            "field": field,
            "geomean": geo,
        }
        if baseline in geo:
            summary["speedup_vs_baseline"] = {
                s: round(v / geo[baseline], 4) for s, v in geo.items()
                if s != baseline}
        summary["scale"] = self._scale_stamp()
        phases = self._phase_medians()
        if phases:
            summary["phases"] = phases
        split = self.plan_run_split()
        if split:
            vals = list(split.values())
            summary["plan_run"] = {
                "median_plan_ms": round(float(np.median(
                    [v["plan_ms"] for v in vals])), 4),
                "median_run_ms": round(float(np.median(
                    [v["run_ms"] for v in vals])), 4),
                "median_amortized_ms": round(float(np.median(
                    [v["amortized_ms"] for v in vals])), 4),
                "amortize_iters": self.spec.policy.amortize_iters,
            }
        return summary

    REPRESENTATIVE_MIN_M = 100_000    # paper-scale row-count floor

    def _scale_stamp(self) -> dict:
        """Matrix-scale / iters provenance for the summary. `regress.py`
        refuses to compare summaries whose stamps differ, and
        `representative: false` marks smoke-scale numbers (e.g. RCM at
        0.70x on tiny matrices) as non-transferable to paper scale."""
        ms = [int(r["m"]) for r in self.records if "m" in r]
        nnzs = [int(r["nnz"]) for r in self.records if "nnz" in r]
        pol = self.spec.policy
        max_m = max(ms) if ms else 0
        stamp = {
            "matrices": sorted({r["matrix"] for r in self.records}),
            "max_m": max_m,
            "max_nnz": max(nnzs) if nnzs else 0,
            "iters": int(pol.iters),
            "warmup": int(pol.warmup),
            "use_kernel": pol.use_kernel,
            "representative": max_m >= self.REPRESENTATIVE_MIN_M,
        }
        if not stamp["representative"]:
            stamp["note"] = (
                f"smoke-scale measurement (max m={max_m} < "
                f"{self.REPRESENTATIVE_MIN_M}); speedups are NOT "
                f"representative of paper-scale matrices")
        return stamp

    def _phase_medians(self) -> dict:
        """Per-phase plan-time attribution medians (ms) over the cells
        that recorded each phase — the span-backed timing fields."""
        out = {}
        for field, label in (("reorder_ms", "reorder_ms"),
                             ("tune_ms", "tune_ms"),
                             ("format_build_ms", "build_ms"),
                             ("op_load_ms", "load_ms")):
            vals = [r[field] for r in self.records if field in r]
            if vals:
                out[f"median_{label}"] = round(float(np.median(vals)), 4)
        return out

    def write_bench_summary(self, path: str,
                            field: str = "seq_ios_gflops") -> dict:
        summary = self.bench_summary(field=field)
        summary["written_at"] = time.time()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
        with open(tmp, "w") as f:
            json.dump(summary, f, indent=1)
        os.replace(tmp, path)
        return summary


def write_csv(path: str, header: list, rows: list) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
    with open(tmp, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(v) for v in r) + "\n")
    os.replace(tmp, path)
