"""repro.experiments — declarative measurement campaigns (the paper's
methodology as an API).

The harness mirrors the Problem→Plan→Operator pipeline one level up:

    spec   = ExperimentSpec(name="locality",          what to measure
                 matrices=suite.locality_names(),
                 schemes=paper_schemes(),
                 profiles=(PRIMARY,),
                 policy=MeasurePolicy(cg_profiles=(PRIMARY,)))
    report = Runner(spec, ResultStore(...)).run()     resumable execution
    perf   = report.grid("seq_ios_gflops", mats, schemes)   typed views

Cells are content-addressed in the ResultStore (atomic write-then-rename
JSON under benchmarks/results/store/), so re-running a campaign measures
nothing and extending an axis measures only the delta. Reports are
strict: a missing cell raises MissingCellError instead of propagating
NaN. `benchmarks/fig*.py` are thin specs-plus-views over this API.
"""
from .cells import CELL_KINDS, get_cell_kind, register_cell_kind
from .machine_profiles import (PRIMARY, get_profile, primary_profile,
                               register_profile)
from .report import MissingCellError, Report, write_csv
from .runner import Runner, run_spec
from .spec import (Cell, ExperimentSpec, MeasurePolicy, paper_schemes,
                   registered_engines)
from .store import ResultStore

__all__ = [
    "Cell", "CELL_KINDS", "ExperimentSpec", "MeasurePolicy",
    "MissingCellError", "PRIMARY", "Report", "ResultStore", "Runner",
    "get_cell_kind", "get_profile", "paper_schemes", "primary_profile",
    "register_cell_kind", "register_profile", "registered_engines",
    "run_spec", "write_csv",
]
