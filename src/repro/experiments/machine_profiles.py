"""Built-in machine profiles (DESIGN.md §7).

Configs standing in for the paper's four hosts — the consistency claims
under reproduction are about the *existence* of cross-machine
inconsistency, so the axis varies engine dtype and core count on this
host:

    M1 csr-f32-p8   — primary
    M2 csr-f64-p8   — 2x bandwidth pressure (bigger values+x)
    M3 csr-f32-p4   — fewer cores
    M4 csr-f32-p16  — more cores
    M5 auto-f32-p8  — autotuned engine (OSKI-style selection)

Registered through core/registry.py so campaigns that say
`profiles="*"` pick up plugin profiles the same way plan(engine="auto")
picks up plugin engines.
"""
from __future__ import annotations

from ..core.registry import (PROFILE_REGISTRY, get_profile, primary_profile,
                             register_profile)


def _register_builtin_profiles() -> None:
    if "M1_csr_f32_p8" in PROFILE_REGISTRY:
        return
    register_profile("M1_csr_f32_p8", engine="csr", dtype="float32", p=8,
                     primary=True, description="primary host")
    register_profile("M2_csr_f64_p8", engine="csr", dtype="float64", p=8,
                     description="2x bandwidth pressure (bigger values+x)")
    register_profile("M3_csr_f32_p4", engine="csr", dtype="float32", p=4,
                     description="fewer cores")
    register_profile("M4_csr_f32_p16", engine="csr", dtype="float32", p=16,
                     description="more cores")
    register_profile("M5_auto_f32_p8", engine="auto", dtype="float32", p=8,
                     description="autotuned engine (core/spmv/tune.py)")


_register_builtin_profiles()

PRIMARY = primary_profile()

__all__ = ["PRIMARY", "PROFILE_REGISTRY", "get_profile", "register_profile",
           "primary_profile"]
