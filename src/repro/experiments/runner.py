"""Runner — resumable execution of an ExperimentSpec over a ResultStore.

The Runner walks the spec's cells matrix-major (each matrix materialized
once), serves every cell it can from the store, measures the rest, and
returns a Report. Resumability is the invariant the CI smoke job pins:
running the same spec twice performs ZERO new measurements the second
time, and extending a spec along any axis measures only the delta.

Corrupt/truncated store entries read as misses (ResultStore.get) and are
re-measured in place — an interrupted campaign can always be resumed by
re-running it.
"""
from __future__ import annotations

import time
import traceback
from typing import Callable, Iterable, Optional

from .. import obs
from .cells import get_cell_kind
from .report import Report
from .spec import ExperimentSpec
from .store import ResultStore


class Runner:
    """on_error:
    * "raise"  — a failing cell aborts the run (default; campaigns are
                 supposed to be green).
    * "record" — the failure is reported (Report.failures) but the run
                 continues; failed cells are NOT persisted, so a re-run
                 retries them.
    """

    def __init__(self, spec: ExperimentSpec,
                 store: Optional[ResultStore] = None,
                 verbose: bool = True, on_error: str = "raise",
                 get_matrix: Optional[Callable] = None):
        if on_error not in ("raise", "record"):
            raise ValueError(f"on_error must be 'raise' or 'record', "
                             f"got {on_error!r}")
        self.spec = spec
        self.store = store if store is not None else ResultStore()
        self.verbose = verbose
        self.on_error = on_error
        self._get_matrix = get_matrix or _suite_get

    def run(self, matrices: Optional[Iterable[str]] = None) -> Report:
        cells = self.spec.cells(matrices)
        measure = get_cell_kind(self.spec.kind)
        entries, failures = [], []
        measured = reused = 0
        mat_name, mat = None, None
        for cell in cells:
            key = cell.key()
            stored = self.store.get(key)
            if stored is not None:
                with obs.span("runner.cell", key=key, label=cell.label(),
                              matrix=cell.matrix, scheme=cell.scheme,
                              store_hit=True):
                    entries.append((cell, stored["record"], True, 0.0))
                reused += 1
                continue
            if cell.matrix != mat_name:    # cells are matrix-major
                mat_name, mat = cell.matrix, self._get_matrix(cell.matrix)
            t0 = time.time()
            try:
                with obs.span("runner.cell", key=key, label=cell.label(),
                              matrix=cell.matrix, scheme=cell.scheme,
                              store_hit=False):
                    record = self._measure_cell(measure, cell, mat)
            except Exception as e:
                if self.on_error == "raise":
                    raise
                failures.append({"cell": cell.coords(), "key": key,
                                 "label": cell.label(),
                                 "error": f"{type(e).__name__}: {e}",
                                 "traceback": traceback.format_exc()})
                if self.verbose:
                    print(f"[{self.spec.name}] {cell.label()}: "
                          f"ERROR {type(e).__name__}: {e}", flush=True)
                continue
            self.store.put(key, cell.coords(), record)
            wall = time.time() - t0
            entries.append((cell, record, False, wall))
            measured += 1
            if self.verbose:
                gf = record.get("seq_ios_gflops")
                extra = f" ios={gf:.2f} gflops" if gf is not None else ""
                print(f"[{self.spec.name}] {cell.label()}:{extra} "
                      f"({wall:.1f}s)", flush=True)
        return Report(self.spec, entries, measured=measured, reused=reused,
                      failures=failures, store=self.store)

    @staticmethod
    def _measure_cell(measure, cell, mat):
        """Measure one cell; policy trace=True additionally records the
        cell's phase-attributed span events into the record (persisted in
        the ResultStore alongside the measurement — MeasurePolicy makes
        `trace` key-relevant only when set, so untraced campaigns keep
        their cell keys)."""
        if not cell.policy_dict().get("trace"):
            return measure(cell, mat)
        with obs.tracing() as buf:
            record = measure(cell, mat)
        record["trace"] = buf.flush()
        return record


def _suite_get(name: str):
    from ..matrices import suite

    return suite.get(name)


def run_spec(spec: ExperimentSpec, store: Optional[ResultStore] = None,
             **kw) -> Report:
    """One-liner: Runner(spec, store).run()."""
    return Runner(spec, store=store, **kw).run()
