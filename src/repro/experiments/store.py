"""ResultStore — content-addressed, resumable persistence for campaign cells.

One cell = one `<key>.json` under the store root (default
`benchmarks/results/store/`, overridable via REPRO_RESULT_STORE or the
`root=` argument). Keys come from `Cell.key()` (spec.py): physical
coordinates + resolved policy, so any two campaigns that request the
same measurement share the entry — partial-grid reuse falls out of the
addressing, there is no campaign-level cache file to invalidate.

Write discipline is the plan store's (core/spmv/plan.py): write to a
`<key>.<pid>.<tid>.json.tmp` sibling, then os.replace — readers never
see a torn file, concurrent runners never clobber each other's tmp.

Read discipline is tolerant: a corrupt/truncated/alien-schema entry is
treated as ABSENT (the Runner re-measures and overwrites), never fatal —
the store persists across code versions and interrupted runs.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

from .. import obs

STORE_SCHEMA_VERSION = 1

_OFF = ("off", "0", "none", "")


def default_root(results_dir: Optional[str] = None) -> str:
    """Store root resolution: REPRO_RESULT_STORE wins; otherwise a
    `results/` sibling under REPRO_OPERATOR_CACHE when that is set
    (hermetic test/CI runs that repoint the caches get a hermetic result
    store for free — plan.py's convention); otherwise
    `<results_dir|benchmarks/results>/store`."""
    env = os.environ.get("REPRO_RESULT_STORE")
    if env:
        return env
    opd = os.environ.get("REPRO_OPERATOR_CACHE")
    if opd and opd.lower() not in _OFF:
        return os.path.join(opd, "results")
    base = results_dir or os.path.join(os.getcwd(), "benchmarks", "results")
    return os.path.join(base, "store")


class ResultStore:
    def __init__(self, root: Optional[str] = None,
                 results_dir: Optional[str] = None):
        self.root = root or default_root(results_dir)

    def path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.json")

    def get(self, key: str) -> Optional[dict]:
        """The stored record for `key`, or None (missing OR unreadable —
        corruption means re-measure, not crash)."""
        path = self.path(key)
        try:
            with open(path) as f:
                entry = json.load(f)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError):
            obs.counter("result_store.misses").inc()
            return None
        if (not isinstance(entry, dict)
                or entry.get("schema") != STORE_SCHEMA_VERSION
                or not isinstance(entry.get("record"), dict)):
            obs.counter("result_store.misses").inc()
            return None
        obs.counter("result_store.hits").inc()
        return entry

    def put(self, key: str, cell: dict, record: dict) -> str:
        """Atomically persist one measured cell. Returns the entry path."""
        os.makedirs(self.root, exist_ok=True)
        path = self.path(key)
        entry = {
            "schema": STORE_SCHEMA_VERSION,
            "key": key,
            "cell": cell,
            "record": record,
            "written_at": time.time(),
        }
        # shared pid.tid tmp + rename convention (plan store / opcache /
        # reorder cache): concurrent writers get distinct tmp names and
        # the rename is the only visible event
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
        with open(tmp, "w") as f:
            json.dump(entry, f)
        os.replace(tmp, path)
        obs.counter("result_store.writes").inc()
        return path

    def entries(self):
        """Iterate (key, entry) over every readable cell in the store.

        Same tolerance as get(): unreadable/alien files are skipped, not
        fatal. This is the mining surface the corpus TuneAdvisor walks to
        learn (features → engine decision) pairs across campaigns.
        """
        try:
            names = sorted(os.listdir(self.root))
        except OSError:
            return
        for name in names:
            if not name.endswith(".json"):
                continue
            key = name[:-len(".json")]
            entry = self.get(key)
            if entry is not None:
                yield key, entry

    def delete(self, key: str) -> bool:
        try:
            os.remove(self.path(key))
            return True
        except OSError:
            return False

    def __contains__(self, key: str) -> bool:
        return self.get(key) is not None

    def __len__(self) -> int:
        try:
            return sum(1 for n in os.listdir(self.root)
                       if n.endswith(".json"))
        except OSError:
            return 0
