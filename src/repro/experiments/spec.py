"""ExperimentSpec — the declarative side of the measurement harness.

A campaign is a grid of *cells*; a cell is the smallest unit of
measurement (one matrix under one scheme on one machine point with one
batch width, measured under one policy). The spec enumerates the grid,
the Runner (runner.py) measures whatever the ResultStore doesn't already
hold, and the Report (report.py) is the typed view over the cells.

Axes mirror the paper's experiment design:

    matrices x schemes x (profiles | engines x dtypes x ps) x ks x variants

`profiles` names registered machine profiles (core/registry.py) — the
paper's "machines" axis; each expands to its (engine, dtype, p) point.
Alternatively the physical axes (engines/dtypes/ps) are given directly.
`ks` is the SpMM batch-width axis, `variants` a free-form axis consumed
by non-default cell kinds (e.g. the scheduling-policy sweep; for
kind="serve" the variant encodes one traffic scenario — see
cells.serve_variant — and `ks` doubles as the service's max_batch).

Cell identity is CONTENT-addressed: the key hashes the physical
coordinates plus the resolved measurement policy — never the profile
*name* (a renamed profile with the same physical point reuses its cells)
and never axes that don't change what is measured (amortize_iters is a
reporting knob). Two specs that overlap in cells share them through the
store, so adding an axis value to a campaign only measures the delta.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Iterable, Optional

from ..core import registry

CELL_SCHEMA_VERSION = 1


def _tup(v) -> tuple:
    if v is None:
        return ()
    if isinstance(v, str):
        return (v,)
    return tuple(v)


@dataclasses.dataclass(frozen=True)
class MeasurePolicy:
    """How each cell is measured (everything here is key-relevant except
    `amortize_iters`, which only parameterizes reporting).

    * iters / warmup / repeats — median-of-(iters x repeats) IOS samples
      after `warmup` warm calls; warmup=0 is the cold-cache protocol.
    * with_yax / with_parallel / with_metrics — include the YAX harness,
      the modelled-parallel timings, and the analytic structural metrics.
    * cg_profiles — profiles whose cells include the instrumented-CG
      measurement ("*" = every cell; the paper runs CG on the primary
      host only).
    * time_spmv=False — analytic-only cells (no operator build at all).
    * verify — gate each cell on the original-index-space numpy oracle.
    * probe — tuner probe mode, threaded to plan(): False (cost model
      only), True (probe the top candidates), "learned" (advisor
      shortlist mined from prior campaign cells), or "exhaustive"
      (probe everything). Bool values keep their historical key
      encoding, so pre-existing store cells stay addressable.
    * trace — record each cell's phase-attributed span events (repro.obs)
      into its stored record. Key-relevant only when True (the
      verify_tol convention), so untraced campaigns keep their keys.
    * amortize_iters — SpMV calls the one-off plan time is spread over in
      the Report's amortization/break-even accounting (paper §3: plan
      time is reported separately, never folded into SpMV time).
    """

    iters: int = 12
    warmup: int = 3
    repeats: int = 1
    time_spmv: bool = True
    with_yax: bool = True
    cg_profiles: tuple = ()
    with_parallel: bool = True
    with_metrics: bool = True
    verify: bool = False
    verify_tol: float = 1e-4
    probe: object = False            # False | True | "learned" | "exhaustive"
    trace: bool = False
    use_kernel: str = "auto"
    seed: int = 0
    amortize_iters: int = 100

    def __post_init__(self):
        object.__setattr__(self, "cg_profiles", _tup(self.cg_profiles))

    def cg_for(self, profile: str) -> bool:
        return "*" in self.cg_profiles or profile in self.cg_profiles

    def resolve(self, profile: str) -> dict:
        """The key-relevant policy as measured for one cell: cg_profiles
        collapses to this cell's with_cg bool, so a primary-only campaign
        and a no-CG campaign share every non-CG cell."""
        out = {
            "iters": int(self.iters), "warmup": int(self.warmup),
            "repeats": int(self.repeats),
            "time_spmv": bool(self.time_spmv),
            "with_yax": bool(self.with_yax),
            "with_cg": self.cg_for(profile),
            "with_parallel": bool(self.with_parallel),
            "with_metrics": bool(self.with_metrics),
            "verify": bool(self.verify),
            "probe": (self.probe if isinstance(self.probe, str)
                      else bool(self.probe)),
            "use_kernel": self.use_kernel,
            "seed": int(self.seed),
        }
        if self.verify:   # tolerance only gates verifying cells
            out["verify_tol"] = float(self.verify_tol)
        if self.trace:    # key-relevant only when tracing (key stability)
            out["trace"] = True
        return out


@dataclasses.dataclass(frozen=True)
class Cell:
    """One grid point, fully resolved (policy already per-cell)."""

    kind: str
    matrix: str
    scheme: str
    engine: str
    dtype: str
    p: int
    k: int
    variant: str
    policy: tuple                    # sorted (name, value) pairs
    profile: str = ""                # presentation label, NOT in the key

    def policy_dict(self) -> dict:
        return dict(self.policy)

    def coords(self) -> dict:
        """The identity coordinates (what the key hashes)."""
        return {
            "v": CELL_SCHEMA_VERSION, "kind": self.kind,
            "matrix": self.matrix, "scheme": self.scheme,
            "engine": self.engine, "dtype": self.dtype,
            "p": int(self.p), "k": int(self.k), "variant": self.variant,
            "policy": dict(self.policy),
        }

    def key(self) -> str:
        blob = json.dumps(self.coords(), sort_keys=True).encode()
        return hashlib.sha1(blob).hexdigest()[:20]

    def label(self) -> str:
        prof = self.profile or f"{self.engine}_{self.dtype}_p{self.p}"
        tail = f"@k{self.k}" if self.k != 1 else ""
        var = f"/{self.variant}" if self.variant else ""
        return f"{prof}|{self.matrix}|{self.scheme}{tail}{var}"


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """A declarative measurement campaign (see module docstring).

    profiles — registered profile names, or "*" for every registered
    profile (plugin profiles join automatically). Mutually exclusive with
    the explicit engines/dtypes/ps axes.
    """

    name: str
    matrices: tuple
    schemes: tuple = ("baseline",)
    profiles: tuple = ()
    engines: tuple = ()
    dtypes: tuple = ("float32",)
    ps: tuple = (8,)
    ks: tuple = (1,)
    variants: tuple = ("",)
    kind: str = "spmv"
    policy: MeasurePolicy = dataclasses.field(default_factory=MeasurePolicy)

    def __post_init__(self):
        for f in ("matrices", "schemes", "profiles", "engines", "dtypes",
                  "ps", "ks", "variants"):
            object.__setattr__(self, f, _tup(getattr(self, f)))
        if self.profiles and (self.engines or self.dtypes != ("float32",)
                              or self.ps != (8,)):
            raise ValueError("give either profiles= or the explicit "
                             "engines/dtypes/ps axes, not both (a profile "
                             "already fixes engine, dtype and p)")
        if not self.matrices:
            raise ValueError("spec has no matrices")

    def _machine_points(self) -> list:
        """[(profile_name, engine, dtype, p)] — the machine axis."""
        if self.profiles:
            names = (list(registry.PROFILE_REGISTRY)
                     if "*" in self.profiles else list(self.profiles))
            out = []
            for n in names:
                ps = registry.get_profile(n)
                out.append((ps.name,) + ps.physical())
            return out
        engines = self.engines or ("auto",)
        return [("", e, d, int(p)) for e in engines for d in self.dtypes
                for p in self.ps]

    def cells(self, matrices: Optional[Iterable[str]] = None) -> list:
        """Enumerate the grid (optionally restricted to some matrices),
        matrix-major so the Runner materializes each matrix once."""
        mats = self.matrices if matrices is None else _tup(matrices)
        points = self._machine_points()
        out = []
        for m in mats:
            for prof, engine, dtype, p in points:
                pol = tuple(sorted(self.policy.resolve(prof).items()))
                for s in self.schemes:
                    for k in self.ks:
                        for var in self.variants:
                            out.append(Cell(
                                kind=self.kind, matrix=m, scheme=s,
                                engine=engine, dtype=dtype, p=p, k=int(k),
                                variant=var, policy=pol, profile=prof))
        return out


def paper_schemes() -> list:
    """The paper's scheme axis: baseline + the §2.1 schemes + the random
    control (Fig. 1's shuffle) — pulled from the plugin registry, so a
    third-party paper=True scheme joins every campaign that uses this
    default."""
    from ..core.reorder import api as _api  # noqa: F401 — registers built-ins

    paper = [s.name for s in registry.SCHEME_REGISTRY.values() if s.paper]
    return ["baseline"] + paper + ["random"]


def registered_engines(spmm_only: bool = False) -> list:
    """Engine axis from the plugin registry (importing the built-ins)."""
    from ..core.spmv import ops  # noqa: F401 — registers built-in engines

    return [e.name for e in registry.ENGINE_REGISTRY.values()
            if e.supports_spmm or not spmm_only]
