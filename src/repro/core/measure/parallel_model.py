"""Calibrated parallel-execution model (DESIGN.md §7).

The container has ONE physical core, so the paper's parallel rows cannot be
measured directly. The model reproduces the parallel mechanism the paper
analyses — per-panel work + static-schedule imbalance:

    T_par(P) = max_p T_seq(panel_p) + alpha_sync

where T_seq(panel_p) is *measured* (sequential IOS timing of the panel's
own sub-operator, which includes its real x-gather locality), and
alpha_sync is a fixed small barrier cost. This is exact for the
load-imbalance component (the term §6 studies) and approximate for shared
bandwidth contention (stated limitation).

Every figure produced from this model is labelled "modelled parallel".
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..sparse.csr import CSRMatrix
from ..sparse.partition import static_partition, nnz_balanced_partition
from ..spmv.ops import make_engine
from .ios import run_ios

ALPHA_SYNC_MS = 0.005  # barrier cost estimate (one core-to-core sync)


def panel_submatrix(mat: CSRMatrix, r0: int, r1: int, m_pad: int = 0) -> CSRMatrix:
    """Rows [r0, r1) as an (h, n) submatrix; optionally pad height to a
    multiple of m_pad with empty rows (shared XLA compilation across
    panels — the padded rows produce zeros, negligible timing skew)."""
    rp = mat.rowptr.astype(np.int64)
    s, e = rp[r0], rp[r1]
    h = r1 - r0
    if m_pad:
        h = ((h + m_pad - 1) // m_pad) * m_pad
    rowptr = np.full(h + 1, e - s, dtype=np.int32)
    rowptr[: r1 - r0 + 1] = (rp[r0:r1 + 1] - s).astype(np.int32)
    return CSRMatrix(rowptr=rowptr, cols=mat.cols[s:e], vals=mat.vals[s:e],
                     shape=(h, mat.n))


def modelled_parallel_ms(mat: CSRMatrix, p: int, engine: str = "csr",
                         schedule: str = "static", iters: int = 8,
                         rng_seed: int = 0, panels=None) -> float:
    """Median modelled parallel SpMV time for P cores.

    panels — explicit int[P+1] contiguous row split (e.g. a topology-aware
    plan's panel_starts, whose partitioner permutation is already folded
    into `mat`); overrides the schedule name."""
    if panels is not None:
        starts = np.asarray(panels, np.int64)
        if starts.size != p + 1:
            raise ValueError(f"panels has {starts.size - 1} panels, "
                             f"expected {p}")
    else:
        starts = (static_partition(mat, p) if schedule == "static"
                  else nnz_balanced_partition(mat, p))
    rng = np.random.default_rng(rng_seed)
    x = jnp.asarray(rng.standard_normal(mat.n), jnp.float32)
    panel_ms = []
    for k in range(p):
        r0, r1 = int(starts[k]), int(starts[k + 1])
        if r1 <= r0:
            panel_ms.append(0.0)
            continue
        sub = panel_submatrix(mat, r0, r1, m_pad=512)
        # bucket nnz so same-sized panels share one XLA compilation
        nz = max(sub.nnz, 1)
        bucket = max(4096, 1 << (int(np.ceil(np.log2(nz))) - 3))
        op = make_engine(sub, engine, nnz_bucket=bucket)
        # IOS-style but x comes from outside the panel (real CG dataflow):
        # swap only the panel's slice of a fresh vector each iteration.
        ms = run_ios_panel(op, x, r0, r1, iters)
        panel_ms.append(float(np.median(ms)))
    return max(panel_ms) + ALPHA_SYNC_MS


def run_ios_panel(op, x, r0, r1, iters: int) -> np.ndarray:
    """IOS variant for a panel: y_panel replaces x[r0:r1] between runs."""
    import time

    times = np.empty(iters)
    for i in range(2):
        y = op(x)
        y.block_until_ready()
        x = x.at[r0:r1].set(y[: r1 - r0])
    for i in range(iters):
        t0 = time.perf_counter()
        y = op(x)
        y.block_until_ready()
        times[i] = (time.perf_counter() - t0) * 1e3
        x = x.at[r0:r1].set(y[: r1 - r0])
    return times
