"""Measurement methodology (paper §3.1): YAX vs IOS harnesses.

YAX (paper Listing 1): time `y = A @ x` repeatedly with the SAME x — the
common-but-misleading protocol (unnaturally warm caches for x).

IOS (paper Listing 2): swap input and output between iterations
(`x, y = y, x`) so the input vector moves like it does inside a real
application (CG writes its direction vector every iteration).

Both return per-iteration wall-clock milliseconds; timing is host-side
around a jit-compiled matvec with block_until_ready (the JAX analogue of
the paper's omp_get_wtime bracketing). Symmetric square matrices (the
corpus guarantee) make the swap well-typed.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def _time_once(fn: Callable, *args) -> tuple[float, jax.Array]:
    t0 = time.perf_counter()
    out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) * 1e3, out


def run_yax(op: Callable, x0: jax.Array, iters: int = 20, warmup: int = 3) -> np.ndarray:
    """Paper Listing 1. Returns ms[iters]."""
    x = x0
    for _ in range(warmup):
        y = op(x)
        y.block_until_ready()
    times = np.empty(iters)
    for i in range(iters):
        times[i], y = _time_once(op, x)
        # x unchanged — the YAX flaw under study
    return times


def run_ios(op: Callable, x0: jax.Array, iters: int = 20, warmup: int = 3) -> np.ndarray:
    """Paper Listing 2. Returns ms[iters]."""
    x = x0
    for _ in range(warmup):
        x = op(x)
        x.block_until_ready()
    times = np.empty(iters)
    for i in range(iters):
        times[i], y = _time_once(op, x)
        x = y  # output becomes input
    return times


def run_ios_batched(op, n: int, k: int, iters: int = 20, warmup: int = 3,
                    dtype=None, seed: int = 0) -> np.ndarray:
    """IOS-time the k-RHS path of an operator. Returns ms[iters].

    Pins the measurement convention in ONE place for the benchmarks, the
    launcher, and the tuner probe: k == 1 times the SpMV `__call__` (the
    honest unbatched baseline — no k-tile padding inflating it), k > 1
    times `op.matmul` on an [n, k] block.
    """
    dt = jnp.float32 if dtype is None else dtype
    rng = np.random.default_rng(seed)
    if k <= 1:
        return run_ios(op, jnp.asarray(rng.standard_normal(n), dt),
                       iters=iters, warmup=warmup)
    x0 = jnp.asarray(rng.standard_normal((n, k)), dt)
    return run_ios(op.matmul, x0, iters=iters, warmup=warmup)


def gflops(nnz: int, ms: np.ndarray) -> np.ndarray:
    """2 flops per nonzero (mul + add), paper's convention."""
    return 2.0 * nnz / (ms * 1e-3) / 1e9


def summarize(ms: np.ndarray) -> dict:
    return {
        "median_ms": float(np.median(ms)),
        "mean_ms": float(np.mean(ms)),
        "min_ms": float(np.min(ms)),
        "p95_ms": float(np.percentile(ms, 95)),
    }
