"""Conjugate Gradient — the paper's "real application" yardstick (Listing 3).

Two forms:
  * cg_solve      — fully jit-compiled (lax.while_loop) production solver
                    used by examples/cg_solver.py and the distributed runtime.
  * cg_measured   — open-coded iteration that times the SpMV separately from
                    the vector updates, exactly like the paper's
                    instrumented Listing 3 (per-iteration SpMV wall-clock).

The corpus generators make matrices strictly diagonally dominant
(diagonal = m), hence SPD, so CG converges.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class CGResult(NamedTuple):
    x: jax.Array
    iters: jax.Array
    residual: jax.Array


@functools.partial(jax.jit, static_argnames=("matvec", "max_iter"))
def cg_solve(matvec: Callable, b: jax.Array, max_iter: int = 100,
             tol: float = 1e-8) -> CGResult:
    """Standard CG, jit-compiled end-to-end (lax.while_loop)."""
    x0 = jnp.zeros_like(b)
    r0 = b - matvec(x0)
    p0 = r0
    rs0 = jnp.vdot(r0, r0)

    def cond(state):
        _, _, _, rs, k = state
        return jnp.logical_and(k < max_iter, rs > tol * tol)

    def body(state):
        x, r, p, rs, k = state
        ap = matvec(p)
        alpha = rs / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        p = r + (rs_new / rs) * p
        return (x, r, p, rs_new, k + 1)

    x, r, p, rs, k = jax.lax.while_loop(cond, body, (x0, r0, p0, rs0, 0))
    return CGResult(x=x, iters=k, residual=jnp.sqrt(rs))


def cg_measured(matvec: Callable, b: jax.Array, iters: int = 20,
                warmup: int = 2) -> np.ndarray:
    """Instrumented CG (paper Listing 3): per-iteration SpMV ms.

    The vector updates (dot, axpy) run between timed SpMVs and perturb the
    cache state exactly as in the real application — this is the behaviour
    IOS approximates and YAX misses.
    """

    @jax.jit
    def vec_update(x, r, p, ap, rs_old):
        pap = jnp.vdot(p, ap)
        alpha = rs_old / pap
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        beta = rs_new / rs_old
        p = r + beta * p
        return x, r, p, rs_new

    x = jnp.zeros_like(b)
    r = b
    p = r
    rs = jnp.vdot(r, r)
    times = []
    for i in range(iters + warmup):
        t0 = time.perf_counter()
        ap = matvec(p)
        ap.block_until_ready()
        dt = (time.perf_counter() - t0) * 1e3
        if i >= warmup:
            times.append(dt)
        x, r, p, rs = vec_update(x, r, p, ap, rs)
    return np.asarray(times)
