"""Conjugate Gradient — the paper's "real application" yardstick (Listing 3).

Four forms:
  * cg_solve      — fully jit-compiled (lax.while_loop) production solver
                    used by examples/cg_solver.py and the distributed runtime.
  * block_cg_solve— k right-hand sides at once; one SpMM (operator.matmul)
                    per iteration instead of k SpMVs — the solver workload
                    the batched engine layer opens.
  * solve_problem — pipeline-facade consumer: plan + build + solve entirely
                    in the ORIGINAL index space (the permutation-carrying
                    operator absorbs the reordering; callers never permute
                    b or un-permute x by hand).
  * cg_measured   — open-coded iteration that times the SpMV separately from
                    the vector updates, exactly like the paper's
                    instrumented Listing 3 (per-iteration SpMV wall-clock).

The corpus generators make matrices strictly diagonally dominant
(diagonal = m), hence SPD, so CG converges.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class CGResult(NamedTuple):
    x: jax.Array
    iters: jax.Array
    residual: jax.Array


@functools.partial(jax.jit, static_argnames=("matvec", "max_iter"))
def cg_solve(matvec: Callable, b: jax.Array, max_iter: int = 100,
             tol: float = 1e-8) -> CGResult:
    """Standard CG, jit-compiled end-to-end (lax.while_loop)."""
    x0 = jnp.zeros_like(b)
    r0 = b - matvec(x0)
    p0 = r0
    rs0 = jnp.vdot(r0, r0)

    def cond(state):
        _, _, _, rs, k = state
        return jnp.logical_and(k < max_iter, rs > tol * tol)

    def body(state):
        x, r, p, rs, k = state
        ap = matvec(p)
        alpha = rs / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        p = r + (rs_new / rs) * p
        return (x, r, p, rs_new, k + 1)

    x, r, p, rs, k = jax.lax.while_loop(cond, body, (x0, r0, p0, rs0, 0))
    return CGResult(x=x, iters=k, residual=jnp.sqrt(rs))


@functools.partial(jax.jit, static_argnames=("matmul", "max_iter"))
def block_cg_solve(matmul: Callable, b: jax.Array, max_iter: int = 100,
                   tol: float = 1e-8) -> CGResult:
    """Batched CG over k right-hand sides: solve A X = B, B of shape [n, k].

    The k recurrences are mathematically independent (per-column α/β —
    'diagonal' block CG), but each iteration issues ONE SpMM `A @ P[n, k]`
    instead of k SpMVs: the solver-side consumer of the batched engine
    layer, streaming the matrix once per iteration for all systems.
    Converged columns freeze (α = β = 0), so the loop runs until the
    slowest column meets tol or max_iter.
    """
    x0 = jnp.zeros_like(b)
    r0 = b - matmul(x0)
    p0 = r0
    rs0 = jnp.sum(r0 * r0, axis=0)                 # [k] per-column ||r||^2

    def cond(state):
        _, _, _, rs, k = state
        return jnp.logical_and(k < max_iter, jnp.any(rs > tol * tol))

    def body(state):
        x, r, p, rs, k = state
        ap = matmul(p)                             # one SpMM for all k RHS
        pap = jnp.sum(p * ap, axis=0)
        live = rs > tol * tol
        alpha = jnp.where(live, rs / jnp.where(pap == 0, 1.0, pap), 0.0)
        x = x + alpha[None, :] * p
        r = r - alpha[None, :] * ap
        rs_new = jnp.sum(r * r, axis=0)
        beta = jnp.where(live, rs_new / jnp.where(rs == 0, 1.0, rs), 0.0)
        p = jnp.where(live[None, :], r + beta[None, :] * p, p)
        return (x, r, p, jnp.where(live, rs_new, rs), k + 1)

    x, r, p, rs, k = jax.lax.while_loop(cond, body, (x0, r0, p0, rs0, 0))
    return CGResult(x=x, iters=k, residual=jnp.sqrt(rs))


def solve_problem(problem, b: jax.Array, reorder: str = "auto",
                  engine: str = "auto", max_iter: int = 100,
                  tol: float = 1e-8, probe: bool = False,
                  cache: bool = True, topology=None, partition="auto"):
    """Plan, build, and CG-solve A x = b through the pipeline facade.

    `problem` is an SpmvProblem or a bare CSRMatrix. b of shape [n] runs
    cg_solve; [n, k] runs block_cg_solve (one SpMM per iteration). Both b
    and the returned solution live in the ORIGINAL index space — the
    reordering the planner picks (e.g. reorder="auto" choosing rcm for
    locality) happens inside the permutation-carrying operator, so there
    is no hand-carried permutation between caller and solver.

    topology/partition (core/spmv/topology.py) run the same solve on a
    sharded plan: every per-iteration SpMV is the ShardedOperator's
    collective step, b and x still in the original index space.

    Returns (CGResult, Operator); the operator's `.plan` records what the
    pipeline decided (scheme, engine, partition, costs).
    """
    from ...api import SpmvProblem, plan as make_plan

    k = int(b.shape[1]) if getattr(b, "ndim", 1) == 2 else 1
    if not isinstance(problem, SpmvProblem):
        problem = SpmvProblem(problem, k=k)
    pl = make_plan(problem, reorder=reorder, engine=engine, probe=probe,
                   cache=cache, topology=topology, partition=partition)
    op = pl.build(cache=cache)
    if k > 1:
        res = block_cg_solve(op.matmul, b, max_iter=max_iter, tol=tol)
    else:
        res = cg_solve(op, b, max_iter=max_iter, tol=tol)
    return res, op


def cg_measured(matvec: Callable, b: jax.Array, iters: int = 20,
                warmup: int = 2) -> np.ndarray:
    """Instrumented CG (paper Listing 3): per-iteration SpMV ms.

    The vector updates (dot, axpy) run between timed SpMVs and perturb the
    cache state exactly as in the real application — this is the behaviour
    IOS approximates and YAX misses.
    """

    @jax.jit
    def vec_update(x, r, p, ap, rs_old):
        pap = jnp.vdot(p, ap)
        alpha = rs_old / pap
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        beta = rs_new / rs_old
        p = r + beta * p
        return x, r, p, rs_new

    x = jnp.zeros_like(b)
    r = b
    p = r
    rs = jnp.vdot(r, r)
    times = []
    for i in range(iters + warmup):
        t0 = time.perf_counter()
        ap = matvec(p)
        ap.block_until_ready()
        dt = (time.perf_counter() - t0) * 1e3
        if i >= warmup:
            times.append(dt)
        x, r, p, rs = vec_update(x, r, p, ap, rs)
    return np.asarray(times)
