"""Analysis utilities for the paper's plots.

* performance_profile — Dolan & Moré profiles (paper Fig. 5)
* speedup_buckets     — stacked-bar bucket counts (Fig. 6)
* pairwise_win_rates  — win-rate matrix (Fig. 7)
* consistency_ratio   — Consistent% = 1 - |IS|/|CCS| (Fig. 8, Eq. 1)
* cdf                 — plain CDF points (Figs. 3, 4)

Everything takes a `perf` array indexed [scheme, matrix] (higher = better,
e.g. GFLOPs) or a `speedup` array [scheme, matrix] relative to baseline.
"""
from __future__ import annotations

import numpy as np

BUCKETS = [0.0, 1.0, 1.1, 1.25, 1.5, 2.0, np.inf]
BUCKET_LABELS = ["<1", "1-1.1", "1.1-1.25", "1.25-1.5", "1.5-2", ">=2"]


def performance_profile(perf: np.ndarray, taus: np.ndarray) -> np.ndarray:
    """perf [S, M] -> profile [S, len(taus)]: fraction of matrices where
    scheme s is within tau of the best scheme for that matrix."""
    best = perf.max(axis=0, keepdims=True)          # [1, M]
    ratio = best / np.maximum(perf, 1e-30)          # >= 1, 1 = best
    return np.stack([(ratio <= t).mean(axis=1) for t in taus], axis=1)


def speedup_buckets(speedup: np.ndarray) -> np.ndarray:
    """speedup [S, M] -> counts [S, len(BUCKET_LABELS)]."""
    out = np.zeros((speedup.shape[0], len(BUCKET_LABELS)), dtype=np.int64)
    for s in range(speedup.shape[0]):
        out[s] = np.histogram(speedup[s], bins=BUCKETS)[0]
    return out


def pairwise_win_rates(perf: np.ndarray) -> np.ndarray:
    """perf [S, M] -> win[S, S]: fraction of matrices where row beats col."""
    s = perf.shape[0]
    win = np.zeros((s, s))
    for i in range(s):
        for j in range(s):
            if i != j:
                win[i, j] = float((perf[i] > perf[j]).mean())
    return win


def consistency_ratio(speedups_by_machine: np.ndarray, tau: float) -> tuple[float, int]:
    """speedups_by_machine [machines, M] for ONE scheme.

    CCS = matrices with speedup > tau on >= 1 machine;
    IS  = CCS members with slowdown (< 1) on >= 1 machine.
    Returns (Consistent%, |CCS|). (paper Eq. 1)"""
    ccs = (speedups_by_machine > tau).any(axis=0)
    is_ = ccs & (speedups_by_machine < 1.0).any(axis=0)
    n_ccs = int(ccs.sum())
    if n_ccs == 0:
        return 1.0, 0
    return 1.0 - is_.sum() / n_ccs, n_ccs


def cdf(values: np.ndarray):
    """Returns (sorted values, cumulative fraction)."""
    v = np.sort(np.asarray(values))
    return v, np.arange(1, v.size + 1) / v.size


def reverse_cdf(values: np.ndarray):
    v = np.sort(np.asarray(values))
    return v, 1.0 - np.arange(v.size) / v.size


def geomean(values: np.ndarray) -> float:
    v = np.asarray(values, dtype=np.float64)
    return float(np.exp(np.mean(np.log(np.maximum(v, 1e-30)))))


# --------------------------------------------------------------------------
# Plan-time vs run-time split (autotuned-engine accounting)
# --------------------------------------------------------------------------
def plan_run_split(records: dict, spmv_field: str = "seq_ios_ms",
                   iters_to_amortize: int = 100) -> dict:
    """Separate plan-time (reorder excluded; tune + format build) from
    SpMV run-time across campaign records (benchmarks/common.py cells).

    The paper's methodology point: preprocessing must be reported apart
    from SpMV time. Per cell the result carries plan_ms / run_ms /
    plan_over_run plus `amortized_ms` — run time with the one-off plan
    cost spread over `iters_to_amortize` SpMV calls (a CG-length solve).
    Cells served from the operator cache count plan time 0 (that is the
    cache's purpose).
    """
    out = {}
    for key, rec in records.items():
        if spmv_field not in rec:
            continue
        plan_ms = (0.0 if rec.get("op_cache_hit")
                   else rec.get("tune_ms", 0.0) + rec.get("format_build_ms", 0.0))
        run_ms = rec[spmv_field]
        out[key] = {
            "plan_ms": plan_ms,
            "run_ms": run_ms,
            "tuner_choice": rec.get("tuner_choice", rec.get("engine", "csr")),
            "op_cache_hit": bool(rec.get("op_cache_hit", False)),
            "plan_over_run": plan_ms / max(run_ms, 1e-9),
            "amortized_ms": run_ms + plan_ms / max(iters_to_amortize, 1),
        }
    return out
