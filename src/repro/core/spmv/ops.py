"""Unified jit'd SpMV engine dispatch.

Engines (each a builder in the plugin registry, core/registry.py):
  csr    — gather + segment-sum (paper Listing 4 semantics; the CPU
           measurement engine for the reproduction study)
  ell    — padded row-major ELLPACK
  sell   — SELL-C-σ Pallas kernel (TPU) / jnp oracle (CPU)
  bell   — Block-ELL Pallas kernel (TPU) / jnp oracle (CPU)
  bcsr   — BCSR Pallas kernel (TPU) / jnp oracle (CPU)
  dense  — dense matmul (tiny matrices / sanity only)

`make_engine(mat, name)` is the registry-dispatched factory; engine="auto"
runs the OSKI-style tuner (core/spmv/tune.py), whose cost model and
candidate grids are themselves registry metadata (`cost_fn` /
`candidates_fn` on each EngineSpec), so a plugin engine registered with
@register_engine participates in tuning and planning with no change here.
The staged pipeline entry point — problem in, serializable plan out,
permutation-carrying operator built from the plan — is repro.api.

Every operator also exposes `matmul(x)` — the multi-vector SpMM path
(y[m, k] = A @ x[n, k]) that amortizes the matrix stream over k right-hand
sides; the batched serving front-end lives in serving/spmv_service.py.

`build_operator` is a deprecated shim over `make_engine` kept for external
callers.
"""
from __future__ import annotations

import functools
import warnings
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from .. import registry
from ..registry import register_engine
from ..sparse.bell import to_bcsr, to_block_ell
from ..sparse.csr import CSRMatrix
from ..sparse.sell import to_sell
from . import ref, tune

Engine = Literal["csr", "ell", "sell", "bell", "bcsr", "dense", "auto"]


@functools.partial(jax.jit, static_argnames=("m",))
def _csr_matvec(row_ids, cols, vals, x, m):
    return ref.spmv_csr(row_ids, cols, vals, x, m)


@functools.partial(jax.jit, static_argnames=("m",))
def _csr_matmul(row_ids, cols, vals, x, m):
    return ref.spmm_csr(row_ids, cols, vals, x, m)


@jax.jit
def _ell_matvec(ell_cols, ell_vals, x):
    return ref.spmv_ell(ell_cols, ell_vals, x)


@jax.jit
def _ell_matmul(ell_cols, ell_vals, x):
    return ref.spmm_ell(ell_cols, ell_vals, x)


class DeviceCSR:
    """Device-resident CSR (COO-expanded) operator.

    nnz_bucket > 0 pads nnz up to the next multiple (val=0, row=0, col=0 —
    result-neutral) so panels of similar size share one XLA compilation.
    """

    def __init__(self, mat: CSRMatrix, dtype=jnp.float32, nnz_bucket: int = 0):
        self.m, self.n = mat.shape
        self.nnz = mat.nnz
        row_ids = np.repeat(np.arange(mat.m, dtype=np.int32), mat.row_nnz())
        cols = mat.cols.astype(np.int32)
        vals = mat.vals
        if nnz_bucket:
            pad = (-mat.nnz) % nnz_bucket
            if pad:
                # pad with (row=m-1, col=0, val=0): keeps row_ids sorted
                # (segment_sum indices_are_sorted) and adds exactly 0.
                row_ids = np.concatenate(
                    [row_ids, np.full(pad, self.m - 1, np.int32)])
                cols = np.concatenate([cols, np.zeros(pad, np.int32)])
                vals = np.concatenate([vals, np.zeros(pad, vals.dtype)])
        self.row_ids = jnp.asarray(row_ids)
        self.cols = jnp.asarray(cols)
        self.vals = jnp.asarray(vals, dtype=dtype)

    def __call__(self, x: jax.Array) -> jax.Array:
        with obs.span("kernel.spmv", engine="csr"):
            return _csr_matvec(self.row_ids, self.cols, self.vals, x,
                               self.m)

    def matmul(self, x: jax.Array) -> jax.Array:
        """x: [n, k] -> y: [m, k]: one gather/segment-sum pass serves all k
        vectors (the matrix stream is paid once, not k times)."""
        if x.ndim == 1:
            return self(x)
        with obs.span("kernel.spmm", engine="csr", k=int(x.shape[1])):
            return _csr_matmul(self.row_ids, self.cols, self.vals, x,
                               self.m)

    # -- operator-cache protocol (opcache.py) ------------------------------
    def state(self):
        meta = {"m": self.m, "n": self.n, "nnz": self.nnz}
        arrays = {"row_ids": np.asarray(self.row_ids),
                  "cols": np.asarray(self.cols),
                  "vals": np.asarray(self.vals)}
        return meta, arrays

    @classmethod
    def from_state(cls, meta, arrays, dtype=jnp.float32):
        op = object.__new__(cls)
        op.m, op.n, op.nnz = meta["m"], meta["n"], meta["nnz"]
        op.row_ids = jnp.asarray(arrays["row_ids"])
        op.cols = jnp.asarray(arrays["cols"])
        op.vals = jnp.asarray(arrays["vals"], dtype=dtype)
        return op


class DeviceELL:
    def __init__(self, mat: CSRMatrix, dtype=jnp.float32):
        self.m, self.n = mat.shape
        counts = mat.row_nnz()
        k = max(int(counts.max()), 1)
        cols = np.zeros((mat.m, k), dtype=np.int32)
        vals = np.zeros((mat.m, k), dtype=np.float64)
        rp = mat.rowptr.astype(np.int64)
        # vectorized scatter: element e of row r lands at (r, e - rowptr[r])
        r = np.repeat(np.arange(mat.m), counts)
        j = np.arange(mat.nnz) - np.repeat(rp[:-1], counts)
        cols[r, j] = mat.cols
        vals[r, j] = mat.vals
        self.ell_cols = jnp.asarray(cols)
        self.ell_vals = jnp.asarray(vals, dtype=dtype)
        self.padded_nnz = mat.m * k

    def __call__(self, x: jax.Array) -> jax.Array:
        with obs.span("kernel.spmv", engine="ell"):
            return _ell_matvec(self.ell_cols, self.ell_vals, x)

    def matmul(self, x: jax.Array) -> jax.Array:
        """x: [n, k] -> y: [m, k] (batched padded-ELL contraction)."""
        if x.ndim == 1:
            return self(x)
        with obs.span("kernel.spmm", engine="ell", k=int(x.shape[1])):
            return _ell_matmul(self.ell_cols, self.ell_vals, x)

    def state(self):
        meta = {"m": self.m, "n": self.n, "padded_nnz": self.padded_nnz}
        return meta, {"ell_cols": np.asarray(self.ell_cols),
                      "ell_vals": np.asarray(self.ell_vals)}

    @classmethod
    def from_state(cls, meta, arrays, dtype=jnp.float32):
        op = object.__new__(cls)
        op.m, op.n = meta["m"], meta["n"]
        op.padded_nnz = meta["padded_nnz"]
        op.ell_cols = jnp.asarray(arrays["ell_cols"])
        op.ell_vals = jnp.asarray(arrays["ell_vals"], dtype=dtype)
        return op


class DeviceDense:
    def __init__(self, mat: CSRMatrix, dtype=jnp.float32):
        self.a = jnp.asarray(mat.to_dense(), dtype=dtype)

    def __call__(self, x: jax.Array) -> jax.Array:
        with obs.span("kernel.spmv", engine="dense"):
            return self.a @ x

    def matmul(self, x: jax.Array) -> jax.Array:
        with obs.span("kernel.spmm", engine="dense"):
            return self.a @ x

    def state(self):
        return {}, {"a": np.asarray(self.a)}

    @classmethod
    def from_state(cls, meta, arrays, dtype=jnp.float32):
        op = object.__new__(cls)
        op.a = jnp.asarray(arrays["a"], dtype=dtype)
        return op


# -- engine registry entries (registration order = tuner candidate order) --

@register_engine("csr", supports_spmm=True, device="any",
                 cost_fn=tune.cost_csr, candidates_fn=tune.cands_default,
                 description="COO-expanded gather + segment-sum")
def _build_csr(mat: CSRMatrix, dtype=jnp.float32, block_shape=(8, 128),
               sell_sigma=None, use_kernel: str = "auto",
               nnz_bucket: int = 0):
    return DeviceCSR(mat, dtype, nnz_bucket=nnz_bucket)


@register_engine("ell", supports_spmm=True, device="any",
                 cost_fn=tune.cost_ell, candidates_fn=tune.cands_default,
                 description="padded row-major ELLPACK")
def _build_ell(mat: CSRMatrix, dtype=jnp.float32, block_shape=(8, 128),
               sell_sigma=None, use_kernel: str = "auto",
               nnz_bucket: int = 0):
    return DeviceELL(mat, dtype)


@register_engine("bell", supports_spmm=True, device="tpu",
                 cost_fn=tune.cost_bell, candidates_fn=tune.cands_default,
                 description="Block-ELL Pallas kernel (ref fallback on CPU)")
def _build_bell(mat: CSRMatrix, dtype=jnp.float32, block_shape=(8, 128),
                sell_sigma=None, use_kernel: str = "auto",
                nnz_bucket: int = 0):
    from ...kernels.bell_spmv.ops import BellOperator

    return BellOperator(to_block_ell(mat, *block_shape), dtype, use_kernel)


@register_engine("bcsr", supports_spmm=True, device="tpu",
                 cost_fn=tune.cost_bcsr, candidates_fn=tune.cands_default,
                 description="BCSR Pallas kernel (ref fallback on CPU)")
def _build_bcsr(mat: CSRMatrix, dtype=jnp.float32, block_shape=(8, 128),
                sell_sigma=None, use_kernel: str = "auto",
                nnz_bucket: int = 0):
    from ...kernels.bcsr_spmv.ops import BcsrOperator

    return BcsrOperator(to_bcsr(mat, *block_shape), dtype, use_kernel)


@register_engine("sell", supports_spmm=True, device="tpu",
                 cost_fn=tune.cost_sell, candidates_fn=tune.cands_sell,
                 description="SELL-C-σ Pallas kernel, k-tiled SpMM")
def _build_sell(mat: CSRMatrix, dtype=jnp.float32, block_shape=(8, 128),
                sell_sigma=None, use_kernel: str = "auto",
                nnz_bucket: int = 0):
    from ...kernels.sell_spmv.ops import SellOperator

    c, w = block_shape
    sigma = 8 * c if sell_sigma is None else sell_sigma
    return SellOperator(to_sell(mat, c=c, sigma=sigma, w=w), dtype,
                        use_kernel)


@register_engine("dense", supports_spmm=True, device="any",
                 cost_fn=tune.cost_dense, candidates_fn=tune.cands_dense,
                 description="dense matmul (tiny matrices / sanity only)")
def _build_dense(mat: CSRMatrix, dtype=jnp.float32, block_shape=(8, 128),
                 sell_sigma=None, use_kernel: str = "auto",
                 nnz_bucket: int = 0):
    return DeviceDense(mat, dtype)


def make_engine(mat: CSRMatrix, engine: Engine = "csr", dtype=jnp.float32,
                block_shape=(8, 128), use_kernel: str = "auto",
                nnz_bucket: int = 0, sell_sigma: int | None = None,
                probe: bool = False, k: int = 1):
    """Factory: host CSRMatrix -> callable device operator y = A @ x,
    dispatched through the engine registry.

    engine="auto" runs the OSKI-style tuner (core/spmv/tune.py): a cost
    model over structural metrics (optionally refined by empirical probing
    when probe=True) picks the engine and its shape parameters; the chosen
    plan is attached to the returned operator as `.plan`.

    k is the expected number of simultaneous right-hand sides (SpMM batch
    width). It only affects tuning — matrix bytes amortize over k vectors,
    shifting the engine choice — never the stored format; every operator's
    `matmul` accepts any k at run time.

    For engine="sell", block_shape is (slice height C, chunk width W) and
    sell_sigma is the σ sort window (default 8 * C).

    Operators built here live in the *given* matrix's index space; the
    permutation-carrying wrapper that accepts original-index-space vectors
    is repro.api.plan(...).build().
    """
    if engine == "auto":
        return tune.build_tuned(mat, dtype=dtype, probe=probe,
                                use_kernel=use_kernel, nnz_bucket=nnz_bucket,
                                k=k)
    spec = registry.get_engine(engine)
    return spec.build(mat, dtype=dtype, block_shape=block_shape,
                      sell_sigma=sell_sigma, use_kernel=use_kernel,
                      nnz_bucket=nnz_bucket)


def build_operator(mat: CSRMatrix, engine: Engine = "csr", dtype=jnp.float32,
                   block_shape=(8, 128), use_kernel: str = "auto",
                   nnz_bucket: int = 0, sell_sigma: int | None = None,
                   probe: bool = False, k: int = 1):
    """Deprecated shim over `make_engine` (same signature and behavior).

    New code plans through repro.api — `plan(SpmvProblem(mat, k=k),
    engine=...).build()` — which adds joint scheme/engine selection, the
    persistent plan store, and permutation-carrying operators; code that
    really wants a bare operator in the matrix's own index space calls
    `make_engine` directly.
    """
    warnings.warn(
        "build_operator() is deprecated; use repro.api.plan(...).build() "
        "(or core.spmv.ops.make_engine for a bare fixed-engine operator)",
        DeprecationWarning, stacklevel=2)
    return make_engine(mat, engine, dtype=dtype, block_shape=block_shape,
                       use_kernel=use_kernel, nnz_bucket=nnz_bucket,
                       sell_sigma=sell_sigma, probe=probe, k=k)
