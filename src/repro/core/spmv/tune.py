"""OSKI-style per-matrix engine autotuning (Akbudak et al.; Schubert et al.).

SpMV is bandwidth-bound, so the cheap cost model scores each candidate
(engine, shape) by the bytes it streams per multiply — stored values +
index metadata + an x-gather term scaled by a locality penalty derived from
the structural metrics the paper uses (bandwidth, row-nnz CV, block fill).
The model is exact for the padded formats (their footprint IS their traffic)
and a calibrated proxy for the gather engines.

The model is k-aware (multi-vector SpMM): stored matrix bytes stream once
per multiply while x/y traffic scales with the RHS batch width k, so
`tune(mat, k=8)` can pick a different engine than `tune(mat)` — padding-
heavy formats with regular access win once their footprint is amortized.

The per-engine cost functions and candidate grids are attached to the
engine registry (core/registry.py) as `cost_fn` / `candidates_fn`
capability metadata: `candidate_cost` and `enumerate_candidates` dispatch
over whatever engines are registered, so a plugin engine that ships a cost
model participates in tuning with no change here.

Four tuning modes (the `probe` argument):
  * False        — model: rank candidates by modelled bytes, build the
                   argmin. Free.
  * True         — probe: additionally time the top PROBE_TOP_K
                   candidates (OSKI's empirical search) and build the
                   measured winner.
  * "exhaustive" — time EVERY candidate: ground truth for the learned
                   mode, and the reference the regression tests hold the
                   advisor to.
  * "learned"    — ask the corpus TuneAdvisor (repro.corpus.advisor) for
                   a nearest-neighbor shortlist mined from prior
                   ResultStore campaigns and probe only that (strictly
                   fewer candidates than either probe mode); empty
                   knowledge base falls back to the model's top
                   PROBE_TOP_K and bumps `advisor.fallbacks`.

`build_tuned` is what the engine="auto" build path calls; the chosen
`TunePlan` rides on the returned operator as `.plan` so benchmarks can
report plan-time decisions next to run-time numbers. Persistent reuse of
tuned operators across processes lives in opcache.py; the joint
(scheme x engine) planner is core/spmv/plan.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from ... import obs
from .. import registry
from ..sparse import metrics
from ..sparse.csr import CSRMatrix
from ..sparse.sell import pick_chunk_width, sell_padded_nnz

# dense fallback threshold: below this many logical entries the dense
# engine's simplicity beats any sparse format's index traffic
_DENSE_MAX_ENTRIES = 64 * 64
PROBE_TOP_K = 3
PROBE_ITERS = 3

# the values `probe` accepts, here and up through plan()/MeasurePolicy
PROBE_MODES = (False, True, "learned", "exhaustive")

_VAL = 4          # float32 bytes
_IDX = 4          # int32 bytes


@dataclasses.dataclass(frozen=True)
class TunePlan:
    engine: str                       # chosen engine name
    block_shape: tuple                # (bm, bn) bell/bcsr; (C, W) sell
    sell_sigma: Optional[int]         # σ window (sell only)
    cost_bytes: float                 # modelled bytes/SpMM of the choice
    costs: dict                       # candidate label -> modelled bytes
    features: dict                    # structural features the model used
    source: str                       # "model" | "probe" | "learned" | "fixed"
    probe_ms: Optional[dict] = None   # candidate label -> measured ms
    tune_ms: float = 0.0              # wall time spent deciding
    k: int = 1                        # RHS batch width the plan was tuned for
    advisor: Optional[dict] = None    # learned mode: {confidence, predicted,
    #                                   hit, shortlist} (None otherwise)

    def label(self) -> str:
        base = _label(self.engine, self.block_shape, self.sell_sigma)
        return base if self.k == 1 else f"{base}@k{self.k}"

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["block_shape"] = list(self.block_shape)
        return d

    @staticmethod
    def from_json(d: dict) -> "TunePlan":
        d = dict(d)
        d["block_shape"] = tuple(d["block_shape"])
        return TunePlan(**d)


def fixed_plan(engine: str, block_shape: tuple = (8, 128),
               sell_sigma: Optional[int] = None, k: int = 1) -> TunePlan:
    """A TunePlan for an explicitly requested engine (no search). Gives the
    pipeline layer (plan.py) one uniform decision record to serialize."""
    if engine == "sell" and sell_sigma is None:
        sell_sigma = 8 * block_shape[0]
    return TunePlan(engine=engine, block_shape=tuple(block_shape),
                    sell_sigma=sell_sigma, cost_bytes=0.0, costs={},
                    features={}, source="fixed", k=max(int(k), 1))


def _label(engine: str, block_shape: tuple, sigma: Optional[int]) -> str:
    if engine in ("csr", "ell", "dense"):
        return engine
    if engine == "sell":
        return f"sell_c{block_shape[0]}w{block_shape[1]}s{sigma}"
    return f"{engine}_{block_shape[0]}x{block_shape[1]}"


def matrix_features(mat: CSRMatrix, bm: int = 8, bn: int = 128) -> dict:
    """The structural quantities the cost model conditions on."""
    counts = mat.row_nnz()
    mean = float(counts.mean()) if mat.m else 0.0
    cv = float(counts.std() / mean) if mean > 0 else 0.0
    r = np.repeat(np.arange(mat.m, dtype=np.int64), counts)
    c = mat.cols.astype(np.int64)
    nbc = (mat.n + bn - 1) // bn
    bkeys = (r // bm) * nbc + (c // bn)
    ub, bcounts = np.unique(bkeys, return_counts=True) if mat.nnz else (
        np.empty(0, np.int64), np.empty(0, np.int64))
    nblocks = int(ub.size)
    br_counts = np.bincount((ub // nbc).astype(np.int64),
                            minlength=(mat.m + bm - 1) // bm) if nblocks else \
        np.zeros((mat.m + bm - 1) // max(bm, 1), dtype=np.int64)
    return {
        "m": int(mat.m),
        "n": int(mat.n),
        "nnz": int(mat.nnz),
        "row_nnz_max": int(counts.max()) if mat.m else 0,
        "row_nnz_cv": cv,
        "avg_row_bandwidth": metrics.avg_row_bandwidth(mat),
        # bandwidth + envelope feed the advisor's feature space (both are
        # single O(nnz) passes); cost models ignore them
        "bandwidth": metrics.bandwidth(mat),
        "profile_per_row": float(metrics.profile(mat)) / max(mat.m, 1),
        "block_fill": float(mat.nnz / max(nblocks * bm * bn, 1)),
        "nonempty_blocks": nblocks,
        "block_row_max": int(br_counts.max()) if br_counts.size else 0,
        "num_block_rows": int(br_counts.shape[0]),
    }


def _gather_penalty(feat: dict, line: int = 128) -> float:
    """Model of x-vector re-read traffic for element-gather engines.

    When the matrix bandwidth is small, consecutive rows touch the same x
    cache lines / VMEM tiles and the effective x traffic approaches one
    read of x; when nonzeros are scattered (shuffled/uniform matrices), each
    nonzero pays a full line fetch. Interpolate on avg row bandwidth
    measured in lines — the quantity RCM minimizes.
    """
    spread = feat["avg_row_bandwidth"] / line
    return 1.0 + min(spread, 8.0)


def _gather(feat: dict, k: int) -> float:
    """k-amortized gather penalty: the k values of a gathered x row are
    contiguous in the [n, k] layout, so the line fetched for one vector's
    element carries its k-tile siblings for free."""
    return 1.0 + (_gather_penalty(feat) - 1.0) / min(k, 32)


# -- per-engine cost models (attached to the registry as cost_fn) ----------
# Signature: (feat, block_shape, sigma, sell_pad, k) -> modelled bytes.
# cost(k) = matrix_bytes + k * per_vector_bytes: stored values and index
# metadata stream ONCE per multiply regardless of k (the SpMM kernels reuse
# each chunk/block across the vector tile), while the x-gather and y-write
# terms scale with k. k=1 reduces exactly to the per-SpMV model.

def cost_dense(feat, block_shape, sigma, sell_pad, k):
    m, n = feat["m"], feat["n"]
    return float(m * n * _VAL + k * (n * _VAL + m * _VAL))


def cost_csr(feat, block_shape, sigma, sell_pad, k):
    # vals + cols + row ids (COO expansion) + k x (gathered x + y)
    m, nnz = feat["m"], feat["nnz"]
    return float(nnz * (_VAL + 2 * _IDX)
                 + k * (nnz * _VAL * _gather(feat, k) * 0.25 + m * _VAL))


def cost_ell(feat, block_shape, sigma, sell_pad, k):
    m = feat["m"]
    pad = m * max(feat["row_nnz_max"], 1)
    return float(pad * (_VAL + _IDX)
                 + k * (pad * _VAL * _gather(feat, k) * 0.25 + m * _VAL))


def cost_sell(feat, block_shape, sigma, sell_pad, k):
    pad = sell_pad if sell_pad is not None else feat["nnz"]
    return float(pad * (_VAL + _IDX)
                 + k * (pad * _VAL * _gather(feat, k) * 0.25
                        + feat["m"] * _VAL))


def cost_bell(feat, block_shape, sigma, sell_pad, k):
    bm, bn = block_shape
    pad_blocks = feat["num_block_rows"] * max(feat["block_row_max"], 1)
    return float(pad_blocks * (bm * bn * _VAL + _IDX)
                 + k * (pad_blocks * bn * _VAL + feat["m"] * _VAL))


def cost_bcsr(feat, block_shape, sigma, sell_pad, k):
    bm, bn = block_shape
    blocks = max(feat["nonempty_blocks"], 1)
    return float(blocks * (bm * bn * _VAL + 2 * _IDX)
                 + k * (blocks * bn * _VAL + feat["m"] * _VAL))


# -- per-engine candidate grids (attached as candidates_fn) ----------------
# Signature: (mat, feat) -> [{"block_shape": ..., "sigma": ..., ...}].
# Kept deliberately small — OSKI's lesson is that a handful of well-chosen
# candidates capture the attainable speedup.

def cands_default(mat, feat):
    return [dict(block_shape=(8, 128), sigma=None)]


def cands_sell(mat, feat):
    c = 8
    w_fit = pick_chunk_width(mat)
    out = []
    for w in {w_fit, 128}:
        # σ = whole-matrix sort packs similar-degree rows best; the small
        # window keeps rows near their reordered position (cache locality)
        for sigma in (8 * c, max(int(feat["m"]), 1)):
            out.append(dict(block_shape=(c, w), sigma=sigma,
                            sell_pad=sell_padded_nnz(mat, c, sigma, w)))
    return out


def cands_dense(mat, feat):
    if feat["m"] * feat["n"] <= _DENSE_MAX_ENTRIES:
        return [dict(block_shape=(8, 128), sigma=None)]
    return []


def candidate_cost(feat: dict, engine: str, block_shape: tuple = (8, 128),
                   sigma: Optional[int] = None,
                   sell_pad: Optional[int] = None, k: int = 1) -> float:
    """Modelled bytes streamed per SpMM with k right-hand sides, dispatched
    through the engine registry's cost_fn. Dividing by k gives the
    amortized per-vector cost the spmm_batch benchmark measures."""
    from . import ops  # noqa: F401 — ensure built-in engines are registered

    spec = registry.get_engine(engine)
    if spec.cost_fn is None:
        raise KeyError(f"engine {engine!r} registered without a cost_fn")
    return spec.cost_fn(feat, block_shape, sigma, sell_pad, max(int(k), 1))


def enumerate_candidates(mat: CSRMatrix, feat: dict) -> list[dict]:
    """The (engine, shape) grid the tuner searches: every registered engine
    with a cost model contributes its candidates_fn grid, in registration
    order (built-ins: csr, ell, bell, bcsr, sell, dense)."""
    from . import ops  # noqa: F401 — ensure built-in engines are registered

    cands = []
    for spec in registry.ENGINE_REGISTRY.values():
        if spec.cost_fn is None or spec.candidates_fn is None:
            continue
        for shape in spec.candidates_fn(mat, feat):
            cands.append(dict({"engine": spec.name}, **shape))
    return cands


def tune(mat: CSRMatrix, probe=False, dtype=None,
         use_kernel: str = "auto", k: int = 1, advisor=None) -> TunePlan:
    """Pick (engine, shape) for `mat` at RHS batch width k.

    `probe` is one of PROBE_MODES (see module docstring). `advisor`
    optionally injects a corpus TuneAdvisor for probe="learned"; by
    default the process-wide advisor over the default ResultStore is
    used.
    """
    if probe not in PROBE_MODES:
        raise ValueError(f"probe must be one of {PROBE_MODES}, got {probe!r}")
    with obs.span("plan.tune", shape=str(tuple(mat.shape)),
                  nnz=int(mat.nnz), probe=str(probe), k=int(k)) as _sp:
        return _tune_impl(mat, probe, dtype, use_kernel, k, _sp, advisor)


def _probe_set(probe, ranked, feat, advisor):
    """The candidates to time, plus the advisor record for learned mode."""
    if probe == "exhaustive":
        return ranked, None
    if probe != "learned":
        return ranked[:PROBE_TOP_K], None
    if advisor is None:
        from ...corpus.advisor import default_advisor
        advisor = default_advisor()
    shortlist, confidence, predicted = advisor.shortlist(feat, ranked)
    if not shortlist:
        obs.counter("advisor.fallbacks").inc()
        return ranked[:PROBE_TOP_K], {"confidence": 0.0, "predicted": None,
                                      "hit": None, "shortlist": 0}
    return shortlist, {"confidence": confidence, "predicted": predicted,
                       "hit": None, "shortlist": len(shortlist)}


def _tune_impl(mat: CSRMatrix, probe, dtype, use_kernel: str, k: int,
               _sp, advisor=None) -> TunePlan:
    t0 = time.perf_counter()
    k = max(int(k), 1)
    feat = matrix_features(mat)
    cands = enumerate_candidates(mat, feat)
    costs = {}
    for cd in cands:
        costs[_label(cd["engine"], cd["block_shape"], cd["sigma"])] = \
            candidate_cost(feat, cd["engine"], cd["block_shape"], cd["sigma"],
                           cd.get("sell_pad"), k=k)
    ranked = sorted(cands, key=lambda cd: costs[
        _label(cd["engine"], cd["block_shape"], cd["sigma"])])
    probe_ms = None
    best = ranked[0]
    source = "model"
    adv_info = None
    if probe:
        import jax.numpy as jnp

        from ..measure import ios
        from .ops import make_engine

        to_probe, adv_info = _probe_set(probe, ranked, feat, advisor)
        dt = jnp.float32 if dtype is None else dtype
        probe_ms = {}
        best_ms = np.inf
        for cd in to_probe:
            lab = _label(cd["engine"], cd["block_shape"], cd["sigma"])
            with obs.span("plan.probe", candidate=lab,
                          engine=cd["engine"], k=int(k)) as psp:
                op = make_engine(mat, cd["engine"], dtype=dt,
                                 block_shape=cd["block_shape"],
                                 sell_sigma=cd["sigma"],
                                 use_kernel=use_kernel)
                ms = float(np.median(ios.run_ios_batched(
                    op, mat.n, k, iters=PROBE_ITERS, warmup=1, dtype=dt)))
                psp.set(ms=ms)
            probe_ms[lab] = ms
            if ms < best_ms:
                best_ms, best = ms, cd
        winner = _label(best["engine"], best["block_shape"], best["sigma"])
        if adv_info is not None and adv_info["predicted"] is not None:
            # predicted-vs-probed agreement: the advisor's learning signal
            hit = adv_info["predicted"] == winner
            adv_info["hit"] = hit
            obs.counter("advisor.hits" if hit else "advisor.misses").inc()
            source = "learned"
        else:
            source = "probe"
    lab = _label(best["engine"], best["block_shape"], best["sigma"])
    _sp.set(engine=best["engine"], source=source)
    return TunePlan(engine=best["engine"], block_shape=best["block_shape"],
                    sell_sigma=best["sigma"], cost_bytes=costs[lab],
                    costs=costs, features=feat, source=source,
                    probe_ms=probe_ms,
                    tune_ms=(time.perf_counter() - t0) * 1e3, k=k,
                    advisor=adv_info)


def build_from_plan(mat: CSRMatrix, plan: TunePlan, dtype=None,
                    use_kernel: str = "auto", nnz_bucket: int = 0):
    """Materialize the operator a plan describes (used by the op cache and
    the pipeline layer). The plan's k only steered the engine choice; the
    format is k-agnostic."""
    import jax.numpy as jnp

    from .ops import make_engine

    dt = jnp.float32 if dtype is None else dtype
    op = make_engine(mat, plan.engine, dtype=dt,
                     block_shape=plan.block_shape,
                     sell_sigma=plan.sell_sigma, use_kernel=use_kernel,
                     nnz_bucket=nnz_bucket)
    op.plan = plan
    return op


def build_tuned(mat: CSRMatrix, dtype=None, probe: bool = False,
                use_kernel: str = "auto", nnz_bucket: int = 0, k: int = 1):
    """engine="auto" entry point: tune, build, attach the plan."""
    plan = tune(mat, probe=probe, dtype=dtype, use_kernel=use_kernel, k=k)
    return build_from_plan(mat, plan, dtype=dtype, use_kernel=use_kernel,
                           nnz_bucket=nnz_bucket)
