"""Problem → Plan → Operator: the staged SpMV pipeline (OSKI's tune-time API).

The paper's core loop — reorder, convert, tune, measure — as three stages:

    problem = SpmvProblem(mat, k=8)                  # what to multiply
    pl      = plan(problem, reorder="auto")          # serializable decision
    op      = pl.build()                             # device operator

`plan()` jointly selects (scheme x engine x shape x k): for each candidate
reordering scheme it computes the *permuted* matrix's structural features
and scores every registered engine's candidate grid with the k-aware cost
model (core/spmv/tune.py) — the per-scheme structural deltas (bandwidth,
block fill, row-nnz spread) are exactly what moves the engine choice, so
scheme and engine are decided together rather than scheme being caller-side
preprocessing. Candidate schemes/engines come from the plugin registries
(core/registry.py); `hints={"schemes": [...]}` widens the scheme search.

Plans are content-addressed in ONE persistent store (REPRO_PLAN_CACHE,
default /tmp/repro_plans) that subsumes the separate reorder cache and
operator cache of the legacy entry points: an entry holds the plan record,
the permutation, and the built operator's device arrays, so `Plan.save` /
`Plan.load` round-trip a tuned operator across processes with zero re-tune
and zero re-conversion. Writes are tmp+rename atomic (the .json lands last
and gates the read — opcache.py's convention).

The built operator CARRIES its permutation: `op(x)` / `op.matmul(X)` take
vectors in the ORIGINAL index space and return results in the original
index space (internally x is gathered through perm and y scattered back
through iperm), eliminating the hand-carried permutation footgun. The
measurement harness opts out with `op(x, permuted=True)` (or times
`op.unwrap()`), which runs in the reordered space like the legacy path.

The same facade covers a device mesh: `plan(problem,
topology=Topology(...), partition=...)` widens the joint selection to
(partition x scheme x engine x shape x k) with the communication-volume
cost model (topology.py), and `build()` returns a ShardedOperator
(distributed.py) carrying perm + panel starts + collective schedule —
same store, same original-index-space contract. Topology/partition join
the content key ONLY when non-trivial, so single-device caches never
fork.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from typing import Any, Optional

import numpy as np

from ... import obs
from .. import registry
from ..sparse import partition as partition_mod
from ..sparse.csr import CSRMatrix
from . import tune as tune_mod
from . import topology as topology_mod
from .topology import Topology
from .tune import TunePlan

_OFF = ("off", "0", "none", "")


def _store_dir() -> str:
    """Plan-store directory. Falls back to a `plans/` sibling under
    REPRO_OPERATOR_CACHE when only that is set (hermetic test/CI runs that
    repoint the legacy caches get a hermetic plan store for free); "off"
    in either variable disables the store."""
    d = os.environ.get("REPRO_PLAN_CACHE")
    if d is not None:
        return d
    opd = os.environ.get("REPRO_OPERATOR_CACHE")
    if opd is not None:
        return opd if opd.lower() in _OFF else os.path.join(opd, "plans")
    return "/tmp/repro_plans"


def store_enabled() -> bool:
    return _store_dir().lower() not in _OFF


@dataclasses.dataclass(frozen=True)
class SpmvProblem:
    """What to multiply: the matrix, the expected RHS batch width, the
    compute dtype, and free-form planning hints.

    hints (all optional):
      seed        — reordering seed (default 0)
      schemes     — scheme names plan(reorder="auto") should consider
                    (default: every registered scheme with auto_candidate)
      block_shape — (bm, bn) / (C, W) for fixed block engines
      sell_sigma  — σ sort window for the fixed sell engine
      use_kernel  — "auto" | "pallas" | "interpret" | "ref"
      nnz_bucket  — CSR nnz padding bucket
    """

    mat: CSRMatrix
    k: int = 1
    dtype: Any = None
    hints: dict = dataclasses.field(default_factory=dict)

    def dtype_name(self) -> str:
        return "float32" if self.dtype is None else np.dtype(self.dtype).name


def _mat_key(mat: CSRMatrix) -> str:
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(mat.rowptr).tobytes())
    h.update(np.ascontiguousarray(mat.cols).tobytes())
    h.update(np.ascontiguousarray(mat.vals).tobytes())
    h.update(f"{tuple(mat.shape)}".encode())
    return h.hexdigest()[:20]


def structure_key(mat: CSRMatrix) -> str:
    """sha1 over the STRUCTURE only (rowptr + cols + shape, never vals).

    Everything a plan decides — scheme permutation, engine, block shape,
    σ window — is a function of the sparsity pattern alone, so two
    matrices with equal structure_key can share one Plan: swapping the
    values is a rebuild (`Plan.rebuild`), never a replan. This is the
    hash the serving layer's dynamic-matrix path keys on."""
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(mat.rowptr).tobytes())
    h.update(np.ascontiguousarray(mat.cols).tobytes())
    h.update(f"{tuple(mat.shape)}".encode())
    return h.hexdigest()[:20]


def values_key(mat: CSRMatrix) -> str:
    """sha1 over the VALUES only — structure_key's complement.

    (structure_key, values_key) identifies a matrix's full content
    without hashing it as one blob, which is what a dynamic-structure
    consumer (workloads.WorkloadSession) needs to tell "same structure,
    same values → reuse the built Operator" apart from "same structure,
    new values → Plan.rebuild"."""
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(mat.vals).tobytes())
    return h.hexdigest()[:20]


def plan_key(problem: SpmvProblem, reorder: str, engine: str,
             probe, seed: int, schemes=None, topology=None,
             partition: str = "auto", partitioners=None) -> str:
    """sha1 over matrix content + the full plan request.

    k steers the auto-engine choice AND (through the per-scheme cost
    deltas) the auto-scheme choice, so it is normalized out only when
    BOTH axes are fixed (a k-sweep over one engine+scheme is a single
    entry — opcache.py's rule); a sharded topology keeps k too (the
    compute/collective trade-off moves with the batch width). `schemes`
    is the resolved candidate set for reorder="auto": two requests
    searching different scheme sets are different plans, even on the
    same matrix.

    Topology joins the key ONLY when non-trivial: a Topology(devices=1)
    request hashes identically to no topology at all, so single-device
    caches never fork (asserted in tests/test_topology_plans.py).
    Sharded plans are model-based, so `probe` is normalized out of their
    keys (a probe=True request builds the identical plan — one entry).
    Probe modes hash distinctly (False / True / "learned" / "exhaustive"
    are different searches, so different plans).
    """
    topo = topology_mod.normalize(topology)
    k = problem.k if (engine == "auto" or reorder == "auto"
                      or topo is not None) else 1
    probe = probe if topo is None else False
    hints = problem.hints
    h = hashlib.sha1()
    h.update(_mat_key(problem.mat).encode())
    h.update(f"{reorder}:{tuple(schemes or ())}:{seed}:{engine}:"
             f"{problem.dtype_name()}:"
             f"{tuple(hints.get('block_shape', (8, 128)))}:"
             f"{hints.get('sell_sigma')}:{int(hints.get('nnz_bucket', 0))}:"
             f"{probe}:{int(k)}".encode())
    if topo is not None:
        h.update(json.dumps(topo.key_dict(), sort_keys=True).encode())
        h.update(f":{partition}:{tuple(partitioners or ())}".encode())
    return h.hexdigest()[:20]


class Operator:
    """Permutation-carrying SpMV/SpMM operator.

    `op(x)` and `op.matmul(X)` accept vectors in the ORIGINAL index space:
    x is gathered through `perm` before the reordered-space engine runs and
    the result is scattered back through `iperm`, so callers never permute
    by hand. `permuted=True` opts out (x already in the reordered space,
    result returned in the reordered space) — the measurement harness path.
    For a baseline/identity plan both paths are the same single engine call.
    """

    def __init__(self, inner, perm: Optional[np.ndarray], plan: "Plan",
                 build_info: Optional[dict] = None):
        import jax.numpy as jnp

        self.inner = inner
        self.plan = plan
        self.build_info = build_info or {}
        if perm is not None and np.array_equal(perm, np.arange(perm.size)):
            perm = None                     # identity: skip the gathers
        self._perm_np = perm
        if perm is None:
            self._perm = self._iperm = None
        else:
            iperm = np.empty_like(perm)
            iperm[perm] = np.arange(perm.size, dtype=perm.dtype)
            self._perm = jnp.asarray(perm, jnp.int32)
            self._iperm = jnp.asarray(iperm, jnp.int32)

    @property
    def perm(self) -> Optional[np.ndarray]:
        """perm[i] = original row at reordered position i (None = identity)."""
        return self._perm_np

    @property
    def iperm(self) -> Optional[np.ndarray]:
        """iperm[r] = reordered position of original row r (None = identity)."""
        if self._perm_np is None:
            return None
        return np.asarray(self._iperm)

    @property
    def shape(self) -> tuple:
        inner = self.inner
        if hasattr(inner, "shape"):
            return tuple(inner.shape)
        if hasattr(inner, "m"):
            return (inner.m, inner.n)
        a = inner.a  # DeviceDense
        return tuple(a.shape)

    def unwrap(self):
        """The bare reordered-space engine operator (equivalent to calling
        with permuted=True) — what the measurement harness times."""
        return self.inner

    def __call__(self, x, permuted: bool = False):
        import jax.numpy as jnp

        if self._perm is None or permuted:
            return self.inner(x)
        xr = jnp.take(x, self._perm, axis=0)
        return jnp.take(self.inner(xr), self._iperm, axis=0)

    def matmul(self, x, permuted: bool = False):
        """x: [n, k] -> y: [m, k], original index space unless permuted."""
        import jax.numpy as jnp

        if self._perm is None or permuted:
            return self.inner.matmul(x)
        xr = jnp.take(x, self._perm, axis=0)
        return jnp.take(self.inner.matmul(xr), self._iperm, axis=0)


@dataclasses.dataclass
class Plan:
    """A serializable pipeline decision: which scheme, which engine/shape,
    for which problem — plus the permutation that realizes the scheme.

    `build()` materializes the operator (from the plan store when possible,
    otherwise by permute + format conversion) — never by re-tuning.
    """

    scheme: str
    seed: int
    engine_request: str               # what the caller asked ("auto"/fixed)
    tune: TunePlan                    # resolved engine decision
    k: int
    dtype_name: str
    probe: bool
    use_kernel: str
    nnz_bucket: int
    mat_shape: tuple
    mat_nnz: int
    key: str                          # plan-store content key
    scheme_costs: dict = dataclasses.field(default_factory=dict)
    reorder_ms: float = 0.0
    tune_ms: float = 0.0
    plan_ms: float = 0.0
    cache_hit: bool = False           # this plan was loaded, not computed
    advisor_confidence: float = 0.0   # probe="learned": nearest-neighbor
    #                                   trust in (0, 1]; 0 = no knowledge
    perm: Optional[np.ndarray] = None  # None = identity
    # -- topology-aware (sharded) plans ------------------------------------
    topology: Optional[Topology] = None          # None = single device
    partitioner: str = ""                        # resolved partitioner name
    panel_starts: Optional[np.ndarray] = None    # [P+1] reordered-row split
    comm: dict = dataclasses.field(default_factory=dict)   # collective model
    partition_costs: dict = dataclasses.field(default_factory=dict)
    _mat: Optional[CSRMatrix] = dataclasses.field(
        default=None, repr=False, compare=False)
    _rmat: Optional[CSRMatrix] = dataclasses.field(
        default=None, repr=False, compare=False)
    _op_state: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)

    def label(self) -> str:
        base = f"{self.scheme}+{self.tune.label()}"
        if self.topology is None:
            return base
        return (f"{base}+{self.partitioner}@{self.topology.layout}"
                f"p{self.topology.devices}")

    # -- serialization -----------------------------------------------------
    def to_json(self) -> dict:
        return {
            "scheme": self.scheme, "seed": self.seed,
            "engine_request": self.engine_request,
            "tune": self.tune.to_json(), "k": self.k,
            "dtype_name": self.dtype_name, "probe": self.probe,
            "use_kernel": self.use_kernel, "nnz_bucket": self.nnz_bucket,
            "mat_shape": list(self.mat_shape), "mat_nnz": self.mat_nnz,
            "key": self.key, "scheme_costs": self.scheme_costs,
            "reorder_ms": self.reorder_ms, "tune_ms": self.tune_ms,
            "plan_ms": self.plan_ms,
            "advisor_confidence": self.advisor_confidence,
            "topology": None if self.topology is None
            else self.topology.to_json(),
            "partitioner": self.partitioner, "comm": self.comm,
            "partition_costs": self.partition_costs,
        }

    @staticmethod
    def from_json(d: dict, perm: Optional[np.ndarray] = None,
                  mat: Optional[CSRMatrix] = None,
                  panel_starts: Optional[np.ndarray] = None) -> "Plan":
        return Plan(scheme=d["scheme"], seed=d["seed"],
                    engine_request=d["engine_request"],
                    tune=TunePlan.from_json(d["tune"]), k=d["k"],
                    dtype_name=d["dtype_name"], probe=d["probe"],
                    use_kernel=d["use_kernel"], nnz_bucket=d["nnz_bucket"],
                    mat_shape=tuple(d["mat_shape"]), mat_nnz=d["mat_nnz"],
                    key=d["key"], scheme_costs=d.get("scheme_costs", {}),
                    reorder_ms=d.get("reorder_ms", 0.0),
                    tune_ms=d.get("tune_ms", 0.0),
                    plan_ms=d.get("plan_ms", 0.0),
                    advisor_confidence=d.get("advisor_confidence", 0.0),
                    topology=Topology.from_json(d.get("topology")),
                    partitioner=d.get("partitioner", ""),
                    panel_starts=panel_starts,
                    comm=d.get("comm", {}),
                    partition_costs=d.get("partition_costs", {}),
                    perm=perm, _mat=mat)

    def save(self, op=None, path: Optional[str] = None) -> str:
        """Persist this plan (and, if given, a built operator's device
        arrays) to the plan store. Returns the entry's json path."""
        d = (os.path.dirname(path) or ".") if path else _store_dir()
        os.makedirs(d, exist_ok=True)
        base = (path[:-5] if path and path.endswith(".json")
                else os.path.join(d, self.key))
        arrays: dict = {}
        if self.perm is not None:
            arrays["perm"] = np.asarray(self.perm, np.int64)
        if self.panel_starts is not None:
            arrays["panel_starts"] = np.asarray(self.panel_starts, np.int64)
        rec = {"plan": self.to_json(), "op": None}
        if op is None and self._op_state is not None:
            # _op_state arrays were de-prefixed at load time; re-prefix so
            # the written entry round-trips (and can never collide with
            # the "perm" array)
            op_rec, op_arrays = self._op_state
            rec["op"] = op_rec
            arrays.update({f"op__{k}": v for k, v in op_arrays.items()})
        elif op is not None:
            meta, op_arrays = op.state()
            rec["op"] = {"cls": type(op).__name__, "meta": meta}
            arrays.update({f"op__{k}": v for k, v in op_arrays.items()})
        # tmp+rename, npz first, json LAST (gates the read) — the opcache
        # convention; tmp names carry pid AND thread id
        tag = f"{os.getpid()}.{threading.get_ident()}"
        ztmp = f"{base}.{tag}.npz.tmp"
        with open(ztmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(ztmp, base + ".npz")
        jtmp = f"{base}.{tag}.json.tmp"
        with open(jtmp, "w") as f:
            json.dump(rec, f)
        os.replace(jtmp, base + ".json")
        obs.counter("plan_store.writes").inc()
        return base + ".json"

    @staticmethod
    def load(key_or_path: str, mat: Optional[CSRMatrix] = None
             ) -> Optional["Plan"]:
        """Load a plan (and any stored operator payload) by store key or
        explicit `<path>.json`. Returns None on miss/corruption — the
        store is persistent across code versions, so unreadable entries
        are treated as absent, never fatal."""
        if key_or_path.endswith(".json"):
            base = key_or_path[:-5]
        else:
            base = os.path.join(_store_dir(), key_or_path)
        jpath, zpath = base + ".json", base + ".npz"
        if not (os.path.exists(jpath) and os.path.exists(zpath)):
            obs.counter("plan_store.misses").inc()
            return None
        try:
            with open(jpath) as f:
                rec = json.load(f)
            z = np.load(zpath)
            perm = z["perm"] if "perm" in z.files else None
            starts = (z["panel_starts"] if "panel_starts" in z.files
                      else None)
            pl = Plan.from_json(rec["plan"], perm=perm, mat=mat,
                                panel_starts=starts)
            if rec.get("op"):
                op_arrays = {k[len("op__"):]: z[k] for k in z.files
                             if k.startswith("op__")}
                pl._op_state = (rec["op"], op_arrays)
            pl.cache_hit = True
            # this invocation paid none of the plan-time costs (paper
            # methodology: preprocessing accounting must reflect THIS
            # run); the originals remain in the on-disk record
            pl.tune_ms = 0.0
            pl.reorder_ms = 0.0
            pl.plan_ms = 0.0
            obs.counter("plan_store.hits").inc()
            return pl
        except Exception:
            obs.counter("plan_store.misses").inc()
            return None

    # -- materialization ---------------------------------------------------
    def reordered_matrix(self) -> CSRMatrix:
        """The problem matrix in the plan's reordered index space."""
        if self._rmat is None:
            if self._mat is None:
                raise ValueError("plan has no attached matrix; pass mat= to "
                                 "Plan.load or use plan(problem, ...)")
            self._rmat = (self._mat if self.perm is None
                          else self._mat.permute(self.perm))
        return self._rmat

    def _restore_operator(self, dtype):
        """Operator from stored device arrays (no conversion, no matrix)."""
        if self._op_state is None:
            return None
        op_rec, arrays = self._op_state
        cls = _operator_registry().get(op_rec["cls"])
        if cls is None:
            return None
        try:
            op = cls.from_state(op_rec["meta"], arrays, dtype=dtype)
        except Exception:
            return None
        # restored kernel choice must match THIS process's backend (an
        # entry written on TPU may be reloaded on CPU and vice versa)
        if getattr(op, "use_kernel", None) is not None:
            import jax

            op.use_kernel = self.use_kernel if self.use_kernel != "auto" \
                else ("pallas" if jax.default_backend() == "tpu" else "ref")
        op.plan = self.tune
        return op

    def build(self, cache: bool = True):
        """Materialize the operator this plan describes: a permutation-
        carrying Operator for single-device plans, a ShardedOperator for
        topology-aware plans (perm + panel starts + collective schedule).
        Store hit -> device arrays reload (load_ms); miss -> permute +
        format conversion (build_ms) and the complete entry (plan + perm
        + operator payload) is persisted. Never re-tunes."""
        with obs.span("plan.build", key=self.key, scheme=self.scheme,
                      engine=self.tune.engine) as sp:
            op = self._build_impl(cache)
            info = getattr(op, "build_info", None) or {}
            sp.set(cache_hit=bool(info.get("cache_hit")))
            return op

    def _build_impl(self, cache: bool):
        import jax.numpy as jnp

        dt = jnp.dtype(self.dtype_name)
        info = {"cache_hit": False, "key": self.key,
                "tune_ms": self.tune_ms, "build_ms": 0.0, "load_ms": 0.0,
                "engine": self.tune.engine, "plan": self.tune.to_json()}
        use_store = cache and store_enabled()
        if self.topology is not None:
            return self._build_sharded(dt, info, use_store)
        inner = None
        if use_store:
            t0 = time.perf_counter()
            if self._op_state is None and self.cache_hit:
                # a freshly computed plan cannot have an op payload in the
                # store yet (plan() just wrote the plan-only entry) — only
                # a loaded plan re-consults the store for arrays
                stored = Plan.load(self.key, mat=self._mat)
                if stored is not None and stored._op_state is not None:
                    self._op_state = stored._op_state
            inner = self._restore_operator(dt)
            if inner is not None:
                info["load_ms"] = (time.perf_counter() - t0) * 1e3
                info["cache_hit"] = True
        if inner is None:
            t0 = time.perf_counter()
            inner = tune_mod.build_from_plan(
                self.reordered_matrix(), self.tune, dtype=dt,
                use_kernel=self.use_kernel, nnz_bucket=self.nnz_bucket)
            info["build_ms"] = (time.perf_counter() - t0) * 1e3
            if use_store:
                self.save(op=inner)
        return Operator(inner, self.perm, self, build_info=info)

    def rebuild(self, mat: CSRMatrix, use_kernel: Optional[str] = None):
        """Operator for a matrix with the SAME sparsity structure but
        (possibly) different values, under this plan's frozen decision:
        permute through the carried perm, convert with the already-chosen
        (engine, shape) — no re-tune, no re-plan, and NO store write (the
        plan store is content-addressed over values; publishing swapped
        values under the old key would poison it).

        The dynamic-matrix path of the serving layer: `update_values`
        (and re-register with an unchanged `structure_key`) is a rebuild,
        never a replan. Sharded plans rebuild too: the frozen partition,
        panel split and collective schedule are reused and only the
        per-device arrays are repacked (no re-partition, no re-tune) —
        what the multi-shard router's value-swap path runs. Raises
        ValueError on a structure mismatch."""
        import jax.numpy as jnp

        if tuple(mat.shape) != tuple(self.mat_shape) \
                or mat.nnz != self.mat_nnz:
            raise ValueError(
                f"rebuild() needs the plan's structure "
                f"({self.mat_shape}, nnz={self.mat_nnz}); got "
                f"({tuple(mat.shape)}, nnz={mat.nnz}) — replan instead")
        dt = jnp.dtype(self.dtype_name)
        with obs.span("plan.rebuild", key=self.key,
                      engine=self.tune.engine,
                      sharded=self.topology is not None):
            rmat = mat if self.perm is None else mat.permute(self.perm)
            t0 = time.perf_counter()
            if self.topology is not None:
                from . import distributed

                layout = distributed.build_sharded_layout(
                    rmat, self.topology, self.panel_starts,
                    engine=self.tune.engine,
                    block_shape=self.tune.block_shape,
                    schedule=self.comm.get("schedule", "all_gather"),
                    halo=int(self.comm.get("halo", 0)))
                info = {"cache_hit": False, "key": self.key,
                        "tune_ms": 0.0,
                        "build_ms": (time.perf_counter() - t0) * 1e3,
                        "load_ms": 0.0, "engine": self.tune.engine,
                        "plan": self.tune.to_json(), "value_swap": True,
                        "comm": dict(self.comm),
                        "partitioner": self.partitioner}
                return distributed.ShardedOperator(
                    layout, self.perm, plan=self, build_info=info)
            inner = tune_mod.build_from_plan(
                rmat, self.tune, dtype=dt,
                use_kernel=(self.use_kernel if use_kernel is None
                            else use_kernel),
                nnz_bucket=self.nnz_bucket)
            info = {"cache_hit": False, "key": self.key, "tune_ms": 0.0,
                    "build_ms": (time.perf_counter() - t0) * 1e3,
                    "load_ms": 0.0, "engine": self.tune.engine,
                    "plan": self.tune.to_json(), "value_swap": True}
        return Operator(inner, self.perm, self, build_info=info)

    def apply_delta(self, delta, *, max_churn: Optional[float] = None,
                    max_bw_growth: Optional[float] = None) -> "Plan":
        """A NEW Plan for this plan's matrix edited by a StructureDelta
        (core/spmv/delta.py), reusing the frozen tuning decision and
        permutation — the amortization tier between `rebuild` (values
        only) and a full replan (new search).

        An empty delta returns this plan unchanged (no counters move).
        A small delta (nnz churn <= max_churn AND bandwidth growth <=
        max_bw_growth, defaults delta.MAX_CHURN / delta.MAX_BW_GROWTH)
        returns the edited plan under a `plan.delta` span, counting
        `delta.applies`; appended rows extend the permutation with
        identity tail positions. Past either threshold the frozen
        decision is stale: DeltaTooLarge is raised (counting
        `delta.fallbacks`) and the caller replans. Sharded plans accept
        same-shape deltas only (panel split indexes a fixed row count)
        and reuse partitioner + panel_starts + schedule, so build() after
        apply_delta repacks arrays without any new search."""
        from . import delta as delta_mod

        kw = {}
        if max_churn is not None:
            kw["max_churn"] = max_churn
        if max_bw_growth is not None:
            kw["max_bw_growth"] = max_bw_growth
        return delta_mod.apply_delta(self, delta, **kw)

    def _build_sharded(self, dt, info: dict, use_store: bool):
        """Topology-aware build: restore the ShardedOperator's layout
        arrays from the plan store when possible, otherwise chop the
        reordered matrix into per-device arrays and persist the entry."""
        from . import distributed

        info["comm"] = dict(self.comm)
        info["partitioner"] = self.partitioner
        if use_store:
            t0 = time.perf_counter()
            if self._op_state is None and self.cache_hit:
                stored = Plan.load(self.key, mat=self._mat)
                if stored is not None and stored._op_state is not None:
                    self._op_state = stored._op_state
            if self._op_state is not None:
                op_rec, arrays = self._op_state
                if op_rec.get("cls") == "ShardedOperator":
                    try:
                        op = distributed.ShardedOperator.from_state(
                            op_rec["meta"], arrays, perm=self.perm,
                            plan=self, build_info=info)
                        info["load_ms"] = (time.perf_counter() - t0) * 1e3
                        info["cache_hit"] = True
                        return op
                    except Exception:
                        pass            # unreadable payload -> rebuild
        t0 = time.perf_counter()
        layout = distributed.build_sharded_layout(
            self.reordered_matrix(), self.topology, self.panel_starts,
            engine=self.tune.engine, block_shape=self.tune.block_shape,
            schedule=self.comm.get("schedule", "all_gather"),
            halo=int(self.comm.get("halo", 0)))
        op = distributed.ShardedOperator(layout, self.perm, plan=self,
                                         build_info=info)
        info["build_ms"] = (time.perf_counter() - t0) * 1e3
        if use_store:
            self.save(op=op)
        return op


def _operator_registry() -> dict:
    """Operator classes speaking the state()/from_state() protocol
    (opcache.py's set). Imported lazily: kernels pull in pallas."""
    from ...kernels.bcsr_spmv.ops import BcsrOperator
    from ...kernels.bell_spmv.ops import BellOperator
    from ...kernels.sell_spmv.ops import SellOperator
    from .ops import DeviceCSR, DeviceDense, DeviceELL

    return {c.__name__: c for c in
            (DeviceCSR, DeviceELL, DeviceDense, SellOperator, BellOperator,
             BcsrOperator)}


def _auto_schemes(hints: dict) -> list:
    names = hints.get("schemes")
    if names is None:
        names = [s.name for s in registry.SCHEME_REGISTRY.values()
                 if s.auto_candidate]
    return list(names)


def _partition_candidates(partition) -> list:
    """Resolve the partition request to a candidate-name list."""
    if partition == "auto":
        names = partition_mod.auto_partitioners()
        if not names:
            raise ValueError("no registered partitioner is auto_candidate")
        return names
    if isinstance(partition, str):
        return [partition]
    return list(partition)


def plan(problem: SpmvProblem, reorder: str = "auto", engine: str = "auto",
         probe=False, cache: bool = True, topology=None,
         partition="auto") -> Plan:
    """See _plan_decide — this wrapper only adds the root "plan" span
    (scheme/engine decision, store consultation, probe runs all nest
    under it)."""
    with obs.span("plan", shape=str(tuple(problem.mat.shape)),
                  nnz=int(problem.mat.nnz), reorder=reorder,
                  engine=engine, probe=str(probe), k=int(problem.k)) as sp:
        pl = _plan_decide(problem, reorder, engine, probe, cache,
                          topology, partition)
        sp.set(scheme=pl.scheme, engine_chosen=pl.tune.engine,
               cache_hit=bool(pl.cache_hit), key=pl.key)
        return pl


def _plan_decide(problem: SpmvProblem, reorder: str = "auto",
                 engine: str = "auto", probe=False,
                 cache: bool = True, topology=None,
                 partition="auto") -> Plan:
    """Stage 1+2 of the pipeline: decide (scheme, engine, shape) — and,
    given a topology, the row partition — for the problem and return the
    serializable Plan.

    reorder   — a registered scheme name, or "auto" to jointly search the
              auto-candidate schemes (hints["schemes"] overrides the set):
              each candidate is permuted, its structural features recomputed,
              and every engine candidate re-scored on them, so the winner is
              the (scheme, engine, shape) argmin of modelled bytes at the
              problem's k.
    engine    — a registered engine name, or "auto" for the OSKI-style
              tuner. Sharded plans execute per-device "bell" or "csr"
              panels; "auto" picks between them.
    probe     — one of tune.PROBE_MODES: False (model only), True (time
              the model's top candidates), "exhaustive" (time every
              candidate), "learned" (time the corpus TuneAdvisor's
              nearest-neighbor shortlist mined from prior ResultStore
              campaigns; the plan carries `advisor_confidence`).
              Auto-scheme selection stays model-based; the winning
              scheme is re-tuned with the requested probe mode. Sharded
              plans are model-based only.
    cache     — consult/populate the persistent plan store.
    topology  — a Topology (core/spmv/topology.py); devices=1/None plans
              single-device. Non-trivial topologies extend the joint
              search to (partition x scheme x engine x shape x k) with
              the communication-volume cost model: per candidate the
              modelled wall cost is max-device compute bytes (engine cost
              x load imbalance / devices) + collective bytes (all-gather
              vs halo exchange vs 2-D reduce — topology.comm_model).
    partition — a registered partitioner name (incl. the parameterized
              chunked_cyclic_c<chunk> form), a list of names, or "auto"
              to search the auto-candidate partitioners.
    """
    from . import ops  # noqa: F401 — ensure built-in engines are registered
    from ..reorder import api as reorder_api

    if probe not in tune_mod.PROBE_MODES:
        raise ValueError(
            f"probe must be one of {tune_mod.PROBE_MODES}, got {probe!r}")
    t_start = time.perf_counter()
    mat = problem.mat
    hints = problem.hints
    seed = int(hints.get("seed", 0))
    use_kernel = hints.get("use_kernel", "auto")
    nnz_bucket = int(hints.get("nnz_bucket", 0))
    block_shape = tuple(hints.get("block_shape", (8, 128)))
    sell_sigma = hints.get("sell_sigma")
    k = max(int(problem.k), 1)
    topo = topology_mod.normalize(topology)

    # validate names up front (KeyError with the known set)
    if engine != "auto":
        registry.get_engine(engine)
    schemes = _auto_schemes(hints) if reorder == "auto" else [reorder]
    if not schemes:
        raise ValueError("no candidate schemes: hints['schemes'] is empty "
                         "and no registered scheme is auto_candidate")
    for s in schemes:
        registry.get_scheme(s)
    partitioners = None
    if topo is not None:
        if mat.m != mat.n:
            raise ValueError(f"sharded plans need a square matrix "
                             f"(conformal x partition), got {mat.shape}")
        if engine not in ("auto", "bell", "csr"):
            raise ValueError(f"sharded plans execute 'bell' or 'csr' "
                             f"panel engines (or 'auto'), got {engine!r}")
        partitioners = _partition_candidates(partition)
        for name in partitioners:
            partition_mod.resolve_partitioner(name)

    key = plan_key(problem, reorder, engine, probe, seed,
                   schemes=schemes if reorder == "auto" else None,
                   topology=topo, partition=str(partition),
                   partitioners=partitioners)
    if cache and store_enabled():
        hit = Plan.load(key, mat=mat)
        if hit is not None:
            hit._mat = mat
            # use_kernel is a runtime execution choice, not plan identity:
            # the requesting process's preference wins (an entry stored by
            # an interpret-mode CI run must not pin later runs to it)
            hit.use_kernel = use_kernel
            return hit

    if topo is not None:
        return _plan_sharded(problem, reorder, engine, cache, topo,
                             partitioners, schemes, key, seed, use_kernel,
                             nnz_bucket, block_shape, t_start)

    dtype_name = problem.dtype_name()
    reorder_ms = tune_ms = 0.0
    best = None                       # (cost, scheme, perm, rmat, tuneplan)
    scheme_costs: dict = {}
    for s in schemes:
        t0 = time.perf_counter()
        perm = (None if s == "baseline"
                else reorder_api.reorder(mat, s, seed, cache=cache))
        rmat = mat if perm is None else mat.permute(perm)
        reorder_ms += (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        if engine == "auto":
            # single explicit scheme: probe directly (the legacy tune path);
            # multi-scheme search stays model-based until a winner exists
            tp = tune_mod.tune(rmat,
                               probe=(probe if len(schemes) == 1 else False),
                               use_kernel=use_kernel, k=k)
            cost = tp.cost_bytes
        else:
            feat = tune_mod.matrix_features(rmat)
            sp = None
            if engine == "sell":
                from ..sparse.sell import sell_padded_nnz

                c, w = block_shape
                sg = 8 * c if sell_sigma is None else sell_sigma
                sp = sell_padded_nnz(rmat, c, sg, w)
            cost = tune_mod.candidate_cost(feat, engine, block_shape,
                                           sell_sigma, sp, k=k)
            tp = tune_mod.fixed_plan(engine, block_shape, sell_sigma, k=k)
        tune_ms += (time.perf_counter() - t0) * 1e3
        scheme_costs[s] = float(cost)
        if best is None or cost < best[0]:
            best = (cost, s, perm, rmat, tp)
    _, scheme, perm, rmat, tp = best
    if probe and engine == "auto" and tp.source not in ("probe", "learned"):
        # model picked the scheme; OSKI's empirical search refines the
        # engine choice on the winner only (probing every scheme would
        # time the planner, not the SpMV) — in the caller's probe mode,
        # so "learned"/"exhaustive" reach the winner's tune too
        t0 = time.perf_counter()
        tp = tune_mod.tune(rmat, probe=probe, use_kernel=use_kernel, k=k)
        tune_ms += (time.perf_counter() - t0) * 1e3

    pl = Plan(scheme=scheme, seed=seed, engine_request=engine, tune=tp,
              k=k, dtype_name=dtype_name, probe=probe, use_kernel=use_kernel,
              nnz_bucket=nnz_bucket, mat_shape=tuple(mat.shape),
              mat_nnz=mat.nnz, key=key, scheme_costs=scheme_costs,
              reorder_ms=reorder_ms, tune_ms=tune_ms,
              plan_ms=(time.perf_counter() - t_start) * 1e3,
              advisor_confidence=float(
                  (tp.advisor or {}).get("confidence", 0.0)),
              perm=None if perm is None else np.asarray(perm, np.int64),
              _mat=mat, _rmat=rmat)
    if cache and store_enabled():
        pl.save()
    return pl


def _plan_sharded(problem: SpmvProblem, reorder: str, engine: str,
                  cache: bool, topo: Topology, partitioners: list,
                  schemes: list, key: str, seed: int, use_kernel: str,
                  nnz_bucket: int, block_shape: tuple,
                  t_start: float) -> Plan:
    """The topology-aware joint search: (partition x scheme x engine) argmin
    of modelled wall bytes = max-device compute (engine cost x load
    imbalance / devices) + collective bytes (topology.comm_model). The
    winner's composed permutation (scheme ∘ partitioner grouping) and
    panel split ride on the Plan, so build() needs no re-decision."""
    from ..reorder import api as reorder_api

    mat = problem.mat
    k = max(int(problem.k), 1)
    dtype_name = problem.dtype_name()
    dsize = int(np.dtype(dtype_name).itemsize)
    engines = ("bell", "csr") if engine == "auto" else (engine,)
    reorder_ms = tune_ms = 0.0
    best = None        # (cost, scheme, perm, rmat2, starts, pname, eng, comm)
    scheme_costs: dict = {}
    partition_costs: dict = {}
    for s in schemes:
        t0 = time.perf_counter()
        perm = (None if s == "baseline"
                else reorder_api.reorder(mat, s, seed, cache=cache))
        rmat = mat if perm is None else mat.permute(perm)
        reorder_ms += (time.perf_counter() - t0) * 1e3
        best_s = None
        feat_rmat = None     # non-reordering partitioners all score the
        # scheme's own rmat: one feature scan serves them all
        for pname in partitioners:
            cname, pfn = partition_mod.resolve_partitioner(pname)
            t0 = time.perf_counter()
            perm2, starts = pfn(rmat, topo.row_devices, seed)
            rmat2 = rmat if perm2 is None else rmat.permute(perm2)
            if perm2 is None:
                perm_total = perm
            else:
                perm_total = (np.asarray(perm2, np.int64) if perm is None
                              else np.asarray(perm, np.int64)[perm2])
            reorder_ms += (time.perf_counter() - t0) * 1e3
            t0 = time.perf_counter()
            if rmat2 is rmat:
                if feat_rmat is None:
                    feat_rmat = tune_mod.matrix_features(rmat)
                feat = feat_rmat
            else:
                feat = tune_mod.matrix_features(rmat2)
            comm = topology_mod.comm_model(rmat2, starts, topo, dsize, k,
                                           block_shape)
            for eng in engines:
                compute = tune_mod.candidate_cost(feat, eng, block_shape,
                                                  None, None, k=k)
                cost = (compute * comm["li"] / topo.devices
                        + comm["bytes_per_spmv"])
                partition_costs[f"{s}+{cname}+{eng}"] = float(cost)
                if best is None or cost < best[0]:
                    best = (cost, s, perm_total, rmat2, starts, cname, eng,
                            float(compute), comm)
                if best_s is None or cost < best_s:
                    best_s = float(cost)
            tune_ms += (time.perf_counter() - t0) * 1e3
        scheme_costs[s] = best_s
    _, scheme, perm_total, rmat2, starts, pname, eng, compute, comm = best
    tp = TunePlan(engine=eng, block_shape=tuple(block_shape),
                  sell_sigma=None, cost_bytes=compute, costs={},
                  features={}, source="model", k=k)
    pl = Plan(scheme=scheme, seed=seed, engine_request=engine, tune=tp,
              k=k, dtype_name=dtype_name, probe=False,
              use_kernel=use_kernel, nnz_bucket=nnz_bucket,
              mat_shape=tuple(mat.shape), mat_nnz=mat.nnz, key=key,
              scheme_costs=scheme_costs, reorder_ms=reorder_ms,
              tune_ms=tune_ms,
              plan_ms=(time.perf_counter() - t_start) * 1e3,
              topology=topo, partitioner=pname,
              panel_starts=np.asarray(starts, np.int64), comm=comm,
              partition_costs=partition_costs,
              perm=(None if perm_total is None
                    else np.asarray(perm_total, np.int64)),
              _mat=mat, _rmat=rmat2)
    if cache and store_enabled():
        pl.save()
    return pl
