"""Persistent tuned-operator cache.

Repeated benchmark runs over the same (matrix, scheme) grid pay the
host-side format conversion and autotuning cost every time; this cache
makes the second run free. Entries are content-addressed the same way as
core/reorder/api.py — a sha1 over the CSR structure AND values (operators
embed values) plus the build request — so a reordered matrix, a different
dtype, or a different engine request each get their own entry, and stale
hits are impossible.

Layout (one entry = two files under $REPRO_OPERATOR_CACHE, default
/tmp/repro_opcache):
    <key>.npz    device-array payload (operator.state() arrays)
    <key>.json   {"cls": operator class, "meta": ..., "plan": TunePlan}

`build_cached` is the low-level entry point; it wraps ops.make_engine /
tune.build_tuned and returns (operator, info) where info separates
plan-time (tune_ms, build_ms, load_ms, cache_hit) from the run-time the
measurement harness goes on to observe — the paper's methodology point
that preprocessing must be reported apart from SpMV time.

Set REPRO_OPERATOR_CACHE=off (or cache=False) to disable.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time

import numpy as np

from ... import obs
from ..sparse.csr import CSRMatrix
from .tune import TunePlan, tune


def _cache_dir() -> str:
    return os.environ.get("REPRO_OPERATOR_CACHE", "/tmp/repro_opcache")


def cache_enabled() -> bool:
    return _cache_dir().lower() not in ("off", "0", "none", "")


def _registry() -> dict:
    """Operator classes that speak the state()/from_state() protocol.
    Imported lazily: kernels pull in pallas."""
    from ...kernels.bcsr_spmv.ops import BcsrOperator
    from ...kernels.bell_spmv.ops import BellOperator
    from ...kernels.sell_spmv.ops import SellOperator
    from .ops import DeviceCSR, DeviceDense, DeviceELL

    return {c.__name__: c for c in
            (DeviceCSR, DeviceELL, DeviceDense, SellOperator, BellOperator,
             BcsrOperator)}


def operator_nbytes(op) -> int:
    """Device-array footprint of an operator, in bytes.

    Walks jax.Array leaves reachable from the operator through
    repro-owned objects and plain containers (lists/tuples/dicts) — the
    structure every operator class here actually has — without
    descending into jax internals. Host-side numpy mirrors (e.g. a
    Plan's stored perm) are deliberately NOT counted: the serving
    layer's memory budget bounds device residency, and evicting an
    operator frees exactly these bytes.
    """
    import jax

    seen: set = set()
    total = 0
    stack = [op]
    while stack:
        o = stack.pop()
        if id(o) in seen:
            continue
        seen.add(id(o))
        if isinstance(o, jax.Array):
            total += int(o.nbytes)
        elif isinstance(o, (list, tuple)):
            stack.extend(o)
        elif isinstance(o, dict):
            stack.extend(o.values())
        elif type(o).__module__.startswith("repro.") \
                and hasattr(o, "__dict__"):
            stack.extend(vars(o).values())
    return total


def operator_nbytes_per_device(op) -> list:
    """Per-device footprint breakdown, in bytes (list of length
    topology.devices; a non-sharded operator is the single-device list
    `[operator_nbytes(op)]`).

    `operator_nbytes` counts a ShardedOperator as ONE blob, so a
    service-global budget can be satisfied while an individual device is
    over — the per-device budget the multi-shard router enforces needs
    the split. Each device is charged its slice of the engine arrays
    (leading mesh axes of layout.arrays, floats priced at the plan's
    compute dtype) PLUS the replicated gather/scatter index maps, which
    every device holds a copy of — so sum(per_device) >= the blob count
    whenever replication exists, which is exactly the accounting gap the
    global number hides. Deterministic: computed from the host layout,
    independent of whether device arrays were materialized yet."""
    lay = getattr(op, "layout", None)
    if lay is None:
        return [operator_nbytes(op)]
    topo = lay.topology
    ndev = int(topo.devices)
    dtype_name = getattr(getattr(op, "plan", None), "dtype_name", None)
    per = np.zeros(ndev, dtype=np.int64)
    for a in lay.arrays.values():
        a = np.asarray(a)
        flat = a.reshape((ndev,) + a.shape[2 if topo.col_devices > 1
                                           else 1:])
        itemsize = (np.dtype(dtype_name).itemsize
                    if dtype_name and np.issubdtype(a.dtype, np.floating)
                    else a.dtype.itemsize)
        per += np.asarray([flat[i].size * itemsize for i in range(ndev)],
                          dtype=np.int64)
    replicated = 0
    for name in ("_in_idx", "_in_idx_r", "_out_idx", "_out_idx_r"):
        arr = getattr(op, name, None)
        if arr is not None:
            replicated += int(arr.nbytes)
    per += replicated
    return [int(b) for b in per]


def content_key(mat: CSRMatrix, engine: str, dtype_name: str,
                block_shape=(8, 128), sell_sigma=None, probe=False,
                k: int = 1) -> str:
    """sha1 over matrix content + build request (reorder/api.py style).

    k (the RHS batch width the tuner planned for) is part of the request:
    a k=8-specialized plan may pick a different engine than the k=1 plan
    for the same matrix, so they are distinct cache entries. For a FIXED
    engine k never changes the stored format, so it is normalized out of
    the key — a k-sweep over one engine is a single entry.
    """
    if engine != "auto":
        k = 1
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(mat.rowptr).tobytes())
    h.update(np.ascontiguousarray(mat.cols).tobytes())
    h.update(np.ascontiguousarray(mat.vals).tobytes())
    h.update(f"{tuple(mat.shape)}:{engine}:{dtype_name}:"
             f"{tuple(block_shape)}:{sell_sigma}:{probe}:{int(k)}".encode())
    return h.hexdigest()[:20]


def _store(key: str, op, plan: TunePlan | None) -> None:
    d = _cache_dir()
    os.makedirs(d, exist_ok=True)
    meta, arrays = op.state()
    rec = {"cls": type(op).__name__, "meta": meta,
           "plan": plan.to_json() if plan is not None else None}
    # both files tmp+rename so concurrent writers never publish a
    # half-written entry; the .json is renamed LAST and gates the read.
    # tmp names carry pid AND thread id: same-process threads (e.g. two
    # SpmvService dispatchers) must not interleave into one tmp file
    tag = f"{os.getpid()}.{threading.get_ident()}"
    ztmp = os.path.join(d, f"{key}.{tag}.npz.tmp")
    with open(ztmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(ztmp, os.path.join(d, key + ".npz"))
    jtmp = os.path.join(d, f"{key}.{tag}.json.tmp")
    with open(jtmp, "w") as f:
        json.dump(rec, f)
    os.replace(jtmp, os.path.join(d, key + ".json"))


def _load(key: str, dtype):
    d = _cache_dir()
    jpath = os.path.join(d, key + ".json")
    zpath = os.path.join(d, key + ".npz")
    if not (os.path.exists(jpath) and os.path.exists(zpath)):
        return None, None
    try:
        with open(jpath) as f:
            rec = json.load(f)
        z = np.load(zpath)
        arrays = {k: z[k] for k in z.files}
        cls = _registry().get(rec["cls"])
        if cls is None:
            return None, None
        op = cls.from_state(rec["meta"], arrays, dtype=dtype)
        plan = TunePlan.from_json(rec["plan"]) if rec.get("plan") else None
    except Exception:
        # corrupt, truncated, or schema-incompatible entry (the cache is
        # persistent across code versions): treat as a miss and rebuild
        return None, None
    if plan is not None:
        op.plan = plan
    return op, plan


def build_cached(mat: CSRMatrix, engine: str = "auto", dtype=None,
                 block_shape=(8, 128), sell_sigma=None, probe: bool = False,
                 use_kernel: str = "auto", cache: bool = True, k: int = 1):
    """Build (or reload) an operator. Returns (op, info).

    k is the RHS batch width to tune for (engine="auto"); the stored entry
    carries the k-specialized plan, so a reload restores both the device
    arrays and the plan that justified them.

    info: {"cache_hit", "key", "tune_ms", "build_ms", "load_ms",
           "engine", "plan"} — plan-time accounting for the benchmarks.
    """
    import jax.numpy as jnp

    from .ops import make_engine
    from .tune import build_from_plan

    dt = jnp.float32 if dtype is None else dtype
    dtype_name = jnp.dtype(dt).name
    use_cache = cache and cache_enabled()
    key = content_key(mat, engine, dtype_name, block_shape, sell_sigma,
                      probe, k=k) if use_cache else None
    info = {"cache_hit": False, "key": key, "tune_ms": 0.0, "build_ms": 0.0,
            "load_ms": 0.0, "engine": engine, "plan": None}

    if use_cache:
        t0 = time.perf_counter()
        op, plan = _load(key, dt)
        if op is not None:
            # restored kernel choice must match THIS process's backend (an
            # entry written on TPU may be reloaded on CPU and vice versa)
            if getattr(op, "use_kernel", None) is not None:
                import jax

                op.use_kernel = use_kernel if use_kernel != "auto" else (
                    "pallas" if jax.default_backend() == "tpu" else "ref")
            info.update(cache_hit=True,
                        load_ms=(time.perf_counter() - t0) * 1e3,
                        engine=plan.engine if plan else engine,
                        plan=plan.to_json() if plan else None)
            obs.counter("opcache.hits").inc()
            return op, info
        obs.counter("opcache.misses").inc()

    plan = None
    t0 = time.perf_counter()
    if engine == "auto":
        plan = tune(mat, probe=probe, dtype=dt, use_kernel=use_kernel, k=k)
        info["tune_ms"] = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        op = build_from_plan(mat, plan, dtype=dt, use_kernel=use_kernel)
    else:
        op = make_engine(mat, engine, dtype=dt, block_shape=block_shape,
                         use_kernel=use_kernel, sell_sigma=sell_sigma)
    info["build_ms"] = (time.perf_counter() - t0) * 1e3
    info["engine"] = plan.engine if plan else engine
    info["plan"] = plan.to_json() if plan else None
    if use_cache:
        _store(key, op, plan)
        obs.counter("opcache.writes").inc()
    return op, info
