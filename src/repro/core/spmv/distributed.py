"""Sharded SpMV — the distributed execution layer of the pipeline facade.

Since PR 5 this module is the BUILD side of topology-aware plans
(DESIGN.md "Topology-aware planning"): `repro.api.plan(problem,
topology=Topology(...))` decides (partition x scheme x engine x shape x k)
with the communication-volume cost model (core/spmv/topology.py), and
`Plan.build()` calls `build_sharded_layout` + `ShardedOperator` here.

Layouts (both operate on uniform padded row panels so every device runs
the same program):

* 1d_rows   — row panels over a flat mesh; x row-sharded and either
              ALL-GATHERED each SpMV (the CG dataflow) or assembled by two
              nearest-neighbour ring permutes when the plan's reordering
              made the halo legal (the paper's data-movement story as a
              collective-schedule choice).
* 2d_panels — rows over "data", columns over "model"; each device holds
              an (m/D x n/M) brick and only its x segment; partial y is
              all-reduced over "model".

Per-device engines: "bell" (Block-ELL bricks — the MXU format) and "csr"
(padded gather + segment-sum — the paper's Listing 4 semantics), chosen
by the planner like any other engine axis.

`ShardedOperator` accepts ORIGINAL-index-space vectors (it carries the
plan's composed permutation AND the panel-padding map), supports
`matmul(X[n, k])` and CG, round-trips through the content-addressed plan
store, and runs on a real device mesh when the process has enough devices
(XLA_FLAGS=--xla_force_host_platform_device_count=8 in tests/CI) or on a
bit-equivalent single-device simulation otherwise (`op.simulated`).

The pre-PR-5 entry points (plan_1d / spmv_1d / plan_2d / spmv_2d /
plan_halo_1d / spmv_halo_1d) remain as DeprecationWarning shims over the
legacy internals with no in-src callers; see the README migration table.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import numpy as np

from ... import obs
from ..sparse.bell import to_block_ell
from ..sparse.csr import CSRMatrix
from ..sparse.partition import (nnz_balanced_partition, partition_to_owner,
                                static_partition)
from .topology import Topology, padded_panel_rows


# ---------------------------------------------------------------------------
# Sharded layout: host-side arrays for one (matrix, topology, partition)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ShardedLayout:
    """Everything `ShardedOperator` needs to execute, all host numpy:
    per-device engine arrays, the panel split, the padding index maps and
    the collective schedule. Built once per plan; round-trips through the
    plan store via ShardedOperator.state()/from_state()."""

    engine: str                  # "bell" | "csr"
    arrays: dict                 # engine arrays (leading axes = mesh axes)
    panel_starts: np.ndarray     # [P+1] row offsets in the reordered space
    padmap: np.ndarray           # [m] padded slot of reordered row r
    pad_idx: np.ndarray          # [n_pad] reordered row per slot (m = pad)
    shape: tuple                 # original (m, n), square
    topology: Topology
    schedule: str                # "all_gather" | "halo" | "psum"
    halo: int
    h_pad: int
    n_pad: int
    seg_n: int                   # 2d x-segment width (0 for 1d)
    block_shape: tuple


def _index_maps(starts: np.ndarray, m: int, h_pad: int):
    """padmap[r] = padded slot of reordered row r; pad_idx[slot] = r (or m
    for a padding slot, which gathers the appended zero)."""
    starts = np.asarray(starts, dtype=np.int64)
    p = starts.size - 1
    owner = partition_to_owner(starts, m).astype(np.int64)
    padmap = owner * h_pad + (np.arange(m, dtype=np.int64) - starts[owner])
    pad_idx = np.full(p * h_pad, m, dtype=np.int64)
    pad_idx[padmap] = np.arange(m, dtype=np.int64)
    return padmap, pad_idx


def _pack_bell_panels(subs: list, bm: int, bn: int):
    """Uniform Block-ELL arrays over a list of equal-shape CSR panels
    (shared K = max block count — the legacy Plan1D packing, factored)."""
    bells = [to_block_ell(sub, bm, bn) for sub in subs]
    kmax = max(b.k for b in bells)
    nbr = bells[0].num_block_rows
    blocks = np.zeros((len(subs), nbr, kmax, bm, bn),
                      dtype=subs[0].vals.dtype)
    cols = np.zeros((len(subs), nbr, kmax), dtype=np.int32)
    for i, b in enumerate(bells):
        blocks[i, :b.num_block_rows, :b.k] = b.blocks
        cols[i, :b.num_block_rows, :b.k] = b.block_cols
    return blocks, cols


def _pack_csr_panels(entries: list, h_pad: int):
    """Uniform padded COO-CSR arrays over per-device (rows, cols, vals)
    triples: nnz padded to the max with (row=h_pad-1, col=0, val=0) —
    sorted row_ids preserved, contribution exactly zero."""
    nnz_pad = max(max((r.size for r, _, _ in entries), default=0), 1)
    n_dev = len(entries)
    row_ids = np.full((n_dev, nnz_pad), h_pad - 1, dtype=np.int32)
    cols = np.zeros((n_dev, nnz_pad), dtype=np.int32)
    vals = np.zeros((n_dev, nnz_pad),
                    dtype=entries[0][2].dtype if entries else np.float64)
    for i, (r, c, v) in enumerate(entries):
        row_ids[i, :r.size] = r
        cols[i, :c.size] = c
        vals[i, :v.size] = v
    return row_ids, cols, vals


def build_sharded_layout(rmat: CSRMatrix, topology: Topology,
                         panel_starts: np.ndarray, engine: str = "bell",
                         block_shape: tuple = (8, 128),
                         schedule: str = "all_gather",
                         halo: int = 0) -> ShardedLayout:
    """Chop the (already reordered) matrix into per-device arrays for the
    topology's layout. Columns are remapped through the same panel-padding
    map as rows (conformal x partition), so the device program never sees
    the ragged panel heights."""
    m, n = rmat.shape
    if m != n:
        raise ValueError(f"sharded plans need a square matrix (conformal "
                         f"x partition), got {rmat.shape}")
    if engine not in ("bell", "csr"):
        raise ValueError(f"sharded engines are 'bell'/'csr', got {engine!r}")
    bm, bn = block_shape
    starts = np.asarray(panel_starts, dtype=np.int64)
    d, mm = topology.row_devices, topology.col_devices
    if starts.size != d + 1:
        raise ValueError(f"panel_starts has {starts.size - 1} panels for "
                         f"{d} row devices")
    h_pad = padded_panel_rows(starts, bm, bn, col_devices=mm)
    n_pad = d * h_pad
    padmap, pad_idx = _index_maps(starts, m, h_pad)
    rp = rmat.rowptr.astype(np.int64)
    rows_p = padmap[np.repeat(np.arange(m, dtype=np.int64), np.diff(rp))]
    cols_p = padmap[rmat.cols.astype(np.int64)]
    vals = rmat.vals
    seg_n = 0

    if topology.layout == "1d_rows":
        if schedule == "halo":
            halo = int(halo)
            if halo % bn or halo > h_pad:
                raise ValueError(f"halo {halo} must be a multiple of "
                                 f"bn={bn} and <= h_pad={h_pad}")
            width = h_pad + 2 * halo
        else:
            schedule, halo, width = "all_gather", 0, n_pad
        panel = rows_p // h_pad
        subs, csr_entries = [], []
        for p in range(d):
            sel = panel == p
            lrows = rows_p[sel] - p * h_pad
            lcols = cols_p[sel] - (p * h_pad - halo if schedule == "halo"
                                   else 0)
            if schedule == "halo" and sel.any():
                if lcols.min() < 0 or lcols.max() >= width:
                    raise ValueError(
                        "halo window violated after padding; the plan's "
                        "comm model and the layout builder disagree")
            if engine == "bell":
                subs.append(CSRMatrix.from_coo(lrows, lcols, vals[sel],
                                               (h_pad, width)))
            else:
                csr_entries.append((lrows, lcols, vals[sel]))
        if engine == "bell":
            blocks, bcols = _pack_bell_panels(subs, bm, bn)
            arrays = {"blocks": blocks, "block_cols": bcols}
        else:
            row_ids, ccols, cvals = _pack_csr_panels(csr_entries, h_pad)
            arrays = {"row_ids": row_ids, "cols": ccols, "vals": cvals}
    else:                                    # 2d_panels
        schedule, halo = "psum", 0
        seg_n = n_pad // mm
        panel = rows_p // h_pad
        seg = cols_p // seg_n
        subs, csr_entries = [], []
        for p in range(d):
            for q in range(mm):
                sel = (panel == p) & (seg == q)
                lrows = rows_p[sel] - p * h_pad
                lcols = cols_p[sel] - q * seg_n
                if engine == "bell":
                    subs.append(CSRMatrix.from_coo(lrows, lcols, vals[sel],
                                                   (h_pad, seg_n)))
                else:
                    csr_entries.append((lrows, lcols, vals[sel]))
        if engine == "bell":
            blocks, bcols = _pack_bell_panels(subs, bm, bn)
            arrays = {"blocks": blocks.reshape((d, mm) + blocks.shape[1:]),
                      "block_cols": bcols.reshape((d, mm) + bcols.shape[1:])}
        else:
            row_ids, ccols, cvals = _pack_csr_panels(csr_entries, h_pad)
            arrays = {"row_ids": row_ids.reshape(d, mm, -1),
                      "cols": ccols.reshape(d, mm, -1),
                      "vals": cvals.reshape(d, mm, -1)}

    return ShardedLayout(engine=engine, arrays=arrays, panel_starts=starts,
                         padmap=padmap, pad_idx=pad_idx, shape=(m, n),
                         topology=topology, schedule=schedule, halo=halo,
                         h_pad=h_pad, n_pad=n_pad, seg_n=seg_n,
                         block_shape=(bm, bn))


# ---------------------------------------------------------------------------
# Device-side local kernels (shared by the shard_map bodies AND the
# single-device simulation, so both execute the same math)
# ---------------------------------------------------------------------------
def _bell_local(blocks, bcols, xw, bn):
    """One device's Block-ELL panel SpMM: xw [win, nv] -> y [h_pad, nv].
    Accumulates at promote(x.dtype, f32) so fp64 plans keep fp64."""
    import jax.numpy as jnp

    x2d = xw.reshape(-1, bn, xw.shape[-1])
    gathered = x2d[bcols]                            # [nbr, K, bn, nv]
    acc = jnp.promote_types(xw.dtype, jnp.float32)
    y = jnp.einsum("rkij,rkjv->riv", blocks, gathered,
                   preferred_element_type=acc).astype(xw.dtype)
    return y.reshape(-1, xw.shape[-1])


def _csr_local(row_ids, cols, vals, xw, h_pad):
    """One device's padded-COO panel SpMM: xw [win, nv] -> y [h_pad, nv]."""
    import jax

    prod = vals[:, None] * xw[cols]                  # [nnz_pad, nv]
    return jax.ops.segment_sum(prod, row_ids, num_segments=h_pad,
                               indices_are_sorted=True)


def _local_y(engine, arrs: tuple, xw, h_pad: int, bn: int):
    if engine == "bell":
        return _bell_local(arrs[0], arrs[1], xw, bn)
    return _csr_local(arrs[0], arrs[1], arrs[2], xw, h_pad)


_ARRAY_ORDER = {"bell": ("blocks", "block_cols"),
                "csr": ("row_ids", "cols", "vals")}


# ---------------------------------------------------------------------------
# ShardedOperator
# ---------------------------------------------------------------------------
class _ReorderedView:
    """`unwrap()` counterpart of Operator.unwrap(): the same sharded
    execution, reordered index space in and out (what harnesses time)."""

    def __init__(self, op: "ShardedOperator"):
        self._op = op

    def __call__(self, x):
        return self._op(x, permuted=True)

    def matmul(self, x):
        return self._op.matmul(x, permuted=True)

    @property
    def shape(self):
        return self._op.shape


class ShardedOperator:
    """Permutation- and topology-carrying distributed SpMV/SpMM operator.

    `op(x)` / `op.matmul(X)` take ORIGINAL-index-space vectors: x is
    gathered through the composed (scheme ∘ partitioner) permutation and
    the panel-padding map in ONE fused gather, the sharded step runs, and
    y scatters back the same way. `permuted=True` opts out of the
    permutation (x already in the reordered space; padding still applies).

    Execution: a shard_map over the topology's mesh when the process has
    enough devices, otherwise a single-device simulation (`op.simulated`)
    that runs the identical local kernels over a vmapped panel axis —
    same math, no mesh — so sharded plans stay usable (service, CG,
    verification) in single-device processes.
    """

    def __init__(self, layout: ShardedLayout, perm: Optional[np.ndarray],
                 plan=None, build_info: Optional[dict] = None):
        import jax.numpy as jnp

        self.layout = layout
        self.plan = plan
        self.build_info = build_info or {}
        m = layout.shape[0]
        if perm is not None and np.array_equal(perm, np.arange(perm.size)):
            perm = None
        self._perm_np = None if perm is None else np.asarray(perm, np.int64)
        pad_idx = layout.pad_idx
        if perm is None:
            in_idx = pad_idx
            out_idx = layout.padmap
        else:
            perm_ext = np.append(np.asarray(perm, np.int64), m)
            in_idx = perm_ext[pad_idx]          # pad slots gather x_ext[m]=0
            iperm = np.empty(m, dtype=np.int64)
            iperm[perm] = np.arange(m, dtype=np.int64)
            out_idx = layout.padmap[iperm]
        self._in_idx = jnp.asarray(in_idx, jnp.int32)
        self._in_idx_r = jnp.asarray(pad_idx, jnp.int32)
        self._out_idx = jnp.asarray(out_idx, jnp.int32)
        self._out_idx_r = jnp.asarray(layout.padmap, jnp.int32)
        self._dev = None                        # engine arrays, lazy
        self._dtype = None
        self._fns = {}                          # nv -> jitted step
        self.force_simulated = False            # testing/debug override

    # -- facade surface ----------------------------------------------------
    @property
    def shape(self) -> tuple:
        return tuple(self.layout.shape)

    @property
    def topology(self) -> Topology:
        return self.layout.topology

    @property
    def perm(self) -> Optional[np.ndarray]:
        return self._perm_np

    @property
    def iperm(self) -> Optional[np.ndarray]:
        if self._perm_np is None:
            return None
        iperm = np.empty_like(self._perm_np)
        iperm[self._perm_np] = np.arange(self._perm_np.size)
        return iperm

    @property
    def panel_starts(self) -> np.ndarray:
        return self.layout.panel_starts

    @property
    def simulated(self) -> bool:
        import jax

        return (self.force_simulated
                or len(jax.devices()) < self.layout.topology.devices)

    def unwrap(self) -> _ReorderedView:
        return _ReorderedView(self)

    # -- execution ---------------------------------------------------------
    def _device_arrays(self, dtype):
        import jax.numpy as jnp

        if self._dev is None or self._dtype != dtype:
            lay = self.layout
            dev = []
            for name in _ARRAY_ORDER[lay.engine]:
                a = lay.arrays[name]
                dev.append(jnp.asarray(
                    a, dtype if np.issubdtype(a.dtype, np.floating) else None))
            self._dev = tuple(dev)
            self._dtype = dtype
        return self._dev

    def _mesh(self):
        import jax
        from jax.sharding import Mesh

        topo = self.layout.topology
        devs = np.array(jax.devices()[:topo.devices])
        return Mesh(devs.reshape(topo.mesh_shape), topo.mesh_axes)

    def _make_fn(self, nv: int):
        """Jitted padded-space step xp [n_pad, nv] -> yp [n_pad? d*h_pad,
        nv] for this batch width (mesh or simulated)."""
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        lay = self.layout
        topo = lay.topology
        d, mm = topo.row_devices, topo.col_devices
        h_pad, halo, bn = lay.h_pad, lay.halo, lay.block_shape[1]
        engine, n_pad, seg_n = lay.engine, lay.n_pad, lay.seg_n
        n_arr = len(_ARRAY_ORDER[engine])

        if not self.simulated:
            mesh = self._mesh()
            ax, = topo.mesh_axes[:1]
            if topo.layout == "1d_rows":
                def body(*ops):
                    arrs, xs = ops[:-1], ops[-1][0]         # xs [h_pad, nv]
                    if lay.schedule == "halo" and halo:
                        fwd = [(i, (i + 1) % d) for i in range(d)]
                        bwd = [((i + 1) % d, i) for i in range(d)]
                        lh = jax.lax.ppermute(xs[-halo:], ax, fwd)
                        rh = jax.lax.ppermute(xs[:halo], ax, bwd)
                        xw = jnp.concatenate([lh, xs, rh])
                    elif lay.schedule == "halo":
                        xw = xs
                    else:
                        xw = jax.lax.all_gather(xs, ax, tiled=True)
                    y = _local_y(engine, tuple(a[0] for a in arrs), xw,
                                 h_pad, bn)
                    return y[None]

                f = shard_map(body, mesh=mesh,
                              in_specs=(P(ax),) * n_arr + (P(ax),),
                              out_specs=P(ax))

                def step(arrs, xp):
                    yp = f(*arrs, xp.reshape(d, h_pad, nv))
                    return yp.reshape(n_pad, nv)
            else:
                rax, cax = topo.mesh_axes

                def body(*ops):
                    arrs, xs = ops[:-1], ops[-1][0]         # xs [seg_n, nv]
                    y = _local_y(engine, tuple(a[0, 0] for a in arrs), xs,
                                 h_pad, bn)
                    return jax.lax.psum(y, cax)[None]

                f = shard_map(body, mesh=mesh,
                              in_specs=(P(rax, cax),) * n_arr + (P(cax),),
                              out_specs=P(rax))

                def step(arrs, xp):
                    yp = f(*arrs, xp.reshape(mm, seg_n, nv))
                    return yp.reshape(n_pad, nv)
        else:
            if topo.layout == "1d_rows":
                if lay.schedule == "halo":
                    win = (np.arange(-halo, h_pad + halo)[None, :]
                           + np.arange(d)[:, None] * h_pad) % n_pad
                    win_idx = jnp.asarray(win, jnp.int32)

                    def step(arrs, xp):
                        xw = xp[win_idx]                   # [d, win, nv]
                        y = jax.vmap(
                            lambda *a: _local_y(engine, a[:-1], a[-1],
                                                h_pad, bn))(*arrs, xw)
                        return y.reshape(n_pad, nv)
                else:
                    def step(arrs, xp):
                        y = jax.vmap(
                            lambda *a: _local_y(engine, a, xp, h_pad, bn),
                        )(*arrs)
                        return y.reshape(n_pad, nv)
            else:
                def step(arrs, xp):
                    xw = xp.reshape(mm, seg_n, nv)
                    inner = jax.vmap(
                        lambda *a: _local_y(engine, a[:-1], a[-1],
                                            h_pad, bn))

                    def per_row(*a):
                        return inner(*a, xw).sum(axis=0)   # psum over model

                    y = jax.vmap(per_row)(*arrs)           # [d, h_pad, nv]
                    return y.reshape(n_pad, nv)

        return jax.jit(lambda arrs, xp: step(arrs, xp))

    def _exec(self, x, permuted: bool, batched: bool):
        import jax.numpy as jnp

        lay = self.layout
        with obs.span("sharded.spmv", engine=lay.engine,
                      schedule=lay.schedule, devices=lay.topology.devices,
                      simulated=self.simulated):
            x = jnp.asarray(x)
            x2 = x if batched else x[:, None]
            nv = int(x2.shape[1])
            dtype = x2.dtype
            with obs.span("sharded.gather_x", schedule=lay.schedule):
                zero = jnp.zeros((1, nv), dtype)
                xe = jnp.concatenate([x2, zero], axis=0)
                xp = jnp.take(xe,
                              self._in_idx_r if permuted else self._in_idx,
                              axis=0)
            key = (nv, self.simulated)
            fn = self._fns.get(key)
            if fn is None:
                fn = self._fns[key] = self._make_fn(nv)
            # one fused jit: per-device compute + the plan's collective
            # (all-gather / halo ring permutes / 2-D all-reduce)
            with obs.span("sharded.exec", schedule=lay.schedule,
                          halo=int(lay.halo)):
                yp = fn(self._device_arrays(dtype), xp)
            with obs.span("sharded.scatter_y", schedule=lay.schedule):
                y = jnp.take(yp,
                             self._out_idx_r if permuted else self._out_idx,
                             axis=0)
            return y if batched else y[:, 0]

    def __call__(self, x, permuted: bool = False):
        return self._exec(x, permuted, batched=getattr(x, "ndim", 1) == 2)

    def matmul(self, x, permuted: bool = False):
        """x: [n, k] -> y: [m, k], original index space unless permuted."""
        return self._exec(x, permuted,
                          batched=getattr(x, "ndim", 2) == 2)

    # -- plan-store protocol ----------------------------------------------
    def state(self):
        lay = self.layout
        meta = {"engine": lay.engine, "topology": lay.topology.to_json(),
                "schedule": lay.schedule, "halo": int(lay.halo),
                "h_pad": int(lay.h_pad), "n_pad": int(lay.n_pad),
                "seg_n": int(lay.seg_n), "shape": list(lay.shape),
                "block_shape": list(lay.block_shape)}
        arrays = dict(lay.arrays)
        arrays["panel_starts"] = np.asarray(lay.panel_starts, np.int64)
        return meta, arrays

    @classmethod
    def from_state(cls, meta, arrays, dtype=None, perm=None, plan=None,
                   build_info=None):
        topo = Topology.from_json(meta["topology"])
        starts = np.asarray(arrays["panel_starts"], np.int64)
        m = int(meta["shape"][0])
        padmap, pad_idx = _index_maps(starts, m, int(meta["h_pad"]))
        eng_arrays = {k: np.asarray(v) for k, v in arrays.items()
                      if k != "panel_starts"}
        layout = ShardedLayout(
            engine=meta["engine"], arrays=eng_arrays, panel_starts=starts,
            padmap=padmap, pad_idx=pad_idx, shape=tuple(meta["shape"]),
            topology=topo, schedule=meta["schedule"],
            halo=int(meta["halo"]), h_pad=int(meta["h_pad"]),
            n_pad=int(meta["n_pad"]), seg_n=int(meta["seg_n"]),
            block_shape=tuple(meta["block_shape"]))
        return cls(layout, perm, plan=plan, build_info=build_info)


# ---------------------------------------------------------------------------
# Legacy internals (pre-PR-5 layout builders) + deprecation shims
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Plan1D:
    """Global arrays for the legacy 1-D layout (leading axis = panels)."""

    blocks: np.ndarray       # [P, nbr_l, K, bm, bn]
    block_cols: np.ndarray   # [P, nbr_l, K]
    row_offset: np.ndarray   # [P] first row of each panel
    panel_rows: int          # uniform (padded) rows per panel
    shape: Tuple[int, int]
    block_shape: Tuple[int, int]


def _legacy_plan_1d(mat: CSRMatrix, num_devices: int, bm: int = 8,
                    bn: int = 128, balanced: bool = True) -> Plan1D:
    starts = (nnz_balanced_partition(mat, num_devices) if balanced
              else static_partition(mat, num_devices))
    heights = np.diff(starts)
    h = int(heights.max())
    h_pad = ((h + bm - 1) // bm) * bm
    rp = mat.rowptr.astype(np.int64)
    panels = []
    for p in range(num_devices):
        r0, r1 = int(starts[p]), int(starts[p + 1])
        s, e = rp[r0], rp[r1]
        panels.append(CSRMatrix(
            rowptr=(rp[r0:r1 + 1] - s).astype(np.int32),
            cols=mat.cols[s:e], vals=mat.vals[s:e],
            shape=(r1 - r0, mat.n)))
    bells = [to_block_ell(sub, bm, bn) for sub in panels]
    k = max(b.k for b in bells)
    nbr_l = h_pad // bm
    blocks = np.zeros((num_devices, nbr_l, k, bm, bn), dtype=mat.vals.dtype)
    cols = np.zeros((num_devices, nbr_l, k), dtype=np.int32)
    for p, b in enumerate(bells):
        blocks[p, :b.num_block_rows, :b.k] = b.blocks
        cols[p, :b.num_block_rows, :b.k] = b.block_cols
    return Plan1D(blocks=blocks, block_cols=cols,
                  row_offset=starts[:-1].astype(np.int64), panel_rows=h_pad,
                  shape=mat.shape, block_shape=(bm, bn))


def _legacy_spmv_1d(mesh, axis_names: Tuple[str, ...]):
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from . import ref

    ax = axis_names

    def local(blocks, block_cols, x_panels):
        xs = jax.lax.all_gather(x_panels[0], ax, tiled=True)
        bm, bn = blocks.shape[-2], blocks.shape[-1]
        y = ref.spmv_bell(blocks[0], block_cols[0], xs.reshape(-1, bn, 1))
        return y.reshape(1, -1)

    f = shard_map(local, mesh=mesh,
                  in_specs=(P(ax), P(ax), P(ax)), out_specs=P(ax))
    return jax.jit(f)


def _legacy_plan_2d(mat: CSRMatrix, d: int, m_axis: int, bm: int = 8,
                    bn: int = 128, balanced: bool = True):
    starts = (nnz_balanced_partition(mat, d) if balanced
              else static_partition(mat, d))
    seg_n = ((mat.n + m_axis - 1) // m_axis + bn - 1) // bn * bn
    heights = np.diff(starts)
    h_pad = ((int(heights.max()) + bm - 1) // bm) * bm
    nbr_l = h_pad // bm
    rp = mat.rowptr.astype(np.int64)
    bricks = []
    kmax = 1
    for p in range(d):
        r0, r1 = int(starts[p]), int(starts[p + 1])
        s, e = rp[r0], rp[r1]
        cols = mat.cols[s:e].astype(np.int64)
        rows = np.repeat(np.arange(r1 - r0), np.diff(rp[r0:r1 + 1]))
        row_bricks = []
        for q in range(m_axis):
            c0, c1 = q * seg_n, (q + 1) * seg_n
            keep = (cols >= c0) & (cols < c1)
            sub = CSRMatrix.from_coo(rows[keep], cols[keep] - c0,
                                     mat.vals[s:e][keep], (r1 - r0, seg_n))
            bell = to_block_ell(sub, bm, bn)
            kmax = max(kmax, bell.k)
            row_bricks.append(bell)
        bricks.append(row_bricks)
    blocks = np.zeros((d, m_axis, nbr_l, kmax, bm, bn), dtype=mat.vals.dtype)
    bcols = np.zeros((d, m_axis, nbr_l, kmax), dtype=np.int32)
    for p in range(d):
        for q in range(m_axis):
            b = bricks[p][q]
            blocks[p, q, :b.num_block_rows, :b.k] = b.blocks
            bcols[p, q, :b.num_block_rows, :b.k] = b.block_cols
    return blocks, bcols, seg_n, h_pad, starts


def _legacy_spmv_2d(mesh, row_axis: str = "data", col_axis: str = "model"):
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from . import ref

    def local(blocks, block_cols, x_segs):
        bm, bn = blocks.shape[-2], blocks.shape[-1]
        x2d = x_segs[0].reshape(-1, bn, 1)
        y = ref.spmv_bell(blocks[0, 0], block_cols[0, 0], x2d)
        y = jax.lax.psum(y, col_axis)
        return y.reshape(1, -1)

    f = shard_map(local, mesh=mesh,
                  in_specs=(P(row_axis, col_axis), P(row_axis, col_axis),
                            P(col_axis)),
                  out_specs=P(row_axis))
    return jax.jit(f)


def _legacy_plan_halo_1d(mat: CSRMatrix, num_devices: int, bm: int = 8,
                         bn: int = 128):
    from ..sparse.metrics import bandwidth as _bandwidth

    assert mat.m % num_devices == 0, "equal panels required"
    panel_n = mat.m // num_devices
    bw = _bandwidth(mat)
    halo = ((bw + bn - 1) // bn) * bn
    if halo >= panel_n:
        raise ValueError(
            f"bandwidth {bw} too wide for halo exchange at P={num_devices} "
            f"(panel {panel_n}); reorder first (RCM) or use plan_1d")
    rp = mat.rowptr.astype(np.int64)
    panels = []
    kmax = 1
    win_n = panel_n + 2 * halo
    for p in range(num_devices):
        r0, r1 = p * panel_n, (p + 1) * panel_n
        s, e = rp[r0], rp[r1]
        cols = mat.cols[s:e].astype(np.int64) - (r0 - halo)
        assert cols.min() >= 0 and cols.max() < win_n, "bandwidth violated"
        rows = np.repeat(np.arange(r1 - r0), np.diff(rp[r0:r1 + 1]))
        sub = CSRMatrix.from_coo(rows, cols, mat.vals[s:e],
                                 (panel_n, win_n))
        bell = to_block_ell(sub, bm, bn)
        kmax = max(kmax, bell.k)
        panels.append(bell)
    nbr_l = (panel_n + bm - 1) // bm
    blocks = np.zeros((num_devices, nbr_l, kmax, bm, bn), dtype=mat.vals.dtype)
    bcols = np.zeros((num_devices, nbr_l, kmax), dtype=np.int32)
    for p, pnl in enumerate(panels):
        blocks[p, :pnl.num_block_rows, :pnl.k] = pnl.blocks
        bcols[p, :pnl.num_block_rows, :pnl.k] = pnl.block_cols
    return blocks, bcols, halo, panel_n


def _legacy_spmv_halo_1d(mesh, axis_names: Tuple[str, ...], halo: int):
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from . import ref

    ax = axis_names if len(axis_names) > 1 else axis_names[0]
    n_dev = int(np.prod([mesh.shape[a] for a in axis_names]))

    def local(blocks, block_cols, x_panels):
        x = x_panels[0]
        right_edge = x[-halo:]
        left_edge = x[:halo]
        fwd = [(i, (i + 1) % n_dev) for i in range(n_dev)]
        bwd = [((i + 1) % n_dev, i) for i in range(n_dev)]
        left_halo = jax.lax.ppermute(right_edge, ax, fwd)
        right_halo = jax.lax.ppermute(left_edge, ax, bwd)
        xw = jnp.concatenate([left_halo, x, right_halo])
        bm, bn = blocks.shape[-2], blocks.shape[-1]
        y = ref.spmv_bell(blocks[0], block_cols[0], xw.reshape(-1, bn, 1))
        return y.reshape(1, -1)

    f = shard_map(local, mesh=mesh,
                  in_specs=(P(ax), P(ax), P(ax)), out_specs=P(ax))
    return jax.jit(f)


def _shim(name: str):
    warnings.warn(
        f"core.spmv.distributed.{name}() is deprecated; plan through "
        f"repro.api — plan(SpmvProblem(mat), topology=Topology(devices=P, "
        f"layout=...)).build() returns a ShardedOperator that owns the "
        f"layout, permutation and collective schedule",
        DeprecationWarning, stacklevel=3)


def plan_1d(mat: CSRMatrix, num_devices: int, bm: int = 8, bn: int = 128,
            balanced: bool = True) -> Plan1D:
    """Deprecated shim over the legacy 1-D layout builder."""
    _shim("plan_1d")
    return _legacy_plan_1d(mat, num_devices, bm=bm, bn=bn, balanced=balanced)


def spmv_1d(mesh, axis_names: Tuple[str, ...]):
    """Deprecated shim over the legacy 1-D all-gather step builder."""
    _shim("spmv_1d")
    return _legacy_spmv_1d(mesh, axis_names)


def plan_2d(mat: CSRMatrix, d: int, m_axis: int, bm: int = 8, bn: int = 128,
            balanced: bool = True):
    """Deprecated shim over the legacy 2-D layout builder."""
    _shim("plan_2d")
    return _legacy_plan_2d(mat, d, m_axis, bm=bm, bn=bn, balanced=balanced)


def spmv_2d(mesh, row_axis: str = "data", col_axis: str = "model"):
    """Deprecated shim over the legacy 2-D step builder."""
    _shim("spmv_2d")
    return _legacy_spmv_2d(mesh, row_axis=row_axis, col_axis=col_axis)


def plan_halo_1d(mat: CSRMatrix, num_devices: int, bm: int = 8,
                 bn: int = 128):
    """Deprecated shim over the legacy halo-exchange layout builder."""
    _shim("plan_halo_1d")
    return _legacy_plan_halo_1d(mat, num_devices, bm=bm, bn=bn)


def spmv_halo_1d(mesh, axis_names: Tuple[str, ...], halo: int):
    """Deprecated shim over the legacy halo-exchange step builder."""
    _shim("spmv_halo_1d")
    return _legacy_spmv_halo_1d(mesh, axis_names, halo)
