"""Multi-device SpMV via shard_map — the distributed runtime for the
paper's workload (and the `--arch spmv` dry-run entry).

Two layouts (DESIGN.md §4):

* 1-D row panels (paper-faithful baseline): rows nnz-balanced over every
  device (paper Listing 5 applied at the device level); x starts
  row-sharded and is ALL-GATHERED each iteration (the CG dataflow: the
  updated direction vector is sharded, the next SpMV needs all of it).
  Collective bytes per SpMV: n * dtype * (P-1)/P per device.

* 2-D panels (beyond-paper optimization, EXPERIMENTS.md §Perf): rows over
  the `data` axis, columns over the `model` axis. Each device holds an
  (m/D x n/M) brick and only its x segment; partial y is reduce-scattered
  over `model`. Collective bytes per SpMV: m/D * dtype — independent of
  total device count on the row axis.

Both operate on Block-ELL bricks (uniform shapes across devices; panels are
nnz-balanced *before* padding so the padding is the residual imbalance).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..sparse.bell import to_block_ell
from ..sparse.csr import CSRMatrix
from ..sparse.partition import nnz_balanced_partition, static_partition
from . import ref


# ---------------------------------------------------------------------------
# Host-side plan: chop a CSR matrix into per-device Block-ELL bricks
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Plan1D:
    """Global arrays for the 1-D layout (leading axis = row panels)."""

    blocks: np.ndarray       # [P, nbr_l, K, bm, bn]
    block_cols: np.ndarray   # [P, nbr_l, K]
    row_offset: np.ndarray   # [P] first row of each panel
    panel_rows: int          # uniform (padded) rows per panel
    shape: Tuple[int, int]
    block_shape: Tuple[int, int]


def plan_1d(mat: CSRMatrix, num_devices: int, bm: int = 8, bn: int = 128,
            balanced: bool = True) -> Plan1D:
    starts = (nnz_balanced_partition(mat, num_devices) if balanced
              else static_partition(mat, num_devices))
    heights = np.diff(starts)
    h = int(heights.max())
    h_pad = ((h + bm - 1) // bm) * bm
    nbr_l = h_pad // bm
    panels = []
    for p in range(num_devices):
        r0, r1 = int(starts[p]), int(starts[p + 1])
        rp = mat.rowptr.astype(np.int64)
        s, e = rp[r0], rp[r1]
        sub = CSRMatrix(
            rowptr=(rp[r0:r1 + 1] - s).astype(np.int32),
            cols=mat.cols[s:e], vals=mat.vals[s:e],
            shape=(r1 - r0, mat.n))
        panels.append(to_block_ell(sub, bm, bn))
    k = max(pl_.k for pl_ in panels)
    blocks = np.zeros((num_devices, nbr_l, k, bm, bn), dtype=mat.vals.dtype)
    cols = np.zeros((num_devices, nbr_l, k), dtype=np.int32)
    for p, pnl in enumerate(panels):
        blocks[p, :pnl.num_block_rows, :pnl.k] = pnl.blocks
        cols[p, :pnl.num_block_rows, :pnl.k] = pnl.block_cols
    return Plan1D(blocks=blocks, block_cols=cols,
                  row_offset=starts[:-1].astype(np.int64), panel_rows=h_pad,
                  shape=mat.shape, block_shape=(bm, bn))


# ---------------------------------------------------------------------------
# Device-side step functions (shard_map bodies close over nothing; all
# operands are explicit so the same functions lower in the dry-run).
# ---------------------------------------------------------------------------
def spmv_1d(mesh: Mesh, axis_names: Tuple[str, ...]):
    """Returns jit'd f(blocks, block_cols, x_panels) -> y_panels.

    blocks [P, nbr_l, K, bm, bn] sharded on axis 0 over `axis_names`;
    x_panels [P, panel_n] row-sharded segments of x (padded); output
    y_panels [P, panel_m] row-sharded. The all-gather of x is explicit.
    """
    ax = axis_names

    def local(blocks, block_cols, x_panels):
        # blocks [1, nbr_l, K, bm, bn]; x_panels [1, seg]
        xs = jax.lax.all_gather(x_panels[0], ax, tiled=True)   # [n_pad]
        bm, bn = blocks.shape[-2], blocks.shape[-1]
        x2d = xs.reshape(-1, bn, 1)
        y = ref.spmv_bell(blocks[0], block_cols[0], x2d)        # [nbr_l, bm, 1]
        return y.reshape(1, -1)

    f = shard_map(local, mesh=mesh,
                  in_specs=(P(ax), P(ax), P(ax)),
                  out_specs=P(ax))
    return jax.jit(f)


def spmv_2d(mesh: Mesh, row_axis: str = "data", col_axis: str = "model"):
    """Returns jit'd f(blocks, block_cols, x_segs) -> y_panels.

    blocks [D, M, nbr_l, K, bm, bn] sharded (row_axis, col_axis);
    x_segs [M, seg_n] sharded on col_axis (replicated over row_axis);
    y [D, panel_m] sharded on row_axis (replicated over col_axis).
    Comm: one psum (all-reduce) of the local y panel over col_axis.
    """

    def local(blocks, block_cols, x_segs):
        bm, bn = blocks.shape[-2], blocks.shape[-1]
        x2d = x_segs[0].reshape(-1, bn, 1)
        y = ref.spmv_bell(blocks[0, 0], block_cols[0, 0], x2d)  # [nbr_l, bm, 1]
        y = jax.lax.psum(y, col_axis)
        return y.reshape(1, -1)

    f = shard_map(local, mesh=mesh,
                  in_specs=(P(row_axis, col_axis), P(row_axis, col_axis),
                            P(col_axis)),
                  out_specs=P(row_axis))
    return jax.jit(f)


def plan_2d(mat: CSRMatrix, d: int, m_axis: int, bm: int = 8, bn: int = 128,
            balanced: bool = True):
    """Chop into d x m_axis bricks: nnz-balanced row panels, equal column
    segments (columns must align with x segmentation). Returns global arrays
    (blocks [D, M, nbr_l, K, bm, bn], block_cols, seg_n, panel_m)."""
    starts = (nnz_balanced_partition(mat, d) if balanced
              else static_partition(mat, d))
    seg_n = ((mat.n + m_axis - 1) // m_axis + bn - 1) // bn * bn
    heights = np.diff(starts)
    h_pad = ((int(heights.max()) + bm - 1) // bm) * bm
    nbr_l = h_pad // bm
    rp = mat.rowptr.astype(np.int64)
    bricks = []
    kmax = 1
    for p in range(d):
        r0, r1 = int(starts[p]), int(starts[p + 1])
        s, e = rp[r0], rp[r1]
        cols = mat.cols[s:e].astype(np.int64)
        rows = np.repeat(np.arange(r1 - r0), np.diff(rp[r0:r1 + 1]))
        row_bricks = []
        for q in range(m_axis):
            c0, c1 = q * seg_n, (q + 1) * seg_n
            keep = (cols >= c0) & (cols < c1)
            sub = CSRMatrix.from_coo(rows[keep], cols[keep] - c0,
                                     mat.vals[s:e][keep], (r1 - r0, seg_n))
            bell = to_block_ell(sub, bm, bn)
            kmax = max(kmax, bell.k)
            row_bricks.append(bell)
        bricks.append(row_bricks)
    blocks = np.zeros((d, m_axis, nbr_l, kmax, bm, bn), dtype=mat.vals.dtype)
    bcols = np.zeros((d, m_axis, nbr_l, kmax), dtype=np.int32)
    for p in range(d):
        for q in range(m_axis):
            b = bricks[p][q]
            blocks[p, q, :b.num_block_rows, :b.k] = b.blocks
            bcols[p, q, :b.num_block_rows, :b.k] = b.block_cols
    return blocks, bcols, seg_n, h_pad, starts


# ---------------------------------------------------------------------------
# Halo-exchange layout (the REORDERING-ENABLED communication primitive)
# ---------------------------------------------------------------------------
def plan_halo_1d(mat: CSRMatrix, num_devices: int, bm: int = 8, bn: int = 128):
    """1-D row panels where each panel's x window is its own slice plus a
    HALO of `halo` elements each side — legal only when the matrix
    bandwidth fits the halo, i.e. AFTER a bandwidth-reducing reordering
    (RCM). This is the paper's data-movement story as a distributed
    primitive: reordering changes the collective from all-gather
    (n*(P-1)/P bytes) to two nearest-neighbour permutes (2*halo bytes).

    Returns (blocks [P, nbr_l, K, bm, bn], block_cols [P, nbr_l, K],
    halo, panel_n) with block_cols RELATIVE to the panel's haloed window
    [r0 - halo, r1 + halo).
    """
    from ..sparse.metrics import bandwidth as _bandwidth

    assert mat.m % num_devices == 0, "equal panels required"
    panel_n = mat.m // num_devices
    bw = _bandwidth(mat)
    halo = ((bw + bn - 1) // bn) * bn
    if halo >= panel_n:
        raise ValueError(
            f"bandwidth {bw} too wide for halo exchange at P={num_devices} "
            f"(panel {panel_n}); reorder first (RCM) or use plan_1d")
    rp = mat.rowptr.astype(np.int64)
    panels = []
    kmax = 1
    win_n = panel_n + 2 * halo
    for p in range(num_devices):
        r0, r1 = p * panel_n, (p + 1) * panel_n
        s, e = rp[r0], rp[r1]
        cols = mat.cols[s:e].astype(np.int64) - (r0 - halo)  # window-relative
        assert cols.min() >= 0 and cols.max() < win_n, "bandwidth violated"
        rows = np.repeat(np.arange(r1 - r0), np.diff(rp[r0:r1 + 1]))
        sub = CSRMatrix.from_coo(rows, cols, mat.vals[s:e],
                                 (panel_n, win_n))
        bell = to_block_ell(sub, bm, bn)
        kmax = max(kmax, bell.k)
        panels.append(bell)
    nbr_l = (panel_n + bm - 1) // bm
    blocks = np.zeros((num_devices, nbr_l, kmax, bm, bn), dtype=mat.vals.dtype)
    bcols = np.zeros((num_devices, nbr_l, kmax), dtype=np.int32)
    for p, pnl in enumerate(panels):
        blocks[p, :pnl.num_block_rows, :pnl.k] = pnl.blocks
        bcols[p, :pnl.num_block_rows, :pnl.k] = pnl.block_cols
    return blocks, bcols, halo, panel_n


def spmv_halo_1d(mesh: Mesh, axis_names: Tuple[str, ...], halo: int):
    """Returns jit'd f(blocks, block_cols, x_panels) -> y_panels where the
    x window is assembled with two collective_permutes (ring neighbours)
    instead of an all-gather."""
    ax = axis_names if len(axis_names) > 1 else axis_names[0]
    # static device count from the mesh (jax.lax has no axis_size; the ring
    # permutation pairs must be concrete anyway)
    n_dev = int(np.prod([mesh.shape[a] for a in axis_names]))

    def local(blocks, block_cols, x_panels):
        x = x_panels[0]                          # [panel_n]
        axname = ax
        # my right edge -> right neighbour's left halo; and vice versa
        right_edge = x[-halo:]
        left_edge = x[:halo]
        fwd = [(i, (i + 1) % n_dev) for i in range(n_dev)]
        bwd = [((i + 1) % n_dev, i) for i in range(n_dev)]
        left_halo = jax.lax.ppermute(right_edge, axname, fwd)
        right_halo = jax.lax.ppermute(left_edge, axname, bwd)
        xw = jnp.concatenate([left_halo, x, right_halo])
        bm, bn = blocks.shape[-2], blocks.shape[-1]
        y = ref.spmv_bell(blocks[0], block_cols[0], xw.reshape(-1, bn, 1))
        return y.reshape(1, -1)

    f = shard_map(local, mesh=mesh,
                  in_specs=(P(ax), P(ax), P(ax)),
                  out_specs=P(ax))
    return jax.jit(f)
