"""Topology — the device-mesh axis of the Problem→Plan→Operator pipeline.

A Topology describes WHERE a plan executes: how many devices, and which
sharded layout (DESIGN.md "Topology-aware planning"):

  * "1d_rows"   — row panels over a flat mesh; x is row-sharded and is
                  either ALL-GATHERED each SpMV (the CG dataflow) or, when
                  a bandwidth-reducing scheme makes it legal, assembled by
                  two nearest-neighbour ring permutes (halo exchange).
  * "2d_panels" — rows over the "data" axis, columns over the "model"
                  axis; each device holds an (m/D x n/M) brick and only
                  its x segment; partial y is all-reduced over "model".

`Topology(devices=1)` is TRIVIAL: it plans, keys and builds exactly like
no topology at all (single-device caches never fork — the content key is
identical, asserted in tests/test_topology_plans.py).

`comm_model` is the plan-time cost model: for a candidate (scheme,
partition) it turns the structural metrics the paper uses to explain
parallel SpMV (load imbalance §6.1, cut volume / halo width — the
PaToH/METIS objectives) into modelled collective bytes per SpMV, so the
planner can trade gather traffic against halo exchanges against the 2-D
reduce. This module is numpy-only (plan-time code — core/registry.py's
jax-free rule).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from ..sparse import metrics

LAYOUTS = ("1d_rows", "2d_panels")


@dataclasses.dataclass(frozen=True)
class Topology:
    """devices — total device count; layout — one of LAYOUTS; mesh_shape —
    (rows,) for 1d_rows, (row_devices, col_devices) for 2d_panels
    (defaults: (devices,) and the most-square factoring)."""

    devices: int = 1
    layout: str = "1d_rows"
    mesh_shape: tuple = ()
    mesh_axes: tuple = ()

    def __post_init__(self):
        if self.devices < 1:
            raise ValueError(f"devices must be >= 1, got {self.devices}")
        if self.layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}, "
                             f"got {self.layout!r}")
        shape = tuple(int(s) for s in self.mesh_shape)
        if not shape:
            shape = ((self.devices,) if self.layout == "1d_rows"
                     else _square_factor(self.devices))
        naxes = 1 if self.layout == "1d_rows" else 2
        if len(shape) != naxes:
            raise ValueError(f"{self.layout} needs a {naxes}-axis "
                             f"mesh_shape, got {shape}")
        if int(np.prod(shape)) != self.devices:
            raise ValueError(f"mesh_shape {shape} does not factor "
                             f"devices={self.devices}")
        axes = tuple(self.mesh_axes) or (("data",) if naxes == 1
                                         else ("data", "model"))
        if len(axes) != naxes:
            raise ValueError(f"mesh_axes {axes} must name {naxes} axes")
        object.__setattr__(self, "mesh_shape", shape)
        object.__setattr__(self, "mesh_axes", axes)

    @property
    def trivial(self) -> bool:
        return self.devices == 1

    @property
    def row_devices(self) -> int:
        return self.mesh_shape[0]

    @property
    def col_devices(self) -> int:
        return self.mesh_shape[1] if len(self.mesh_shape) > 1 else 1

    def key_dict(self) -> dict:
        """The content-key-relevant coordinates (mesh_axes are naming,
        not placement — excluded, like profile names in cell keys)."""
        return {"devices": int(self.devices), "layout": self.layout,
                "mesh_shape": list(self.mesh_shape)}

    def to_json(self) -> dict:
        d = self.key_dict()
        d["mesh_axes"] = list(self.mesh_axes)
        return d

    @staticmethod
    def from_json(d: Optional[dict]) -> Optional["Topology"]:
        if not d:
            return None
        return Topology(devices=d["devices"], layout=d["layout"],
                        mesh_shape=tuple(d.get("mesh_shape", ())),
                        mesh_axes=tuple(d.get("mesh_axes", ())))


def _square_factor(n: int) -> tuple:
    """Most-square (rows, cols) factoring with rows >= cols."""
    c = int(math.isqrt(n))
    while c > 1 and n % c:
        c -= 1
    return (n // max(c, 1), max(c, 1))


def normalize(topology) -> Optional[Topology]:
    """None / trivial topologies collapse to None (the single-device
    pipeline); dicts are revived (Plan.from_json path)."""
    if topology is None:
        return None
    if isinstance(topology, dict):
        topology = Topology.from_json(topology)
    if not isinstance(topology, Topology):
        raise TypeError(f"topology must be a Topology, got "
                        f"{type(topology).__name__}")
    return None if topology.trivial else topology


def padded_panel_rows(panel_starts: np.ndarray, bm: int, bn: int,
                      col_devices: int = 1) -> int:
    """Uniform padded panel height: max panel height rounded up to
    lcm(bm, bn * col_devices) so block rows, the all-gathered x tiling,
    and (for 2d_panels) the x column segments all align at every panel
    boundary."""
    heights = np.diff(np.asarray(panel_starts, dtype=np.int64))
    bnc = bn * max(int(col_devices), 1)
    align = bm * bnc // math.gcd(bm, bnc)
    h = int(heights.max()) if heights.size else 0
    return max(((h + align - 1) // align) * align, align)


def comm_model(rmat, panel_starts: np.ndarray, topology: Topology,
               dtype_size: int, k: int, block_shape: tuple) -> dict:
    """Modelled collective bytes per SpMM for one (scheme, partition)
    candidate, from the partition-quality metrics (metrics.py):

      1d_rows all-gather : n * (P-1)/P * dsize * k      per device
      1d_rows halo       : 2 * halo * dsize * k         per device,
        legal only when every out-of-panel column lies within the
        adjacent panel even after padding (halo_pad <= h_pad) — i.e.
        AFTER a bandwidth-reducing reordering; this is the paper's
        data-movement story as a collective-schedule choice.
      2d_panels psum     : 2 * h_pad * (M-1)/M * dsize * k  per device
        (ring all-reduce of the partial y panel over the model axis).

    Also records cut_volume (what hypergraph partitioning minimizes —
    reported so campaigns can correlate cut with measured comm) and the
    nnz load imbalance of the row split.
    """
    starts = np.asarray(panel_starts, dtype=np.int64)
    heights = np.diff(starts)
    bm, bn = block_shape
    h_pad = padded_panel_rows(starts, bm, bn,
                              col_devices=topology.col_devices)
    li = metrics.load_imbalance(rmat, starts)
    cut = metrics.cut_volume(rmat, starts)
    hw = metrics.halo_width(rmat, starts)
    k = max(int(k), 1)
    out = {"li": float(li), "cut_volume": int(cut), "halo_width": int(hw),
           "h_pad": int(h_pad)}
    if topology.layout == "1d_rows":
        p = topology.row_devices
        n_pad = p * h_pad
        gather = n_pad * (p - 1) / p * dtype_size * k
        # padding inflates the halo by (h_pad - height) of the shortest
        # neighbour; round to the bn tile the exchange moves
        hmin = int(heights.min()) if heights.size else 0
        halo_pad = hw + (h_pad - hmin)
        halo_pad = ((halo_pad + bn - 1) // bn) * bn
        halo_legal = p > 1 and hw <= hmin and halo_pad <= h_pad
        halo_bytes = 2 * halo_pad * dtype_size * k
        if halo_legal and halo_bytes < gather:
            out.update(schedule="halo", halo=int(halo_pad),
                       bytes_per_spmv=float(halo_bytes))
        else:
            out.update(schedule="all_gather", halo=0,
                       bytes_per_spmv=float(gather))
        out["gather_bytes"] = float(gather)
        out["halo_bytes"] = float(halo_bytes) if halo_legal else None
    else:
        mm = topology.col_devices
        psum = 2 * h_pad * (mm - 1) / mm * dtype_size * k
        out.update(schedule="psum", halo=0, bytes_per_spmv=float(psum))
    return out
