"""Pure-jnp SpMV oracles — the correctness references for every engine.

These are deliberately straight-line jnp (no pallas, no shard_map); each
optimized engine (ops.py, kernels/, distributed.py) is tested allclose
against these, which in turn are tested against the numpy CSRMatrix.spmv.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_dense(a_dense: jax.Array, x: jax.Array) -> jax.Array:
    return a_dense @ x


def spmv_csr(row_ids: jax.Array, cols: jax.Array, vals: jax.Array,
             x: jax.Array, m: int) -> jax.Array:
    """CSR-as-COO gather + segment-sum (paper Listing 4 semantics).

    row_ids: int32[nnz] (row of each stored element, nondecreasing)
    """
    prod = vals * x[cols]
    return jax.ops.segment_sum(prod, row_ids, num_segments=m,
                               indices_are_sorted=True)


def spmm_csr(row_ids: jax.Array, cols: jax.Array, vals: jax.Array,
             x: jax.Array, m: int) -> jax.Array:
    """Batched CSR gather + segment-sum: x [n, k] -> y [m, k].

    Same accumulation order per column as spmv_csr — the vectorized k axis
    rides along each gathered element, so the matrix stream (vals/cols/
    row_ids) is paid once for all k vectors.
    """
    prod = vals[:, None] * x[cols]                   # [nnz, k]
    return jax.ops.segment_sum(prod, row_ids, num_segments=m,
                               indices_are_sorted=True)


def spmv_ell(ell_cols: jax.Array, ell_vals: jax.Array, x: jax.Array) -> jax.Array:
    """ELLPACK: ell_cols/vals [m, K], padding has val 0 (col arbitrary)."""
    return jnp.sum(ell_vals * x[ell_cols], axis=1)


def spmm_ell(ell_cols: jax.Array, ell_vals: jax.Array, x: jax.Array) -> jax.Array:
    """Batched ELLPACK: x [n, k] -> y [m, k] (one pass over the pads).

    Accumulates at >= the operator dtype (f32 floor), so an f64 operator's
    matmul keeps f64 accuracy like its SpMV __call__ does.

    Peak memory is the gathered [m, K, k] intermediate — the same
    footprint class as spmm_csr's [nnz, k] in ELL's intended near-uniform
    regime (K ~ mean row nnz); on padding-inflated matrices the tuner
    never picks ELL in the first place.
    """
    out_dtype = jnp.promote_types(ell_vals.dtype, x.dtype)   # == __call__'s
    acc = jnp.promote_types(out_dtype, jnp.float32)
    gathered = x[ell_cols]                           # [m, K, k]
    return jnp.einsum("mj,mjv->mv", ell_vals, gathered,
                      preferred_element_type=acc).astype(out_dtype)


def spmv_bell(blocks: jax.Array, block_cols: jax.Array, x2d: jax.Array) -> jax.Array:
    """Block-ELL: blocks [nbr, K, bm, bn]; block_cols [nbr, K];
    x2d [ncb, bn, nv] (x padded & reshaped). Returns y [nbr, bm, nv].

    Padding blocks are all-zero so their contribution vanishes regardless of
    block_cols padding value.
    """
    gathered = x2d[block_cols]                       # [nbr, K, bn, nv]
    return jnp.einsum("rkij,rkjv->riv", blocks, gathered,
                      preferred_element_type=jnp.float32).astype(x2d.dtype)


def spmv_sell(chunk_vals: jax.Array, chunk_cols: jax.Array,
              chunk_slice: jax.Array, x: jax.Array,
              num_slices: int) -> jax.Array:
    """SELL-C-σ: chunk_vals/cols [T, C, W]; chunk_slice int32[T]
    nondecreasing; x [n_pad, nv]. Returns y [S, C, nv] in slice order
    (caller un-permutes via SellCS.inv_perm).

    Padding slots have val 0 (col 0), so they add exactly 0.
    """
    gathered = x[chunk_cols]                         # [T, C, W, nv]
    partial = jnp.einsum("tcw,tcwv->tcv", chunk_vals, gathered,
                         preferred_element_type=jnp.float32)
    y = jax.ops.segment_sum(partial, chunk_slice, num_segments=num_slices,
                            indices_are_sorted=True)
    return y.astype(x.dtype)


def spmv_bcsr(blocks: jax.Array, block_rows: jax.Array, block_cols: jax.Array,
              x2d: jax.Array, num_block_rows: int) -> jax.Array:
    """BCSR: blocks [T, bm, bn], block_rows/cols [T]. Returns [nbr, bm, nv]."""
    gathered = x2d[block_cols]                       # [T, bn, nv]
    partial = jnp.einsum("tij,tjv->tiv", blocks, gathered,
                         preferred_element_type=jnp.float32)
    y = jax.ops.segment_sum(partial, block_rows, num_segments=num_block_rows,
                            indices_are_sorted=True)
    return y.astype(x2d.dtype)
