"""Incremental structure deltas — the amortization tier between
`Plan.rebuild` (values only) and a full replan (new structure).

The paper's plan-reuse economics (OSKI-style tuning pays off only when a
decision is reused) break down the moment a workload mutates its sparsity
pattern: `WorkloadSession` and the serving layer both fall back to a full
`plan()` — reorder + feature scan + tuner scoring — even when the change
is a handful of nonzeros. `StructureDelta` names that change explicitly:

    delta = StructureDelta(add_rows=[3], add_cols=[7], add_vals=[1.0],
                           del_rows=[0], del_cols=[2])
    pl2 = pl.apply_delta(delta)        # frozen scheme/engine/perm reused

`Plan.apply_delta` (plan.py, delegating here) keeps the frozen tuning
decision and permutation when the delta is SMALL — bounded nnz churn and
bounded bandwidth growth, the two axes along which a stale decision goes
wrong (churn moves the row-nnz spread the engine grid was scored on;
bandwidth growth breaks halo-schedule legality and SELL locality) — and
refuses (`DeltaTooLarge`) past either threshold so the caller replans.
Every outcome is counted: `delta.applies` / `delta.fallbacks`, and each
apply runs under a `plan.delta` span.

Appended rows (`append_rows`) extend the permutation with identity tail
positions — a new row has no structural history, so placing it last is
the only choice consistent with the frozen perm. Sharded plans accept
same-shape deltas only (the panel split indexes a fixed row count); their
apply reuses partitioner + panel_starts + collective schedule, so the
"replan" left to pay is array repacking, never a new search.

`delta_between(old, new)` recovers a delta from two matrices — what the
router and `WorkloadSession` use when the caller hands them a whole new
matrix instead of an explicit delta.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import numpy as np

from ... import obs
from ..sparse.csr import CSRMatrix

# Refusal thresholds (module-level so tests and callers can reference the
# exact bounds): churn is (added + deleted) / old nnz, growth is
# new_bandwidth / max(old_bandwidth, 1).
MAX_CHURN = 0.15
MAX_BW_GROWTH = 1.5


class DeltaTooLarge(ValueError):
    """apply_delta refused: the delta exceeds the churn or bandwidth
    threshold, so the frozen tuning decision can no longer be trusted —
    replan instead. `delta.fallbacks` was already incremented."""


class BadDelta(ValueError):
    """Malformed delta: out-of-range indices, deleting an entry that does
    not exist, or adding an entry that already does."""


def _as_idx(a) -> np.ndarray:
    return np.asarray([] if a is None else a, dtype=np.int64).ravel()


@dataclasses.dataclass(frozen=True)
class StructureDelta:
    """A sparse edit script against one CSR structure.

    append_rows — rows appended at the bottom (and, for square matrices,
                  columns appended at the right: the pipeline's sharded
                  and CG paths require square operands, so appending
                  grows both dimensions together).
    add_*       — entries to insert; add_rows may index appended rows.
    del_*       — (row, col) of existing entries to remove.
    """

    append_rows: int = 0
    add_rows: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))
    add_cols: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))
    add_vals: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.float64))
    del_rows: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))
    del_cols: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))

    def __post_init__(self):
        object.__setattr__(self, "add_rows", _as_idx(self.add_rows))
        object.__setattr__(self, "add_cols", _as_idx(self.add_cols))
        object.__setattr__(self, "add_vals",
                           np.asarray(self.add_vals).ravel())
        object.__setattr__(self, "del_rows", _as_idx(self.del_rows))
        object.__setattr__(self, "del_cols", _as_idx(self.del_cols))
        if not (self.add_rows.size == self.add_cols.size
                == self.add_vals.size):
            raise BadDelta("add_rows/add_cols/add_vals lengths differ")
        if self.del_rows.size != self.del_cols.size:
            raise BadDelta("del_rows/del_cols lengths differ")
        if self.append_rows < 0:
            raise BadDelta("append_rows must be >= 0")

    @property
    def is_empty(self) -> bool:
        return (self.append_rows == 0 and self.add_rows.size == 0
                and self.del_rows.size == 0)

    @property
    def churn_nnz(self) -> int:
        """Edited entries — what the churn threshold is measured on."""
        return int(self.add_rows.size + self.del_rows.size)

    def signature(self) -> str:
        """Content hash of the edit script (chains plan keys: the same
        base plan edited by the same delta addresses one store entry)."""
        h = hashlib.sha1()
        h.update(f"append:{self.append_rows}".encode())
        for a in (self.add_rows, self.add_cols, self.add_vals,
                  self.del_rows, self.del_cols):
            h.update(np.ascontiguousarray(a).tobytes())
        return h.hexdigest()[:20]

    def rows_touched(self, m: Optional[int] = None) -> np.ndarray:
        """Sorted unique row indices the edit touches (rows appended past
        `m` excluded when given) — what a shard-scoped replan uses to
        find the affected panels."""
        touched = np.concatenate([self.add_rows, self.del_rows])
        if m is not None:
            touched = touched[touched < m]
        return np.unique(touched) if touched.size else touched

    # -- application -------------------------------------------------------
    def apply_to(self, mat: CSRMatrix) -> CSRMatrix:
        """The edited matrix (pure numpy splice; surviving entries keep
        their values). Validates every edit: deleting a missing entry or
        adding a present one raises BadDelta — a silent no-op there would
        desynchronize the caller's idea of the structure from ours."""
        m, n = mat.shape
        m2 = m + self.append_rows
        n2 = n + self.append_rows if m == n else n
        if self.add_rows.size and (self.add_rows.min() < 0
                                   or self.add_rows.max() >= m2):
            raise BadDelta(f"add_rows out of range for m={m2}")
        if self.add_cols.size and (self.add_cols.min() < 0
                                   or self.add_cols.max() >= n2):
            raise BadDelta(f"add_cols out of range for n={n2}")
        if self.del_rows.size and (self.del_rows.min() < 0
                                   or self.del_rows.max() >= m):
            raise BadDelta(f"del_rows out of range for m={m}")
        rows = np.repeat(np.arange(m, dtype=np.int64),
                         np.diff(mat.rowptr.astype(np.int64)))
        cols = mat.cols.astype(np.int64)
        vals = mat.vals
        key = rows * n2 + cols
        if self.del_rows.size:
            dkey = self.del_rows * n2 + self.del_cols
            if np.unique(dkey).size != dkey.size:
                raise BadDelta("duplicate delete entries")
            hit = np.isin(dkey, key)
            if not hit.all():
                miss = int(np.argmin(hit))
                raise BadDelta(
                    f"delete targets absent entry "
                    f"({int(self.del_rows[miss])}, "
                    f"{int(self.del_cols[miss])})")
            keep = ~np.isin(key, dkey)
            rows, cols, vals, key = (rows[keep], cols[keep], vals[keep],
                                     key[keep])
        if self.add_rows.size:
            akey = self.add_rows * n2 + self.add_cols
            if np.unique(akey).size != akey.size:
                raise BadDelta("duplicate add entries")
            if np.isin(akey, key).any():
                clash = int(np.argmax(np.isin(akey, key)))
                raise BadDelta(
                    f"add collides with existing entry "
                    f"({int(self.add_rows[clash])}, "
                    f"{int(self.add_cols[clash])})")
            rows = np.concatenate([rows, self.add_rows])
            cols = np.concatenate([cols, self.add_cols])
            vals = np.concatenate(
                [vals, self.add_vals.astype(vals.dtype, copy=False)])
        return CSRMatrix.from_coo(rows, cols, vals, (m2, n2))

    def churn(self, mat: CSRMatrix) -> float:
        """Fraction of the OLD matrix's nonzeros this delta edits."""
        return self.churn_nnz / max(mat.nnz, 1)


def delta_between(old: CSRMatrix, new: CSRMatrix
                  ) -> Optional[StructureDelta]:
    """Recover the StructureDelta turning `old`'s structure into `new`'s,
    or None when no delta can express it (shrunk shape, or column growth
    without matching row growth). Surviving entries keep NEW values only
    if they are unchanged — a value change on a surviving entry is left
    to `Plan.rebuild` (the caller applies the delta, then rebuilds with
    the new value array; see WorkloadSession)."""
    mo, no = old.shape
    mn, nn = new.shape
    append = mn - mo
    if append < 0 or nn < no:
        return None
    if mo == no and (mn != nn or nn - no != append):
        return None                  # square must stay square, grown alike
    if mo != no and nn != no:
        return None
    rows_o = np.repeat(np.arange(mo, dtype=np.int64),
                       np.diff(old.rowptr.astype(np.int64)))
    rows_n = np.repeat(np.arange(mn, dtype=np.int64),
                       np.diff(new.rowptr.astype(np.int64)))
    ko = rows_o * nn + old.cols.astype(np.int64)
    kn = rows_n * nn + new.cols.astype(np.int64)
    add = ~np.isin(kn, ko)
    dele = ~np.isin(ko, kn)
    return StructureDelta(
        append_rows=append,
        add_rows=rows_n[add], add_cols=new.cols.astype(np.int64)[add],
        add_vals=new.vals[add],
        del_rows=rows_o[dele], del_cols=old.cols.astype(np.int64)[dele])


def _bandwidth(mat: CSRMatrix) -> int:
    from ..sparse.metrics import bandwidth

    return int(bandwidth(mat))


def apply_delta(plan, delta: StructureDelta, *,
                max_churn: float = MAX_CHURN,
                max_bw_growth: float = MAX_BW_GROWTH):
    """The engine behind `Plan.apply_delta` — see plan.py for the public
    contract. Returns a NEW Plan (the input plan is never mutated);
    returns the input plan unchanged for an empty delta (no counters
    move); raises DeltaTooLarge (counting `delta.fallbacks`) past a
    threshold and BadDelta/ValueError on malformed input."""
    import dataclasses as _dc

    if delta.is_empty:
        return plan
    mat = plan._mat
    if mat is None:
        raise ValueError("plan has no attached matrix; pass mat= to "
                         "Plan.load before apply_delta")
    if plan.topology is not None and delta.append_rows:
        obs.counter("delta.fallbacks").inc()
        raise DeltaTooLarge(
            "sharded plans accept same-shape deltas only (the panel "
            "split indexes a fixed row count); replan instead")
    churn = delta.churn(mat)
    if churn > max_churn:
        obs.counter("delta.fallbacks").inc()
        raise DeltaTooLarge(
            f"delta edits {churn:.1%} of nnz (> {max_churn:.0%}); the "
            f"frozen tuning decision is stale — replan instead")
    with obs.span("plan.delta", key=plan.key, scheme=plan.scheme,
                  appended=int(delta.append_rows),
                  edited=delta.churn_nnz) as sp:
        import time

        t0 = time.perf_counter()
        new_mat = delta.apply_to(mat)
        bw_old = max(_bandwidth(mat), 1)
        bw_new = _bandwidth(new_mat)
        growth = bw_new / bw_old
        if growth > max_bw_growth:
            obs.counter("delta.fallbacks").inc()
            sp.set(fallback=True)
            raise DeltaTooLarge(
                f"bandwidth grew {growth:.2f}x (> {max_bw_growth:.2f}x); "
                f"the frozen permutation no longer localizes the "
                f"structure — replan instead")
        perm = plan.perm
        if perm is not None and delta.append_rows:
            tail = np.arange(mat.shape[0], new_mat.shape[0], dtype=np.int64)
            perm = np.concatenate([np.asarray(perm, np.int64), tail])
        key = hashlib.sha1(
            f"{plan.key}:delta:{delta.signature()}".encode()
        ).hexdigest()[:20]
        new_plan = _dc.replace(
            plan, key=key, mat_shape=tuple(new_mat.shape),
            mat_nnz=new_mat.nnz, perm=perm, cache_hit=False,
            reorder_ms=0.0, tune_ms=0.0,
            plan_ms=(time.perf_counter() - t0) * 1e3,
            _mat=new_mat, _rmat=None, _op_state=None)
        obs.counter("delta.applies").inc()
        sp.set(churn=round(churn, 4), bw_growth=round(growth, 3),
               key_out=key)
        return new_plan
