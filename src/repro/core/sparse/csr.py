"""Host-side CSR sparse-matrix container.

This mirrors the C struct used throughout the paper (rowPtr / cols / values)
and is the plan-time representation every other component consumes:
reorderers permute it, partitioners split it, and the device formats
(Block-ELL / BCSR, see bell.py / bcsr.py) are built from it.

All arrays are numpy (host). Device/JAX formats are separate classes so that
nothing here ever touches jax device state (important: the dry-run must be
able to set XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    """Compressed Sparse Row matrix (square or rectangular).

    rowptr: int32[m + 1]
    cols:   int32[nnz]   column index of each stored element, row-major
    vals:   float{32,64}[nnz]
    shape:  (m, n)
    """

    rowptr: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    shape: Tuple[int, int]

    # -- construction ------------------------------------------------------
    @staticmethod
    def from_coo(rows, cols, vals, shape) -> "CSRMatrix":
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        vals = np.asarray(vals)
        m, n = shape
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        # deduplicate (sum duplicates, scipy semantics)
        if rows.size:
            key = rows * n + cols
            uniq, inv = np.unique(key, return_inverse=True)
            if uniq.size != rows.size:
                summed = np.zeros(uniq.size, dtype=vals.dtype)
                np.add.at(summed, inv, vals)
                rows = (uniq // n).astype(np.int64)
                cols = (uniq % n).astype(np.int64)
                vals = summed
        rowptr = np.zeros(m + 1, dtype=np.int64)
        np.add.at(rowptr, rows + 1, 1)
        rowptr = np.cumsum(rowptr)
        return CSRMatrix(
            rowptr=rowptr.astype(np.int32),
            cols=cols.astype(np.int32),
            vals=vals,
            shape=(int(m), int(n)),
        )

    @staticmethod
    def from_dense(dense: np.ndarray) -> "CSRMatrix":
        dense = np.asarray(dense)
        rows, cols = np.nonzero(dense)
        return CSRMatrix.from_coo(rows, cols, dense[rows, cols], dense.shape)

    @staticmethod
    def from_scipy(sp) -> "CSRMatrix":
        sp = sp.tocsr()
        sp.sum_duplicates()
        return CSRMatrix(
            rowptr=sp.indptr.astype(np.int32),
            cols=sp.indices.astype(np.int32),
            vals=sp.data,
            shape=tuple(sp.shape),
        )

    # -- basic properties --------------------------------------------------
    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(self.cols.shape[0])

    def row_nnz(self) -> np.ndarray:
        """int64[m] — nonzeros per row (the paper's per-row workload)."""
        return np.diff(self.rowptr.astype(np.int64))

    # -- conversions -------------------------------------------------------
    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=self.vals.dtype)
        r = np.repeat(np.arange(self.m), self.row_nnz())
        out[r, self.cols] = self.vals
        return out

    def to_scipy(self):
        import scipy.sparse as sps

        return sps.csr_matrix(
            (self.vals, self.cols, self.rowptr), shape=self.shape
        )

    # -- operations --------------------------------------------------------
    def permute(self, row_perm: np.ndarray, col_perm: np.ndarray | None = None) -> "CSRMatrix":
        """Symmetric (or general) permutation: B = P A Q^T.

        row_perm[i] = original row placed at new position i (gather
        semantics). When col_perm is None the same permutation is applied to
        columns — the paper's symmetric row/column reordering, which keeps a
        symmetric matrix symmetric and is what every scheme in §2.1 emits.
        """
        row_perm = np.asarray(row_perm, dtype=np.int64)
        if col_perm is None:
            col_perm = row_perm
        m, n = self.shape
        assert row_perm.shape == (m,) and col_perm.shape == (n,)
        # inverse permutation for the column relabel:
        inv_col = np.empty(n, dtype=np.int64)
        inv_col[col_perm] = np.arange(n)

        counts = self.row_nnz()[row_perm]
        new_rowptr = np.zeros(m + 1, dtype=np.int64)
        np.cumsum(counts, out=new_rowptr[1:])
        rp = self.rowptr.astype(np.int64)
        # Vectorized ragged gather: element j of new row i comes from
        # rp[row_perm[i]] + j. Then one lexsort restores per-row column order.
        offs = np.arange(self.nnz, dtype=np.int64) - np.repeat(new_rowptr[:-1], counts)
        src = np.repeat(rp[row_perm], counts) + offs
        new_rows = np.repeat(np.arange(m, dtype=np.int64), counts)
        new_cols = inv_col[self.cols[src]].astype(np.int32)
        new_vals = self.vals[src]
        order = np.lexsort((new_cols, new_rows))
        new_cols = new_cols[order]
        new_vals = new_vals[order]
        return CSRMatrix(
            rowptr=new_rowptr.astype(np.int32),
            cols=new_cols,
            vals=new_vals,
            shape=self.shape,
        )

    def transpose(self) -> "CSRMatrix":
        r = np.repeat(np.arange(self.m), self.row_nnz())
        return CSRMatrix.from_coo(self.cols, r, self.vals, (self.n, self.m))

    def is_symmetric(self, tol: float = 0.0) -> bool:
        t = self.transpose()
        if not np.array_equal(t.rowptr, self.rowptr):
            return False
        if not np.array_equal(t.cols, self.cols):
            return False
        return bool(np.allclose(t.vals, self.vals, atol=tol, rtol=0))

    def spmv(self, x: np.ndarray) -> np.ndarray:
        """Numpy oracle: y = A @ x (paper Listing 4, sequential)."""
        y = np.zeros(self.m, dtype=np.result_type(self.vals, x))
        rp = self.rowptr.astype(np.int64)
        # vectorized segment-sum
        prod = self.vals * x[self.cols]
        np.add.at(y, np.repeat(np.arange(self.m), self.row_nnz()), prod)
        return y

    def astype(self, dtype) -> "CSRMatrix":
        return dataclasses.replace(self, vals=self.vals.astype(dtype))
