"""Structural diagnostics from the paper (§2, §6) — all plan-time numpy.

These are the quantities the paper uses to *explain* SpMV performance:
  * nnz load imbalance (paper Eq. in §6.1)      -> load_imbalance()
  * matrix bandwidth / profile (RCM's target)   -> bandwidth(), profile()
  * cache-line / block locality proxies         -> distinct_col_blocks(),
                                                   block_fill_ratio()
  * partition communication volume (cut)        -> cut_volume()

block_fill_ratio() is the TPU adaptation: on an MXU-based device the analogue
of "x[col] hits L1" is "the nnz lands in an already-materialized dense tile".
"""
from __future__ import annotations

import numpy as np

from .csr import CSRMatrix


# --------------------------------------------------------------------------
# Load imbalance (paper §6.1)
# --------------------------------------------------------------------------
def panel_loads(mat: CSRMatrix, panel_starts: np.ndarray) -> np.ndarray:
    """nnz assigned to each row panel. panel_starts: int[P+1] row offsets."""
    rp = mat.rowptr.astype(np.int64)
    return rp[panel_starts[1:]] - rp[panel_starts[:-1]]


def load_imbalance(mat: CSRMatrix, panel_starts: np.ndarray) -> float:
    """LI = max_load / fair_load, fair_load = total_nnz / P (paper §6.1)."""
    loads = panel_loads(mat, panel_starts)
    p = len(panel_starts) - 1
    fair = mat.nnz / max(p, 1)
    if fair == 0:
        return 1.0
    return float(loads.max() / fair)


def static_block_panels(m: int, p: int) -> np.ndarray:
    """Default OpenMP static schedule: one maximal contiguous chunk per
    processor (paper §3.2). Returns int[P+1] row offsets."""
    base, rem = divmod(m, p)
    sizes = np.full(p, base, dtype=np.int64)
    sizes[:rem] += 1
    return np.concatenate([[0], np.cumsum(sizes)])


# --------------------------------------------------------------------------
# Bandwidth / profile (RCM's objective)
# --------------------------------------------------------------------------
def bandwidth(mat: CSRMatrix) -> int:
    """max_i max_{j: a_ij != 0} |i - j|."""
    if mat.nnz == 0:
        return 0
    r = np.repeat(np.arange(mat.m), mat.row_nnz())
    return int(np.abs(r - mat.cols.astype(np.int64)).max())


def profile(mat: CSRMatrix) -> int:
    """sum_i (i - min_col(i)) over the lower triangle — the 'envelope'."""
    total = 0
    rp = mat.rowptr.astype(np.int64)
    nnz_rows = np.flatnonzero(np.diff(rp) > 0)
    for i in nnz_rows:
        cmin = mat.cols[rp[i] : rp[i + 1]].min()
        if cmin < i:
            total += int(i - cmin)
    return total


def avg_row_bandwidth(mat: CSRMatrix) -> float:
    """Mean |i - j| over nonzeros — a smoother locality proxy than max."""
    if mat.nnz == 0:
        return 0.0
    r = np.repeat(np.arange(mat.m), mat.row_nnz())
    return float(np.abs(r - mat.cols.astype(np.int64)).mean())


# --------------------------------------------------------------------------
# TPU tile locality (hardware adaptation, DESIGN.md §3)
# --------------------------------------------------------------------------
def distinct_col_blocks(mat: CSRMatrix, panel_starts: np.ndarray, block_n: int) -> np.ndarray:
    """Per panel: number of distinct column blocks of width block_n touched.

    TPU analogue of 'distinct cache lines of x touched per core': each
    distinct block is one HBM->VMEM transfer of an x tile in the Pallas
    kernel. Lower = better data movement (what RCM improves).
    """
    rp = mat.rowptr.astype(np.int64)
    out = np.zeros(len(panel_starts) - 1, dtype=np.int64)
    blocks = mat.cols.astype(np.int64) // block_n
    for p in range(len(panel_starts) - 1):
        s, e = rp[panel_starts[p]], rp[panel_starts[p + 1]]
        out[p] = np.unique(blocks[s:e]).size
    return out


def block_fill_ratio(mat: CSRMatrix, block_m: int, block_n: int) -> float:
    """nnz / (num_nonempty_blocks * block_m * block_n).

    Fraction of useful work when the matrix is tiled into dense
    block_m x block_n 'MXU bricks'. 1.0 = perfectly dense blocks.
    """
    if mat.nnz == 0:
        return 1.0
    r = np.repeat(np.arange(mat.m), mat.row_nnz()).astype(np.int64)
    c = mat.cols.astype(np.int64)
    keys = (r // block_m) * ((mat.n + block_n - 1) // block_n) + (c // block_n)
    nblocks = np.unique(keys).size
    return float(mat.nnz / (nblocks * block_m * block_n))


def num_nonempty_blocks(mat: CSRMatrix, block_m: int, block_n: int) -> int:
    if mat.nnz == 0:
        return 0
    r = np.repeat(np.arange(mat.m), mat.row_nnz()).astype(np.int64)
    c = mat.cols.astype(np.int64)
    keys = (r // block_m) * ((mat.n + block_n - 1) // block_n) + (c // block_n)
    return int(np.unique(keys).size)


# --------------------------------------------------------------------------
# Partition quality (distributed setting; PaToH/METIS objective)
# --------------------------------------------------------------------------
def cut_volume(mat: CSRMatrix, panel_starts: np.ndarray) -> int:
    """Communication volume of a 1-D row partition with x partitioned
    conformally: nnz whose column lives in a different panel than the row.

    This is what hypergraph partitioning minimizes and what turns into
    collective bytes in the distributed SpMV.
    """
    m = mat.m
    owner = np.zeros(m, dtype=np.int64)
    for p in range(len(panel_starts) - 1):
        owner[panel_starts[p] : panel_starts[p + 1]] = p
    r = np.repeat(np.arange(m), mat.row_nnz()).astype(np.int64)
    c = mat.cols.astype(np.int64)
    return int(np.count_nonzero(owner[r] != owner[c]))


def halo_width(mat: CSRMatrix, panel_starts: np.ndarray) -> int:
    """Max distance a panel must reach outside its own x range.

    For a bandwidth-reduced (RCM) matrix this equals the bandwidth, and it
    bounds the halo-exchange size of the distributed SpMV.
    """
    rp = mat.rowptr.astype(np.int64)
    worst = 0
    for p in range(len(panel_starts) - 1):
        r0, r1 = panel_starts[p], panel_starts[p + 1]
        s, e = rp[r0], rp[r1]
        if e > s:
            seg = mat.cols[s:e].astype(np.int64)
            worst = max(worst, int(max(r0 - seg.min(), seg.max() - (r1 - 1), 0)))
    return worst


def summary(mat: CSRMatrix, p: int = 8, block: int = 128) -> dict:
    panels = static_block_panels(mat.m, p)
    return {
        "m": mat.m,
        "nnz": mat.nnz,
        "bandwidth": bandwidth(mat),
        "avg_row_bandwidth": avg_row_bandwidth(mat),
        "load_imbalance": load_imbalance(mat, panels),
        "block_fill_ratio": block_fill_ratio(mat, 8, block),
        "cut_volume": cut_volume(mat, panels),
    }
