"""Structural diagnostics from the paper (§2, §6) — all plan-time numpy.

These are the quantities the paper uses to *explain* SpMV performance:
  * nnz load imbalance (paper Eq. in §6.1)      -> load_imbalance()
  * matrix bandwidth / profile (RCM's target)   -> bandwidth(), profile()
  * cache-line / block locality proxies         -> distinct_col_blocks(),
                                                   block_fill_ratio()
  * partition communication volume (cut)        -> cut_volume()

block_fill_ratio() is the TPU adaptation: on an MXU-based device the analogue
of "x[col] hits L1" is "the nnz lands in an already-materialized dense tile".
"""
from __future__ import annotations

import numpy as np

from .csr import CSRMatrix


# --------------------------------------------------------------------------
# Load imbalance (paper §6.1)
# --------------------------------------------------------------------------
def panel_loads(mat: CSRMatrix, panel_starts: np.ndarray) -> np.ndarray:
    """nnz assigned to each row panel. panel_starts: int[P+1] row offsets."""
    rp = mat.rowptr.astype(np.int64)
    return rp[panel_starts[1:]] - rp[panel_starts[:-1]]


def load_imbalance(mat: CSRMatrix, panel_starts: np.ndarray) -> float:
    """LI = max_load / fair_load, fair_load = total_nnz / P (paper §6.1)."""
    loads = panel_loads(mat, panel_starts)
    p = len(panel_starts) - 1
    fair = mat.nnz / max(p, 1)
    if fair == 0:
        return 1.0
    return float(loads.max() / fair)


def static_block_panels(m: int, p: int) -> np.ndarray:
    """Default OpenMP static schedule: one maximal contiguous chunk per
    processor (paper §3.2). Returns int[P+1] row offsets."""
    base, rem = divmod(m, p)
    sizes = np.full(p, base, dtype=np.int64)
    sizes[:rem] += 1
    return np.concatenate([[0], np.cumsum(sizes)])


# --------------------------------------------------------------------------
# Bandwidth / profile (RCM's objective)
# --------------------------------------------------------------------------
def bandwidth(mat: CSRMatrix) -> int:
    """max_i max_{j: a_ij != 0} |i - j|."""
    if mat.nnz == 0:
        return 0
    r = np.repeat(np.arange(mat.m), mat.row_nnz())
    return int(np.abs(r - mat.cols.astype(np.int64)).max())


def profile(mat: CSRMatrix) -> int:
    """sum_i (i - min_col(i)) over the lower triangle — the 'envelope'.

    Vectorized per-row minima via ufunc.reduceat: the segment from a
    nonempty row's rowptr to the NEXT nonempty row's rowptr is exactly that
    row's elements (empty rows in between contribute none), so reduceat
    over the nonempty starts gives every row min in one pass.
    """
    rp = mat.rowptr.astype(np.int64)
    nnz_rows = np.flatnonzero(np.diff(rp) > 0)
    if nnz_rows.size == 0:
        return 0
    cmin = np.minimum.reduceat(mat.cols, rp[nnz_rows]).astype(np.int64)
    d = nnz_rows - cmin
    return int(d[d > 0].sum())


def avg_row_bandwidth(mat: CSRMatrix) -> float:
    """Mean |i - j| over nonzeros — a smoother locality proxy than max."""
    if mat.nnz == 0:
        return 0.0
    r = np.repeat(np.arange(mat.m), mat.row_nnz())
    return float(np.abs(r - mat.cols.astype(np.int64)).mean())


# --------------------------------------------------------------------------
# TPU tile locality (hardware adaptation, DESIGN.md §3)
# --------------------------------------------------------------------------
def distinct_col_blocks(mat: CSRMatrix, panel_starts: np.ndarray, block_n: int) -> np.ndarray:
    """Per panel: number of distinct column blocks of width block_n touched.

    TPU analogue of 'distinct cache lines of x touched per core': each
    distinct block is one HBM->VMEM transfer of an x tile in the Pallas
    kernel. Lower = better data movement (what RCM improves).
    """
    rp = mat.rowptr.astype(np.int64)
    p = len(panel_starts) - 1
    if mat.nnz == 0 or p == 0:
        return np.zeros(p, dtype=np.int64)
    blocks = mat.cols.astype(np.int64) // block_n
    bounds = rp[np.asarray(panel_starts, dtype=np.int64)]   # [P+1] nnz offsets
    # panel of each in-panel nonzero (linear repeat over segment lengths,
    # same construction as partition_to_owner), then count distinct
    # (panel, block) pairs in one vectorized unique over composite keys
    pid = np.repeat(np.arange(p, dtype=np.int64), np.diff(bounds))
    nbt = (mat.n + block_n - 1) // block_n
    uniq = np.unique(pid * nbt + blocks[bounds[0]:bounds[-1]])
    return np.bincount(uniq // nbt, minlength=p).astype(np.int64)


def block_fill_ratio(mat: CSRMatrix, block_m: int, block_n: int) -> float:
    """nnz / (num_nonempty_blocks * block_m * block_n).

    Fraction of useful work when the matrix is tiled into dense
    block_m x block_n 'MXU bricks'. 1.0 = perfectly dense blocks.
    """
    if mat.nnz == 0:
        return 1.0
    r = np.repeat(np.arange(mat.m), mat.row_nnz()).astype(np.int64)
    c = mat.cols.astype(np.int64)
    keys = (r // block_m) * ((mat.n + block_n - 1) // block_n) + (c // block_n)
    nblocks = np.unique(keys).size
    return float(mat.nnz / (nblocks * block_m * block_n))


def num_nonempty_blocks(mat: CSRMatrix, block_m: int, block_n: int) -> int:
    if mat.nnz == 0:
        return 0
    r = np.repeat(np.arange(mat.m), mat.row_nnz()).astype(np.int64)
    c = mat.cols.astype(np.int64)
    keys = (r // block_m) * ((mat.n + block_n - 1) // block_n) + (c // block_n)
    return int(np.unique(keys).size)


# --------------------------------------------------------------------------
# Partition quality (distributed setting; PaToH/METIS objective)
# --------------------------------------------------------------------------
def cut_volume(mat: CSRMatrix, panel_starts: np.ndarray) -> int:
    """Communication volume of a 1-D row partition with x partitioned
    conformally: nnz whose column lives in a different panel than the row.

    This is what hypergraph partitioning minimizes and what turns into
    collective bytes in the distributed SpMV.
    """
    # tolerant owner map (old-loop semantics: rows outside the partition
    # belong to panel 0) — partition_to_owner is the strict covering-
    # partition variant, and this metric, like halo_width, must keep
    # accepting prefix/partial partitions
    starts = np.asarray(panel_starts, dtype=np.int64)
    owner = np.zeros(mat.m, dtype=np.int32)
    owner[starts[0]:starts[-1]] = np.repeat(
        np.arange(starts.size - 1, dtype=np.int32), np.diff(starts))
    r = np.repeat(np.arange(mat.m), mat.row_nnz()).astype(np.int64)
    c = mat.cols.astype(np.int64)
    return int(np.count_nonzero(owner[r] != owner[c]))


def halo_width(mat: CSRMatrix, panel_starts: np.ndarray) -> int:
    """Max distance a panel must reach outside its own x range.

    For a bandwidth-reduced (RCM) matrix this equals the bandwidth, and it
    bounds the halo-exchange size of the distributed SpMV.
    """
    rp = mat.rowptr.astype(np.int64)
    starts = np.asarray(panel_starts, dtype=np.int64)
    bounds = rp[starts]                              # [P+1] nnz offsets
    ne = np.flatnonzero(np.diff(bounds) > 0)         # nonempty panels
    if ne.size == 0:
        return 0
    # reduceat over nonempty panel starts: each segment is exactly that
    # panel's elements (empty panels in between contribute none); slicing
    # at bounds[-1] keeps the LAST segment inside the final panel even for
    # a partition that does not reach row m
    cols = mat.cols[:bounds[-1]].astype(np.int64)
    cmin = np.minimum.reduceat(cols, bounds[ne])
    cmax = np.maximum.reduceat(cols, bounds[ne])
    reach = np.maximum(starts[ne] - cmin, cmax - (starts[ne + 1] - 1))
    return int(max(np.max(reach), 0))


def summary(mat: CSRMatrix, p: int = 8, block: int = 128) -> dict:
    panels = static_block_panels(mat.m, p)
    return {
        "m": mat.m,
        "nnz": mat.nnz,
        "bandwidth": bandwidth(mat),
        "avg_row_bandwidth": avg_row_bandwidth(mat),
        "load_imbalance": load_imbalance(mat, panels),
        "block_fill_ratio": block_fill_ratio(mat, 8, block),
        "cut_volume": cut_volume(mat, panels),
    }
