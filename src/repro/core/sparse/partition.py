"""Row-panel partitioning — the paper's two scheduling strategies, plus
the partitioner plugin registry the topology-aware planner searches.

* static_partition      — default OpenMP static schedule: equal ROW counts
                          (paper §3.2, the winner of the scheduling study).
* nnz_balanced_partition— equal NNZ counts (paper Listing 5): the custom
                          load-balanced schedule used in §6.2 to isolate
                          load-balance effects from data-movement effects.
* chunked_cyclic_panels — static,chunk round-robin (for the Fig. 4 sweep).

Each strategy is also registered as a PARTITIONER plugin
(@register_partitioner, core/registry.py) with the uniform contract

    fn(mat, p, seed=0, **kw) -> (perm | None, panel_starts[p + 1])

so `repro.api.plan(problem, topology=...)` selects the partition jointly
with scheme/engine/shape. Partitioners that regroup rows (chunked_cyclic,
the cut-minimizing metis_cut) return the grouping permutation instead of
emitting non-contiguous panels — contiguous panels of the permuted matrix
ARE the strided/cut-minimized assignment, which is what lets one sharded
layout builder serve every strategy.

On TPU these produce the per-device row panels for the shard_map SpMV and
the per-grid-step panels inside the Pallas kernel.
"""
from __future__ import annotations

import functools
import re

import numpy as np

from ..registry import PARTITIONER_REGISTRY, get_partitioner, \
    register_partitioner
from .csr import CSRMatrix
from .metrics import static_block_panels


def static_partition(mat: CSRMatrix, p: int) -> np.ndarray:
    """int[P+1] — contiguous equal-row panels (default static schedule)."""
    return static_block_panels(mat.m, p)


def nnz_balanced_partition(mat: CSRMatrix, p: int) -> np.ndarray:
    """int[P+1] — contiguous panels with ~equal nnz (paper Listing 5).

    Greedy prefix splitter: panel k ends at the first row where the running
    nnz count reaches (k+1)/P of total. Rows are never split (same
    granularity as the paper's rowPanel_start).

    Invariants (property-tested in tests/test_partition_props.py): result
    has length p+1, starts at 0, ends at m, is nondecreasing, and panel
    loads sum to nnz with max load <= nnz/p + max_row_nnz. Edge cases:
      * p > m — trailing/interspersed panels come out empty but the offsets
        stay monotone and cover every row exactly once;
      * a giant row swallowing several targets — maximum.accumulate
        collapses the overtaken cuts onto the row boundary (empty panels);
      * nnz == 0 — no balance signal exists, fall back to equal-row panels.
    """
    if p < 1:
        raise ValueError(f"p must be >= 1, got {p}")
    if mat.m == 0:
        return np.zeros(p + 1, dtype=np.int64)
    if mat.nnz == 0:
        return static_block_panels(mat.m, p)
    rp = mat.rowptr.astype(np.int64)
    targets = (np.arange(1, p, dtype=np.float64) * mat.nnz / p)
    # rp is nondecreasing; searchsorted finds the split rows.
    cuts = np.searchsorted(rp[1:], targets, side="left") + 1
    cuts = np.minimum(cuts, mat.m)
    starts = np.concatenate([[0], cuts, [mat.m]]).astype(np.int64)
    # enforce monotonicity when several targets land in one giant row
    starts = np.maximum.accumulate(starts)
    return starts


def chunked_cyclic_panels(m: int, p: int, chunk: int) -> list[np.ndarray]:
    """static,chunk scheduling: thread t gets rows {t*chunk..(t+1)*chunk-1,
    (t+P)*chunk.., ...}. Returns, per thread, the array of its row ids.
    (Non-contiguous — used only by the Fig. 4 scheduling benchmark.)"""
    out = []
    nchunks = (m + chunk - 1) // chunk
    for t in range(p):
        ids = []
        for ck in range(t, nchunks, p):
            ids.append(np.arange(ck * chunk, min((ck + 1) * chunk, m)))
        out.append(np.concatenate(ids) if ids else np.empty(0, dtype=np.int64))
    return out


def partition_to_owner(panel_starts: np.ndarray, m: int) -> np.ndarray:
    """int[m] — panel id owning each row. panel_starts must cover [0, m]."""
    starts = np.asarray(panel_starts, dtype=np.int64)
    if starts.size == 0 or starts[0] != 0 or starts[-1] != m:
        raise ValueError(f"panel_starts must cover [0, {m}], got "
                         f"{starts[:1]}..{starts[-1:]}")
    return np.repeat(np.arange(starts.size - 1, dtype=np.int32),
                     np.diff(starts))


# --------------------------------------------------------------------------
# Partitioner plugins (the topology-aware planning axis)
# --------------------------------------------------------------------------
@register_partitioner("static", auto_candidate=True,
                      description="equal contiguous row panels "
                                  "(default static schedule)")
def static_partitioner(mat: CSRMatrix, p: int, seed: int = 0):
    return None, static_partition(mat, p)


@register_partitioner("nnz_balanced", auto_candidate=True,
                      description="~equal-nnz contiguous panels "
                                  "(paper Listing 5)")
def nnz_balanced_partitioner(mat: CSRMatrix, p: int, seed: int = 0):
    return None, nnz_balanced_partition(mat, p)


@register_partitioner("chunked_cyclic", reorders=True,
                      description="static,chunk round-robin; panels made "
                                  "contiguous by a grouping permutation")
def chunked_cyclic_partitioner(mat: CSRMatrix, p: int, seed: int = 0,
                               chunk: int = 16):
    """Thread t owns rows {t*chunk.., (t+p)*chunk.., ...}; the returned
    permutation concatenates each thread's strided row set so panel t of
    the permuted matrix IS thread t's assignment (including its striding
    locality loss)."""
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    panels = chunked_cyclic_panels(mat.m, p, chunk)
    sizes = np.array([ids.size for ids in panels], dtype=np.int64)
    perm = (np.concatenate(panels).astype(np.int64) if mat.m
            else np.empty(0, np.int64))
    starts = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
    return perm, starts


@register_partitioner("metis_cut", reorders=True,
                      description="cut-minimizing: METIS k-way labels group "
                                  "rows, nnz-balanced contiguous split")
def metis_cut_partitioner(mat: CSRMatrix, p: int, seed: int = 0):
    """Communication-volume-minimizing partition via the reorder/metis
    machinery (Akbudak/Kayaaslan/Aykanat's co-optimization direction):
    rows are grouped by their METIS k-way partition label, then the
    grouped matrix is split into p nnz-balanced contiguous panels — label
    groups minimize the cut, the balanced split bounds load imbalance."""
    from ..reorder.metis import metis_partition

    labels = metis_partition(mat, p, seed)
    perm = np.argsort(labels, kind="stable").astype(np.int64)
    starts = nnz_balanced_partition(mat.permute(perm), p)
    return perm, starts


def resolve_partitioner(name: str):
    """(canonical_name, fn) for a registered partitioner name, supporting
    the parameterized `<base>_c<chunk>` form (e.g. chunked_cyclic_c16)."""
    if name in PARTITIONER_REGISTRY:
        return name, get_partitioner(name).fn
    m = re.match(r"^(.+)_c(\d+)$", name)
    if m and m.group(1) in PARTITIONER_REGISTRY:
        return name, functools.partial(get_partitioner(m.group(1)).fn,
                                       chunk=int(m.group(2)))
    raise KeyError(f"unknown partitioner {name!r}; known: "
                   f"{sorted(PARTITIONER_REGISTRY)} "
                   f"(+ parameterized <name>_c<chunk>)")


def auto_partitioners() -> list:
    """Names plan(partition='auto') searches for a sharded topology."""
    return [s.name for s in PARTITIONER_REGISTRY.values() if s.auto_candidate]


def pad_panels_to_uniform(mat: CSRMatrix, panel_starts: np.ndarray):
    """Pad each panel's rows to the max panel height (device-side SPMD needs
    uniform shapes). Returns (row_index[P, H], valid[P, H]) where
    row_index[p, i] is the matrix row handled by slot i of panel p (padding
    slots repeat row 0 and are masked by valid)."""
    p = len(panel_starts) - 1
    heights = np.diff(panel_starts)
    h = int(heights.max()) if p else 0
    idx = np.zeros((p, h), dtype=np.int32)
    valid = np.zeros((p, h), dtype=bool)
    for k in range(p):
        n = heights[k]
        idx[k, :n] = np.arange(panel_starts[k], panel_starts[k + 1])
        valid[k, :n] = True
    return idx, valid
