"""Row-panel partitioning — the paper's two scheduling strategies.

* static_partition      — default OpenMP static schedule: equal ROW counts
                          (paper §3.2, the winner of the scheduling study).
* nnz_balanced_partition— equal NNZ counts (paper Listing 5): the custom
                          load-balanced schedule used in §6.2 to isolate
                          load-balance effects from data-movement effects.
* chunked_cyclic_panels — static,chunk round-robin (for the Fig. 4 sweep).

On TPU these produce the per-device row panels for the shard_map SpMV and
the per-grid-step panels inside the Pallas kernel.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRMatrix
from .metrics import static_block_panels


def static_partition(mat: CSRMatrix, p: int) -> np.ndarray:
    """int[P+1] — contiguous equal-row panels (default static schedule)."""
    return static_block_panels(mat.m, p)


def nnz_balanced_partition(mat: CSRMatrix, p: int) -> np.ndarray:
    """int[P+1] — contiguous panels with ~equal nnz (paper Listing 5).

    Greedy prefix splitter: panel k ends at the first row where the running
    nnz count reaches (k+1)/P of total. Rows are never split (same
    granularity as the paper's rowPanel_start).

    Invariants (property-tested in tests/test_partition_props.py): result
    has length p+1, starts at 0, ends at m, is nondecreasing, and panel
    loads sum to nnz with max load <= nnz/p + max_row_nnz. Edge cases:
      * p > m — trailing/interspersed panels come out empty but the offsets
        stay monotone and cover every row exactly once;
      * a giant row swallowing several targets — maximum.accumulate
        collapses the overtaken cuts onto the row boundary (empty panels);
      * nnz == 0 — no balance signal exists, fall back to equal-row panels.
    """
    if p < 1:
        raise ValueError(f"p must be >= 1, got {p}")
    if mat.m == 0:
        return np.zeros(p + 1, dtype=np.int64)
    if mat.nnz == 0:
        return static_block_panels(mat.m, p)
    rp = mat.rowptr.astype(np.int64)
    targets = (np.arange(1, p, dtype=np.float64) * mat.nnz / p)
    # rp is nondecreasing; searchsorted finds the split rows.
    cuts = np.searchsorted(rp[1:], targets, side="left") + 1
    cuts = np.minimum(cuts, mat.m)
    starts = np.concatenate([[0], cuts, [mat.m]]).astype(np.int64)
    # enforce monotonicity when several targets land in one giant row
    starts = np.maximum.accumulate(starts)
    return starts


def chunked_cyclic_panels(m: int, p: int, chunk: int) -> list[np.ndarray]:
    """static,chunk scheduling: thread t gets rows {t*chunk..(t+1)*chunk-1,
    (t+P)*chunk.., ...}. Returns, per thread, the array of its row ids.
    (Non-contiguous — used only by the Fig. 4 scheduling benchmark.)"""
    out = []
    nchunks = (m + chunk - 1) // chunk
    for t in range(p):
        ids = []
        for ck in range(t, nchunks, p):
            ids.append(np.arange(ck * chunk, min((ck + 1) * chunk, m)))
        out.append(np.concatenate(ids) if ids else np.empty(0, dtype=np.int64))
    return out


def partition_to_owner(panel_starts: np.ndarray, m: int) -> np.ndarray:
    """int[m] — panel id owning each row. panel_starts must cover [0, m]."""
    starts = np.asarray(panel_starts, dtype=np.int64)
    if starts.size == 0 or starts[0] != 0 or starts[-1] != m:
        raise ValueError(f"panel_starts must cover [0, {m}], got "
                         f"{starts[:1]}..{starts[-1:]}")
    return np.repeat(np.arange(starts.size - 1, dtype=np.int32),
                     np.diff(starts))


def pad_panels_to_uniform(mat: CSRMatrix, panel_starts: np.ndarray):
    """Pad each panel's rows to the max panel height (device-side SPMD needs
    uniform shapes). Returns (row_index[P, H], valid[P, H]) where
    row_index[p, i] is the matrix row handled by slot i of panel p (padding
    slots repeat row 0 and are masked by valid)."""
    p = len(panel_starts) - 1
    heights = np.diff(panel_starts)
    h = int(heights.max()) if p else 0
    idx = np.zeros((p, h), dtype=np.int32)
    valid = np.zeros((p, h), dtype=bool)
    for k in range(p):
        n = heights[k]
        idx[k, :n] = np.arange(panel_starts[k], panel_starts[k + 1])
        valid[k, :n] = True
    return idx, valid
