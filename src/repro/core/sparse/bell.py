"""Device-facing block formats: Block-ELL and BCSR (host-side builders).

TPU adaptation of CSR (DESIGN.md §3): the MXU consumes dense (bm x bn)
tiles, so the device format stores *dense blocks* at the nonempty block
positions of the (reordered) matrix. Reordering quality on TPU manifests as
block fill ratio (fewer, denser blocks) and block-column locality (fewer
distinct x tiles per row panel).

* BlockELL — per block-row, blocks padded to the max count K. Uniform shape,
  grid = (num_block_rows, K). Padding blocks point at column-block 0 with
  zero values (result-neutral).
* BCSR — true variable-count block rows, flattened grid = (total_blocks,)
  with scalar-prefetched (block_row, block_col) ids. No padding waste; used
  when the block-count distribution is skewed (power-law graphs).

Builders are numpy-only; the arrays are handed to JAX by the ops layer.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import CSRMatrix


@dataclasses.dataclass(frozen=True)
class BlockELL:
    blocks: np.ndarray      # [nbr, K, bm, bn] float
    block_cols: np.ndarray  # [nbr, K] int32 (padding -> 0, with zero block)
    nblocks: np.ndarray     # [nbr] int32 true block count per block row
    shape: tuple            # (m, n) original logical shape
    block_shape: tuple      # (bm, bn)

    @property
    def num_block_rows(self) -> int:
        return self.blocks.shape[0]

    @property
    def k(self) -> int:
        return self.blocks.shape[1]

    @property
    def padded_shape(self) -> tuple:
        bm, bn = self.block_shape
        return (self.num_block_rows * bm, self.blocks.shape[0] and self._padded_n())

    def _padded_n(self) -> int:
        bm, bn = self.block_shape
        return ((self.shape[1] + bn - 1) // bn) * bn

    def density_stats(self) -> dict:
        bm, bn = self.block_shape
        total = int(self.nblocks.sum())
        nnz = int(np.count_nonzero(self.blocks))
        return {
            "num_blocks": total,
            "padded_blocks": int(self.blocks.shape[0] * self.blocks.shape[1]),
            "fill_ratio": nnz / max(total * bm * bn, 1),
            "pad_ratio": total / max(self.blocks.shape[0] * self.blocks.shape[1], 1),
        }


@dataclasses.dataclass(frozen=True)
class BCSR:
    blocks: np.ndarray      # [total_blocks, bm, bn]
    block_rows: np.ndarray  # [total_blocks] int32, nondecreasing
    block_cols: np.ndarray  # [total_blocks] int32
    block_rowptr: np.ndarray  # [nbr+1] int32
    shape: tuple
    block_shape: tuple

    @property
    def num_block_rows(self) -> int:
        return len(self.block_rowptr) - 1

    @property
    def total_blocks(self) -> int:
        return self.blocks.shape[0]


def _block_coo(mat: CSRMatrix, bm: int, bn: int):
    """(block_row, block_col, dense_block) triples for nonempty blocks."""
    m, n = mat.shape
    r = np.repeat(np.arange(m), mat.row_nnz()).astype(np.int64)
    c = mat.cols.astype(np.int64)
    br, bc = r // bm, c // bn
    nbc = (n + bn - 1) // bn
    key = br * nbc + bc
    uniq, inv = np.unique(key, return_inverse=True)
    blocks = np.zeros((uniq.size, bm, bn), dtype=mat.vals.dtype)
    # vectorized scatter: CSR guarantees unique (r, c), so no collisions
    blocks[inv, r % bm, c % bn] = mat.vals
    return (uniq // nbc).astype(np.int32), (uniq % nbc).astype(np.int32), blocks


def to_block_ell(mat: CSRMatrix, bm: int = 8, bn: int = 128, k: int | None = None) -> BlockELL:
    """Build Block-ELL. k: pad/cap width (default = max block count)."""
    m, n = mat.shape
    nbr = (m + bm - 1) // bm
    br, bc, dense = _block_coo(mat, bm, bn)
    counts = np.zeros(nbr, dtype=np.int32)
    np.add.at(counts, br, 1)
    kk = int(counts.max()) if k is None else int(k)
    kk = max(kk, 1)
    if k is not None and counts.max() > k:
        raise ValueError(f"k={k} < max block count {counts.max()}")
    blocks = np.zeros((nbr, kk, bm, bn), dtype=mat.vals.dtype)
    cols = np.zeros((nbr, kk), dtype=np.int32)
    # br is sorted (block-COO keys are row-major), so the slot of block i
    # within its block row is i - first_index_of(br[i]).
    csum = np.concatenate([[0], np.cumsum(np.bincount(br, minlength=nbr))])
    slot = np.arange(br.size) - csum[br]
    blocks[br, slot] = dense
    cols[br, slot] = bc
    return BlockELL(blocks=blocks, block_cols=cols, nblocks=counts,
                    shape=(m, n), block_shape=(bm, bn))


def to_bcsr(mat: CSRMatrix, bm: int = 8, bn: int = 128) -> BCSR:
    m, n = mat.shape
    nbr = (m + bm - 1) // bm
    br, bc, dense = _block_coo(mat, bm, bn)
    rowptr = np.zeros(nbr + 1, dtype=np.int64)
    np.add.at(rowptr, br.astype(np.int64) + 1, 1)
    rowptr = np.cumsum(rowptr)
    return BCSR(blocks=dense, block_rows=br, block_cols=bc,
                block_rowptr=rowptr.astype(np.int32), shape=(m, n),
                block_shape=(bm, bn))


def bell_to_dense(b: BlockELL) -> np.ndarray:
    bm, bn = b.block_shape
    m, n = b.shape
    nbc = (n + bn - 1) // bn
    out = np.zeros((b.num_block_rows * bm, nbc * bn), dtype=b.blocks.dtype)
    for i in range(b.num_block_rows):
        for kk in range(int(b.nblocks[i])):
            c = b.block_cols[i, kk]
            out[i * bm:(i + 1) * bm, c * bn:(c + 1) * bn] += b.blocks[i, kk]
    return out[:m, :n]
