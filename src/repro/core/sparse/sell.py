"""SELL-C-σ host-side builder (Kreutzer et al., adapted for TPU lanes).

SELL-C-σ = sliced ELLPACK: rows are grouped into slices of C rows and each
slice is padded only to *its own* widest row, after a σ-window sort that
places rows of similar nnz into the same slice. Padding therefore scales
with the per-slice max instead of the global max — on power-law matrices
(the regime where reordering matters most, and where plain ELL storage
explodes) this is the difference between O(nnz) and O(m * max_deg).

TPU adaptation: the kernel consumes the slice data as [C, W] chunks
(C = sublane count, W = lane-aligned chunk width), so a slice of width K_s
becomes ceil(K_s / W) chunks. All chunks across all slices are flattened
into one array, exactly like the BCSR kernel's flattened block list, with a
scalar-prefetched `chunk_slice` map saying which slice (and hence which y
tile) each chunk accumulates into. Empty slices still get one zero chunk so
every output tile is written (same contract as bcsr pad_empty_rows).

The σ-sort is a pure *storage* permutation: `row_perm` maps slice position
-> original row, and `inv_perm` undoes it after the multiply. It composes
with (and is independent of) the paper's reordering schemes, which permute
the matrix itself.

Builder is numpy-only and fully vectorized; arrays go to JAX in the ops
layer (kernels/sell_spmv/ops.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import CSRMatrix


@dataclasses.dataclass(frozen=True)
class SellCS:
    chunk_cols: np.ndarray    # [T, C, W] int32 column ids (padding -> 0)
    chunk_vals: np.ndarray    # [T, C, W] float  (padding -> 0)
    chunk_slice: np.ndarray   # [T] int32, nondecreasing slice id per chunk
    slice_width: np.ndarray   # [S] int32 true (pre-chunk) width of each slice
    row_perm: np.ndarray      # [S*C] int64: original row at slice position i
                              #   (positions >= m are phantom padding rows)
    inv_perm: np.ndarray      # [m] int64: slice position of original row r
    shape: tuple              # (m, n)
    c: int                    # slice height (TPU sublane count)
    sigma: int                # sort-window size (1 = no sorting)
    w: int                    # chunk width (TPU lane alignment)

    @property
    def num_slices(self) -> int:
        return int(self.slice_width.shape[0])

    @property
    def num_chunks(self) -> int:
        return int(self.chunk_slice.shape[0])

    @property
    def padded_nnz(self) -> int:
        """Stored element count (the format's memory/work footprint)."""
        return int(self.chunk_vals.size)

    def density_stats(self) -> dict:
        nnz = int(np.count_nonzero(self.chunk_vals))
        return {
            "num_slices": self.num_slices,
            "num_chunks": self.num_chunks,
            "padded_nnz": self.padded_nnz,
            "fill_ratio": nnz / max(self.padded_nnz, 1),
        }


def sell_padded_nnz(mat: CSRMatrix, c: int = 8, sigma: int = 64,
                    w: int = 1) -> int:
    """Predict SELL-C-σ stored elements WITHOUT building the format.

    Cheap enough for the autotuner's cost model: one sort of row counts per
    σ-window, then per-slice maxima. w quantizes slice widths up to the
    chunk width (w=1 -> un-chunked ideal SELL padding).
    """
    counts = _sorted_counts(mat.row_nnz(), c, sigma)
    s = counts.shape[0] // c
    widths = counts.reshape(s, c).max(axis=1)
    widths = np.maximum(((widths + w - 1) // w) * w, w)
    return int(widths.sum() * c)


def pick_chunk_width(mat: CSRMatrix, lo: int = 8, hi: int = 128) -> int:
    """Adaptive chunk width: smallest power of two covering the 75th
    percentile row, clipped to [lo, hi]. Small-degree corpora want narrow
    chunks (padding scales with W); on real TPU lanes the tuner also keeps
    a W=128 candidate in the race."""
    counts = mat.row_nnz()
    p75 = float(np.percentile(counts, 75)) if counts.size else 1.0
    w = lo
    while w < hi and w < p75:
        w *= 2
    return w


def _sorted_counts(counts: np.ndarray, c: int, sigma: int) -> np.ndarray:
    """Row-nnz counts, padded to a multiple of c, after the σ-window sort."""
    m = counts.shape[0]
    m_pad = ((m + c - 1) // c) * c
    padded = np.zeros(m_pad, dtype=np.int64)
    padded[:m] = counts
    return padded[_sigma_sort_perm(counts, c, sigma)]


def _sigma_sort_perm(counts: np.ndarray, c: int, sigma: int) -> np.ndarray:
    """row_perm[i] = original row at slice position i (descending nnz within
    each σ-window; stable, so the reordering scheme's row order is preserved
    among equal-degree rows). Positions beyond m map to phantom rows >= m.

    Vectorized: all windows sort as rows of one 2-D argsort. Buffer slots
    beyond m_pad carry key -1 and larger indices than any real slot, so the
    stable sort puts them last in their window; dropping indices >= m_pad
    afterwards is exact.
    """
    m = counts.shape[0]
    sigma = max(int(sigma), 1)
    m_pad = ((m + c - 1) // c) * c
    nwin = max((m_pad + sigma - 1) // sigma, 1)
    buf = np.full(nwin * sigma, -1, dtype=np.int64)
    buf[:m] = counts
    order = np.argsort(-buf.reshape(nwin, sigma), axis=1, kind="stable")
    perm = (order + sigma * np.arange(nwin, dtype=np.int64)[:, None]).ravel()
    return perm[perm < m_pad]


def to_sell(mat: CSRMatrix, c: int = 8, sigma: int = 64, w: int = 128) -> SellCS:
    """Build SELL-C-σ with lane-aligned chunking.

    c:     slice height (8 = f32 sublane count)
    sigma: sort window; multiple of c, sigma=1 disables sorting (pure SELL-C)
    w:     chunk width in elements (128 = one TPU vector lane row)
    """
    m, n = mat.shape
    counts = mat.row_nnz()
    perm = _sigma_sort_perm(counts, c, sigma)
    m_pad = perm.shape[0]
    s = m_pad // c

    counts_pad = np.zeros(m_pad, dtype=np.int64)
    counts_pad[:m] = counts
    counts_p = counts_pad[perm]                       # counts in slice order
    slice_width = counts_p.reshape(s, c).max(axis=1).astype(np.int32)

    # chunks per slice (>= 1 so each y tile is written at least once)
    chunks_per_slice = np.maximum((slice_width + w - 1) // w, 1).astype(np.int64)
    chunk_start = np.concatenate([[0], np.cumsum(chunks_per_slice)])
    t = int(chunk_start[-1])

    chunk_cols = np.zeros((t, c, w), dtype=np.int32)
    chunk_vals = np.zeros((t, c, w), dtype=mat.vals.dtype)
    chunk_slice = np.repeat(np.arange(s, dtype=np.int32), chunks_per_slice)

    # Vectorized fill. For slice position i = slice*c + lane holding original
    # row perm[i], its element j (j-th nonzero of the row) lands in chunk
    # chunk_start[slice] + j // w at [lane, j % w].
    nnz = mat.nnz
    if nnz:
        rp = mat.rowptr.astype(np.int64)
        real = perm < m                                # mask phantom rows
        rows_p = perm[real]
        cnt_p = counts_pad[perm][real]
        pos_p = np.flatnonzero(real)                   # slice position of each
        # ragged per-element indices, in slice-position order:
        ends = np.cumsum(cnt_p)
        j = np.arange(nnz, dtype=np.int64) - np.repeat(ends - cnt_p, cnt_p)
        src = np.repeat(rp[rows_p], cnt_p) + j         # CSR source index
        pos = np.repeat(pos_p, cnt_p)                  # slice position
        sl, lane = pos // c, pos % c
        chunk = chunk_start[sl] + j // w
        flat = (chunk * c + lane) * w + (j % w)
        chunk_cols.reshape(-1)[flat] = mat.cols[src]
        chunk_vals.reshape(-1)[flat] = mat.vals[src]

    inv_perm = np.empty(m_pad, dtype=np.int64)
    inv_perm[perm] = np.arange(m_pad)
    return SellCS(chunk_cols=chunk_cols, chunk_vals=chunk_vals,
                  chunk_slice=chunk_slice, slice_width=slice_width,
                  row_perm=perm, inv_perm=inv_perm[:m][...],
                  shape=(m, n), c=c, sigma=sigma, w=w)


def sell_to_dense(s: SellCS) -> np.ndarray:
    """Debug/test helper: densify (inverse of to_sell up to explicit zeros)."""
    m, n = s.shape
    out = np.zeros((m, n), dtype=s.chunk_vals.dtype)
    t, c, w = s.chunk_vals.shape
    ch, lane, ww = np.nonzero(s.chunk_vals)
    pos = s.chunk_slice[ch].astype(np.int64) * c + lane
    rows = s.row_perm[pos]
    cols = s.chunk_cols[ch, lane, ww]
    out[rows, cols] = s.chunk_vals[ch, lane, ww]
    return out
