"""Plugin registries for reordering schemes, SpMV engines, row partitioners
and machine profiles.

The pipeline facade (repro.api) plans over *whatever is registered*, not a
hardcoded list: a reordering scheme is a function `(mat, seed) -> perm`
registered with @register_scheme, an engine is a builder
`(mat, dtype=..., block_shape=..., sell_sigma=..., use_kernel=...,
nnz_bucket=...) -> operator` registered with @register_engine, and a
partitioner is a function `(mat, p, seed=0, **kw) -> (perm | None,
panel_starts)` registered with @register_partitioner (the topology-aware
planning axis — see core/spmv/topology.py). Capability metadata rides on
the spec so planners can reason about candidates without importing them:

  * SchemeSpec.paper           — one of the paper's §2.1 schemes
  * SchemeSpec.auto_candidate  — plan(reorder="auto") tries it by default
  * EngineSpec.supports_spmm   — operator.matmul(X[n, k]) is implemented
  * EngineSpec.cost_fn         — bytes-per-SpMM model (core/spmv/tune.py)
  * EngineSpec.candidates_fn   — (mat, feat) -> shape grid the tuner scores
  * EngineSpec.device          — "any" (pure XLA) or "tpu" (Pallas kernel
                                 with interpret/ref fallback elsewhere)

Machine profiles are the measurement counterpart: a named (engine, dtype,
p) bundle standing in for one of the paper's hosts. The experiment harness
(repro.experiments) builds campaign axes from PROFILE_REGISTRY, so a
plugin profile joins every campaign that iterates `profiles="*"` the
moment it calls register_profile.

Built-ins register at import of core.reorder.api / core.spmv.ops /
repro.experiments (all imported by repro.api, so `import repro.api` is
the one-line way to get fully populated registries). Third-party
schemes/engines/profiles register the same way and immediately
participate in plan(reorder="auto", engine="auto") and in campaigns.

This module must stay jax-free: it is imported by plan-time code that runs
before XLA_FLAGS are pinned (see core/sparse/csr.py's rule).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional


@dataclasses.dataclass(frozen=True)
class SchemeSpec:
    """A registered reordering scheme: perm = fn(mat, seed)."""

    name: str
    fn: Callable
    paper: bool = False
    auto_candidate: bool = False
    description: str = ""


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """A registered SpMV engine: operator = build(mat, **build_kwargs)."""

    name: str
    build: Callable
    supports_spmm: bool = True
    device: str = "any"
    cost_fn: Optional[Callable] = None
    candidates_fn: Optional[Callable] = None
    description: str = ""


@dataclasses.dataclass(frozen=True)
class PartitionerSpec:
    """A registered row partitioner for topology-aware (sharded) plans.

    fn(mat, p, seed=0, **kw) -> (perm | None, panel_starts[p + 1]):
    `perm` is an optional row permutation (perm[i] = old row at new
    position i) applied BEFORE the contiguous split — a partitioner that
    only splits (static, nnz_balanced) returns None; one that regroups
    rows (chunked_cyclic, the cut-minimizing metis_cut) returns the
    grouping permutation. `panel_starts` indexes the (permuted) matrix
    and must cover [0, m] monotonically — the same invariants as
    core/sparse/partition.nnz_balanced_partition.
    """

    name: str
    fn: Callable
    auto_candidate: bool = False
    reorders: bool = False            # may return a non-None perm
    description: str = ""


@dataclasses.dataclass(frozen=True)
class ProfileSpec:
    """A registered machine/measurement profile: one point on the paper's
    'machines' axis — the engine family, compute dtype and core count a
    campaign cell is measured under (DESIGN.md §7)."""

    name: str
    engine: str = "csr"
    dtype: str = "float32"
    p: int = 8
    primary: bool = False
    description: str = ""

    def physical(self) -> tuple:
        """The (engine, dtype, p) coordinates a cell key is built from —
        the profile NAME is presentation, not measurement identity."""
        return (self.engine, self.dtype, int(self.p))


SCHEME_REGISTRY: Dict[str, SchemeSpec] = {}
ENGINE_REGISTRY: Dict[str, EngineSpec] = {}
PROFILE_REGISTRY: Dict[str, ProfileSpec] = {}
PARTITIONER_REGISTRY: Dict[str, PartitionerSpec] = {}


def register_scheme(name: str, *, paper: bool = False,
                    auto_candidate: bool = False, description: str = "",
                    override: bool = False) -> Callable:
    """Decorator: register `fn(mat, seed=0) -> perm` under `name`."""

    def deco(fn: Callable) -> Callable:
        if name in SCHEME_REGISTRY and not override:
            raise ValueError(f"scheme {name!r} already registered "
                             f"(pass override=True to replace)")
        SCHEME_REGISTRY[name] = SchemeSpec(
            name=name, fn=fn, paper=paper, auto_candidate=auto_candidate,
            description=description)
        return fn

    return deco


def register_engine(name: str, *, supports_spmm: bool = True,
                    device: str = "any", cost_fn: Optional[Callable] = None,
                    candidates_fn: Optional[Callable] = None,
                    description: str = "",
                    override: bool = False) -> Callable:
    """Decorator: register an operator builder under `name`.

    The builder must accept the keyword surface
    (mat, dtype=..., block_shape=..., sell_sigma=..., use_kernel=...,
    nnz_bucket=...) and may ignore what it doesn't use.
    """

    def deco(build: Callable) -> Callable:
        if name in ENGINE_REGISTRY and not override:
            raise ValueError(f"engine {name!r} already registered "
                             f"(pass override=True to replace)")
        ENGINE_REGISTRY[name] = EngineSpec(
            name=name, build=build, supports_spmm=supports_spmm,
            device=device, cost_fn=cost_fn, candidates_fn=candidates_fn,
            description=description)
        return build

    return deco


def register_partitioner(name: str, *, auto_candidate: bool = False,
                         reorders: bool = False, description: str = "",
                         override: bool = False) -> Callable:
    """Decorator: register `fn(mat, p, seed=0, **kw) -> (perm | None,
    panel_starts)` under `name`. auto_candidate partitioners join
    plan(partition="auto") for every sharded topology."""

    def deco(fn: Callable) -> Callable:
        if name in PARTITIONER_REGISTRY and not override:
            raise ValueError(f"partitioner {name!r} already registered "
                             f"(pass override=True to replace)")
        PARTITIONER_REGISTRY[name] = PartitionerSpec(
            name=name, fn=fn, auto_candidate=auto_candidate,
            reorders=reorders, description=description)
        return fn

    return deco


def register_profile(name: str, *, engine: str = "csr",
                     dtype: str = "float32", p: int = 8,
                     primary: bool = False, description: str = "",
                     override: bool = False) -> ProfileSpec:
    """Register a machine/measurement profile (plain data, no decorator)."""
    if name in PROFILE_REGISTRY and not override:
        raise ValueError(f"profile {name!r} already registered "
                         f"(pass override=True to replace)")
    spec = ProfileSpec(name=name, engine=engine, dtype=dtype, p=int(p),
                       primary=primary, description=description)
    PROFILE_REGISTRY[name] = spec
    return spec


def get_scheme(name: str) -> SchemeSpec:
    try:
        return SCHEME_REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scheme {name!r}; known: "
                       f"{sorted(SCHEME_REGISTRY)}") from None


def get_engine(name: str) -> EngineSpec:
    try:
        return ENGINE_REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; known: "
                       f"{sorted(ENGINE_REGISTRY)}") from None


def get_partitioner(name: str) -> PartitionerSpec:
    try:
        return PARTITIONER_REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown partitioner {name!r}; known: "
                       f"{sorted(PARTITIONER_REGISTRY)}") from None


def get_profile(name: str) -> ProfileSpec:
    try:
        return PROFILE_REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown profile {name!r}; known: "
                       f"{sorted(PROFILE_REGISTRY)}") from None


def primary_profile() -> str:
    """Name of the primary profile (the one the paper's single-machine
    figures are measured on). Falls back to the first registered."""
    for spec in PROFILE_REGISTRY.values():
        if spec.primary:
            return spec.name
    if PROFILE_REGISTRY:
        return next(iter(PROFILE_REGISTRY))
    raise KeyError("no machine profiles registered "
                   "(import repro.experiments to get the built-ins)")
