"""Unified reordering API + disk cache.

reorder(mat, scheme, seed) -> permutation (perm[i] = old row at position i)
apply_scheme(mat, scheme)  -> reordered CSRMatrix

Schemes (paper §2.1): baseline (identity), random (the Fig. 1 shuffle),
rcm, metis, louvain, patoh. Plus the beyond-paper `rcm_blocked`
(block-fill-aware tie-break — DESIGN.md §10).

Reordering is plan-time preprocessing (the paper never times it); results
are content-addressed cached on disk so the benchmark suite is re-runnable.
"""
from __future__ import annotations

import hashlib
import os
from typing import Callable, Dict

import numpy as np

from ..sparse.csr import CSRMatrix
from .louvain import louvain_order
from .metis import metis_order, metis_partition
from .patoh import patoh_order, patoh_partition
from .rcm import rcm_order

def _cache_dir() -> str:
    # read per call (not at import) so tests can repoint it via monkeypatch
    return os.environ.get("REPRO_REORDER_CACHE", "/tmp/repro_reorder")


def _identity(mat: CSRMatrix, seed: int = 0) -> np.ndarray:
    return np.arange(mat.m, dtype=np.int64)


def _random(mat: CSRMatrix, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).permutation(mat.m).astype(np.int64)


def _rcm_blocked(mat: CSRMatrix, seed: int = 0, block: int = 8) -> np.ndarray:
    """Beyond-paper: RCM followed by a within-window pass that greedily packs
    rows with similar column-block signatures into the same block-row,
    raising MXU tile density (see benchmarks/roofline + EXPERIMENTS.md §Perf)."""
    base = rcm_order(mat, seed)
    rmat = mat.permute(base)
    m = rmat.m
    win = block * 8
    perm_local = np.arange(m, dtype=np.int64)
    rp = rmat.rowptr.astype(np.int64)
    cols = rmat.cols.astype(np.int64)
    for w0 in range(0, m, win):
        w1 = min(w0 + win, m)
        rows = np.arange(w0, w1)
        # signature = min col-block touched (cheap proxy for tile overlap)
        sig = np.full(rows.size, np.iinfo(np.int64).max)
        for i, r in enumerate(rows):
            if rp[r + 1] > rp[r]:
                sig[i] = cols[rp[r]] // 128
        order = np.argsort(sig, kind="stable")
        perm_local[w0:w1] = rows[order]
    return base[perm_local]


def _metis_nnzbal(mat: CSRMatrix, seed: int = 0) -> np.ndarray:
    """METIS with degree-weighted (nnz) balance — the variant that improves
    static LI on skewed graphs (see EXPERIMENTS §Repro claim 7)."""
    return metis_order(mat, seed, degree_weighted=True)


SCHEMES: Dict[str, Callable] = {
    "baseline": _identity,
    "metis_nnzbal": _metis_nnzbal,
    "random": _random,
    "rcm": rcm_order,
    "metis": metis_order,
    "louvain": louvain_order,
    "patoh": patoh_order,
    "rcm_blocked": _rcm_blocked,
}

PAPER_SCHEMES = ["rcm", "metis", "louvain", "patoh"]


def _content_key(mat: CSRMatrix, scheme: str, seed: int) -> str:
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(mat.rowptr).tobytes())
    h.update(np.ascontiguousarray(mat.cols).tobytes())
    h.update(f"{scheme}:{seed}".encode())
    return h.hexdigest()[:20]


def reorder(mat: CSRMatrix, scheme: str, seed: int = 0, cache: bool = True) -> np.ndarray:
    if scheme not in SCHEMES:
        raise KeyError(f"unknown scheme {scheme!r}; known: {sorted(SCHEMES)}")
    if not cache:
        return SCHEMES[scheme](mat, seed)
    cache_dir = _cache_dir()
    os.makedirs(cache_dir, exist_ok=True)
    path = os.path.join(cache_dir, _content_key(mat, scheme, seed) + ".npy")
    if os.path.exists(path):
        return np.load(path)
    perm = SCHEMES[scheme](mat, seed)
    np.save(path, perm)
    return perm


def apply_scheme(mat: CSRMatrix, scheme: str, seed: int = 0, cache: bool = True) -> CSRMatrix:
    perm = reorder(mat, scheme, seed, cache)
    return mat.permute(perm)


PARTITIONERS = {
    "metis": metis_partition,
    "patoh": patoh_partition,
}


def partition_labels(mat: CSRMatrix, scheme: str, k: int, seed: int = 0) -> np.ndarray:
    return PARTITIONERS[scheme](mat, k, seed)
