"""Unified reordering API + disk cache.

reorder(mat, scheme, seed) -> permutation (perm[i] = old row at position i)

Schemes (paper §2.1): baseline (identity), random (the Fig. 1 shuffle),
rcm, metis, louvain, patoh. Plus the beyond-paper `rcm_blocked`
(block-fill-aware tie-break — DESIGN.md §10).

Schemes live in the plugin registry (core/registry.py): each is a
`(mat, seed) -> perm` function registered with @register_scheme, and the
pipeline facade (repro.api) plans over whatever is registered. `SCHEMES`
remains as a read-only mapping view for existing callers.

Reordering is plan-time preprocessing (the paper never times it); results
are content-addressed cached on disk so the benchmark suite is re-runnable.
Cache writes are write-then-rename atomic (same tmp-name convention as
core/spmv/opcache.py) so concurrent benchmark runs never read a torn .npy.

`apply_scheme` is a deprecated shim kept for external callers; new code
goes through repro.api.plan(...) whose operators carry the permutation.
"""
from __future__ import annotations

import hashlib
import os
import threading
import warnings
from typing import Iterator, Mapping

import numpy as np

from ... import obs
from ..registry import SCHEME_REGISTRY, get_scheme, register_scheme
from ..sparse.csr import CSRMatrix
from .louvain import louvain_order
from .metis import metis_order, metis_partition
from .patoh import patoh_order, patoh_partition
from .rcm import rcm_order


def _cache_dir() -> str:
    # read per call (not at import) so tests can repoint it via monkeypatch
    return os.environ.get("REPRO_REORDER_CACHE", "/tmp/repro_reorder")


@register_scheme("baseline", auto_candidate=True,
                 description="identity (no reordering)")
def _identity(mat: CSRMatrix, seed: int = 0) -> np.ndarray:
    return np.arange(mat.m, dtype=np.int64)


@register_scheme("metis_nnzbal",
                 description="METIS with degree-weighted (nnz) balance")
def _metis_nnzbal(mat: CSRMatrix, seed: int = 0) -> np.ndarray:
    """METIS with degree-weighted (nnz) balance — the variant that improves
    static LI on skewed graphs (see EXPERIMENTS §Repro claim 7)."""
    return metis_order(mat, seed, degree_weighted=True)


@register_scheme("random", description="random shuffle (paper Fig. 1)")
def _random(mat: CSRMatrix, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).permutation(mat.m).astype(np.int64)


register_scheme("rcm", paper=True, auto_candidate=True,
                description="reverse Cuthill-McKee")(rcm_order)
register_scheme("metis", paper=True,
                description="METIS k-way partition order")(metis_order)
register_scheme("louvain", paper=True,
                description="Louvain community order")(louvain_order)
register_scheme("patoh", paper=True,
                description="PaToH hypergraph partition order")(patoh_order)


@register_scheme("rcm_blocked", auto_candidate=True,
                 description="RCM + block-fill-aware within-window packing")
def _rcm_blocked(mat: CSRMatrix, seed: int = 0, block: int = 8) -> np.ndarray:
    """Beyond-paper: RCM followed by a within-window pass that greedily packs
    rows with similar column-block signatures into the same block-row,
    raising MXU tile density (see benchmarks/roofline + EXPERIMENTS.md §Perf)."""
    base = rcm_order(mat, seed)
    rmat = mat.permute(base)
    m = rmat.m
    win = block * 8
    perm_local = np.arange(m, dtype=np.int64)
    rp = rmat.rowptr.astype(np.int64)
    cols = rmat.cols.astype(np.int64)
    # signature = min col-block touched (cheap proxy for tile overlap);
    # rowptr-gather over all rows at once, empty rows keep the sentinel
    sig = np.full(m, np.iinfo(np.int64).max)
    nonempty = rp[1:] > rp[:-1]
    sig[nonempty] = cols[rp[:-1][nonempty]] // 128
    for w0 in range(0, m, win):
        w1 = min(w0 + win, m)
        rows = np.arange(w0, w1)
        order = np.argsort(sig[w0:w1], kind="stable")
        perm_local[w0:w1] = rows[order]
    return base[perm_local]


class _SchemeView(Mapping):
    """Read-only name -> fn view over the scheme registry (back-compat:
    existing callers index/iterate `SCHEMES` like the old dict)."""

    def __getitem__(self, name: str):
        return get_scheme(name).fn

    def __iter__(self) -> Iterator[str]:
        return iter(SCHEME_REGISTRY)

    def __len__(self) -> int:
        return len(SCHEME_REGISTRY)


SCHEMES = _SchemeView()

PAPER_SCHEMES = [s.name for s in SCHEME_REGISTRY.values() if s.paper]


def _content_key(mat: CSRMatrix, scheme: str, seed: int) -> str:
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(mat.rowptr).tobytes())
    h.update(np.ascontiguousarray(mat.cols).tobytes())
    h.update(f"{scheme}:{seed}".encode())
    return h.hexdigest()[:20]


def reorder(mat: CSRMatrix, scheme: str, seed: int = 0, cache: bool = True) -> np.ndarray:
    fn = get_scheme(scheme).fn
    with obs.span("plan.reorder", scheme=scheme, seed=int(seed),
                  shape=str(tuple(mat.shape))) as sp:
        if not cache:
            return fn(mat, seed)
        cache_dir = _cache_dir()
        os.makedirs(cache_dir, exist_ok=True)
        path = os.path.join(cache_dir,
                            _content_key(mat, scheme, seed) + ".npy")
        if os.path.exists(path):
            obs.counter("reorder_cache.hits").inc()
            sp.set(cache_hit=True)
            return np.load(path)
        obs.counter("reorder_cache.misses").inc()
        sp.set(cache_hit=False)
        perm = fn(mat, seed)
        # write-then-rename (opcache.py's tmp-name convention: pid AND
        # thread id) so a concurrent run never reads a torn .npy
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
        with open(tmp, "wb") as f:
            np.save(f, perm)
        os.replace(tmp, path)
        return perm


def apply_scheme(mat: CSRMatrix, scheme: str, seed: int = 0, cache: bool = True) -> CSRMatrix:
    """Deprecated: reorder + permute in one call, losing the permutation.

    Use repro.api.plan(SpmvProblem(mat), reorder=scheme) instead — the plan
    carries the permutation and its operator accepts original-index-space
    vectors, so callers no longer hand-permute x/y themselves.
    """
    warnings.warn(
        "apply_scheme() is deprecated; use repro.api.plan(SpmvProblem(mat), "
        "reorder=scheme) — plans carry the permutation (or call "
        "reorder() + mat.permute() explicitly)",
        DeprecationWarning, stacklevel=2)
    perm = reorder(mat, scheme, seed, cache)
    return mat.permute(perm)


PARTITIONERS = {
    "metis": metis_partition,
    "patoh": patoh_partition,
}


def partition_labels(mat: CSRMatrix, scheme: str, k: int, seed: int = 0) -> np.ndarray:
    return PARTITIONERS[scheme](mat, k, seed)
