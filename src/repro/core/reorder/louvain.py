"""Louvain community detection as a reordering (paper §2.1).

Vectorized synchronous variant of Blondel et al. 2008: local-move sweeps
computed for all vertices at once (each vertex picks the neighbouring
community with max modularity gain; a fraction of movers is applied per
sweep to damp oscillation), then community aggregation, repeated until
modularity stalls. Ordering = communities concatenated (hierarchically:
the aggregated graph's ordering recursively orders the communities),
vertices within a community kept in original relative order.
"""
from __future__ import annotations

import numpy as np

from ..sparse.csr import CSRMatrix
from . import graphutil
from .graphutil import Graph


def _local_moves(g: Graph, comm: np.ndarray, rng: np.random.Generator,
                 sweeps: int = 8) -> np.ndarray:
    """Synchronous local-move phase. Returns updated community labels."""
    m = g.m
    src = g.edge_sources()
    two_m = g.weights.sum()  # = 2|E| for symmetric input
    if two_m == 0:
        return comm
    k = np.zeros(m)  # weighted degree
    np.add.at(k, src, g.weights)
    comm = comm.copy()
    for s in range(sweeps):
        sigma_tot = np.zeros(m)
        np.add.at(sigma_tot, comm, k)
        # weight from each vertex to each neighbouring community:
        key = src * np.int64(m) + comm[g.indices]
        uk, inv = np.unique(key, return_inverse=True)
        w_vc = np.zeros(uk.size)
        np.add.at(w_vc, inv, g.weights)
        v_of = (uk // m).astype(np.int64)
        c_of = (uk % m).astype(np.int64)
        # modularity gain of moving v into community c (after removal from own):
        # dQ ∝ w_vc - k_v * sigma_tot(c \ v) / two_m
        sig_adj = sigma_tot[c_of] - np.where(comm[v_of] == c_of, k[v_of], 0.0)
        gain = w_vc - k[v_of] * sig_adj / two_m
        # current community score for each vertex (gain of staying = its own entry)
        # pick per-vertex argmax via lexsort trick
        order = np.lexsort((gain, v_of))
        vo = v_of[order]
        seg_end = np.flatnonzero(np.diff(np.append(vo, m)) != 0)
        best_c = np.full(m, -1, dtype=np.int64)
        best_g = np.full(m, -np.inf)
        best_c[vo[seg_end]] = c_of[order][seg_end]
        best_g[vo[seg_end]] = gain[order][seg_end]
        # gain of keeping current community
        cur_key_gain = np.full(m, 0.0)
        own = comm[v_of] == c_of
        cur_key_gain[v_of[own]] = gain[own]
        movers = np.flatnonzero((best_c >= 0) & (best_c != comm) &
                                (best_g > cur_key_gain + 1e-12))
        if movers.size == 0:
            break
        # damp: move a random half on even sweeps (synchronous Louvain trick)
        if movers.size > 1:
            movers = movers[rng.random(movers.size) < 0.7]
        comm[movers] = best_c[movers]
    # compact labels
    _, comm = np.unique(comm, return_inverse=True)
    return comm


def louvain_communities(mat: CSRMatrix, seed: int = 0, max_levels: int = 6):
    """Returns (labels per level list, final labels on original vertices)."""
    g = graphutil.from_matrix(mat)
    rng = np.random.default_rng(seed)
    mapping = np.arange(g.m, dtype=np.int64)  # original -> current coarse id
    levels = []
    for _ in range(max_levels):
        comm = _local_moves(g, np.arange(g.m, dtype=np.int64), rng)
        ncomm = int(comm.max()) + 1 if comm.size else 0
        levels.append(comm)
        if ncomm >= g.m or ncomm <= 1:
            break
        # aggregate
        g, _ = _aggregate(g, comm)
        mapping = comm[mapping]
    return levels, mapping


def _aggregate(g: Graph, comm: np.ndarray):
    src = g.edge_sources()
    cm = int(comm.max()) + 1
    cs, cd = comm[src], comm[g.indices]
    keep = cs != cd
    key = cs[keep] * np.int64(cm) + cd[keep]
    uk, inv = np.unique(key, return_inverse=True)
    w = np.zeros(uk.size)
    np.add.at(w, inv, g.weights[keep])
    indptr = np.zeros(cm + 1, dtype=np.int64)
    np.add.at(indptr, (uk // cm).astype(np.int64) + 1, 1)
    indptr = np.cumsum(indptr)
    vwgt = np.zeros(cm)
    np.add.at(vwgt, comm, g.vwgt)
    return Graph(indptr=indptr, indices=(uk % cm).astype(np.int32),
                 weights=w, vwgt=vwgt), None


def louvain_order(mat: CSRMatrix, seed: int = 0) -> np.ndarray:
    """Order = sort by final community id (stable -> original order within),
    communities themselves ordered by the hierarchy's discovery order."""
    _, labels = louvain_communities(mat, seed)
    return np.argsort(labels, kind="stable").astype(np.int64)
