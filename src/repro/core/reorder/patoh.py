"""PaToH-style multilevel *hypergraph* partitioning / reordering (§2.1).

Column-net model [Catalyurek & Aykanat 1999]: vertices = rows of A, net j =
column j connecting every row with a nonzero in column j (for symmetric A,
net i = {i} ∪ neighbours(i)). Objective = connectivity-1 cut
(sum over nets of (#parts spanned - 1)) — the communication volume of
row-parallel SpMV, which is exactly what the distributed runtime pays.

Multilevel scheme mirrors metis.py but the refinement gain is net-based:
moving v across helps when v is a net's sole pin on its side (net becomes
uncut) and hurts when it breaks a pure net. Simplified vs real PaToH
(documented in DESIGN.md): synchronous gain passes instead of sequential FM
with a bucket queue; exact connectivity recomputed per pass, best kept.
"""
from __future__ import annotations

import numpy as np

from ..sparse.csr import CSRMatrix
from . import graphutil
from .graphutil import Graph


def _net_side_counts(mat_rowptr, mat_cols, side):
    """For each net (= row i of symmetric A): pins = {i} ∪ cols(i).
    Returns (pins_on_1, pin_count) arrays over nets."""
    m = len(mat_rowptr) - 1
    counts = np.diff(mat_rowptr.astype(np.int64))
    on1 = np.zeros(m, dtype=np.int64)
    src = np.repeat(np.arange(m), counts)
    np.add.at(on1, src, side[mat_cols].astype(np.int64))
    on1 += side.astype(np.int64)  # the row vertex itself is a pin
    return on1, counts + 1


def connectivity_cut(mat: CSRMatrix, side: np.ndarray) -> int:
    on1, tot = _net_side_counts(mat.rowptr, mat.cols, side)
    return int(np.count_nonzero((on1 > 0) & (on1 < tot)))


def _refine_hg(mat: CSRMatrix, side: np.ndarray, passes: int = 4,
               tol: float = 0.08) -> np.ndarray:
    """Synchronous net-gain refinement on the fine hypergraph."""
    m = mat.m
    side = side.copy().astype(np.int8)
    best_side = side.copy()
    best_cut = connectivity_cut(mat, side)
    rowptr = mat.rowptr.astype(np.int64)
    src = np.repeat(np.arange(m), np.diff(rowptr))
    for _ in range(passes):
        on1, tot = _net_side_counts(mat.rowptr, mat.cols, side)
        on0 = tot - on1
        # per-vertex gain: a vertex v participates in net n (as row-pin of
        # its own net and as col-pin of nets of its neighbours). Moving v to
        # the other side: gain += 1 if v was the only pin on its side of n
        # (n becomes uncut); gain -= 1 if n was pure and v breaks it.
        own_count = np.where(side == 1, on1, on0)
        gain = np.zeros(m, dtype=np.int64)
        # contribution of v's own net:
        gain += (own_count == 1).astype(np.int64) - (own_count == tot).astype(np.int64)
        # contribution as a pin of each neighbour's net:
        n_own = np.where(side[src] == 1, on1[mat.cols], on0[mat.cols])
        # careful: for net of neighbour u (net id = column value), v=src pin side = side[src]
        n_own = np.where(side[src] == 1, on1[mat.cols], on0[mat.cols])
        n_tot = tot[mat.cols]
        contrib = (n_own == 1).astype(np.int64) - (n_own == n_tot).astype(np.int64)
        np.add.at(gain, src, contrib)
        cand = np.flatnonzero(gain > 0)
        if cand.size == 0:
            break
        cand = cand[np.argsort(-gain[cand], kind="stable")]
        # keep balance
        total = m
        w1 = int(side.sum())
        delta = np.where(side[cand] == 1, -1, 1)
        run = w1 + np.cumsum(delta)
        ok = (run >= total * (0.5 - tol)) & (run <= total * (0.5 + tol))
        limit = max(1, cand.size // 2)
        sel = cand[:limit][ok[:limit]]
        if sel.size == 0:
            break
        side[sel] ^= 1
        cut = connectivity_cut(mat, side)
        if cut < best_cut:
            best_cut, best_side = cut, side.copy()
    return best_side


def _bisect_hg(mat: CSRMatrix, vertices: np.ndarray, g: Graph,
               rng: np.random.Generator) -> np.ndarray:
    """Bisection of the induced sub(hyper)graph: seed with the graph
    bisection (heavy-edge multilevel — a good hypergraph start since the
    clique-net expansion of the column-net model is the graph itself), then
    refine with the true connectivity-1 objective."""
    from .metis import bisect

    sub_g = graphutil.subgraph(g, vertices)
    side = bisect(sub_g, rng)
    # build the induced CSR submatrix for net-based refinement
    sub = _induced_csr(mat, vertices)
    side = _refine_hg(sub, side)
    return side


def _induced_csr(mat: CSRMatrix, vertices: np.ndarray) -> CSRMatrix:
    m = mat.m
    local = np.full(m, -1, dtype=np.int64)
    local[vertices] = np.arange(vertices.size)
    rowptr = mat.rowptr.astype(np.int64)
    src = np.repeat(np.arange(m), np.diff(rowptr))
    keep = (local[src] >= 0) & (local[mat.cols] >= 0)
    return CSRMatrix.from_coo(local[src[keep]], local[mat.cols[keep]],
                              mat.vals[keep], (vertices.size, vertices.size))


def patoh_order(mat: CSRMatrix, seed: int = 0, leaf: int | None = None) -> np.ndarray:
    g = graphutil.from_matrix(mat)
    rng = np.random.default_rng(seed)
    # cap recursion depth on big matrices: locality plateaus past
    # ~32 partitions while cost keeps growing linearly
    leaf = leaf or max(64, mat.m // 32)
    out: list = []

    def rec(vertices):
        if vertices.size <= leaf:
            out.append(vertices)
            return
        side = _bisect_hg(mat, vertices, g, rng)
        left, right = vertices[side == 0], vertices[side == 1]
        if left.size == 0 or right.size == 0:
            out.append(vertices)
            return
        rec(left)
        rec(right)

    rec(np.arange(mat.m, dtype=np.int64))
    return np.concatenate(out)


def patoh_partition(mat: CSRMatrix, k: int, seed: int = 0) -> np.ndarray:
    g = graphutil.from_matrix(mat)
    rng = np.random.default_rng(seed)
    labels = np.zeros(mat.m, dtype=np.int64)
    parts = [np.arange(mat.m, dtype=np.int64)]
    for _ in range(int(np.ceil(np.log2(max(k, 1))))):
        nxt = []
        for p in parts:
            if p.size <= 1:
                nxt.append(p)
                continue
            side = _bisect_hg(mat, p, g, rng)
            nxt.append(p[side == 0])
            nxt.append(p[side == 1])
        parts = nxt
    for i, p in enumerate(parts):
        labels[p] = i
    return labels
