"""Shared graph machinery for the reorderers (vectorized numpy).

A CSRMatrix is viewed as an undirected weighted graph: vertices = rows,
edges = off-diagonal nonzeros, weight = |a_ij| (symmetric input guaranteed
by the corpus, mirroring the paper's symmetric-only filter).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.csr import CSRMatrix


@dataclasses.dataclass
class Graph:
    """Adjacency in CSR layout, self-loops removed."""

    indptr: np.ndarray   # int64[m+1]
    indices: np.ndarray  # int32[nnz]
    weights: np.ndarray  # float64[nnz]
    vwgt: np.ndarray     # float64[m] vertex weights (coarsening mass)

    @property
    def m(self) -> int:
        return len(self.indptr) - 1

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def edge_sources(self) -> np.ndarray:
        return np.repeat(np.arange(self.m), self.degrees()).astype(np.int64)


def from_matrix(mat: CSRMatrix, degree_weighted: bool = False) -> Graph:
    """degree_weighted: vertex weight = row nnz, so balanced bisections
    balance NNZ (the paper's load-balance object) instead of vertex count —
    this is what makes METIS-style orderings IMPROVE static LI on skewed
    graphs (EXPERIMENTS §Repro claim 7 note)."""
    r = np.repeat(np.arange(mat.m), mat.row_nnz()).astype(np.int64)
    keep = r != mat.cols
    r = r[keep]
    c = mat.cols[keep].astype(np.int64)
    w = np.abs(mat.vals[keep]).astype(np.float64)
    indptr = np.zeros(mat.m + 1, dtype=np.int64)
    np.add.at(indptr, r + 1, 1)
    indptr = np.cumsum(indptr)
    vwgt = (mat.row_nnz().astype(np.float64) if degree_weighted
            else np.ones(mat.m))
    return Graph(indptr=indptr, indices=c.astype(np.int32), weights=w,
                 vwgt=vwgt)


def heavy_edge_matching(g: Graph, rng: np.random.Generator, rounds: int = 3) -> np.ndarray:
    """Parallel heavy-edge matching: each vertex proposes to its heaviest
    unmatched neighbour; mutual proposals match. Returns match[v] = partner
    (or v itself if unmatched). Fully vectorized."""
    m = g.m
    match = np.arange(m, dtype=np.int64)
    matched = np.zeros(m, dtype=bool)
    src = g.edge_sources()
    for _ in range(rounds):
        free = ~matched
        # mask edges between free vertices
        ok = free[src] & free[g.indices]
        if not ok.any():
            break
        w = np.where(ok, g.weights, -np.inf)
        # per-source argmax via segmented reduction
        # trick: sort by (src, w) and take last per segment
        order = np.lexsort((w, src))
        s_sorted = src[order]
        last = np.zeros(m, dtype=np.int64) - 1
        # positions where segment ends
        seg_end = np.flatnonzero(np.diff(np.append(s_sorted, m)) != 0)
        cand = np.full(m, -1, dtype=np.int64)
        valid_end = seg_end[w[order][seg_end] > -np.inf]
        cand[s_sorted[valid_end]] = g.indices[order][valid_end]
        # mutual match
        has = cand >= 0
        mutual = has & (cand[np.clip(cand, 0, m - 1)] == np.arange(m)) & (cand != np.arange(m))
        a = np.flatnonzero(mutual)
        b = cand[a]
        pick = a < b  # each pair once
        a, b = a[pick], b[pick]
        match[a] = b
        match[b] = a
        matched[a] = True
        matched[b] = True
    return match


def coarsen(g: Graph, match: np.ndarray):
    """Contract matched pairs. Returns (coarse_graph, cmap) where
    cmap[v] = coarse vertex id of v."""
    m = g.m
    rep = np.minimum(np.arange(m), match)  # pair representative
    uniq, cmap = np.unique(rep, return_inverse=True)
    cm = uniq.size
    src = g.edge_sources()
    cs, cd = cmap[src], cmap[g.indices]
    keep = cs != cd
    key = cs[keep] * cm + cd[keep]
    uk, inv = np.unique(key, return_inverse=True)
    w = np.zeros(uk.size)
    np.add.at(w, inv, g.weights[keep])
    new_src = (uk // cm).astype(np.int64)
    new_dst = (uk % cm).astype(np.int32)
    indptr = np.zeros(cm + 1, dtype=np.int64)
    np.add.at(indptr, new_src + 1, 1)
    indptr = np.cumsum(indptr)
    vwgt = np.zeros(cm)
    np.add.at(vwgt, cmap, g.vwgt)
    return Graph(indptr=indptr, indices=new_dst, weights=w, vwgt=vwgt), cmap


def subgraph(g: Graph, vertices: np.ndarray):
    """Induced subgraph. Returns (sub, local_ids_of_vertices_order)."""
    m = g.m
    sel = np.zeros(m, dtype=bool)
    sel[vertices] = True
    local = np.full(m, -1, dtype=np.int64)
    local[vertices] = np.arange(vertices.size)
    src = g.edge_sources()
    keep = sel[src] & sel[g.indices]
    s = local[src[keep]]
    d = local[g.indices[keep]]
    w = g.weights[keep]
    indptr = np.zeros(vertices.size + 1, dtype=np.int64)
    np.add.at(indptr, s + 1, 1)
    indptr = np.cumsum(indptr)
    order = np.argsort(s, kind="stable")
    return Graph(indptr=indptr, indices=d[order].astype(np.int32),
                 weights=w[order], vwgt=g.vwgt[vertices])


def neighbor_side_weights(g: Graph, side: np.ndarray):
    """For each vertex: (weight to side 0, weight to side 1)."""
    src = g.edge_sources()
    to1 = np.zeros(g.m)
    np.add.at(to1, src, g.weights * side[g.indices])
    tot = np.zeros(g.m)
    np.add.at(tot, src, g.weights)
    return tot - to1, to1


def edge_cut(g: Graph, side: np.ndarray) -> float:
    src = g.edge_sources()
    return float(np.sum(g.weights[side[src] != side[g.indices]]) / 2.0)
