"""METIS-style multilevel graph partitioning / reordering (paper §2.1).

No native METIS offline, so this is a faithful from-scratch multilevel
scheme with the same three phases [Karypis & Kumar 1998]:
  1. coarsen by (parallel) heavy-edge matching until small,
  2. initial bisection by greedy BFS region growing from a pseudo-random
     seed (best of several trials),
  3. uncoarsen + boundary refinement (vectorized FM-style passes that move
     the best-gain boundary vertices under a balance constraint).

`metis_order` = recursive bisection ordering: vertices of part 0 before
part 1 at every level (locality clustering, the reordering the paper uses).
`metis_partition` = k-way labels for partition-aware distribution.
"""
from __future__ import annotations

import numpy as np

from ..sparse.csr import CSRMatrix
from . import graphutil
from .graphutil import Graph


def _initial_bisection(g: Graph, rng: np.random.Generator, trials: int = 4) -> np.ndarray:
    """Greedy BFS growing: grow side 1 from a seed until half the vertex
    weight is absorbed. Returns best side array over `trials` seeds."""
    m = g.m
    total = g.vwgt.sum()
    best_side, best_cut = None, np.inf
    for t in range(trials):
        seed = int(rng.integers(0, m))
        side = np.zeros(m, dtype=np.int8)
        side[seed] = 1
        wgt = g.vwgt[seed]
        frontier = np.array([seed])
        visited = np.zeros(m, dtype=bool)
        visited[seed] = True
        while wgt < total / 2 and frontier.size:
            idx = np.concatenate([np.arange(g.indptr[v], g.indptr[v + 1]) for v in frontier])
            nbrs = np.unique(g.indices[idx]) if idx.size else np.empty(0, dtype=np.int64)
            nbrs = nbrs[~visited[nbrs]]
            if nbrs.size == 0:
                # disconnected: jump to an unvisited vertex
                rest = np.flatnonzero(~visited)
                if rest.size == 0:
                    break
                nbrs = rest[:1]
            # absorb greedily until the budget is hit
            cw = np.cumsum(g.vwgt[nbrs])
            take = int(np.searchsorted(cw, total / 2 - wgt, side="left")) + 1
            nbrs = nbrs[:take]
            side[nbrs] = 1
            visited[nbrs] = True
            wgt += g.vwgt[nbrs].sum()
            frontier = nbrs
        cut = graphutil.edge_cut(g, side)
        if cut < best_cut:
            best_cut, best_side = cut, side
    return best_side


def _refine(g: Graph, side: np.ndarray, passes: int = 4, tol: float = 0.05) -> np.ndarray:
    """Vectorized FM-flavoured refinement: per pass, compute gain for every
    vertex (external - internal weight), move the highest-gain prefix that
    keeps the partition within `tol` balance, stop when no positive gain."""
    total = g.vwgt.sum()
    side = side.copy()
    for _ in range(passes):
        w0, w1 = graphutil.neighbor_side_weights(g, side)
        # gain of flipping v: weight to other side - weight to own side
        own = np.where(side == 1, w1, w0)
        other = np.where(side == 1, w0, w1)
        gain = other - own
        cand = np.flatnonzero(gain > 0)
        if cand.size == 0:
            break
        cand = cand[np.argsort(-gain[cand], kind="stable")]
        # balance bookkeeping: process in gain order, accept while balanced.
        wgt1 = float((g.vwgt * (side == 1)).sum())
        lim_lo, lim_hi = total * (0.5 - tol), total * (0.5 + tol)
        flipped = 0
        # vectorized approximation: accept the best prefix whose net weight
        # shift keeps balance; conflicts (adjacent flips) are accepted — the
        # next pass repairs any regression, and we keep the best seen cut.
        delta = np.where(side[cand] == 1, -g.vwgt[cand], g.vwgt[cand])
        run = wgt1 + np.cumsum(delta)
        ok = (run >= lim_lo) & (run <= lim_hi)
        # take at most the first half of candidates to damp oscillation
        limit = max(1, cand.size // 2)
        sel = cand[:limit][ok[:limit]]
        if sel.size == 0:
            break
        side[sel] ^= 1
        flipped = sel.size
        if flipped == 0:
            break
    return side


def bisect(g: Graph, rng: np.random.Generator, coarse_target: int = 96) -> np.ndarray:
    """Multilevel bisection of g. Returns side int8[m]."""
    graphs = [g]
    cmaps = []
    cur = g
    while cur.m > coarse_target:
        match = graphutil.heavy_edge_matching(cur, rng)
        if (match == np.arange(cur.m)).all():
            break  # no edges / cannot coarsen
        nxt, cmap = graphutil.coarsen(cur, match)
        if nxt.m >= cur.m * 0.95:
            break  # diminishing returns
        graphs.append(nxt)
        cmaps.append(cmap)
        cur = nxt
    side = _initial_bisection(cur, rng)
    side = _refine(cur, side)
    for gph, cmap in zip(reversed(graphs[:-1]), reversed(cmaps)):
        side = side[cmap]  # project
        side = _refine(gph, side)
    return side


def _recursive_order(g: Graph, vertices: np.ndarray, rng: np.random.Generator,
                     leaf: int, out: list) -> None:
    if vertices.size <= leaf:
        out.append(vertices)
        return
    sub = graphutil.subgraph(g, vertices)
    side = bisect(sub, rng)
    left = vertices[side == 0]
    right = vertices[side == 1]
    if left.size == 0 or right.size == 0:
        out.append(vertices)
        return
    _recursive_order(g, left, rng, leaf, out)
    _recursive_order(g, right, rng, leaf, out)


def metis_order(mat: CSRMatrix, seed: int = 0, leaf: int | None = None,
                degree_weighted: bool = False) -> np.ndarray:
    """Recursive-bisection locality ordering (perm[i] = old row at pos i)."""
    g = graphutil.from_matrix(mat, degree_weighted=degree_weighted)
    rng = np.random.default_rng(seed)
    # cap recursion depth on big matrices: locality plateaus past
    # ~32 partitions while cost keeps growing linearly
    leaf = leaf or max(64, mat.m // 32)
    out: list = []
    _recursive_order(g, np.arange(mat.m, dtype=np.int64), rng, leaf, out)
    return np.concatenate(out)


def metis_partition(mat: CSRMatrix, k: int, seed: int = 0) -> np.ndarray:
    """k-way labels via recursive bisection (k a power of two rounds up)."""
    g = graphutil.from_matrix(mat)
    rng = np.random.default_rng(seed)
    labels = np.zeros(mat.m, dtype=np.int64)
    parts = [np.arange(mat.m, dtype=np.int64)]
    levels = int(np.ceil(np.log2(max(k, 1))))
    for _ in range(levels):
        nxt = []
        for p in parts:
            if p.size <= 1:
                nxt.append(p)
                continue
            sub = graphutil.subgraph(g, p)
            side = bisect(sub, rng)
            nxt.append(p[side == 0])
            nxt.append(p[side == 1])
        parts = nxt
    for i, p in enumerate(parts):
        labels[p] = i
    return labels
