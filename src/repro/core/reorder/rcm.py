"""Reverse Cuthill-McKee ordering (paper §2.1, the sequential-case winner).

Own implementation (validated in tests against
scipy.sparse.csgraph.reverse_cuthill_mckee):
  * pseudo-peripheral start vertex per connected component (George & Liu
    double-BFS heuristic),
  * BFS visiting neighbours in order of increasing degree,
  * final ordering reversed.

Vectorized level-by-level BFS: each frontier expansion is one numpy gather +
lexsort, so cost is O(levels) python overhead, O(nnz) work.
"""
from __future__ import annotations

import numpy as np

from ..sparse.csr import CSRMatrix
from . import graphutil


def _bfs_levels(g: graphutil.Graph, start: int, component_mask: np.ndarray):
    """Level sets of BFS from start (within component). Returns (levels list,
    level id array)."""
    m = g.m
    level = np.full(m, -1, dtype=np.int64)
    level[start] = 0
    frontier = np.array([start], dtype=np.int64)
    levels = [frontier]
    lv = 0
    while True:
        # gather all neighbours of frontier (vectorized range concat)
        counts = g.indptr[frontier + 1] - g.indptr[frontier]
        if counts.sum() == 0:
            break
        nbrs = g.indices[_ranges(g.indptr, frontier, counts)]
        nbrs = np.unique(nbrs)
        nbrs = nbrs[(level[nbrs] < 0) & component_mask[nbrs]]
        if nbrs.size == 0:
            break
        lv += 1
        level[nbrs] = lv
        frontier = nbrs
        levels.append(frontier)
    return levels, level


def _cm_component_exact(g, deg, visited, comp_seed, order, pos):
    """Classic per-vertex Cuthill-McKee queue (exact; O(m) python loop)."""
    queue = [comp_seed]
    visited[comp_seed] = True
    head = 0
    while head < len(queue):
        v = queue[head]
        head += 1
        order[pos] = v
        pos += 1
        nb = g.indices[g.indptr[v]:g.indptr[v + 1]]
        nb = nb[~visited[nb]]
        if nb.size:
            nb = np.unique(nb)  # dedup parallel edges
            nb = nb[~visited[nb]]
            nb = nb[np.argsort(deg[nb], kind="stable")]
            visited[nb] = True
            queue.extend(nb.tolist())
    return pos


def _cm_component_leveled(g, deg, visited, comp_seed, order, pos):
    """Level-vectorized Cuthill-McKee: each BFS level is ordered by
    (position of first parent, degree) via one lexsort — the standard
    parallel-CM relaxation (identical level sets, near-identical in-level
    order). O(levels) python overhead instead of O(m)."""
    m = g.m
    rank = np.full(m, np.iinfo(np.int64).max, dtype=np.int64)
    visited[comp_seed] = True
    frontier = np.array([comp_seed], dtype=np.int64)
    rank[comp_seed] = 0
    while frontier.size:
        order[pos:pos + frontier.size] = frontier
        pos += frontier.size
        counts = g.indptr[frontier + 1] - g.indptr[frontier]
        if counts.sum() == 0:
            break
        idx = np.concatenate([np.arange(g.indptr[v], g.indptr[v + 1]) for v in frontier]) \
            if frontier.size < 128 else _ranges(g.indptr, frontier, counts)
        nbrs = g.indices[idx]
        parent_rank = np.repeat(rank[frontier], counts)
        fresh = ~visited[nbrs]
        nbrs, parent_rank = nbrs[fresh], parent_rank[fresh]
        if nbrs.size == 0:
            break
        # min parent rank per child
        orderv = np.lexsort((parent_rank, nbrs))
        nb_s = nbrs[orderv]
        first = np.ones(nb_s.size, dtype=bool)
        first[1:] = nb_s[1:] != nb_s[:-1]
        kids = nb_s[first]
        kid_parent = parent_rank[orderv][first]
        sortk = np.lexsort((deg[kids], kid_parent))
        kids = kids[sortk]
        visited[kids] = True
        rank[kids] = np.arange(kids.size)
        frontier = kids
    return pos


def _ranges(indptr, verts, counts):
    """Concatenated index ranges [indptr[v], indptr[v+1]) — vectorized."""
    total = int(counts.sum())
    out = np.ones(total, dtype=np.int64)
    starts = np.zeros(len(verts) + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    out[starts[:-1]] = indptr[verts]
    out[starts[1:-1]] -= indptr[verts[:-1]] + counts[:-1] - 1
    return np.cumsum(out)


def rcm_order(mat: CSRMatrix, seed: int = 0) -> np.ndarray:
    """Returns perm with perm[i] = original row at new position i.

    Exact queue-CM for small matrices; level-vectorized CM above 100k rows
    (same algorithmic definition, lexsort tie-break per level)."""
    g = graphutil.from_matrix(mat)
    m = g.m
    deg = g.degrees()
    visited = np.zeros(m, dtype=bool)
    order = np.empty(m, dtype=np.int64)
    component = _cm_component_exact if m <= 100_000 else _cm_component_leveled
    pos = 0
    # iterate components in order of their min-degree vertex (deterministic)
    while pos < m:
        remaining = np.flatnonzero(~visited)
        comp_seed = _pseudo_peripheral_masked(g, remaining, deg, visited)
        pos = component(g, deg, visited, comp_seed, order, pos)
    return order[::-1].copy()  # the Reverse in RCM


def _pseudo_peripheral_masked(g, remaining, deg, visited):
    mask = ~visited
    start = remaining[np.argmin(deg[remaining])]
    best_ecc = -1
    for _ in range(4):
        levels, _ = _bfs_levels(g, int(start), mask)
        ecc = len(levels) - 1
        if ecc <= best_ecc:
            break
        best_ecc = ecc
        last = levels[-1]
        start = last[np.argmin(deg[last])]
    return int(start)
