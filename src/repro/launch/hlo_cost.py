"""HLO cost walker: scan-aware FLOP / byte / collective accounting.

XLA's compiled.cost_analysis() counts a `while` body ONCE, so models built
on lax.scan (all of ours — layers, microbatches, KV chunks) are undercounted
by the trip count. This walker parses the scheduled HLO text, builds the
computation call graph, and multiplies through `known_trip_count`:

  flops      — 2 * numel(result) * prod(lhs contracting dims) per dot
               (matmul flops; elementwise excluded, dots dominate these models)
  bytes      — sum over fusion/dot/copy/collective ops of
               (operand bytes + result bytes): post-fusion HBM traffic model
  collectives— operand bytes per kind x trip counts (feeds the roofline's
               collective term; same conventions as hlo.collective_bytes)

Validated against closed-form matmul counts in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?.*?\)?)\s*([a-z][a-z0-9\-]*)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r"known_trip_count\":\{\"n\":\"(\d+)\"")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_SET_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_SET_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes_all(type_str: str) -> int:
    return sum(_numel(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_RE.findall(type_str))


def _numel(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class Op:
    name: str
    kind: str
    type_str: str
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    ops: Dict[str, Op] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line)
        if mc and ("->" in line):
            cur = Computation(mc.group(1))
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        md = _DEF_RE.match(line)
        if not md:
            continue
        name, type_str, kind = md.groups()
        # operand names: %refs inside the first paren group
        rest = line[md.end():]
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = re.findall(r"%([\w\.\-]+)", rest[:end])
        op = Op(name, kind, type_str, operands, line)
        cur.ops[name] = op
        cur.order.append(name)
    return comps


class CostWalker:
    def __init__(self, comps: Dict[str, Computation]):
        self.comps = comps
        self._memo: Dict[str, Tuple[float, float, dict]] = {}

    def _op_shape_bytes(self, comp: Computation, opname: str) -> int:
        op = comp.ops.get(opname)
        return _shape_bytes_all(op.type_str) if op else 0

    def _root_kind(self, comp_name: Optional[str]) -> str:
        comp = self.comps.get(comp_name or "")
        if comp is None or not comp.order:
            return ""
        for on in comp.order:
            if "ROOT" in comp.ops[on].line:
                return comp.ops[on].kind
        return comp.ops[comp.order[-1]].kind

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        result_elems = sum(_numel(dims) for _, dims in
                           _SHAPE_RE.findall(op.type_str))
        m = _LHS_CDIMS_RE.search(op.line)
        if not m or not op.operands:
            return 2.0 * result_elems  # degenerate
        lhs = comp.ops.get(op.operands[0])
        if lhs is None:
            return 2.0 * result_elems
        shapes = _SHAPE_RE.findall(lhs.type_str)
        if not shapes:
            return 2.0 * result_elems
        lhs_dims = shapes[0][1].split(",") if shapes[0][1] else []
        k = 1
        for ci in (m.group(1).split(",") if m.group(1) else []):
            idx = int(ci)
            if idx < len(lhs_dims):
                k *= int(lhs_dims[idx])
        return 2.0 * result_elems * k

    def comp_cost(self, name: str) -> Tuple[float, float, dict]:
        """Returns (flops, bytes, collectives dict) for one execution."""
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        if comp is None:
            return 0.0, 0.0, {}
        flops = 0.0
        bytes_ = 0.0
        coll: Dict[str, float] = defaultdict(float)
        for opname in comp.order:
            op = comp.ops[opname]
            kind = op.kind
            base = kind.removesuffix("-start")
            if kind == "dot":
                flops += self._dot_flops(comp, op)
                bytes_ += _shape_bytes_all(op.type_str) + sum(
                    self._op_shape_bytes(comp, o) for o in op.operands)
            elif kind == "fusion":
                m = _CALLS_RE.search(op.line)
                called = m.group(1) if m else None
                if called:
                    f, b, c = self.comp_cost(called)
                    flops += f
                    for k, v in c.items():
                        coll[k] += v
                result_b = _shape_bytes_all(op.type_str)
                operand_b = [self._op_shape_bytes(comp, o) for o in op.operands]
                total = result_b + sum(operand_b)
                # in-place / sliced-access fusions: a fused
                # dynamic-update-slice aliases its buffer (read+write only
                # the slice); dynamic-slice / gather read only the slice.
                # Billing the whole buffer makes decode look 100-1000x more
                # memory-bound than it is (KV caches in the layer scan).
                root = self._root_kind(called)
                if root == "dynamic-update-slice" and operand_b:
                    total -= 2 * max(operand_b)
                elif root in ("dynamic-slice", "gather") and operand_b:
                    total -= max(operand_b)
                bytes_ += max(total, result_b // 64, 0)
            elif kind == "while":
                m = _BODY_RE.search(op.line)
                trips = 1
                mt = _TRIP_RE.search(op.line)
                if mt:
                    trips = int(mt.group(1))
                if m:
                    f, b, c = self.comp_cost(m.group(1))
                    flops += f * trips
                    bytes_ += b * trips
                    for k, v in c.items():
                        coll[k] += v * trips
            elif kind in ("call", "async-start"):
                m = _TOAPPLY_RE.search(op.line) or _CALLS_RE.search(op.line)
                if m:
                    f, b, c = self.comp_cost(m.group(1))
                    flops += f
                    bytes_ += b
                    for k, v in c.items():
                        coll[k] += v
            elif kind == "conditional":
                m = _COND_BRANCH_RE.search(op.line)
                if m:
                    branches = re.findall(r"%?([\w\.\-]+)", m.group(1))
                    costs = [self.comp_cost(b) for b in branches]
                    if costs:
                        f, b, c = max(costs, key=lambda t: t[0] + t[1])
                        flops += f
                        bytes_ += b
                        for k, v in c.items():
                            coll[k] += v
            elif base in COLLECTIVES and not kind.endswith("-done"):
                result_bytes = _shape_bytes_all(op.type_str)
                g = _group_size(op.line)
                if base == "all-gather":
                    operand = result_bytes / max(g, 1)
                    wire = result_bytes * (g - 1) / max(g, 1)
                elif base == "reduce-scatter":
                    operand = result_bytes * g
                    wire = result_bytes * (g - 1)
                elif base == "all-reduce":
                    operand = result_bytes
                    wire = 2 * result_bytes * (g - 1) / max(g, 1)
                elif base == "all-to-all":
                    operand = result_bytes
                    wire = result_bytes * (g - 1) / max(g, 1)
                else:
                    operand = result_bytes
                    wire = result_bytes
                coll[base] += operand
                coll[base + "_count"] += 1
                coll["wire"] += wire
                bytes_ += result_bytes + operand
            elif kind == "dynamic-update-slice":
                # in-place: read+write the UPDATE (operand 1), not the buffer
                upd = (self._op_shape_bytes(comp, op.operands[1])
                       if len(op.operands) > 1 else 0)
                bytes_ += 2 * upd
            elif kind in ("dynamic-slice", "gather"):
                bytes_ += _shape_bytes_all(op.type_str)  # slice read+write
            elif kind in ("copy", "copy-start", "transpose", "reshape",
                          "broadcast", "scatter", "sort",
                          "reduce", "convert", "iota", "concatenate", "pad",
                          "slice", "select-and-scatter", "reverse", "rng",
                          "compare", "add", "multiply", "subtract", "divide",
                          "exponential", "tanh", "select"):
                bytes_ += _shape_bytes_all(op.type_str)
        res = (flops, bytes_, dict(coll))
        self._memo[name] = res
        return res


def analyze_text(hlo_text: str, entry: Optional[str] = None) -> dict:
    comps = parse_module(hlo_text)
    if not comps:
        return {"flops": 0, "bytes": 0, "collectives": {}}
    if entry is None:
        # entry computation: the one marked ENTRY (first in file heuristics)
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
        entry = m.group(1) if m else next(iter(comps))
    walker = CostWalker(comps)
    flops, bytes_, coll = walker.comp_cost(entry)
    coll["total"] = sum(v for k, v in coll.items() if k in COLLECTIVES)
    return {"flops": flops, "bytes": bytes_, "collectives": coll}
