"""HLO-text analysis: collective-byte accounting for the roofline.

cost_analysis() has no collective term, so we parse the compiled (SPMD,
per-device) HLO and account bytes for every communication op
(spec: ROOFLINE ANALYSIS).

The scheduled-HLO rendering shows only RESULT types on op lines
(`%all-gather = f32[64,64]{0,1} all-gather(%bitcast), replica_groups=...`),
so per-op OPERAND bytes are derived from the result + group size:
    all-reduce:          operand = result
    all-gather:          operand = result / group_size
    reduce-scatter:      operand = result * group_size
    all-to-all:          operand = result
    collective-permute:  operand = result
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(
    r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(r"=\s*(\(?[^)=]*?\)?)\s*([a-z][a-z0-9\-]*)\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_SET_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_SET_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-kind operand bytes (+ 'total', 'wire') for one device's program.

    'wire' estimates bytes actually moved per device with ring algorithms:
      all-reduce 2*S*(g-1)/g, all-gather/reduce-scatter S*(g-1)/g,
      all-to-all S*(g-1)/g, collective-permute S.
    """
    out: Dict[str, float] = defaultdict(float)
    wire = 0.0
    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(2)
        base = op.removesuffix("-start")
        if base not in COLLECTIVES or op.endswith("-done"):
            continue
        result_bytes = sum(_shape_bytes(dt, dims)
                           for dt, dims in _SHAPE_RE.findall(m.group(1)))
        if result_bytes == 0:  # result type may sit left of '=' oddly; fallback
            result_bytes = sum(_shape_bytes(dt, dims)
                               for dt, dims in _SHAPE_RE.findall(line[:m.start(2)]))
        g = max(_group_size(line), 1)
        if base == "all-gather":
            operand = result_bytes / g
            wire += result_bytes * (g - 1) / g
        elif base == "reduce-scatter":
            operand = result_bytes * g
            wire += result_bytes * (g - 1)
        elif base == "all-reduce":
            operand = result_bytes
            wire += 2 * result_bytes * (g - 1) / g
        elif base == "all-to-all":
            operand = result_bytes
            wire += result_bytes * (g - 1) / g
        else:  # collective-permute
            operand = result_bytes
            wire += result_bytes
        out[base] += operand
        out[base + "_count"] += 1
    out["total"] = sum(v for k, v in out.items() if k in COLLECTIVES)
    out["wire"] = wire
    return {k: (int(v) if not k.endswith("_count") else int(v))
            for k, v in out.items()}


def op_histogram(hlo_text: str, ops=("fusion", "dot", "custom-call",
                                     "while", "dynamic-update-slice")) -> Dict[str, int]:
    hist: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line.strip())
        if m and m.group(2) in ops:
            hist[m.group(2)] += 1
    return dict(hist)
