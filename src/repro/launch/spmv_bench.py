import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede jax import (same rule as dryrun.py).
#
# Distributed-SpMV dry-run: the paper's own workload (--arch spmv) on the
# production mesh. Lowers the 1-D (row panels + x all-gather) and 2-D
# (rows x cols + partial-y reduce) layouts for a synthetic 4.2M-row matrix
# and reports the collective bytes of each — the DESIGN.md §4 / EXPERIMENTS
# beyond-paper comparison, measured from HLO rather than claimed.

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..core.spmv import ref
from . import hlo_cost
from .mesh import make_production_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results")


def _fname(name: str) -> str:
    """Filesystem-safe matrix tag: corpus://group/name -> corpus_group_name
    (corpus names carry URL-ish separators that would split the path)."""
    import re

    return re.sub(r"[:/]+", "_", name).strip("_")

# synthetic production matrix: 4.19M rows, ~16 nnz/row, 8x128 bricks
M_ROWS = 1 << 22
BM, BN = 8, 128
K_1D = 32          # padded blocks per block-row (1-D panels)
ITERS = 8          # CG-like repeated SpMV (xs swap)


def lower_1d(mesh: Mesh):
    n_dev = mesh.devices.size
    nbr_l = M_ROWS // n_dev // BM
    panel_n = M_ROWS // n_dev
    axes = tuple(mesh.axis_names)

    def step(blocks, cols, x):
        def body(b, c, xl):
            def one(x_local, _):
                # CG dataflow: the updated (panel-sharded) vector must be
                # re-gathered EVERY iteration — the 1-D layout's cost.
                xs = jax.lax.all_gather(x_local, axes, tiled=True)
                y = ref.spmv_bell(b[0], c[0], xs.reshape(-1, BN, 1))
                return y.reshape(-1)[:panel_n], None
            xf, _ = jax.lax.scan(one, xl[0], None, length=ITERS)
            return xf[None]
        f = shard_map(body, mesh=mesh, in_specs=(P(axes), P(axes), P(axes)),
                      out_specs=P(axes))
        return f(blocks, cols, x)

    blocks = jax.ShapeDtypeStruct((n_dev, nbr_l, K_1D, BM, BN), jnp.float32,
                                  sharding=NamedSharding(mesh, P(axes)))
    cols = jax.ShapeDtypeStruct((n_dev, nbr_l, K_1D), jnp.int32,
                                sharding=NamedSharding(mesh, P(axes)))
    x = jax.ShapeDtypeStruct((n_dev, panel_n), jnp.float32,
                             sharding=NamedSharding(mesh, P(axes)))
    return jax.jit(step).lower(blocks, cols, x)


def lower_2d(mesh: Mesh):
    d, m = mesh.shape["data"], mesh.shape["model"]
    nbr_l = M_ROWS // d // BM
    seg_n = M_ROWS // m
    k2 = max(K_1D // m, 1) * (1 if K_1D // m else 1)
    k2 = max(K_1D // m, 2)

    def step(blocks, cols, x_segs):
        def body(b, c, xl):
            def one(x_, _):
                y = ref.spmv_bell(b[0, 0], c[0, 0], x_.reshape(-1, BN, 1))
                y = jax.lax.psum(y.reshape(-1), "model")     # combine partials
                # next x segment for THIS model rank = slice of y (CG swap):
                x_next = jax.lax.dynamic_slice_in_dim(
                    y, 0, seg_n // d if seg_n // d else seg_n, 0)
                x_next = jax.lax.all_gather(x_next, "data", tiled=True)
                return x_next[:x_.shape[0]], None
            x0 = jax.lax.pcast(xl[0], ("data",), to="varying")
            xf, _ = jax.lax.scan(one, x0, None, length=ITERS)
            return xf[None]
        f = shard_map(body, mesh=mesh,
                      in_specs=(P("data", "model"), P("data", "model"),
                                P("model")),
                      out_specs=P("model"), check_rep=False)
        return f(blocks, cols, x_segs)

    blocks = jax.ShapeDtypeStruct((d, m, nbr_l, k2, BM, BN), jnp.float32,
                                  sharding=NamedSharding(mesh, P("data", "model")))
    cols = jax.ShapeDtypeStruct((d, m, nbr_l, k2), jnp.int32,
                                sharding=NamedSharding(mesh, P("data", "model")))
    x = jax.ShapeDtypeStruct((m, seg_n), jnp.float32,
                             sharding=NamedSharding(mesh, P("model")))
    return jax.jit(step).lower(blocks, cols, x)


def lower_halo(mesh: Mesh, halo: int = 128):
    """RCM-enabled halo exchange (bandwidth <= halo after reordering):
    two ring permutes instead of the all-gather; K=2 blocks per block row
    (banded structure)."""
    n_dev = mesh.devices.size
    panel_n = M_ROWS // n_dev
    nbr_l = panel_n // BM
    axes = tuple(mesh.axis_names)
    axname = axes if len(axes) > 1 else axes[0]

    def step(blocks, cols, x):
        def body(b, c, xl):
            def one(x_local, _):
                nd = n_dev  # static: ring pairs must be concrete
                fwd = [(i, (i + 1) % nd) for i in range(nd)]
                bwd = [((i + 1) % nd, i) for i in range(nd)]
                lh = jax.lax.ppermute(x_local[-halo:], axname, fwd)
                rh = jax.lax.ppermute(x_local[:halo], axname, bwd)
                xw = jnp.concatenate([lh, x_local, rh])
                y = ref.spmv_bell(b[0], c[0], xw.reshape(-1, BN, 1))
                return y.reshape(-1)[:panel_n], None
            xf, _ = jax.lax.scan(one, xl[0], None, length=ITERS)
            return xf[None]
        f = shard_map(body, mesh=mesh, in_specs=(P(axes), P(axes), P(axes)),
                      out_specs=P(axes))
        return f(blocks, cols, x)

    k_halo = 2  # banded window spans <= 2 column blocks per block row
    blocks = jax.ShapeDtypeStruct((n_dev, nbr_l, k_halo, BM, BN), jnp.float32,
                                  sharding=NamedSharding(mesh, P(axes)))
    cols = jax.ShapeDtypeStruct((n_dev, nbr_l, k_halo), jnp.int32,
                                sharding=NamedSharding(mesh, P(axes)))
    x = jax.ShapeDtypeStruct((n_dev, panel_n), jnp.float32,
                             sharding=NamedSharding(mesh, P(axes)))
    return jax.jit(step).lower(blocks, cols, x)


def run_parallel(matrix: str, scheme: str = "baseline", engine: str = "auto",
                 devices: int = 8, layout: str = "1d_rows",
                 partition: str = "nnz_balanced", iters: int = 6,
                 write_results: bool = True, k: int = 1,
                 use_store: bool = True) -> dict:
    """Sharded-SpMV benchmark for one (matrix, scheme, topology) cell.

    One one-cell "parallel"-kind ExperimentSpec through the experiment
    harness — the same content-addressed result store as the fig09-11
    campaigns, so a repeat invocation is a pure store hit. The cell plans
    through the topology-aware facade (partition x scheme x engine joint
    selection when either is "auto"), verifies the ShardedOperator
    against the numpy oracle in the ORIGINAL index space, and reports the
    modelled collective bytes of the chosen schedule next to the
    modelled-parallel timing."""
    from ..experiments import ExperimentSpec, MeasurePolicy, ResultStore, \
        Runner
    from ..experiments.cells import parallel_variant

    if devices < 2:
        raise ValueError(f"--devices must be >= 2 in parallel mode, "
                         f"got {devices}")
    spec = ExperimentSpec(
        name="spmv_parallel_single", matrices=(matrix,), schemes=(scheme,),
        engines=(engine,), ps=(devices,), ks=(k,), kind="parallel",
        variants=(parallel_variant(layout, partition),),
        policy=MeasurePolicy(iters=iters, verify=True, with_yax=False,
                             with_parallel=False, with_metrics=False))
    store = ResultStore(results_dir=RESULTS)
    if not use_store:                       # --fresh: force a re-measure
        store.delete(spec.cells()[0].key())
    rep = Runner(spec, store=store, verbose=False).run()
    cr = rep.records[0]
    rec = {
        "matrix": matrix, "scheme": scheme,
        "resolved_scheme": cr["resolved_scheme"],
        "engine": cr["engine"], "plan_label": cr["plan_label"],
        "devices": devices, "layout": layout,
        "partitioner": cr["partitioner"],
        "store_hit": cr["store_reused"], "cell_key": cr["cell_key"],
        "comm_schedule": cr["comm_schedule"],
        "comm_bytes_per_spmv": cr["comm_bytes_per_spmv"],
        "li": cr["li"], "cut_volume": cr["cut_volume"],
        "halo_width": cr["halo_width"],
        "reorder_ms": cr["reorder_ms"], "tune_ms": cr["tune_ms"],
        "modelled_par_ms": cr["modelled_par_ms"],
        "gflops": cr["gflops"],
        "verify_rel_err": cr["verify_rel_err"],
        "simulated": cr["simulated"],
    }
    print(f"[spmv-parallel] {matrix}/{scheme} {layout} p={devices} "
          f"partition={rec['partitioner']} engine={rec['engine']} "
          f"sched={rec['comm_schedule']} "
          f"comm={rec['comm_bytes_per_spmv']:.0f}B li={rec['li']:.3f} "
          f"par_ms={rec['modelled_par_ms']:.3f} "
          f"store_hit={rec['store_hit']} sim={rec['simulated']} "
          f"err={rec['verify_rel_err']:.2e}", flush=True)
    if write_results:
        os.makedirs(RESULTS, exist_ok=True)
        out = os.path.join(
            RESULTS, f"spmv_parallel_{_fname(matrix)}_{scheme}_{layout}"
                     f"_p{devices}.json")
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def run_single(matrix: str, scheme: str = "baseline", engine: str = "auto",
               iters: int = 12, probe=False,
               write_results: bool = True, k: int = 1,
               use_store: bool = True) -> dict:
    """Single-node tuned SpMV/SpMM benchmark for one (matrix, scheme) cell.

    One one-cell ExperimentSpec through the experiment harness
    (repro.experiments), measured into the SAME content-addressed result
    store the benchmark campaigns use: the first invocation pays reorder +
    tune + format conversion (the plan store persists those) and the
    measurement itself; a repeat invocation is served entirely from the
    result store (`store_hit=true`, zero new measurement). `--fresh`
    (use_store=False) forces a re-measure. Plan-time and run-time are
    reported separately (paper §3 methodology — preprocessing is never
    folded into SpMV time).

    scheme may be "auto": the planner jointly selects (scheme, engine);
    the resolved choice is reported as `resolved_scheme`.

    k > 1 (--spmm) times the k-RHS SpMM path `op.matmul(X[n, k])` with a
    k-specialized tuning plan and reports amortized per-vector time.

    probe accepts the full plan() mode set: False (cost model), True
    (--probe: top candidates), "learned" (--learned: the TuneAdvisor
    shortlist), "exhaustive".
    """
    from ..experiments import (ExperimentSpec, MeasurePolicy, ResultStore,
                               Runner)

    if k < 1:
        raise ValueError(f"--spmm batch width must be >= 1, got {k}")
    spec = ExperimentSpec(
        name="spmv_single", matrices=(matrix,), schemes=(scheme,),
        engines=(engine,), ks=(k,),
        policy=MeasurePolicy(iters=iters, probe=probe, with_yax=False,
                             with_parallel=False, with_metrics=False))
    store = ResultStore(results_dir=RESULTS)
    if not use_store:                       # --fresh: force a re-measure
        store.delete(spec.cells()[0].key())
    rep = Runner(spec, store=store, verbose=False).run()
    cr = rep.records[0]
    store_hit = cr["store_reused"]
    med = cr["spmm_ms"]
    rec = {
        "matrix": matrix,
        "scheme": scheme,
        "resolved_scheme": cr["resolved_scheme"],
        "engine": cr["engine"],
        "plan_label": cr["plan_label"],
        "cache_hit": cr["op_cache_hit"],
        "store_hit": store_hit,
        "cell_key": cr["cell_key"],
        "k": k,
        "reorder_ms": cr["reorder_ms"],
        "tune_ms": cr["tune_ms"],
        "build_ms": cr["format_build_ms"],
        "load_ms": cr["op_load_ms"],
        "spmv_ios_ms": med,
        "per_vector_ms": cr["per_vector_ms"],
        "spmv_ios_gflops": cr.get("spmm_gflops", cr.get("seq_ios_gflops")),
    }
    tag = "spmm" if k > 1 else "spmv"
    print(f"[{tag}-single] {matrix}/{scheme} engine={rec['engine']} k={k} "
          f"store_hit={store_hit} cache_hit={rec['cache_hit']} plan_ms="
          f"{rec['tune_ms'] + rec['build_ms'] + rec['load_ms']:.1f} "
          f"{tag}_ms={med:.3f} per_vec_ms={rec['per_vector_ms']:.3f}",
          flush=True)
    if write_results:
        os.makedirs(RESULTS, exist_ok=True)
        suffix = f"_k{k}" if k > 1 else ""      # SpMM never clobbers SpMV
        out = os.path.join(
            RESULTS, f"spmv_single_{_fname(matrix)}_{scheme}{suffix}.json")
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def run_serve_sim(matrices=("smoke_banded", "smoke_powerlaw", "smoke_rmat"),
                  requests: int = 48, max_batch: int = 8,
                  window_ms: float = 20.0, engine: str = "auto",
                  reorder: str = "baseline", seed: int = 0,
                  write_results: bool = True) -> dict:
    """Serving simulation: a burst of mixed (matrix, x) requests through the
    micro-batching SpmvService (serving/spmv_service.py). Verifies every
    response against the numpy oracle and reports coalescing stats.

    reorder != "baseline" exercises the permutation-carrying operators:
    the service reorders internally for locality while requests and
    responses stay in the ORIGINAL index space (the oracle check still
    compares against the unreordered matrix)."""
    from ..matrices import suite
    from ..serving.spmv_service import SpmvService

    mats = {name: suite.get(name) for name in matrices}
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    with SpmvService(engine=engine, reorder=reorder, max_batch=max_batch,
                     window_ms=window_ms) as svc:
        for name, mat in mats.items():
            svc.register(name, mat)
        pending = []
        for _ in range(requests):
            name = list(matrices)[rng.integers(len(matrices))]
            x = rng.standard_normal(mats[name].n)
            pending.append((name, x, svc.submit(name, x)))
        svc.flush()
        stats = svc.stats()
        max_rel_err = 0.0
        for name, x, fut in pending:
            want = mats[name].spmv(x)
            got = np.asarray(fut.result(timeout=10))
            scale = float(np.abs(want).max()) + 1e-9
            max_rel_err = max(max_rel_err,
                              float(np.abs(got - want).max()) / scale)
    wall_ms = (time.perf_counter() - t0) * 1e3
    rec = {
        "matrices": list(matrices),
        "reorder": reorder,
        "requests": requests,
        "max_batch": max_batch,
        "window_ms": window_ms,
        "wall_ms": wall_ms,
        "batches": stats["batches"],
        "avg_batch": stats["avg_batch"],
        "batch_size_max": stats["batch_size_max"],
        "coalesce_ratio": stats["coalesce_ratio"],
        "avg_wait_ms": stats["avg_wait_ms"],
        "p50_ms": stats["slo"]["p50_ms"],
        "p95_ms": stats["slo"]["p95_ms"],
        "p99_ms": stats["slo"]["p99_ms"],
        "throughput_rps": stats["slo"]["throughput_rps"],
        "wakeups": stats["wakeups"],
        "max_rel_err": max_rel_err,
        "ok": max_rel_err < 1e-4,
    }
    print(f"[serve-sim] {requests} requests over {len(matrices)} matrices -> "
          f"{rec['batches']} SpMM dispatches (avg batch "
          f"{rec['avg_batch']:.1f}, max {rec['batch_size_max']}), "
          f"max_rel_err={max_rel_err:.2e}", flush=True)
    if write_results:
        os.makedirs(RESULTS, exist_ok=True)
        with open(os.path.join(RESULTS, "spmv_serve_sim.json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def run_serve_traffic(matrix: str = "smoke_powerlaw",
                      arrival: str = "poisson", rate_rps: float = 500.0,
                      requests: int = 200, n_keys: int = 4,
                      zipf_s: float = 1.1, update_frac: float = 0.1,
                      structure_frac: float = 0.0,
                      budget_mb: float = 0.0, max_batch: int = 8,
                      window_ms: float = 2.0, max_queue: int = 32,
                      overload: str = "reject", engine: str = "auto",
                      reorder: str = "baseline", devices: int = 1,
                      layout: str = "1d_rows", meshes: int = 2,
                      placement: str = "bin_pack", seed: int = 0,
                      write_results: bool = True) -> dict:
    """Open-loop traffic run against the hardened service (one scenario,
    driven directly — the campaign-shaped path is `benchmarks/run.py
    --smoke-serve` / `--smoke-route`). The matrix is registered under
    n_keys service keys with Zipf-skewed traffic; a budget_mb > 0 memory
    budget makes the operator LRU (eviction + zero-re-tune plan-store
    reload) part of the scenario, update_frac > 0 mixes in no-replan
    value swaps, structure_frac > 0 mixes in StructureDelta background
    replans. devices > 1 serves the keys SHARDED from a
    RoutedSpmvService fleet (`meshes` meshes of `devices` devices each,
    keys placed by `placement`; budget_mb then bounds every DEVICE, not
    the fleet). Reports outcome counts, SLO percentiles and the
    hardening invariants (`ok` = every future — requests and replans —
    resolved + budget respected + counters balance)."""
    from ..matrices import suite
    from ..serving import traffic
    from ..serving.spmv_service import SpmvService

    mat = suite.get(matrix)
    pattern = traffic.TrafficPattern(
        arrival=arrival, rate_rps=rate_rps, requests=requests,
        n_keys=n_keys, zipf_s=zipf_s, update_frac=update_frac,
        structure_frac=structure_frac, seed=seed)
    budget = None if budget_mb <= 0 else int(budget_mb * (1 << 20))
    keys = [f"{matrix}#{i}" for i in range(n_keys)]
    routed = devices > 1
    if routed:
        from ..core.spmv.topology import Topology
        from ..router import MeshSpec, RoutedSpmvService

        fleet = [MeshSpec(f"mesh{i}",
                          Topology(devices=devices, layout=layout),
                          budget_per_device=budget)
                 for i in range(meshes)]
        svc = RoutedSpmvService(fleet, policy=placement, engine=engine,
                                reorder=reorder, max_batch=max_batch,
                                window_ms=window_ms, max_queue=max_queue,
                                overload=overload)
    else:
        svc = SpmvService(engine=engine, reorder=reorder,
                          max_batch=max_batch, window_ms=window_ms,
                          max_queue=max_queue, memory_budget_bytes=budget,
                          overload=overload)
    with svc:
        for k in keys:
            svc.register(k, mat)
        summary = traffic.run_open_loop(svc, {k: mat for k in keys},
                                        pattern)
        svc.flush()
        stats = svc.stats()
    if routed:
        # fleet rollup: worst-mesh SLO, summed build/reload counters
        per = [m["service"] for m in stats["per_mesh"].values()]
        slo = {k: max(s["slo"][k] for s in per)
               for k in ("p50_ms", "p95_ms", "p99_ms", "shed_rate",
                         "eviction_rate")}
        coalesce = max(s["coalesce_ratio"] for s in per)
        op_builds = sum(s["op_builds"] for s in per)
        op_reloads = sum(s["op_reloads"] for s in per)
        resident_max = max(s["resident_bytes_max"] for s in per)
    else:
        slo = stats["slo"]
        coalesce = stats["coalesce_ratio"]
        op_builds = stats["op_builds"]
        op_reloads = stats["op_reloads"]
        resident_max = stats["resident_bytes_max"]
    rec = {
        "matrix": matrix, "n_keys": n_keys, "arrival": arrival,
        "rate_rps": rate_rps, "requests": requests, "zipf_s": zipf_s,
        "update_frac": update_frac, "structure_frac": structure_frac,
        "overload": overload,
        "memory_budget_bytes": budget or 0,
        "offered": summary["offered"], "ok_count": summary["ok"],
        "shed": summary["shed"], "rejected": summary["rejected"],
        "errors": summary["errors"], "unresolved": summary["unresolved"],
        "updates": summary["updates"],
        "structure_updates": summary["structure_updates"],
        "replans_landed": summary["replans_landed"],
        "replan_errors": summary["replan_errors"],
        "replan_unresolved": summary["replan_unresolved"],
        "offered_rps": summary["offered_rps"],
        "achieved_rps": summary["achieved_rps"],
        "p50_ms": slo["p50_ms"], "p95_ms": slo["p95_ms"],
        "p99_ms": slo["p99_ms"], "shed_rate": slo["shed_rate"],
        "eviction_rate": slo["eviction_rate"],
        "coalesce_ratio": coalesce,
        "op_builds": op_builds, "op_reloads": op_reloads,
        "evictions": stats["evictions"],
        "value_swaps": stats["value_swaps"],
        "resident_bytes_max": resident_max,
        "budget_ok": summary["budget_ok"],
        "counters_balanced": (
            stats["requests"] == stats["results"] + stats["sheds"]
            + stats["errors"] and stats["pending"] == 0),
        "ok": (summary["unresolved"] == 0
               and summary["replan_unresolved"] == 0
               and summary["budget_ok"]
               and stats.get("per_device_ok", True)
               and stats["requests"] == stats["results"] + stats["sheds"]
               + stats["errors"]),
    }
    if routed:
        rec.update({
            "devices": devices, "layout": layout, "meshes": meshes,
            "placement": placement, "replans": stats["replans"],
            "per_device_ok": bool(stats["per_device_ok"]),
            "assignments": dict(stats["routing"]["assignments"]),
        })
    fleet_tag = (f" [{meshes}x{devices}dev {layout} {placement}]"
                 if routed else "")
    print(f"[serve-traffic] {matrix} x{n_keys} keys {arrival}@"
          f"{rate_rps:g}rps {overload}{fleet_tag}: ok={rec['ok_count']} "
          f"shed={rec['shed']} rejected={rec['rejected']} "
          f"errors={rec['errors']} unresolved={rec['unresolved']} | "
          f"p50={rec['p50_ms']:.2f}ms p99={rec['p99_ms']:.2f}ms "
          f"coalesce={rec['coalesce_ratio']:.2f} "
          f"evictions={rec['evictions']} reloads={rec['op_reloads']} "
          f"swaps={rec['value_swaps']} "
          f"replans={rec['replans_landed']} budget_ok={rec['budget_ok']}",
          flush=True)
    if write_results:
        os.makedirs(RESULTS, exist_ok=True)
        with open(os.path.join(RESULTS, "spmv_serve_traffic.json"),
                  "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--matrix", default="",
                    help="single-node mode: suite matrix name")
    ap.add_argument("--scheme", default="baseline")
    ap.add_argument("--engine", default="auto")
    ap.add_argument("--probe", action="store_true",
                    help="empirically probe top tuner candidates")
    ap.add_argument("--learned", action="store_true",
                    help="probe only the TuneAdvisor shortlist mined from "
                         "prior campaign cells (plan(probe='learned'))")
    ap.add_argument("--iters", type=int, default=12)
    ap.add_argument("--spmm", type=int, default=1, metavar="K",
                    help="batch width: time K-RHS SpMM instead of SpMV")
    ap.add_argument("--fresh", action="store_true",
                    help="bypass the result store and re-measure the cell")
    ap.add_argument("--devices", type=int, default=1,
                    help="sharded mode: plan a Topology over N devices "
                         "(simulated when the host has fewer)")
    ap.add_argument("--layout", default=None,
                    choices=["1d_rows", "2d_panels"],
                    help="sharded layout (with --devices; default 1d_rows)")
    ap.add_argument("--partition", default=None,
                    help="partitioner name or 'auto' (with --devices; "
                         "default nnz_balanced)")
    ap.add_argument("--serve-sim", action="store_true",
                    help="micro-batching service simulation over smoke "
                         "matrices")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--window-ms", type=float, default=20.0)
    ap.add_argument("--serve-reorder", default="baseline",
                    help="reordering scheme the service applies internally "
                         "(requests stay in the original index space)")
    ap.add_argument("--serve-traffic", action="store_true",
                    help="open-loop traffic run against the hardened "
                         "service (arrivals, Zipf keys, budgets, shedding)")
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "uniform", "bursty"])
    ap.add_argument("--rate", type=float, default=500.0,
                    help="mean offered arrival rate (requests/s)")
    ap.add_argument("--keys", type=int, default=4,
                    help="distinct service keys (Zipf-skewed traffic)")
    ap.add_argument("--zipf", type=float, default=1.1)
    ap.add_argument("--update-frac", type=float, default=0.1,
                    help="fraction of arrivals that are value updates")
    ap.add_argument("--structure-frac", type=float, default=0.0,
                    help="fraction of arrivals that are StructureDelta "
                         "background replans (with --serve-traffic)")
    ap.add_argument("--meshes", type=int, default=2,
                    help="fleet size for routed --serve-traffic "
                         "(--devices > 1: meshes x devices)")
    ap.add_argument("--placement", default="bin_pack",
                    help="router placement policy for routed "
                         "--serve-traffic (bin_pack, nnz_balance, "
                         "comm_aware, or any @register_placement name)")
    ap.add_argument("--budget-mb", type=float, default=0.0,
                    help="operator memory budget in MiB (0 = unbudgeted)")
    ap.add_argument("--max-queue", type=int, default=32)
    ap.add_argument("--overload", default="reject",
                    choices=["reject", "shed-oldest", "degrade-to-k1"])
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="record phase-attributed spans (repro.obs): "
                         ".jsonl -> raw event log, anything else -> "
                         "Chrome-trace JSON (load in ui.perfetto.dev)")
    args = ap.parse_args()
    if not args.trace:
        _dispatch(ap, args)
        return
    from .. import obs

    try:
        with obs.tracing() as buf:
            _dispatch(ap, args)
    finally:
        obs.write_trace(args.trace, buf.flush())
        print(f"# trace: {len(buf)} span events -> {args.trace}",
              flush=True)


def _dispatch(ap, args):
    if args.probe and args.learned:
        ap.error("--probe and --learned are mutually exclusive probe modes")
    probe = "learned" if args.learned else args.probe
    if args.serve_traffic:
        if args.spmm != 1 or probe:
            ap.error("--serve-traffic does not combine with "
                     "--spmm/--probe")
        # --devices > 1 serves routed SHARDED keys from a
        # RoutedSpmvService fleet (--meshes x --devices, --layout,
        # --placement); budget_mb then bounds every device
        rec = run_serve_traffic(
            matrix=args.matrix or "smoke_powerlaw", arrival=args.arrival,
            rate_rps=args.rate, requests=args.requests, n_keys=args.keys,
            zipf_s=args.zipf, update_frac=args.update_frac,
            structure_frac=args.structure_frac,
            budget_mb=args.budget_mb, max_batch=args.max_batch,
            window_ms=args.window_ms, max_queue=args.max_queue,
            overload=args.overload, engine=args.engine,
            reorder=args.serve_reorder, devices=args.devices,
            layout=args.layout or "1d_rows", meshes=args.meshes,
            placement=args.placement)
        if not rec["ok"]:
            raise SystemExit(
                f"serve-traffic invariants FAILED: "
                f"unresolved={rec['unresolved']} "
                f"replan_unresolved={rec['replan_unresolved']} "
                f"budget_ok={rec['budget_ok']} "
                f"per_device_ok={rec.get('per_device_ok', True)} "
                f"counters_balanced={rec['counters_balanced']}")
        return
    if args.serve_sim:
        if args.matrix or args.spmm != 1 or probe:
            ap.error("--serve-sim does not combine with "
                     "--matrix/--spmm/--probe")
        rec = run_serve_sim(requests=args.requests, max_batch=args.max_batch,
                            window_ms=args.window_ms, engine=args.engine,
                            reorder=args.serve_reorder)
        if not rec["ok"]:
            raise SystemExit(
                f"serve-sim verification FAILED: max_rel_err="
                f"{rec['max_rel_err']:.2e}")
        return
    if args.devices <= 1 and (args.layout or args.partition):
        ap.error("--layout/--partition require --devices > 1 "
                 "(sharded single-cell mode)")
    if args.devices > 1 and not args.matrix:
        ap.error("--devices requires --matrix (sharded single-cell mode)")
    if args.matrix and args.devices > 1:
        if probe:
            ap.error("--devices does not combine with --probe "
                     "(sharded plans are model-based)")
        run_parallel(args.matrix, args.scheme, args.engine,
                     devices=args.devices,
                     layout=args.layout or "1d_rows",
                     partition=args.partition or "nnz_balanced",
                     iters=args.iters, k=args.spmm,
                     use_store=not args.fresh)
        return
    if args.matrix:
        run_single(args.matrix, args.scheme, args.engine, iters=args.iters,
                   probe=probe, k=args.spmm,
                   use_store=not args.fresh)
        return
    if args.spmm != 1 or probe:
        ap.error("--spmm/--probe/--learned require --matrix "
                 "(single-cell mode)")
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    out = {}
    for name, fn in [("1d", lower_1d), ("2d", lower_2d), ("halo", lower_halo)]:
        with mesh:
            lowered = fn(mesh)
            compiled = lowered.compile()
        walk = hlo_cost.analyze_text(compiled.as_text())
        out[name] = {
            "flops": walk["flops"],
            "collectives": {k: int(v) for k, v in walk["collectives"].items()},
        }
        print(f"[spmv-{name}] flops/dev={walk['flops']:.3e} "
              f"coll wire/dev={walk['collectives'].get('wire', 0):.3e} B "
              f"(per {ITERS} SpMVs)", flush=True)
    r = (out["1d"]["collectives"].get("wire", 0)
         / max(out["2d"]["collectives"].get("wire", 1), 1))
    out["wire_ratio_1d_over_2d"] = r
    rh = (out["1d"]["collectives"].get("wire", 0)
          / max(out["halo"]["collectives"].get("wire", 1), 1))
    out["wire_ratio_1d_over_halo"] = rh
    print(f"[spmv] 1d/2d wire ratio: {r:.1f}x; 1d/halo: {rh:.0f}x")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "spmv_distributed.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
