import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: device count locks at first init.
#
# Multi-pod dry-run (spec deliverable e): lower + compile every
# (architecture x input shape) on the production meshes and record
# memory/cost/collective analysis for the roofline.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--no-compile]
#
# Results land in benchmarks/results/dryrun/<cell>.json.

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import registry
from ..configs.base import SHAPES
from ..distributed import sharding as SH
from ..models import model as MDL
from ..serving.decode import make_serve_step
from ..training import optimizer as OPT
from ..training import train_loop as TL
from . import hlo as HLO
from . import hlo_cost as HLO_COST
from . import specs as SPECS
from .mesh import dp_axes_of, make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def _param_shardings(cfg, mesh, dtype=jnp.bfloat16, weight_stationary=False):
    """weight_stationary (§Perf serving iteration): drop the FSDP ('data')
    axis from param specs — weights live TP-sharded (model axis) only, so
    decode pays ZERO per-token weight gathers. Affordable because serving
    keeps bf16 weights and no optimizer state (104B: 13 GiB/dev)."""
    pshape = jax.eval_shape(
        lambda k: MDL.init_params(cfg, k, dtype), jax.random.PRNGKey(0))
    specs = SH.param_specs(pshape)
    if weight_stationary:
        from jax.sharding import PartitionSpec as P

        specs = jax.tree_util.tree_map(
            lambda sp: P(*[None if a == "data" else a for a in sp]),
            specs, is_leaf=lambda x: isinstance(x, P))
    specs = SH.validate_specs(pshape, specs, mesh)
    return pshape, SH.named_shardings(specs, mesh)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               microbatches: int | None = None, kv_chunk: int = 1024,
               weight_stationary: bool = False, kv_shard: str = "seq"):
    """Builds and lowers the cell's step function. Returns (lowered, meta)."""
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    if microbatches is None:
        # per-device micro batch of 1 for >50B models, 2 otherwise — but the
        # per-microbatch batch must still cover the dp axes (pod x data)
        microbatches = 16 if cfg.param_count() > 5e10 else 8
        dp_size = (2 * 16) if multi_pod else 16
        microbatches = max(1, min(microbatches,
                                  shape.global_batch // dp_size))
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp_axes = dp_axes_of(mesh)

    with mesh:
        if shape.kind == "train":
            opt_cfg = OPT.OptConfig()
            step, state_sh_fn, _ = TL.make_train_step(
                cfg, opt_cfg, mesh, dp_axes, microbatches=microbatches)
            state_shape = TL.init_state_shape(cfg)
            state_sh = state_sh_fn(state_shape["params"])
            batch = SPECS.batch_specs(cfg, shape, mesh, dp_axes)
            fn = jax.jit(step, in_shardings=(state_sh, None),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
            lowered = fn.lower(state_shape, batch)
        elif shape.kind == "prefill":
            pshape, psh = _param_shardings(cfg, mesh,
                                           weight_stationary=weight_stationary)

            def prefill(params, batch):
                logits, _, _ = MDL.forward(params, batch, cfg, mesh=mesh,
                                           dp_axes=dp_axes, train=False,
                                           kv_chunk=kv_chunk)
                return jnp.argmax(logits[:, -1], axis=-1)

            batch = SPECS.batch_specs(cfg, shape, mesh, dp_axes)
            fn = jax.jit(prefill, in_shardings=(psh, None))
            lowered = fn.lower(pshape, batch)
        else:  # decode
            pshape, psh = _param_shardings(cfg, mesh,
                                           weight_stationary=weight_stationary)
            serve = make_serve_step(cfg, mesh=mesh, dp_axes=dp_axes)
            cache_shape = SPECS.cache_shape(cfg, shape)
            cache_sp = SPECS.cache_specs(cache_shape, cfg, shape, mesh, dp_axes,
                                         kv_shard=kv_shard)
            cache_sh = SH.named_shardings(cache_sp, mesh)
            batch = SPECS.batch_specs(cfg, shape, mesh, dp_axes)
            fn = jax.jit(serve, in_shardings=(psh, None, cache_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(2,))
            lowered = fn.lower(pshape, batch, cache_shape)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kind": shape.kind,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count()}
    return lowered, meta


def analyze(lowered, compile_: bool = True):
    rec = {}
    t0 = time.time()
    if compile_:
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t0
        try:
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca
            rec["flops"] = float(ca.get("flops", -1))
            rec["bytes_accessed"] = float(ca.get("bytes accessed", -1))
        except Exception as e:  # pragma: no cover
            rec["cost_error"] = str(e)
        try:
            ma = compiled.memory_analysis()
            for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                         "output_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                v = getattr(ma, attr, None)
                if v is not None:
                    rec[attr] = int(v)
        except Exception as e:  # pragma: no cover
            rec["memory_error"] = str(e)
        text = compiled.as_text()
    else:
        text = lowered.as_text()
    # scan-aware walker (trip-count corrected): authoritative for roofline
    walk = HLO_COST.analyze_text(text)
    rec["walk_flops"] = walk["flops"]
    rec["walk_bytes"] = walk["bytes"]
    rec["collectives"] = {k: int(v) for k, v in walk["collectives"].items()}
    rec["op_hist"] = HLO.op_histogram(text)
    return rec


def run_cell(arch, shape_name, multi_pod, compile_=True, out_dir=RESULTS_DIR,
             **opt):
    name = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, name + ".json")
    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, shape_name, multi_pod, **opt)
        meta.update({k: v for k, v in opt.items() if v})
        meta["lower_s"] = time.time() - t0
        rec = {**meta, **analyze(lowered, compile_)}
        rec["status"] = "ok"
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--weight-stationary", action="store_true")
    ap.add_argument("--kv-shard", default="seq", choices=["seq", "hd"])
    ap.add_argument("--moe-no-fsdp", action="store_true")
    args = ap.parse_args()

    if args.moe_no_fsdp:
        SH.MOE_FSDP = False
    cells = []
    if args.all:
        for arch, sname, runnable, reason in registry.runnable_cells():
            if not runnable:
                print(f"SKIP {arch} x {sname}: {reason}")
                continue
            cells.append((arch, sname))
    else:
        cells = [(args.arch, args.shape)]
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]

    failures = 0
    for arch, sname in cells:
        for mp in meshes:
            t0 = time.time()
            rec = run_cell(arch, sname, mp, compile_=not args.no_compile,
                           out_dir=args.out,
                           microbatches=args.microbatches,
                           weight_stationary=args.weight_stationary,
                           kv_shard=args.kv_shard)
            ok = rec["status"] == "ok"
            failures += (not ok)
            msg = (f"flops={rec.get('flops', 0):.3e} "
                   f"coll={rec.get('collectives', {}).get('total', 0):.3e}B"
                   if ok else rec.get("error", ""))
            print(f"[dryrun] {arch} x {sname} x "
                  f"{'2x16x16' if mp else '16x16'}: {rec['status']} "
                  f"({time.time() - t0:.0f}s) {msg}", flush=True)
            if ok and "temp_size_in_bytes" in rec:
                per_dev_gb = rec["temp_size_in_bytes"] / 2**30
                print(f"         temp={per_dev_gb:.2f}GiB/dev "
                      f"args={rec.get('argument_size_in_bytes', 0)/2**30:.2f}GiB",
                      flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
