"""Serving driver (deliverable b): batched greedy decoding with a KV/state
cache — `python -m repro.launch.serve --arch qwen2-7b --tokens 32`.

Runs the smoke-size config of the chosen arch on CPU; the production decode
path is the same serve_step lowered by launch/dryrun.py decode cells.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import registry
from ..configs.base import smoke_config
from ..models import model as MDL
from ..serving.decode import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_config(registry.get(args.arch))
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       (args.batch, args.prompt_len)),
                          jnp.int32)
    img = None
    if cfg.cross_attn_period:
        img = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.num_image_tokens, cfg.d_model)), jnp.float32)
    t0 = time.time()
    out = generate(cfg, params, prompts, args.tokens,
                   cache_len=args.prompt_len + args.tokens + 1,
                   image_embeds=img)
    dt = time.time() - t0
    total = args.batch * args.tokens
    print(f"[serve] {args.arch}: generated {total} tokens in {dt:.1f}s "
          f"({total / dt:.1f} tok/s, batch {args.batch})")
    print("[serve] sample:", np.asarray(out[0])[:16].tolist())


if __name__ == "__main__":
    main()
