"""Production mesh builders (spec: MULTI-POD DRY-RUN step 1).

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init)."""
from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (1 device => (1,1))."""
    import jax

    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


HardwareSpec = {
    # TPU v5e per chip (ROOFLINE ANALYSIS constants from the spec)
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
}
