"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every
(arch x shape) dry-run cell — weak-type-correct, shardable, no allocation."""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models import model as MDL


def _sds(shape, dtype, mesh=None, spec=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec or P()))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh | None,
                dp_axes: Tuple[str, ...]) -> Dict[str, Any]:
    """ShapeDtypeStructs for the model input batch of this cell."""
    b = shape.global_batch
    s = 1 if shape.kind == "decode" else shape.seq_len
    dp = dp_axes if (mesh is not None and b % _axes_size(mesh, dp_axes) == 0
                     and _axes_size(mesh, dp_axes) > 1) else None
    tok_spec = P(dp, None)
    out: Dict[str, Any] = {}
    if cfg.embed_inputs:
        out["tokens"] = _sds((b, s), jnp.int32, mesh, tok_spec)
    else:
        out["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16, mesh,
                             P(dp, None, None))
        out["labels"] = _sds((b, s), jnp.int32, mesh, tok_spec)
    if cfg.cross_attn_period:
        out["image_embeds"] = _sds((b, cfg.num_image_tokens, cfg.d_model),
                                   jnp.bfloat16, mesh, P(dp, None, None))
    return out


def _axes_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in (axes or ()):
        n *= mesh.shape[a]
    return n


def cache_shape(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the decode cache (seq_len-long)."""
    return jax.eval_shape(functools.partial(
        MDL.init_cache, cfg, shape.global_batch, shape.seq_len, dtype))


def cache_specs(cache_tree, cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                dp_axes: Tuple[str, ...], kv_shard: str = "seq"):
    """Path-heuristic sharding for the cache (DESIGN.md §4):
    batch -> dp axes (when divisible); kv head_dim / state channels ->
    "model" (when divisible); for global_batch=1 long-context cells the KV
    SEQUENCE dim shards over "data" instead."""
    b = shape.global_batch
    dp = dp_axes if (b % _axes_size(mesh, dp_axes) == 0
                     and _axes_size(mesh, dp_axes) > 1) else None
    seq_shard = "data" if dp is None else None  # long_500k: shard the cache seq
    msize = mesh.shape["model"]

    def spec_for(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", ""))) for k in path)
        nd = len(leaf.shape)
        if leaf.shape == () or nd == 0:
            return P()
        if name.endswith("len"):
            return P()
        def last_model(dim):
            return "model" if dim % msize == 0 else None
        last = name.rsplit("/", 1)[-1]
        if last in ("k", "v"):
            # [..., B, S, KVH, HD] — shard the SEQ dim over "model"
            # (flash-decode: per-shard partial softmax + tiny psums). Sharding
            # HD instead conflicts with the attention einsum and XLA emits a
            # full cache reshard copy per layer (§Perf iteration, Cell C).
            lead = [None] * (nd - 4)
            sdim = leaf.shape[-3]
            if kv_shard == "hd":  # baseline variant (§Perf Cell C before)
                sshard = seq_shard if (seq_shard and
                                       sdim % mesh.shape["data"] == 0) else None
                return P(*lead, dp, sshard, None, last_model(leaf.shape[-1]))
            if dp is None:  # long_500k: batch=1 -> seq over data AND model
                axes = tuple(a for a in ("data", "model")
                             if sdim % _axes_size(mesh, (a,)) == 0)
                if axes and sdim % _axes_size(mesh, axes) != 0:
                    axes = axes[:1]
                return P(*lead, None, axes or None, None, None)
            sshard = "model" if sdim % msize == 0 else None
            return P(*lead, dp, sshard, None, None)
        if "wkv" in name:      # [L, B, H, D, D]
            return P(None, dp, last_model(leaf.shape[-3]), None, None)
        if "shift" in name:    # [L, B, 1, d]
            return P(None, dp, None, last_model(leaf.shape[-1]))
        if "conv" in name:     # [..., B, W-1, C]
            lead = [None] * (nd - 3)
            return P(*lead, dp, None, last_model(leaf.shape[-1]))
        if "ssm" in name:      # [..., B, H, N, Pd]
            lead = [None] * (nd - 4)
            return P(*lead, dp, last_model(leaf.shape[-3]), None, None)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


def with_shardings(shape_tree, spec_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda sds, sp: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, sp)),
        shape_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
