"""End-to-end training driver (deliverable b): real loop with checkpointing,
auto-resume, and fault injection for the FT test.

CPU-scale run (default): a ~100M-param qwen2-family model for a few hundred
steps — `python -m repro.launch.train --steps 300`.
Production: same code path lowers on the dry-run meshes (launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import registry
from ..configs.base import ModelConfig
from ..training import checkpoint as CKPT
from ..training import data as DATA
from ..training import optimizer as OPT
from ..training import train_loop as TL


def small_lm_config(vocab: int = 2048) -> ModelConfig:
    """~100M params, qwen2-like (GQA + SwiGLU)."""
    return ModelConfig(
        name="small-lm-100m", family="dense", n_layers=8, d_model=768,
        n_heads=12, kv_heads=4, d_ff=2048, vocab=vocab, head_dim=64)


def train(cfg: ModelConfig, steps: int, ckpt_dir: str, batch: int = 8,
          seq: int = 256, ckpt_every: int = 50, crash_at: int | None = None,
          lr: float = 3e-4, log_every: int = 10,
          wsd: bool | None = None) -> dict:
    opt_cfg = OPT.OptConfig(
        peak_lr=lr, warmup_steps=min(50, steps // 4), total_steps=steps,
        schedule="wsd" if (wsd if wsd is not None else cfg.wsd_schedule)
        else "cosine")
    step_fn, _, _ = TL.make_train_step(cfg, opt_cfg, mesh=None, dp_axes=(),
                                       microbatches=1,
                                       compute_dtype=jnp.float32)
    # mesh=None: single-device CPU run; the model code is identical.
    data = DATA.SyntheticLM(DATA.DataConfig(
        vocab=cfg.vocab, seq_len=seq, global_batch=batch))
    ckpt = CKPT.Checkpointer(ckpt_dir, keep=2)
    cfg_hash = CKPT.config_hash((cfg, dataclasses.asdict(opt_cfg)))

    state = TL.init_state(cfg, jax.random.PRNGKey(0))
    start_step = 0
    restored = ckpt.restore_latest(state, cfg_hash)
    if restored is not None:
        start_step, state, extra = restored
        print(f"[train] resumed from step {start_step}", flush=True)

    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        batch_np = data.batch_for_model(step, cfg)
        batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
        state, metrics = jit_step(state, batch_dev)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
        if (step + 1) % ckpt_every == 0 or step + 1 == steps:
            ckpt.save(step + 1, state, extra={"losses_tail": losses[-5:]},
                      cfg_hash=cfg_hash)
        if crash_at is not None and step + 1 >= crash_at:
            ckpt.wait()
            print(f"[train] simulated crash at step {step + 1}", flush=True)
            return {"crashed_at": step + 1, "losses": losses}
    ckpt.wait()
    return {"final_loss": losses[-1], "first_loss": losses[0],
            "losses": losses, "steps": steps}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="small-lm-100m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--crash-at", type=int, default=None)
    args = ap.parse_args()
    if args.arch == "small-lm-100m":
        cfg = small_lm_config()
    else:
        from ..configs.base import smoke_config
        cfg = smoke_config(registry.get(args.arch))
    out = train(cfg, args.steps, args.ckpt_dir, batch=args.batch,
                seq=args.seq, crash_at=args.crash_at)
    print({k: v for k, v in out.items() if k != "losses"})


if __name__ == "__main__":
    main()
