"""repro.api — the unified Problem → Plan → Operator pipeline facade.

One staged, OSKI-style entry point for the paper's reorder/convert/tune/
measure loop (DESIGN.md "Pipeline API"):

    from repro.api import SpmvProblem, plan

    problem = SpmvProblem(mat, k=8)              # matrix + RHS width + dtype
    pl = plan(problem, reorder="auto")           # scheme x engine x shape x k
    op = pl.build()                              # permutation-carrying op
    y = op(x)                                    # x in the ORIGINAL space

    pl.save()                                    # one content-addressed
    pl2 = Plan.load(pl.key, mat=mat)             # store: plan + perm + op
    op2 = pl2.build()                            # arrays — no re-tune

Schemes and engines are plugins: anything registered through
@register_scheme / @register_engine (core/registry.py) participates in
planning, including `plan(reorder="auto", engine="auto")` joint selection.
Importing this module registers every built-in (core.reorder.api schemes,
core.spmv.ops engines), so the registries are populated as a side effect.

Legacy entry points (`core.spmv.ops.build_operator`,
`core.reorder.api.apply_scheme`) remain as deprecation shims; see the
README migration table.
"""
from __future__ import annotations

from .core.registry import (ENGINE_REGISTRY, SCHEME_REGISTRY, EngineSpec,
                            SchemeSpec, get_engine, get_scheme,
                            register_engine, register_scheme)
# importing these populates the registries with every built-in
from .core.reorder import api as _reorder_api  # noqa: F401
from .core.spmv import ops as _ops  # noqa: F401
from .core.spmv.plan import Operator, Plan, SpmvProblem, plan, plan_key

__all__ = [
    "SpmvProblem", "plan", "Plan", "Operator", "plan_key",
    "register_scheme", "register_engine", "get_scheme", "get_engine",
    "SchemeSpec", "EngineSpec", "SCHEME_REGISTRY", "ENGINE_REGISTRY",
]
