"""repro.api — the unified Problem → Plan → Operator pipeline facade.

One staged, OSKI-style entry point for the paper's reorder/convert/tune/
measure loop (DESIGN.md "Pipeline API"):

    from repro.api import SpmvProblem, plan

    problem = SpmvProblem(mat, k=8)              # matrix + RHS width + dtype
    pl = plan(problem, reorder="auto")           # scheme x engine x shape x k
    op = pl.build()                              # permutation-carrying op
    y = op(x)                                    # x in the ORIGINAL space

    pl.save()                                    # one content-addressed
    pl2 = Plan.load(pl.key, mat=mat)             # store: plan + perm + op
    op2 = pl2.build()                            # arrays — no re-tune

Schemes, engines and row partitioners are plugins: anything registered
through @register_scheme / @register_engine / @register_partitioner
(core/registry.py) participates in planning, including
`plan(reorder="auto", engine="auto")` joint selection. Importing this
module registers every built-in (core.reorder.api schemes, core.spmv.ops
engines, core.sparse.partition partitioners), so the registries are
populated as a side effect.

The same facade covers one device through a full mesh: pass
`topology=Topology(devices=8, layout="1d_rows" | "2d_panels")` and
plan() jointly selects (partition x scheme x engine x shape x k) with
the communication-volume cost model, while `Plan.build()` returns a
`ShardedOperator` carrying perm + panel starts + collective schedule —
still fed ORIGINAL-index-space vectors, still round-tripping through the
content-addressed plan store (DESIGN.md "Topology-aware planning").

Measurement is the same shape one level up: `repro.experiments` turns a
declarative ExperimentSpec (matrices x schemes x machine profiles x k)
into a resumable campaign over a content-addressed ResultStore; its key
types are re-exported here.

Legacy entry points (`core.spmv.ops.build_operator`,
`core.reorder.api.apply_scheme`, `benchmarks.common.run_campaign/grid`)
remain as deprecation shims; see the README migration table.
"""
from __future__ import annotations

from .core.registry import (ENGINE_REGISTRY, PARTITIONER_REGISTRY,
                            PROFILE_REGISTRY, SCHEME_REGISTRY, EngineSpec,
                            PartitionerSpec, ProfileSpec, SchemeSpec,
                            get_engine, get_partitioner, get_profile,
                            get_scheme, register_engine,
                            register_partitioner, register_profile,
                            register_scheme)
# importing these populates the registries with every built-in
from .core.reorder import api as _reorder_api  # noqa: F401
from .core.sparse import partition as _partition  # noqa: F401
from .core.spmv import ops as _ops  # noqa: F401
from .core.spmv.distributed import ShardedOperator
from .core.spmv.plan import Operator, Plan, SpmvProblem, plan, plan_key
from .core.spmv.topology import Topology
from .experiments import (ExperimentSpec, MeasurePolicy, MissingCellError,
                          Report, ResultStore, Runner)
# observability: obs.tracing() spans every layer above; obs.snapshot()
# is the process-wide metrics registry (DESIGN.md "Observability")
from . import obs

__all__ = [
    "SpmvProblem", "plan", "Plan", "Operator", "plan_key", "Topology",
    "ShardedOperator", "obs",
    "register_scheme", "register_engine", "register_partitioner",
    "register_profile",
    "get_scheme", "get_engine", "get_partitioner", "get_profile",
    "SchemeSpec", "EngineSpec", "PartitionerSpec", "ProfileSpec",
    "SCHEME_REGISTRY", "ENGINE_REGISTRY", "PARTITIONER_REGISTRY",
    "PROFILE_REGISTRY",
    "ExperimentSpec", "MeasurePolicy", "MissingCellError", "Report",
    "ResultStore", "Runner",
]
