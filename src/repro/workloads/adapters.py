"""Model-layer adapters: moe/attention/gnn rewired through the pipeline.

Two jobs. First, the workload-facing forward paths: the sorted MoE
dispatch of models/layers/moe.py and a block-sparse attention
score-matmul expressed as *registry operators* — same engines, same
opcache, same obs spans as every static benchmark, so workload-shaped
sparsity is measured by exactly the machinery the paper's static
matrices go through. Second, the reference paths `run_stream` verifies
and races against: the GShard-style onehot scatter dispatch (the
unreordered baseline of benchmarks/moe_dispatch) and plain dense
matmuls for attention masks / GNN adjacencies.

Equality contract: the sparse dispatch D @ x and the onehot scatter
place each kept token's row exactly once (one nonzero of value 1.0 per
slot row — multiplying by 1.0 and adding 0.0 are exact in f32), so the
dispatch buffers must be BITWISE equal; the combine sums k gate-weighted
contributions per token in different orders, so it is compared at
rel err < 1e-3.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.spmv.plan import SpmvProblem, plan as plan_fn
from . import sources


def to_device(x):
    return jnp.asarray(x)


def block_until_ready(y):
    return y.block_until_ready() if hasattr(y, "block_until_ready") else y


def rel_err(a, b) -> float:
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return float(np.abs(a - b).max() / max(np.abs(b).max(), 1e-12))


def _plan_op(mat, k, *, reorder="baseline", engine="auto", hints=None):
    return plan_fn(SpmvProblem(mat=mat, k=k, hints=dict(hints or {})),
                   reorder=reorder, engine=engine).build()


# --------------------------------------------------------------------------
# pipeline-rewired forward paths
# --------------------------------------------------------------------------
def moe_sorted_dispatch(x, w_router, top_k: int, num_experts: int,
                        capacity_factor: float = 1.25, *, session=None,
                        reorder: str = "baseline", engine: str = "auto"):
    """models/layers/moe.py sorted dispatch as pipeline operators.

    route (numpy mirror of moe.route) → (dispatch D, combine C) →
    registry operators → buf = D @ x, y = C @ buf. With a
    `WorkloadSession`, plans amortize across calls (value-only routing
    changes rebuild, identical routing reuses). Returns
    (buf [E*cap, d], y [n, d], info) — info carries li/drop_frac/cap and
    the session events when one is used.
    """
    x = np.asarray(x, np.float32)
    gates, experts = sources.moe_route_np(x, np.asarray(w_router, np.float32),
                                          top_k)
    cap = sources.moe_capacity(x.shape[0], top_k, num_experts,
                               capacity_factor)
    disp, comb, info = sources.routing_matrices(experts, gates,
                                                num_experts, cap)
    info.update(cap=cap, num_experts=num_experts)
    if session is not None:
        d_op, ev_d = session.operator(disp, role="dispatch")
        c_op, ev_c = session.operator(comb, role="combine")
        info["events"] = (ev_d, ev_c)
    else:
        d = x.shape[1]
        d_op = _plan_op(disp, d, reorder=reorder, engine=engine)
        c_op = _plan_op(comb, d, reorder=reorder, engine=engine)
    xd = to_device(x)
    buf = d_op.matmul(xd)
    y = block_until_ready(c_op.matmul(buf))
    return np.asarray(buf), np.asarray(y), info


def block_sparse_attention(scores, v, *, session=None,
                           reorder: str = "baseline", engine: str = "auto",
                           block: int = 0):
    """Block-sparse attention score application y = scores @ v through a
    registry operator. `scores` is the masked (already-normalized) score
    matrix as CSRMatrix — dense inside each (b × b) block — lowered with
    the `block_shape` hint so BCSR-shaped engines are on the menu."""
    hints = {"block_shape": (block, block)} if block else None
    if session is not None:
        op, _ = session.operator(scores, role="mask")
    else:
        op = _plan_op(scores, np.asarray(v).shape[1], reorder=reorder,
                      engine=engine, hints=hints)
    return np.asarray(block_until_ready(op.matmul(to_device(v))))


def gnn_aggregate(adj, x, *, session=None, reorder: str = "baseline",
                  engine: str = "auto"):
    """GNN neighborhood aggregation X' = A @ X (SpMM at feature width)."""
    if session is not None:
        op, _ = session.operator(adj, role="aggregate")
    else:
        op = _plan_op(adj, np.asarray(x).shape[1], reorder=reorder,
                      engine=engine)
    return np.asarray(block_until_ready(op.matmul(to_device(x))))


# --------------------------------------------------------------------------
# reference paths (what run_stream verifies against and races)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("num_experts", "cap"))
def _onehot_dispatch_combine(x_flat, experts, gates, num_experts, cap):
    """The onehot branch of models/layers/moe.py `_moe_body`, minus the
    expert FFN: rank via cumsum over UNSORTED assignments (GShard
    baseline), scatter to the slot buffer, gate-weighted combine."""
    n, d = x_flat.shape
    k = experts.shape[1]
    ef = experts.reshape(-1)
    tok = jnp.repeat(jnp.arange(n), k)
    gf = gates.reshape(-1)
    onehot_full = jax.nn.one_hot(ef, num_experts, dtype=jnp.int32)
    rank = (jnp.cumsum(onehot_full, axis=0) - 1)[jnp.arange(n * k), ef]
    keep = rank < cap
    slot = jnp.where(keep, ef * cap + rank, num_experts * cap)
    buf = jnp.zeros((num_experts * cap + 1, d),
                    x_flat.dtype).at[slot].set(x_flat[tok])
    buf = buf[:-1]
    y_flat = jnp.concatenate([buf, jnp.zeros((1, d), buf.dtype)])
    contrib = y_flat[slot] * (gf * keep)[:, None]
    y = jnp.zeros((n, d), x_flat.dtype).at[tok].add(contrib)
    return buf, y


@jax.jit
def _dense_matmul(a, x):
    return a @ x


def reference(kind: str, step: sources.WorkloadStep, iters: int = 3) -> dict:
    """Run the kind's reference path on one step; returns {"ms", "y"}
    (+ "buf" for moe). ms is the median of `iters` timed runs after a
    warmup call, same protocol as run_stream's sparse chain."""
    if kind == "moe":
        x = to_device(step.operands[0].x)
        experts = to_device(step.meta["experts"])
        gates = to_device(step.meta["gates"])
        args = (x, experts, gates)
        fn = functools.partial(_onehot_dispatch_combine,
                               num_experts=step.meta["num_experts"],
                               cap=step.meta["cap"])
    else:
        a = to_device(step.operands[0].mat.to_dense())
        args = (a, to_device(step.operands[0].x))
        fn = _dense_matmul
    out = fn(*args)
    block_until_ready(out[-1] if isinstance(out, tuple) else out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        o = fn(*args)
        block_until_ready(o[-1] if isinstance(o, tuple) else o)
        times.append((time.perf_counter() - t0) * 1e3)
    rec = {"ms": float(np.median(times))}
    if kind == "moe":
        rec["buf"], rec["y"] = out
    else:
        rec["y"] = out
    return rec
