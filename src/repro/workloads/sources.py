"""Workload streams — model-layer sparsity as per-step CSR matrices.

Each workload kind lowers one model-shaped sparse computation to a
per-step stream of `WorkloadStep`s whose operands are plain `CSRMatrix`
problems, so the Problem → Plan → Operator pipeline (and its plan store,
tuner, obs spans) measures workload-shaped sparsity with the same
machinery it uses for static SuiteSparse-style matrices:

  moe   — token→expert routing (models/layers/moe.py `route`): the
          sorted dispatch is a slot×token gather matrix D (one nonzero
          per slot row — the reordering), the combine a token×slot
          matrix C whose values are the router gates. Capacity clipping
          is the paper's nnz-balanced schedule; the routing LI (§6.1)
          rides on every step.
  attn  — block-sparse attention masks as BCSR-shaped CSR: causal
          block-banded window plus a few global column blocks, dense
          inside each (b × b) block (the MXU tile story of DESIGN.md §3
          applied to attention).
  gnn   — graph-NN neighborhood aggregation X' = A @ X: a synthetic
          adjacency (matrices/generators) with per-step edge weights,
          the SpMM path at feature width f.

Names are `workload://<kind>-<tag><int>-...` (hyphen-separated,
letter-tagged integers — CSV-safe), e.g.
`workload://moe-e8-k2-t512-d32-n6`. The *scenario* — how the stream
evolves step to step — is deliberately NOT part of the name; it is the
experiment spec's variants axis:

  static — the sparsity STRUCTURE is frozen; only values change per step
           (router gates / attention scores / edge weights). The
           amortization best case: one plan, value-only rebuilds.
  drift  — the structure changes every step (tokens drift, global
           attention blocks resample, edges rewire). The paper's
           break-even question at its least favorable: plan cost must
           amortize within a single step.
  shift1 — the structure changes exactly once, mid-stream (regime
           change); everything else is reuse.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterator, Optional, Tuple

import numpy as np

from ..core.sparse.csr import CSRMatrix
from ..matrices import generators as G

SCENARIOS = ("static", "drift", "shift1")
PREFIX = "workload://"

# canonical presets (the suite's "workload tier"; any parameterization of
# the grammar resolves, these are just the named entry points)
WORKLOAD_PRESETS = (
    "workload://moe-e8-k2-t512-d32-n6",
    "workload://moe-e16-k2-t2048-d128-n4",
    "workload://attn-s256-b32-w2-g1-d16-n6",
    "workload://gnn-m512-deg4-f16-n6",
)

_DEFAULTS = {
    "moe": {"e": 8, "k": 2, "t": 512, "d": 32, "n": 6, "cf": 1.25},
    "attn": {"s": 256, "b": 32, "w": 2, "g": 1, "d": 16, "n": 6},
    # rw — drift rewire fraction: 0 resamples the WHOLE adjacency each
    # drift step (legacy full-churn drift); rw>0 rewires only that edge
    # fraction per step (delete rw*nnz edges, add as many new ones) —
    # the incremental drift a StructureDelta amortizes (core/spmv/delta)
    "gnn": {"m": 512, "deg": 4, "f": 16, "n": 6, "rw": 0},
}
_TOKEN_RE = re.compile(r"^([a-z]+)(\d+(?:\.\d+)?)$")


@dataclasses.dataclass(frozen=True)
class WorkloadDef:
    """A parsed workload name: the kind plus its integer/float params."""

    name: str
    kind: str
    params: dict

    @property
    def steps(self) -> int:
        return int(self.params["n"])

    @property
    def width(self) -> int:
        """Feature width — the SpMM k the stream's operands carry."""
        return int(self.params["d" if self.kind != "gnn" else "f"])


@dataclasses.dataclass(frozen=True)
class Operand:
    """One sparse stage of a step: `x` is the [n, width] input block;
    x=None chains the previous stage's output (moe combine)."""

    role: str
    mat: CSRMatrix
    x: Optional[np.ndarray] = None


@dataclasses.dataclass(frozen=True)
class WorkloadStep:
    """One step of the stream: the operand chain plus per-step metadata
    (routing LI, drop fraction, and whatever the kind's reference path
    needs — see adapters.py)."""

    index: int
    operands: Tuple[Operand, ...]
    meta: dict


def parse_workload(name: str) -> WorkloadDef:
    """`workload://moe-e8-k2-t512-d32-n6` → WorkloadDef. Unknown kinds or
    tags raise with the known grammar."""
    if not name.startswith(PREFIX):
        raise ValueError(f"workload names start with {PREFIX!r}: {name!r}")
    toks = name[len(PREFIX):].split("-")
    kind = toks[0]
    if kind not in _DEFAULTS:
        raise ValueError(f"unknown workload kind {kind!r} in {name!r}; "
                         f"known: {sorted(_DEFAULTS)}")
    params = dict(_DEFAULTS[kind])
    for t in toks[1:]:
        m = _TOKEN_RE.match(t)
        if not m or m.group(1) not in params:
            raise ValueError(
                f"bad workload token {t!r} in {name!r}; known tags for "
                f"{kind!r}: {sorted(_DEFAULTS[kind])}")
        tag, val = m.group(1), m.group(2)
        params[tag] = float(val) if "." in val else int(val)
    return WorkloadDef(name=name, kind=kind, params=params)


def preset_names() -> list:
    return list(WORKLOAD_PRESETS)


def representative(name: str) -> CSRMatrix:
    """The step-0 primary matrix — what `suite.get("workload://...")`
    returns, so non-workload consumers (spmv cells, spmv_bench) can treat
    a workload name as a static matrix."""
    step = next(steps(parse_workload(name), "static", seed=0))
    return step.operands[0].mat


def steps(wdef: WorkloadDef, scenario: str = "drift",
          seed: int = 0) -> Iterator[WorkloadStep]:
    if scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {scenario!r}; "
                         f"known: {SCENARIOS}")
    gen = {"moe": _moe_steps, "attn": _attn_steps, "gnn": _gnn_steps}
    return gen[wdef.kind](wdef.params, scenario, int(seed))


# --------------------------------------------------------------------------
# MoE routing (numpy mirror of models/layers/moe.py `route`)
# --------------------------------------------------------------------------
def _softmax(z: np.ndarray) -> np.ndarray:
    z = z - z.max(axis=-1, keepdims=True)
    ez = np.exp(z)
    return ez / ez.sum(axis=-1, keepdims=True)


def moe_route_np(x: np.ndarray, w_router: np.ndarray, top_k: int):
    """Numpy mirror of moe.route: (gates [n,k], experts [n,k]). Stable
    argsort ties match jax.lax.top_k (lower index wins)."""
    probs = _softmax(x.astype(np.float32) @ w_router)
    experts = np.argsort(-probs, axis=-1, kind="stable")[:, :top_k]
    gates = np.take_along_axis(probs, experts, axis=-1)
    gates = gates / np.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates.astype(np.float32), experts.astype(np.int32)


def moe_capacity(n_tokens: int, top_k: int, num_experts: int,
                 capacity_factor: float = 1.25) -> int:
    """The nnz-balanced slot count (paper Listing 5 analogue; the
    models/layers/moe.py formula, 8-aligned)."""
    return int(np.ceil(n_tokens * top_k * capacity_factor
                       / num_experts / 8)) * 8


def routing_matrices(experts: np.ndarray, gates: np.ndarray,
                     num_experts: int, cap: int):
    """Lower one routing decision to the (dispatch, combine) matrix pair.

    Dispatch D [E*cap, n]: D[e*cap + rank, tok] = 1 for each kept
    (token, expert) assignment, rank computed in SORTED (expert-major)
    order — one nonzero per slot row, so D @ x is exactly the sorted
    dispatch gather. Combine C [n, E*cap]: C[tok, slot] = gate. Returns
    (D, C, meta) with the per-step routing LI (paper §6.1) and the
    capacity drop fraction.
    """
    n, k = experts.shape
    ef = experts.reshape(-1).astype(np.int64)
    tok = np.repeat(np.arange(n, dtype=np.int64), k)
    gf = gates.reshape(-1)
    order = np.argsort(ef, kind="stable")
    ef_s, tok_s, gf_s = ef[order], tok[order], gf[order]
    seg_start = np.searchsorted(ef_s, ef_s, side="left")
    rank = np.arange(n * k, dtype=np.int64) - seg_start
    keep = rank < cap
    slot = ef_s[keep] * cap + rank[keep]
    disp = CSRMatrix.from_coo(slot, tok_s[keep],
                              np.ones(slot.size, np.float32),
                              (num_experts * cap, n))
    comb = CSRMatrix.from_coo(tok_s[keep], slot,
                              gf_s[keep].astype(np.float32),
                              (n, num_experts * cap))
    counts = np.bincount(ef, minlength=num_experts).astype(np.float64)
    meta = {
        "li": float(counts.max() / max(counts.mean(), 1e-9)),
        "drop_frac": float(1.0 - keep.mean()),
    }
    return disp, comb, meta


def _moe_steps(p: dict, scenario: str, seed: int) -> Iterator[WorkloadStep]:
    e, k, n, d = int(p["e"]), int(p["k"]), int(p["t"]), int(p["d"])
    nsteps, cf = int(p["n"]), float(p["cf"])
    rng = np.random.default_rng(seed)
    w_router = (rng.standard_normal((d, e)) / np.sqrt(d)).astype(np.float32)
    x0 = rng.standard_normal((n, d)).astype(np.float32)
    x_shift = None
    cap = moe_capacity(n, k, e, cf)
    for t in range(nsteps):
        srng = np.random.default_rng(seed + 1000 + t)
        if scenario == "static":
            # positive per-step rescale: softmax sharpens, so the GATE
            # VALUES change every step while the top-k set (and order,
            # hence the dispatch/combine STRUCTURE) is invariant
            x = x0 * np.float32(1.0 + 0.25 * t)
        elif scenario == "drift":
            x = (x0 + 0.5 * srng.standard_normal((n, d))).astype(np.float32)
        else:  # shift1: regime change at the midpoint
            if t < nsteps // 2:
                x = x0
            else:
                if x_shift is None:
                    x_shift = np.random.default_rng(seed + 7) \
                        .standard_normal((n, d)).astype(np.float32)
                x = x_shift
        gates, experts = moe_route_np(x, w_router, k)
        disp, comb, meta = routing_matrices(experts, gates, e, cap)
        meta.update(experts=experts, gates=gates, num_experts=e, cap=cap,
                    top_k=k)
        yield WorkloadStep(index=t, operands=(
            Operand("dispatch", disp, x),
            Operand("combine", comb, None),      # chains the dispatch buf
        ), meta=meta)


# --------------------------------------------------------------------------
# block-sparse attention masks (BCSR-shaped)
# --------------------------------------------------------------------------
def attn_block_pattern(nb: int, window: int, n_global: int,
                       rng: np.random.Generator):
    """Block coordinates of a causal banded-window mask plus n_global
    randomly chosen global column blocks (kept causal)."""
    bi, bj = [], []
    gcols = (rng.choice(nb, size=min(n_global, nb), replace=False)
             if n_global else np.empty(0, np.int64))
    for i in range(nb):
        js = set(range(max(0, i - window + 1), i + 1))
        js.update(int(g) for g in gcols if g <= i)
        for j in sorted(js):
            bi.append(i)
            bj.append(j)
    return np.asarray(bi, np.int64), np.asarray(bj, np.int64)


def _attn_steps(p: dict, scenario: str, seed: int) -> Iterator[WorkloadStep]:
    s, b, w = int(p["s"]), int(p["b"]), int(p["w"])
    g, d, nsteps = int(p["g"]), int(p["d"]), int(p["n"])
    if s % b:
        raise ValueError(f"attn workload needs block|seq: s={s}, b={b}")
    nb = s // b
    rng = np.random.default_rng(seed)
    bi0, bj0 = attn_block_pattern(nb, w, g, rng)
    bi1 = bj1 = None
    x = rng.standard_normal((s, d)).astype(np.float32)
    di, dj = np.meshgrid(np.arange(b), np.arange(b), indexing="ij")
    for t in range(nsteps):
        srng = np.random.default_rng(seed + 2000 + t)
        if scenario == "static":
            bi, bj = bi0, bj0
        elif scenario == "drift":
            bi, bj = attn_block_pattern(nb, w, g, srng)
        else:  # shift1
            if t < nsteps // 2:
                bi, bj = bi0, bj0
            else:
                if bi1 is None:
                    bi1, bj1 = attn_block_pattern(
                        nb, w, g, np.random.default_rng(seed + 9))
                bi, bj = bi1, bj1
        rows = (bi[:, None, None] * b + di[None]).reshape(-1)
        cols = (bj[:, None, None] * b + dj[None]).reshape(-1)
        # per-step scores: a value-only change whenever the pattern holds
        vals = (srng.standard_normal(rows.size) / np.sqrt(b)) \
            .astype(np.float32)
        mask = CSRMatrix.from_coo(rows, cols, vals, (s, s))
        bcounts = np.bincount(bi, minlength=nb).astype(np.float64)
        meta = {"li": float(bcounts.max() / max(bcounts.mean(), 1e-9)),
                "block": b, "nblocks": int(bi.size)}
        yield WorkloadStep(index=t, operands=(
            Operand("mask", mask, x),), meta=meta)


# --------------------------------------------------------------------------
# graph-NN aggregation (SpMM over a synthetic adjacency)
# --------------------------------------------------------------------------
def _rewire_graph(mat: CSRMatrix, frac: float, seed: int) -> CSRMatrix:
    """Rewire `frac` of the edges: delete that many uniformly chosen
    entries and add as many fresh (row, col) pairs that don't collide
    with the survivors. Shape and nnz are preserved — the incremental
    counterpart of a full adjacency resample."""
    rng = np.random.default_rng(seed)
    m, n = mat.shape
    rows = np.repeat(np.arange(m, dtype=np.int64),
                     np.diff(mat.rowptr.astype(np.int64)))
    cols = mat.cols.astype(np.int64)
    vals = mat.vals
    k = max(1, int(round(frac * mat.nnz)))
    drop = rng.choice(mat.nnz, size=k, replace=False)
    keep = np.ones(mat.nnz, dtype=bool)
    keep[drop] = False
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    taken = set((rows * n + cols).tolist())
    new = []
    while len(new) < k:
        r = int(rng.integers(m)) * n + int(rng.integers(n))
        if r not in taken:
            taken.add(r)
            new.append(r)
    new = np.asarray(new, dtype=np.int64)
    rows = np.concatenate([rows, new // n])
    cols = np.concatenate([cols, new % n])
    vals = np.concatenate(
        [vals, rng.standard_normal(k).astype(vals.dtype)])
    return CSRMatrix.from_coo(rows, cols, vals, (m, n))


def _gnn_steps(p: dict, scenario: str, seed: int) -> Iterator[WorkloadStep]:
    m, deg, f, nsteps = (int(p["m"]), int(p["deg"]), int(p["f"]),
                         int(p["n"]))
    rw = float(p.get("rw", 0))
    rng = np.random.default_rng(seed)
    base = G.random_uniform(m, deg, seed=seed)
    cur = base
    shifted = None
    x = rng.standard_normal((m, f)).astype(np.float32)
    for t in range(nsteps):
        srng = np.random.default_rng(seed + 3000 + t)
        if scenario == "drift" and rw > 0 and t > 0:
            cur = _rewire_graph(cur, rw, seed=seed + 100 + t)
            adj = cur
        elif scenario == "drift" and rw > 0:
            adj = cur
        elif scenario == "drift":
            adj = G.random_uniform(m, deg, seed=seed + 100 + t)
        elif scenario == "shift1" and t >= nsteps // 2:
            if shifted is None:
                shifted = G.random_uniform(m, deg, seed=seed + 11)
            adj = shifted
        else:
            adj = base
        # per-step edge weights (message weights): value-only when the
        # adjacency is held
        adj = dataclasses.replace(
            adj, vals=srng.standard_normal(adj.nnz).astype(np.float32))
        counts = adj.row_nnz().astype(np.float64)
        meta = {"li": float(counts.max() / max(counts.mean(), 1e-9))}
        yield WorkloadStep(index=t, operands=(
            Operand("aggregate", adj, x),), meta=meta)
