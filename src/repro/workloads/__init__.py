"""repro.workloads — workload-shaped dynamic sparsity through the
Problem → Plan → Operator pipeline.

The paper's amortization question, asked where it is least favorable:
model-layer sparse structures (MoE token routing, block-sparse attention
masks, GNN adjacencies) that change step to step. sources.py lowers each
workload to a per-step stream of CSR operands, dynamic.py runs the
stream through `plan()` under an explicit reuse policy
(`WorkloadSession`: reuse / rebuild / plan / replan, keyed on
`structure_key`/`values_key`), adapters.py rewires the model layers
through registry operators and supplies the onehot/dense reference
paths. The `"workload"` experiment cell kind (experiments/cells.py) and
`benchmarks/workloads.py` make these first-class, resumable campaign
citizens.
"""
from . import adapters, dynamic, sources
from .adapters import (block_sparse_attention, gnn_aggregate,
                       moe_sorted_dispatch)
from .dynamic import DynamicSparseProblem, WorkloadSession, run_stream
from .sources import (SCENARIOS, WORKLOAD_PRESETS, WorkloadDef,
                      WorkloadStep, moe_capacity, moe_route_np,
                      parse_workload, preset_names, representative,
                      routing_matrices, steps)

__all__ = [
    "DynamicSparseProblem", "WorkloadSession", "run_stream",
    "block_sparse_attention", "gnn_aggregate", "moe_sorted_dispatch",
    "SCENARIOS", "WORKLOAD_PRESETS", "WorkloadDef", "WorkloadStep",
    "moe_capacity", "moe_route_np", "parse_workload", "preset_names",
    "representative", "routing_matrices", "steps",
    "adapters", "dynamic", "sources",
]
