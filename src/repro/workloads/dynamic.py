"""Dynamic sparse problems and the plan-amortization session.

`DynamicSparseProblem` is the stream-of-structures analogue of
`SpmvProblem`: a workload name + scenario + seed that yields
`WorkloadStep`s (sources.py). `WorkloadSession` is where the paper's
amortization question gets an explicit policy instead of an assumption:

  reuse   — structure AND values identical to a cached step: hand back
            the cached Operator, zero plan cost.
  rebuild — structure identical, values changed: `Plan.rebuild` swaps
            the value array under the frozen plan (no reorder, no tune).
  plan    — first time a role sees this structure: full `plan()`.
  replan  — a role that already planned sees a NEW structure: full
            `plan()` again; this is the cost that must amortize.

Identity is `structure_key` (rowptr+cols sha1, core/spmv/plan.py) for
structure and `values_key` for values — content, not object identity, so
a drifted-then-returned structure still reuses. Every decision bumps a
`workload.{plans,replans,reuses,rebuilds}` counter and runs under a
`workload.*` span; reuse_rate = (reuses + rebuilds + deltas) / requests
and
plan_cost_share = plan_ms / (plan_ms + exec_ms) are the two headline
numbers the "workload" cell kind reports.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator, Optional

import numpy as np

from .. import obs
from ..core.spmv.plan import (SpmvProblem, plan as plan_fn, structure_key,
                              values_key)
from . import adapters, sources
from .sources import WorkloadStep


@dataclasses.dataclass(frozen=True)
class DynamicSparseProblem:
    """A per-step sparse structure stream, addressable like a problem.

    `name` is a `workload://` name (sources.parse_workload grammar),
    `scenario` one of sources.SCENARIOS. `steps()` yields the stream;
    `lower(mat)` produces the static `SpmvProblem` a single step's
    operand lowers to (what the session feeds `plan()`).
    """

    name: str
    scenario: str = "drift"
    seed: int = 0
    dtype: str = "float32"
    hints: Optional[dict] = None

    def __post_init__(self):
        sources.parse_workload(self.name)          # validate eagerly
        if self.scenario not in sources.SCENARIOS:
            raise ValueError(f"unknown scenario {self.scenario!r}; "
                             f"known: {sources.SCENARIOS}")

    @property
    def wdef(self) -> sources.WorkloadDef:
        return sources.parse_workload(self.name)

    @property
    def width(self) -> int:
        return self.wdef.width

    def steps(self) -> Iterator[WorkloadStep]:
        return sources.steps(self.wdef, self.scenario, self.seed)

    def lower(self, mat) -> SpmvProblem:
        hints = dict(self.hints or {})
        wd = self.wdef
        if wd.kind == "attn":
            # the mask is dense inside (b × b) blocks — tell the planner
            b = int(wd.params["b"])
            hints.setdefault("block_shape", (b, b))
        return SpmvProblem(mat=mat, k=self.width, dtype=self.dtype,
                           hints=hints)


class WorkloadSession:
    """Plan-amortization cache for one stream: structure_key → frozen
    Plan (+ per-values Operator). See module docstring for the policy."""

    def __init__(self, problem: DynamicSparseProblem, *,
                 reorder: str = "baseline", engine: str = "auto",
                 probe=False, use_deltas: bool = False):
        self.problem = problem
        self.reorder = reorder
        self.engine = engine
        self.probe = probe
        # opt-in: when a role's structure drifts, try to express the move
        # as a StructureDelta against the role's previous plan and
        # Plan.apply_delta it (frozen decision + perm kept, no reorder,
        # no tuner search) instead of a full replan. Off by default so
        # replan counts stay the amortization ground truth.
        self.use_deltas = bool(use_deltas)
        self._cache: dict = {}        # skey -> {plan, vkey, op}
        self._planned_roles: set = set()
        self._role_skey: dict = {}    # role -> last structure key seen
        self.counts = {"plans": 0, "replans": 0, "reuses": 0,
                       "rebuilds": 0, "deltas": 0}
        self.plan_ms = 0.0            # wall time spent planning/rebuilding
        self.events: list = []        # per-request event log

    @property
    def requests(self) -> int:
        return sum(self.counts.values())

    @property
    def reuse_rate(self) -> float:
        total = self.requests
        if not total:
            return 0.0
        return (self.counts["reuses"] + self.counts["rebuilds"]
                + self.counts["deltas"]) / total

    def operator(self, mat, role: str = ""):
        """Resolve a step operand to an Operator under the amortization
        policy. Returns (op, event) with event in plan/replan/reuse/
        rebuild."""
        skey = structure_key(mat)
        vkey = values_key(mat)
        ent = self._cache.get(skey)
        t0 = time.perf_counter()
        if ent is not None and ent["vkey"] == vkey:
            event = "reuses"
            op = ent["op"]
        elif ent is not None:
            event = "rebuilds"
            with obs.span("workload.rebuild", role=role):
                ent["op"] = ent["plan"].rebuild(mat)
                ent["vkey"] = vkey
            op = ent["op"]
        else:
            op = None
            if self.use_deltas and role in self._planned_roles:
                op, pl = self._try_delta(mat, role, vkey)
            if op is not None:
                event = "deltas"
                self._cache[skey] = {"plan": pl, "vkey": vkey, "op": op}
            else:
                event = ("plans" if role not in self._planned_roles
                         else "replans")
                self._planned_roles.add(role)
                with obs.span("workload.plan", role=role, event=event):
                    pl = plan_fn(self.problem.lower(mat),
                                 reorder=self.reorder,
                                 engine=self.engine, probe=self.probe)
                    op = pl.build()
                self._cache[skey] = {"plan": pl, "vkey": vkey, "op": op}
        self._role_skey[role] = skey
        dt_ms = (time.perf_counter() - t0) * 1e3
        if event != "reuses":
            self.plan_ms += dt_ms
        self.counts[event] += 1
        obs.counter(f"workload.{event}").inc()
        self.events.append({"role": role, "event": event, "ms": dt_ms})
        return op, event

    def _try_delta(self, mat, role: str, vkey: str):
        """Express the role's structure drift as a StructureDelta against
        its previous plan and apply it (frozen decision kept). Returns
        (op, plan) or (None, None) when no delta expresses the move or it
        exceeds the churn/bandwidth thresholds (DeltaTooLarge — the
        caller replans). Surviving entries may carry drifted values, so a
        values mismatch after the apply is settled with a rebuild."""
        from ..core.spmv import delta as delta_mod

        prev = self._cache.get(self._role_skey.get(role))
        if prev is None or prev["plan"]._mat is None:
            return None, None
        d = delta_mod.delta_between(prev["plan"]._mat, mat)
        if d is None or d.is_empty:
            return None, None
        try:
            pl = prev["plan"].apply_delta(d)
        except delta_mod.DeltaTooLarge:
            return None, None
        with obs.span("workload.delta", role=role,
                      edited=d.churn_nnz):
            op = (pl.build() if values_key(pl._mat) == vkey
                  else pl.rebuild(mat))
        return op, pl


def run_stream(problem: DynamicSparseProblem,
               session: Optional[WorkloadSession] = None, *,
               iters: int = 3, compare_dense: bool = True,
               verify: bool = True) -> dict:
    """Drive the full stream through the session; the shared step loop
    behind the "workload" cell kind, tests, and examples.

    Per step: resolve each operand chain stage to an Operator (amortized
    per the session policy), execute the chain `iters` times (median
    wall ms), and — when `compare_dense` — run the kind's reference path
    (onehot scatter-dispatch for moe, dense matmul for attn/gnn) for the
    sorted-vs-onehot / sparse-vs-dense speedup. `verify` checks the
    sparse output against the reference (rel err) and, for moe, that the
    dispatch buffer is BITWISE equal to the onehot scatter (both place
    each token's row with no summation, so exact equality is the spec,
    not a tolerance).
    """
    session = session or WorkloadSession(problem)
    kind = problem.wdef.kind
    per_step = []
    li, drops = [], []
    exec_ms_total = 0.0
    ref_ms = []
    max_rel_err = 0.0
    bitwise_ok = True
    nsteps = 0
    m0 = n0 = nnz0 = 0
    for step in problem.steps():
        nsteps += 1
        with obs.span("workload.step", step=step.index, kind=kind,
                      scenario=problem.scenario):
            plan_ms_before = session.plan_ms
            ops, events = [], []
            for opnd in step.operands:
                op, ev = session.operator(opnd.mat, role=opnd.role)
                ops.append(op)
                events.append(ev)
            if step.index == 0:
                m0, n0 = step.operands[0].mat.shape
                nnz0 = step.operands[0].mat.nnz
            xs = [adapters.to_device(o.x) if o.x is not None else None
                  for o in step.operands]

            def chain():
                y = None
                for op, x in zip(ops, xs):
                    y = op.matmul(x if x is not None else y)
                return adapters.block_until_ready(y)

            outs = chain()                       # warm + output for verify
            times = []
            for _ in range(iters):
                t0 = time.perf_counter()
                chain()
                times.append((time.perf_counter() - t0) * 1e3)
            exec_ms = float(np.median(times))
            exec_ms_total += exec_ms

            rec = {"step": step.index, "events": events,
                   "plan_ms": session.plan_ms - plan_ms_before,
                   "exec_ms": exec_ms, "li": step.meta.get("li")}
            if compare_dense:
                ref = adapters.reference(kind, step, iters=iters)
                ref_ms.append(ref["ms"])
                rec["ref_ms"] = ref["ms"]
                if verify:
                    y = np.asarray(outs)
                    err = adapters.rel_err(y, ref["y"])
                    max_rel_err = max(max_rel_err, err)
                    rec["rel_err"] = err
                    if kind == "moe":
                        buf = np.asarray(ops[0].matmul(xs[0]))
                        if not np.array_equal(buf, np.asarray(ref["buf"])):
                            bitwise_ok = False
            if step.meta.get("li") is not None:
                li.append(step.meta["li"])
            if "drop_frac" in step.meta:
                drops.append(step.meta["drop_frac"])
            per_step.append(rec)

    plan_ms = session.plan_ms
    out = {
        "workload": problem.name, "kind": kind,
        "scenario": problem.scenario, "steps": nsteps,
        "width": problem.width, "m": m0, "n": n0, "nnz": nnz0,
        "plans": session.counts["plans"],
        "replans": session.counts["replans"],
        "reuses": session.counts["reuses"],
        "rebuilds": session.counts["rebuilds"],
        "deltas": session.counts["deltas"],
        "reuse_rate": round(session.reuse_rate, 4),
        "plan_ms_total": round(plan_ms, 3),
        "exec_ms_total": round(exec_ms_total, 3),
        "plan_cost_share": round(
            plan_ms / max(plan_ms + exec_ms_total, 1e-9), 4),
        "li_mean": round(float(np.mean(li)), 3) if li else None,
        "li_max": round(float(np.max(li)), 3) if li else None,
        "sparse_ms": round(exec_ms_total / max(nsteps, 1), 4),
        "per_step": per_step,
    }
    if drops:
        out["drop_frac"] = round(float(np.mean(drops)), 4)
    if compare_dense and ref_ms:
        out["ref_ms"] = round(float(np.mean(ref_ms)), 4)
        out["speedup_vs_ref"] = round(out["ref_ms"]
                                      / max(out["sparse_ms"], 1e-9), 3)
        if verify:
            out["max_rel_err"] = float(max_rel_err)
            out["verify_ok"] = bool(max_rel_err < 1e-3)
            if kind == "moe":
                out["dispatch_bitwise_equal"] = bool(bitwise_ok)
    return out
