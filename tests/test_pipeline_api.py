"""Problem -> Plan -> Operator pipeline facade (repro.api).

Covers the PR's acceptance criteria:
  * one plan() + Plan.build() reproduces the legacy apply_scheme +
    build_operator(engine="auto") wiring bit-identically
  * Plan.save / Plan.load round-trips restore operators for EVERY
    registered engine (including k-specialized SELL-SpMM plans) without
    re-tuning or re-conversion
  * permutation-carrying operator equivalence: Plan.build()(x) on the
    ORIGINAL index space matches the dense oracle for every registered
    scheme x engine pair
  * plugin registries: duplicate registration raises; a custom scheme
    participates in planning end-to-end
  * deprecation shims warn; the facade paths never touch them
  * the reorder disk cache writes atomically (no torn/partial files)
  * vectorized _rcm_blocked signature pass is bit-identical to the loop
"""
import os
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import (ENGINE_REGISTRY, SCHEME_REGISTRY, Plan, SpmvProblem,
                       plan, register_scheme)
from repro.core.reorder import api as reorder_api
from repro.core.reorder.rcm import rcm_order
from repro.matrices import generators as G

ALL_SCHEMES = list(SCHEME_REGISTRY)
ALL_ENGINES = list(ENGINE_REGISTRY)


@pytest.fixture()
def stores(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    monkeypatch.setenv("REPRO_REORDER_CACHE", str(tmp_path / "reorder"))
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path / "opcache"))
    return tmp_path


def _mat96():
    return G.shuffle(G.banded(96, 3, seed=0), seed=1)


# -- original-index-space equivalence, every scheme x engine ---------------

@pytest.mark.parametrize("engine", ALL_ENGINES)
@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_operator_original_space_equivalence(scheme, engine, stores):
    """Plan.build()(x) with x in the ORIGINAL index space must match the
    dense oracle for every registered scheme x engine pair."""
    mat = _mat96()
    hints = {"block_shape": (4, 4)} if engine in ("sell", "bell", "bcsr") \
        else {}
    pl = plan(SpmvProblem(mat, hints=hints), reorder=scheme, engine=engine)
    op = pl.build()
    x = np.random.default_rng(0).standard_normal(mat.n)
    want = mat.spmv(x)                      # == dense oracle (seed tests)
    got = np.asarray(op(jnp.asarray(x, jnp.float32)))
    scale = np.abs(want).max() + 1e-9
    assert np.abs(got - want).max() / scale < 1e-5, (scheme, engine)
    # and the SpMM path, same index-space contract
    X = np.random.default_rng(1).standard_normal((mat.n, 3))
    wantX = mat.to_dense() @ X
    gotX = np.asarray(op.matmul(jnp.asarray(X, jnp.float32)))
    assert np.abs(gotX - wantX).max() / (np.abs(wantX).max() + 1e-9) < 1e-5


def test_permuted_optout_equals_unwrap(stores):
    mat = _mat96()
    pl = plan(SpmvProblem(mat), reorder="rcm", engine="csr")
    op = pl.build()
    assert op.perm is not None and op.iperm is not None
    assert np.array_equal(np.sort(op.perm), np.arange(mat.m))
    xr = jnp.asarray(
        np.random.default_rng(2).standard_normal(mat.n), jnp.float32)
    y_opt = np.asarray(op(xr, permuted=True))
    y_raw = np.asarray(op.unwrap()(xr))
    assert np.array_equal(y_opt, y_raw)
    # carried permutation is exactly perm/iperm gathers around the engine
    x = np.asarray(xr)
    y_carried = np.asarray(op(xr))
    assert np.array_equal(
        y_carried, np.asarray(op.unwrap()(
            jnp.asarray(x[op.perm], jnp.float32)))[op.iperm])


# -- bit-identical reproduction of the legacy wiring -----------------------

def test_facade_matches_legacy_wiring_bitwise(stores):
    from repro.core.spmv.opcache import build_cached

    mat = G.shuffle(G.banded(256, 4, seed=0), seed=1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        rmat = reorder_api.apply_scheme(mat, "rcm")
    op_legacy, info = build_cached(rmat, engine="auto")
    pl = plan(SpmvProblem(mat), reorder="rcm", engine="auto")
    op_new = pl.build()
    # same joint decision ...
    assert pl.tune.engine == info["plan"]["engine"]
    assert list(pl.tune.block_shape) == list(info["plan"]["block_shape"])
    assert pl.tune.sell_sigma == info["plan"]["sell_sigma"]
    assert pl.tune.costs == info["plan"]["costs"]
    # ... and bit-identical numerics in the reordered space
    xr = jnp.asarray(
        np.random.default_rng(0).standard_normal(mat.n), jnp.float32)
    y_legacy = np.asarray(op_legacy(xr))
    y_new = np.asarray(op_new(xr, permuted=True))
    assert np.array_equal(y_legacy, y_new)


# -- plan store round-trips, every engine ----------------------------------

@pytest.mark.parametrize("engine", ALL_ENGINES + ["auto"])
def test_plan_save_load_roundtrip(engine, stores):
    """Plan.load restores the decision AND the operator payload: no
    re-tune, no re-conversion, bit-identical results — for every engine,
    with a k=8-specialized plan (the SELL case exercises the k-tiled SpMM
    kernel path after reload)."""
    mat = G.power_law(128, alpha=1.8, seed=3)
    hints = {"block_shape": (4, 4)} if engine in ("sell", "bell", "bcsr") \
        else {}
    pl = plan(SpmvProblem(mat, k=8, hints=hints), reorder="rcm",
              engine=engine)
    op = pl.build()
    assert pl.tune.k == 8

    pl2 = Plan.load(pl.key, mat=mat)
    assert pl2 is not None and pl2.cache_hit
    assert pl2.tune_ms == 0.0
    assert pl2.tune.to_json() == pl.tune.to_json()
    assert pl2.scheme == "rcm" and np.array_equal(pl2.perm, pl.perm)
    op2 = pl2.build()
    assert op2.build_info["cache_hit"]
    assert op2.build_info["build_ms"] == 0.0

    x = jnp.asarray(
        np.random.default_rng(4).standard_normal(mat.n), jnp.float32)
    assert np.array_equal(np.asarray(op(x)), np.asarray(op2(x)))
    X = jnp.asarray(
        np.random.default_rng(5).standard_normal((mat.n, 8)), jnp.float32)
    assert np.array_equal(np.asarray(op.matmul(X)), np.asarray(op2.matmul(X)))


def test_plan_load_restores_operator_without_matrix(stores):
    """A complete store entry rebuilds the operator with NO matrix at all
    (device arrays + perm live in the entry)."""
    mat = _mat96()
    pl = plan(SpmvProblem(mat), reorder="rcm", engine="ell")
    y_ref = np.asarray(pl.build()(jnp.ones(mat.n, jnp.float32)))
    pl2 = Plan.load(pl.key)              # no mat=
    op2 = pl2.build()
    assert op2.build_info["cache_hit"]
    assert np.array_equal(np.asarray(op2(jnp.ones(mat.n, jnp.float32))),
                          y_ref)


def test_plan_second_call_hits_store(stores):
    mat = _mat96()
    p1 = plan(SpmvProblem(mat, k=4), reorder="auto", engine="auto")
    assert not p1.cache_hit and p1.scheme_costs
    p1.build()
    p2 = plan(SpmvProblem(mat, k=4), reorder="auto", engine="auto")
    assert p2.cache_hit and p2.tune_ms == 0.0
    assert p2.scheme == p1.scheme
    assert p2.label() == p1.label()


def test_auto_plan_distinct_scheme_sets_are_distinct_entries(stores):
    """hints["schemes"] is part of the plan identity: searching a
    different candidate set must never return another request's plan."""
    mat = _mat96()
    p1 = plan(SpmvProblem(mat, hints={"schemes": ["rcm"]}),
              reorder="auto", engine="csr")
    p2 = plan(SpmvProblem(mat, hints={"schemes": ["random"]}),
              reorder="auto", engine="csr")
    assert p1.key != p2.key
    assert not p2.cache_hit and p2.scheme == "random"


def test_auto_scheme_plans_are_k_specialized(stores):
    """reorder="auto" selection is k-dependent (per-scheme cost deltas
    amortize differently), so k must stay in the key even when the engine
    is fixed."""
    mat = _mat96()
    p1 = plan(SpmvProblem(mat, k=1), reorder="auto", engine="ell")
    p8 = plan(SpmvProblem(mat, k=8), reorder="auto", engine="ell")
    assert p1.key != p8.key and not p8.cache_hit
    assert p8.k == 8
    # fixed scheme AND engine: k normalizes out (one entry per k-sweep)
    f1 = plan(SpmvProblem(mat, k=1), reorder="rcm", engine="ell")
    f8 = plan(SpmvProblem(mat, k=8), reorder="rcm", engine="ell")
    assert f1.key == f8.key and f8.cache_hit


def test_loaded_plan_resave_roundtrips_operator(stores, tmp_path):
    """Saving a LOADED plan re-prefixes the operator payload: the copy
    must restore the operator exactly like the original entry."""
    mat = _mat96()
    pl = plan(SpmvProblem(mat), reorder="rcm", engine="ell")
    y_ref = np.asarray(pl.build()(jnp.ones(mat.n, jnp.float32)))
    copy_path = str(tmp_path / "copies" / "entry.json")
    Plan.load(pl.key).save(path=copy_path)
    pl2 = Plan.load(copy_path)
    op2 = pl2.build()
    assert op2.build_info["cache_hit"]
    assert np.array_equal(
        np.asarray(op2(jnp.ones(mat.n, jnp.float32))), y_ref)


def test_plan_hit_reports_zero_plan_time(stores):
    """Cache-hit accounting reflects THIS run: no reorder/tune was paid."""
    mat = _mat96()
    p1 = plan(SpmvProblem(mat), reorder="rcm", engine="csr")
    assert p1.reorder_ms > 0.0
    p2 = plan(SpmvProblem(mat), reorder="rcm", engine="csr")
    assert p2.cache_hit
    assert p2.reorder_ms == 0.0 and p2.tune_ms == 0.0 and p2.plan_ms == 0.0


def test_plan_store_disabled(stores, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", "off")
    mat = _mat96()
    p1 = plan(SpmvProblem(mat), reorder="rcm", engine="csr")
    op = p1.build()
    assert not p1.cache_hit and not op.build_info["cache_hit"]
    p2 = plan(SpmvProblem(mat), reorder="rcm", engine="csr")
    assert not p2.cache_hit


# -- registries ------------------------------------------------------------

def test_duplicate_registration_raises():
    with pytest.raises(ValueError):
        register_scheme("rcm")(lambda mat, seed=0: None)


def test_unknown_names_raise(stores):
    mat = _mat96()
    with pytest.raises(KeyError):
        plan(SpmvProblem(mat), reorder="nope")
    with pytest.raises(KeyError):
        plan(SpmvProblem(mat), engine="nope")


def test_custom_scheme_plugin_plans_end_to_end(stores):
    """A scheme registered by a third party is immediately plannable."""
    name = "test_reverse"

    def reverse_order(mat, seed=0):
        return np.arange(mat.m - 1, -1, -1, dtype=np.int64)

    register_scheme(name, description="test plugin",
                    override=name in SCHEME_REGISTRY)(reverse_order)
    try:
        mat = _mat96()
        pl = plan(SpmvProblem(mat), reorder=name, engine="csr")
        assert pl.scheme == name
        x = np.random.default_rng(6).standard_normal(mat.n)
        got = np.asarray(pl.build()(jnp.asarray(x, jnp.float32)))
        want = mat.spmv(x)
        assert np.abs(got - want).max() / (np.abs(want).max() + 1e-9) < 1e-5
    finally:
        SCHEME_REGISTRY.pop(name, None)


def test_engine_capability_metadata():
    for name in ("csr", "ell", "sell", "bell", "bcsr", "dense"):
        spec = ENGINE_REGISTRY[name]
        assert spec.supports_spmm
        assert spec.cost_fn is not None and spec.candidates_fn is not None
    assert ENGINE_REGISTRY["sell"].device == "tpu"
    assert ENGINE_REGISTRY["csr"].device == "any"


# -- deprecation shims ------------------------------------------------------

def test_shims_emit_deprecation_warnings(stores):
    from repro.core.spmv.ops import build_operator

    mat = _mat96()
    with pytest.warns(DeprecationWarning):
        build_operator(mat, "csr")
    with pytest.warns(DeprecationWarning):
        reorder_api.apply_scheme(mat, "rcm")


def test_facade_paths_use_no_shims(stores):
    """Nothing inside src/ goes through the deprecated entry points: the
    full pipeline (plan, build, both call paths, bench cell, service
    round-trip) runs clean under DeprecationWarning-as-error."""
    from repro.launch.spmv_bench import run_single
    from repro.serving.spmv_service import SpmvService

    mat = _mat96()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        pl = plan(SpmvProblem(mat, k=4), reorder="auto", engine="auto")
        op = pl.build()
        op(jnp.ones(mat.n, jnp.float32))
        op.matmul(jnp.ones((mat.n, 4), jnp.float32))
        run_single("smoke_powerlaw", "rcm", iters=2, write_results=False)
        with SpmvService(engine="csr", reorder="rcm", max_batch=4,
                         window_ms=2.0) as svc:
            svc.register("m", mat)
            fut = svc.submit("m", np.ones(mat.n))
            svc.flush()
            fut.result(timeout=10)


# -- service x permutation-carrying operators ------------------------------

def test_service_reorders_internally_serves_original_space(stores):
    from repro.serving.spmv_service import SpmvService

    mat = _mat96()
    rng = np.random.default_rng(7)
    xs = [rng.standard_normal(mat.n) for _ in range(6)]
    with SpmvService(engine="auto", reorder="rcm", max_batch=4,
                     window_ms=5.0) as svc:
        svc.register("m", mat)
        futs = [svc.submit("m", x) for x in xs]
        svc.flush()
        for x, fut in zip(xs, futs):
            want = mat.spmv(x)
            got = np.asarray(fut.result(timeout=10))
            scale = np.abs(want).max() + 1e-9
            assert np.abs(got - want).max() / scale < 1e-4


# -- satellite: atomic reorder cache ---------------------------------------

def test_reorder_cache_write_is_atomic(stores):
    mat = _mat96()
    perm = reorder_api.reorder(mat, "rcm")
    d = os.environ["REPRO_REORDER_CACHE"]
    files = os.listdir(d)
    assert len([f for f in files if f.endswith(".npy")]) == 1
    assert not [f for f in files if f.endswith(".tmp")], files
    # cache hit returns the identical permutation
    assert np.array_equal(reorder_api.reorder(mat, "rcm"), perm)


# -- satellite: vectorized _rcm_blocked ------------------------------------

def _rcm_blocked_loop_reference(mat, seed=0, block=8):
    """The pre-vectorization per-row loop form, verbatim."""
    base = rcm_order(mat, seed)
    rmat = mat.permute(base)
    m = rmat.m
    win = block * 8
    perm_local = np.arange(m, dtype=np.int64)
    rp = rmat.rowptr.astype(np.int64)
    cols = rmat.cols.astype(np.int64)
    for w0 in range(0, m, win):
        w1 = min(w0 + win, m)
        rows = np.arange(w0, w1)
        sig = np.full(rows.size, np.iinfo(np.int64).max)
        for i, r in enumerate(rows):
            if rp[r + 1] > rp[r]:
                sig[i] = cols[rp[r]] // 128
        order = np.argsort(sig, kind="stable")
        perm_local[w0:w1] = rows[order]
    return base[perm_local]


def test_rcm_blocked_vectorized_bit_identical(stores):
    mats = [
        G.power_law(200, alpha=1.9, seed=7),
        G.shuffle(G.banded(300, 5, seed=0), seed=2),
        G.shuffle(G.sbm(256, 4, 0.2, 0.01, seed=4), seed=5),
    ]
    # plus a matrix WITH empty rows (the sentinel branch of the gather)
    dense = np.zeros((70, 70))
    rng = np.random.default_rng(0)
    for i in range(0, 70, 2):                    # odd rows/cols stay empty
        js = rng.integers(0, 35, size=3) * 2
        dense[i, js] = 1.0
        dense[js, i] = 1.0
    from repro.core.sparse.csr import CSRMatrix

    mats.append(CSRMatrix.from_dense(dense))
    fn = SCHEME_REGISTRY["rcm_blocked"].fn
    for mat in mats:
        assert np.array_equal(fn(mat), _rcm_blocked_loop_reference(mat))
