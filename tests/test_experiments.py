"""Experiment harness: spec enumeration, content-addressed store,
resumable runner, strict report accessors, deprecation shims."""
from __future__ import annotations

import json
import os

import numpy as np
import pytest

from repro.core import registry
from repro.experiments import (PRIMARY, Cell, ExperimentSpec, MeasurePolicy,
                               MissingCellError, Report, ResultStore, Runner,
                               paper_schemes)
from repro.matrices import generators as G

FAST = MeasurePolicy(iters=1, warmup=0, with_yax=False, with_parallel=False,
                     with_metrics=False)


@pytest.fixture()
def stores(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RESULT_STORE", str(tmp_path / "results"))
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    monkeypatch.setenv("REPRO_REORDER_CACHE", str(tmp_path / "reorder"))
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path / "opcache"))
    return tmp_path


_MATS = {}


def _get_matrix(name):
    if name not in _MATS:
        gen = {"tiny_banded": lambda: G.banded(192, 3, seed=0),
               "tiny_stencil": lambda: G.stencil_2d(14, seed=1),
               "tiny_powerlaw": lambda: G.power_law(256, alpha=1.9, seed=2)}
        _MATS[name] = gen[name]()
    return _MATS[name]


def _runner(spec, **kw):
    kw.setdefault("verbose", False)
    kw.setdefault("get_matrix", _get_matrix)
    return Runner(spec, **kw)


# -- spec / cell identity ---------------------------------------------------

class TestSpec:
    def test_axis_cross_product(self):
        spec = ExperimentSpec(name="t", matrices=("a", "b"),
                              schemes=("baseline", "rcm"),
                              engines=("csr", "ell"), ks=(1, 4),
                              variants=("x", "y"))
        assert len(spec.cells()) == 2 * 2 * 2 * 2 * 2

    def test_profile_expansion(self):
        spec = ExperimentSpec(name="t", matrices=("a",),
                              profiles=(PRIMARY, "M2_csr_f64_p8"))
        cells = spec.cells()
        assert len(cells) == 2
        assert {c.profile for c in cells} == {PRIMARY, "M2_csr_f64_p8"}
        m2 = next(c for c in cells if c.profile == "M2_csr_f64_p8")
        assert (m2.engine, m2.dtype, m2.p) == ("csr", "float64", 8)

    def test_star_profiles_include_plugins(self):
        registry.register_profile("Mtest_plugin", engine="csr", p=2)
        try:
            spec = ExperimentSpec(name="t", matrices=("a",), profiles="*")
            assert "Mtest_plugin" in {c.profile for c in spec.cells()}
        finally:
            registry.PROFILE_REGISTRY.pop("Mtest_plugin")

    def test_profiles_and_physical_axes_exclusive(self):
        with pytest.raises(ValueError):
            ExperimentSpec(name="t", matrices=("a",), profiles=(PRIMARY,),
                           engines=("csr",))
        # dtypes/ps would be silently ignored next to a profile — reject
        with pytest.raises(ValueError):
            ExperimentSpec(name="t", matrices=("a",), profiles=(PRIMARY,),
                           dtypes=("float64",))
        with pytest.raises(ValueError):
            ExperimentSpec(name="t", matrices=("a",), profiles=(PRIMARY,),
                           ps=(16,))

    def test_key_is_content_addressed_not_named(self):
        """A profile is presentation: the same physical point under a
        profile name and under explicit axes shares one cell key."""
        by_prof = ExperimentSpec(name="p", matrices=("a",),
                                 profiles=(PRIMARY,)).cells()[0]
        by_axes = ExperimentSpec(name="q", matrices=("a",),
                                 engines=("csr",), dtypes=("float32",),
                                 ps=(8,)).cells()[0]
        assert by_prof.key() == by_axes.key()

    def test_key_tracks_policy_but_not_reporting_knobs(self):
        base = ExperimentSpec(name="t", matrices=("a",), engines=("csr",))
        warm = ExperimentSpec(name="t", matrices=("a",), engines=("csr",),
                              policy=MeasurePolicy(warmup=0))
        amort = ExperimentSpec(name="t", matrices=("a",), engines=("csr",),
                               policy=MeasurePolicy(amortize_iters=7))
        assert base.cells()[0].key() != warm.cells()[0].key()
        assert base.cells()[0].key() == amort.cells()[0].key()

    def test_cg_profile_resolution_shares_non_cg_cells(self):
        """Campaigns differing only in OTHER profiles' CG policy share
        this profile's cells."""
        a = ExperimentSpec(name="a", matrices=("m",), profiles=(PRIMARY,),
                           policy=MeasurePolicy(cg_profiles=()))
        b = ExperimentSpec(name="b", matrices=("m",),
                           profiles=("M3_csr_f32_p4",),
                           policy=MeasurePolicy(cg_profiles=(PRIMARY,)))
        assert not b.cells()[0].policy_dict()["with_cg"]
        c = ExperimentSpec(name="c", matrices=("m",),
                           profiles=("M3_csr_f32_p4",),
                           policy=MeasurePolicy(cg_profiles=()))
        assert b.cells()[0].key() == c.cells()[0].key()
        assert a.cells()[0].key() != b.cells()[0].key()  # different point

    def test_paper_schemes_from_registry(self):
        s = paper_schemes()
        assert s[0] == "baseline" and s[-1] == "random"
        assert {"rcm", "metis", "louvain", "patoh"} <= set(s)


# -- store ------------------------------------------------------------------

class TestStore:
    def test_roundtrip_and_atomic_naming(self, tmp_path):
        store = ResultStore(str(tmp_path))
        store.put("k1", {"matrix": "a"}, {"v": 1.5})
        entry = store.get("k1")
        assert entry["record"] == {"v": 1.5} and entry["cell"]["matrix"] == "a"
        # no tmp leftovers after the rename
        assert [f for f in os.listdir(tmp_path)] == ["k1.json"]

    def test_corrupt_truncated_and_alien_entries_read_as_missing(self, tmp_path):
        store = ResultStore(str(tmp_path))
        store.put("k1", {}, {"v": 1})
        # truncated
        with open(store.path("k1"), "w") as f:
            f.write('{"schema": 1, "record": {"v"')
        assert store.get("k1") is None
        # valid json, alien schema
        with open(store.path("k1"), "w") as f:
            json.dump({"schema": 99, "record": {}}, f)
        assert store.get("k1") is None
        # not a dict
        with open(store.path("k1"), "w") as f:
            json.dump([1, 2], f)
        assert store.get("k1") is None
        # binary garbage
        with open(store.path("k1"), "wb") as f:
            f.write(b"\x00\xff\x00garbage")
        assert store.get("k1") is None
        assert store.get("never_written") is None


# -- runner -----------------------------------------------------------------

class TestRunner:
    def test_resumable_and_partial_grid_delta(self, stores):
        spec = ExperimentSpec(name="t", matrices=("tiny_banded",),
                              schemes=("baseline", "rcm"),
                              engines=("csr",), policy=FAST)
        r1 = _runner(spec).run()
        assert (r1.measured, r1.reused) == (2, 0)
        r2 = _runner(spec).run()
        assert (r2.measured, r2.reused) == (0, 2)
        # adding an axis value measures ONLY the delta
        wider = ExperimentSpec(name="t", matrices=("tiny_banded",
                                                   "tiny_stencil"),
                               schemes=("baseline", "rcm"),
                               engines=("csr",), policy=FAST)
        r3 = _runner(wider).run()
        assert (r3.measured, r3.reused) == (2, 2)

    def test_corrupt_cell_remeasured_not_fatal(self, stores):
        spec = ExperimentSpec(name="t", matrices=("tiny_banded",),
                              schemes=("baseline",), engines=("csr",),
                              policy=FAST)
        store = ResultStore()
        r1 = _runner(spec, store=store).run()
        assert r1.measured == 1
        key = spec.cells()[0].key()
        with open(store.path(key), "w") as f:
            f.write("{torn")
        r2 = _runner(spec, store=store).run()
        assert (r2.measured, r2.reused) == (1, 0)
        assert store.get(key) is not None     # healed in place

    def test_on_error_record_continues_and_does_not_persist(self, stores):
        spec = ExperimentSpec(name="t", matrices=("tiny_banded",),
                              schemes=("baseline", "nonexistent_scheme"),
                              engines=("csr",), policy=FAST)
        rep = _runner(spec, on_error="record").run()
        assert rep.measured == 1 and len(rep.failures) == 1
        assert "nonexistent_scheme" in rep.failures[0]["error"]
        # failures are retried on re-run (nothing bogus persisted)
        rep2 = _runner(spec, on_error="record").run()
        assert rep2.reused == 1 and len(rep2.failures) == 1
        with pytest.raises(KeyError):
            _runner(spec).run()               # default on_error="raise"

    def test_spmm_cells_and_verify(self, stores):
        spec = ExperimentSpec(
            name="t", matrices=("tiny_powerlaw",), schemes=("rcm",),
            engines=("csr",), ks=(4,),
            policy=MeasurePolicy(iters=1, warmup=0, with_yax=False,
                                 with_parallel=False, with_metrics=False,
                                 verify=True))
        rep = _runner(spec).run()
        rec = rep.cell("tiny_powerlaw", "rcm")
        assert rec["per_vector_ms"] == pytest.approx(rec["spmm_ms"] / 4)
        assert rec["verify_rel_err"] < 1e-4

    def test_schedule_kind(self, stores):
        spec = ExperimentSpec(
            name="t", matrices=("tiny_stencil",),
            schemes=("baseline", "random"),
            engines=("csr",), ps=(2,), kind="schedule",
            variants=("static_default", "static_c16", "nnz_balanced"),
            policy=MeasurePolicy(iters=2, warmup=0))
        rep = _runner(spec).run()
        for scheme in spec.schemes:
            for var in spec.variants:
                rec = rep.cell("tiny_stencil", scheme, variant=var)
                assert rec["modelled_par_ms"] > 0 and rec["gflops"] > 0

    def test_schedule_kind_applies_scheme(self, stores, monkeypatch):
        """The scheme axis permutes the matrix before panels are cut —
        a non-identity scheme must reach the measurement reordered."""
        from repro.core.reorder import api as reorder_api
        from repro.experiments import cells as cells_mod
        from repro.experiments.spec import Cell

        calls = []
        real = reorder_api.reorder
        monkeypatch.setattr(
            reorder_api, "reorder",
            lambda mat, scheme, *a, **kw: calls.append(scheme)
            or real(mat, scheme, *a, **kw))
        pol = tuple(sorted(MeasurePolicy(iters=1, warmup=0)
                           .resolve("").items()))
        mat = _get_matrix("tiny_powerlaw")
        for scheme in ("baseline", "random"):
            cells_mod.measure_schedule_cell(
                Cell(kind="schedule", matrix="m", scheme=scheme,
                     engine="csr", dtype="float32", p=2, k=1,
                     variant="nnz_balanced", policy=pol), mat)
        assert calls == ["random"]   # baseline untouched, random permuted

    def test_full_protocol_fields(self, stores):
        spec = ExperimentSpec(
            name="t", matrices=("tiny_banded",), schemes=("baseline",),
            profiles=(PRIMARY,),
            policy=MeasurePolicy(iters=2, warmup=1,
                                 cg_profiles=(PRIMARY,)))
        rec = _runner(spec).run().cell("tiny_banded", "baseline")
        for f in ("seq_ios_ms", "seq_yax_ms", "cg_ms", "par_static_ms",
                  "par_nnz_balanced_ms", "li_static", "bandwidth",
                  "block_fill_8x128", "tune_ms", "format_build_ms"):
            assert f in rec, f


# -- report -----------------------------------------------------------------

def _fake_report(values):
    """Report over synthetic records: values[scheme][matrix] -> gflops."""
    schemes = tuple(values)
    matrices = tuple(next(iter(values.values())))
    spec = ExperimentSpec(name="fake", matrices=matrices, schemes=schemes,
                          engines=("csr",))
    entries = [(c, {"seq_ios_gflops": values[c.scheme][c.matrix]})
               for c in spec.cells()]
    return spec, Report(spec, entries)


class TestReport:
    def test_grid_and_speedup(self):
        _, rep = _fake_report({"baseline": {"a": 1.0, "b": 2.0},
                               "rcm": {"a": 2.0, "b": 1.0}})
        g = rep.grid("seq_ios_gflops", ["a", "b"], ["baseline", "rcm"])
        assert np.allclose(g, [[1, 2], [2, 1]])
        sp = rep.speedup("seq_ios_gflops", ["a", "b"], ["rcm"])
        assert np.allclose(sp, [[2.0, 0.5]])

    def test_missing_cell_raises_with_coords(self):
        _, rep = _fake_report({"baseline": {"a": 1.0}})
        with pytest.raises(MissingCellError) as ei:
            rep.grid("seq_ios_gflops", ["a"], ["baseline", "rcm"])
        assert "rcm" in str(ei.value) and "'a'" in str(ei.value)

    def test_missing_field_raises_naming_field(self):
        _, rep = _fake_report({"baseline": {"a": 1.0}})
        with pytest.raises(MissingCellError) as ei:
            rep.value("cg_gflops", "a", "baseline")
        assert "cg_gflops" in str(ei.value)

    def test_no_silent_nan(self):
        """The failure mode the redesign kills: absent cells must never
        turn into NaN speedups that skew consistency stats."""
        _, rep = _fake_report({"baseline": {"a": 1.0}, "rcm": {"a": 0.0}})
        g = rep.grid("seq_ios_gflops", ["a"], ["baseline", "rcm"])
        assert np.isfinite(g).all()
        with pytest.raises(MissingCellError):
            rep.speedup("seq_ios_gflops", ["a", "ghost"], ["rcm"])

    def test_stats_wrappers(self):
        _, rep = _fake_report({"baseline": {"a": 1.0, "b": 1.0},
                               "rcm": {"a": 2.0, "b": 0.5}})
        prof = rep.performance_profile("seq_ios_gflops", ["a", "b"],
                                       ["baseline", "rcm"],
                                       np.array([1.0, 4.0]))
        assert prof.shape == (2, 2) and np.allclose(prof[:, 1], 1.0)
        counts = rep.speedup_buckets("seq_ios_gflops", ["a", "b"], ["rcm"])
        assert counts.sum() == 2
        win = rep.pairwise_win_rates("seq_ios_gflops", ["a", "b"],
                                     ["baseline", "rcm"])
        assert win[1, 0] == 0.5

    def test_bench_summary_written_atomically(self, tmp_path):
        _, rep = _fake_report({"baseline": {"a": 1.0, "b": 1.0},
                               "rcm": {"a": 2.0, "b": 2.0}})
        path = str(tmp_path / "BENCH_spmv.json")
        rep.write_bench_summary(path)
        with open(path) as f:
            summary = json.load(f)
        assert summary["schema"] == 1
        assert summary["speedup_vs_baseline"]["rcm"] == pytest.approx(2.0)
        assert not [p for p in os.listdir(tmp_path) if p.endswith(".tmp")]

    def test_break_even(self, stores):
        spec = ExperimentSpec(name="t", matrices=("tiny_banded",),
                              schemes=("baseline", "rcm"),
                              engines=("csr",), policy=FAST)
        rep = _runner(spec).run()
        be = rep.break_even("seq_ios_ms")
        assert len(be) == 1                     # one non-baseline cell
        item = be[0]
        assert (item["matrix"], item["scheme"]) == ("tiny_banded", "rcm")
        assert item["break_even_iters"] > 0     # inf allowed (no saving)

    def test_break_even_one_entry_per_machine_point(self):
        """Multi-profile campaigns must not collapse per-machine entries."""
        spec = ExperimentSpec(name="fake", matrices=("a",),
                              schemes=("baseline", "rcm"),
                              profiles=(PRIMARY, "M3_csr_f32_p4"))
        entries = [(c, {"seq_ios_ms": 1.0 if c.scheme == "baseline"
                        else 0.5}) for c in spec.cells()]
        be = Report(spec, entries).break_even("seq_ios_ms")
        assert len(be) == 2
        assert {e["profile"] for e in be} == {PRIMARY, "M3_csr_f32_p4"}


# -- machine-profile registry ----------------------------------------------

class TestProfiles:
    def test_builtins_registered(self):
        assert PRIMARY == "M1_csr_f32_p8"
        assert registry.get_profile(PRIMARY).primary
        assert registry.get_profile("M5_auto_f32_p8").engine == "auto"

    def test_duplicate_rejected_unless_override(self):
        with pytest.raises(ValueError):
            registry.register_profile(PRIMARY)
        registry.register_profile(PRIMARY, primary=True, override=True)
        assert registry.primary_profile() == PRIMARY

    def test_unknown_profile_message(self):
        with pytest.raises(KeyError, match="unknown profile"):
            registry.get_profile("M99_nope")


# -- deprecation shims ------------------------------------------------------

class TestLegacyShims:
    def test_run_campaign_and_grid_shims(self, stores):
        from benchmarks import common

        with pytest.warns(DeprecationWarning):
            recs = common.run_campaign(matrices=["smoke_banded"],
                                       schemes=["baseline"], iters=2,
                                       verbose=False)
        key = f"{common.PRIMARY}|smoke_banded|baseline"
        assert key in recs and recs[key]["seq_ios_ms"] > 0
        with pytest.warns(DeprecationWarning):
            g = common.grid(recs, common.PRIMARY, ["smoke_banded", "ghost"],
                            ["baseline"], "seq_ios_gflops")
        assert np.isfinite(g[0, 0]) and np.isnan(g[0, 1])

    def test_measure_cell_shim(self, stores):
        from benchmarks import common

        with pytest.warns(DeprecationWarning):
            rec = common.measure_cell(_get_matrix("tiny_banded"), "baseline",
                                      dict(engine="csr", dtype="float32",
                                           p=2), iters=1, with_cg=False)
        assert rec["seq_ios_ms"] > 0 and "li_static" in rec
