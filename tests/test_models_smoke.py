"""Per-architecture smoke tests: reduced same-family config, one forward
(and a grad step for a subset), asserting shapes + finiteness on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import smoke_config
from repro.models import model as MDL

ARCHS = sorted(registry.ARCHS)
B, S = 2, 64


def make_batch(cfg, key, batch=B, seq=S):
    ks = jax.random.split(key, 3)
    out = {}
    if cfg.embed_inputs:
        out["tokens"] = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab)
    else:
        out["embeds"] = jax.random.normal(ks[0], (batch, seq, cfg.d_model),
                                          jnp.float32)
        out["labels"] = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab)
    if cfg.cross_attn_period:
        out["image_embeds"] = jax.random.normal(
            ks[2], (batch, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    cfg = smoke_config(registry.get(arch))
    key = jax.random.PRNGKey(0)
    params = MDL.init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, cache, metrics = jax.jit(
        lambda p, b: MDL.forward(p, b, cfg))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    for k, v in metrics.items():
        assert np.isfinite(np.asarray(v)).all(), (arch, k)


@pytest.mark.parametrize("arch", ["qwen2-7b", "qwen3-moe-30b-a3b", "rwkv6-7b",
                                  "zamba2-7b", "gemma2-27b", "hubert-xlarge"])
def test_train_grad_step(arch):
    cfg = smoke_config(registry.get(arch))
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    def loss(p):
        l, _ = MDL.loss_fn(p, batch, cfg, train=True)
        return l

    l, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(l)), arch
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), arch
    # loss magnitude sane for random init: ~ln(vocab)
    assert 0.1 < float(l) < 3 * np.log(cfg.vocab) + 2


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not registry.get(a).encoder_only])
def test_decode_step_matches_prefill_tail(arch):
    """Prefill S tokens, then decode token S; logits must match a full
    forward over S+1 tokens at the last position (cache correctness)."""
    cfg = smoke_config(registry.get(arch))
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    full = make_batch(cfg, jax.random.PRNGKey(1), batch=1, seq=17)
    tokens = full["tokens"]

    # full forward for reference
    ref_logits, _, _ = MDL.forward(params, full, cfg)

    # prefill first 16 by decoding token-by-token (exercises the cache), then
    # compare the final step's logits.
    cache = MDL.init_cache(cfg, 1, 32, dtype=jnp.float32)
    step_fn = jax.jit(lambda p, b, c: MDL.forward(p, b, cfg, cache=c))
    for t in range(17):
        b1 = {"tokens": tokens[:, t:t + 1]}
        if cfg.cross_attn_period:
            b1["image_embeds"] = full["image_embeds"]
        logits, cache, _ = step_fn(params, b1, cache)
    got = np.asarray(logits[0, 0])
    want = np.asarray(ref_logits[0, -1])
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_param_counts_match_spec():
    """Full configs' parameter counts are in the advertised ballpark."""
    expect = {
        "qwen2-7b": (6e9, 9e9),
        "command-r-plus-104b": (95e9, 115e9),
        "gemma2-27b": (22e9, 30e9),
        "minicpm-2b": (2e9, 3.5e9),
        "qwen3-moe-30b-a3b": (25e9, 34e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "rwkv6-7b": (6e9, 9e9),
        "llama-3.2-vision-11b": (8e9, 12e9),
        "zamba2-7b": (6e9, 9e9),
        "hubert-xlarge": (0.7e9, 1.3e9),
    }
    for arch, (lo, hi) in expect.items():
        n = registry.get(arch).param_count()
        assert lo < n < hi, (arch, f"{n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]")


def test_moe_active_params():
    cfg = registry.get("qwen3-moe-30b-a3b")
    active = cfg.active_param_count()
    assert 2e9 < active < 5e9, f"{active/1e9:.2f}B"
