"""Batched multi-vector SpMV (SpMM) engine layer + streaming service.

Acceptance coverage for the SpMM PR:
  * cross-engine SpMM equivalence — for every engine and k in {1, 3, 8},
    `operator.matmul(X)` matches the column-stacked k-fold SpMV oracle on
    the paper suite generators (including power-law skew), baseline and
    reordered;
  * the k-tiled SELL SpMM Pallas kernel (interpret mode) == jnp oracle,
    including k that is not a multiple of the k-tile;
  * the k-aware tuner: cost(k=1) is the SpMV model, matrix bytes amortize
    over k, plans record k and restore through the opcache;
  * the micro-batching service returns per-request results identical to
    unbatched execution while actually coalescing;
  * block CG consumes one SpMM per iteration and matches per-column CG.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.measure.cg import block_cg_solve, cg_solve
from repro.core.reorder import api as reorder_api
from repro.core.spmv.ops import build_operator
from repro.core.spmv.tune import candidate_cost, matrix_features, tune
from repro.kernels.sell_spmm.ops import pick_k_tile
from repro.matrices import generators as G
from repro.serving.spmv_service import SpmvService

ENGINES = ["csr", "ell", "sell", "bell", "bcsr", "dense"]

MATS = {
    "banded": lambda: G.banded(64, 3, 0),
    "stencil": lambda: G.stencil_2d(8, seed=1),
    "rmat": lambda: G.rmat(6, 4, 2),
    "powerlaw": lambda: G.power_law(96, alpha=1.8, seed=3),
}


def _oracle(mat, x_block):
    return np.stack([mat.spmv(x_block[:, j])
                     for j in range(x_block.shape[1])], axis=1)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("matname", list(MATS))
@pytest.mark.parametrize("k", [1, 3, 8])
def test_cross_engine_spmm_equivalence(engine, matname, k):
    """Acceptance: matmul == column-stacked SpMV oracle, every engine."""
    mat = MATS[matname]()
    x = np.random.default_rng(0).standard_normal((mat.n, k))
    want = _oracle(mat, x)
    kw = {"block_shape": (4, 4)} if engine in ("bell", "bcsr", "sell") else {}
    op = build_operator(mat, engine, **kw)
    got = np.asarray(op.matmul(jnp.asarray(x, jnp.float32)))
    assert got.shape == want.shape
    scale = np.abs(want).max() + 1e-9
    assert np.abs(got - want).max() / scale < 1e-5, engine


@pytest.mark.parametrize("scheme", ["rcm", "metis"])
def test_spmm_equivalence_under_reordering(scheme):
    mat = MATS["powerlaw"]()
    perm = reorder_api.reorder(mat, scheme, cache=False)
    rmat = mat.permute(perm)
    x = np.random.default_rng(1).standard_normal((rmat.n, 8))
    want = _oracle(rmat, x)
    for engine in ("csr", "sell"):
        kw = {"block_shape": (4, 4)} if engine == "sell" else {}
        op = build_operator(rmat, engine, **kw)
        got = np.asarray(op.matmul(jnp.asarray(x, jnp.float32)))
        scale = np.abs(want).max() + 1e-9
        assert np.abs(got - want).max() / scale < 1e-5, (scheme, engine)


def test_matmul_1d_input_degrades_to_spmv():
    mat = MATS["banded"]()
    x = np.random.default_rng(2).standard_normal(mat.n)
    for engine in ENGINES:
        kw = {"block_shape": (4, 4)} if engine in ("bell", "bcsr", "sell") else {}
        op = build_operator(mat, engine, **kw)
        a = np.asarray(op.matmul(jnp.asarray(x, jnp.float32)))
        b = np.asarray(op(jnp.asarray(x, jnp.float32)))
        assert a.shape == (mat.m,) and np.array_equal(a, b), engine


@pytest.mark.parametrize("k", [1, 5, 8, 20])
def test_sell_spmm_ktiled_interpret_matches_ref(k):
    """The k-tiled Pallas kernel (interpret mode on CPU) == jnp oracle,
    including k not a multiple of the lane tile (padding path)."""
    mat = G.power_law(128, alpha=1.9, seed=8)
    x = np.random.default_rng(8).standard_normal((mat.n, k))
    outs = []
    for uk in ("ref", "interpret"):
        op = build_operator(mat, "sell", block_shape=(8, 16), use_kernel=uk)
        outs.append(np.asarray(op.matmul(jnp.asarray(x, jnp.float32))))
    assert np.allclose(outs[0], outs[1],
                       atol=1e-5 * (np.abs(outs[0]).max() + 1))


def test_pick_k_tile():
    assert pick_k_tile(1) == 8
    assert pick_k_tile(8) == 8
    assert pick_k_tile(9) == 16
    assert pick_k_tile(128) == 128
    assert pick_k_tile(1000) == 128  # multiple passes over the matrix


# --------------------------------------------------------------------------
# k-aware tuning
# --------------------------------------------------------------------------
def test_cost_model_amortizes_matrix_bytes_over_k():
    mat = G.power_law(2048, alpha=1.8, seed=0)
    feat = matrix_features(mat)
    for engine in ("csr", "ell", "sell"):
        kw = {"sell_pad": mat.nnz} if engine == "sell" else {}
        c1 = candidate_cost(feat, engine, **kw)
        c8 = candidate_cost(feat, engine, k=8, **kw)
        c32 = candidate_cost(feat, engine, k=32, **kw)
        # total grows with k, amortized per-vector cost strictly falls
        assert c1 < c8 < c32
        assert c32 / 32 < c8 / 8 < c1


def test_cost_model_k1_is_the_spmv_model():
    """k defaults must not perturb the existing per-SpMV ranking."""
    banded = tune(G.banded(2048, 8, 0))
    skew = tune(G.power_law(2048, alpha=1.8, seed=0))
    assert banded.engine == "ell" and skew.engine != "ell"
    assert banded.k == 1 and "@k" not in banded.label()


def test_tuned_plan_records_k_and_label():
    mat = G.power_law(512, alpha=1.9, seed=1)
    op = build_operator(mat, "auto", k=8)
    assert op.plan.k == 8 and op.plan.label().endswith("@k8")
    x = np.random.default_rng(1).standard_normal((mat.n, 8))
    got = np.asarray(op.matmul(jnp.asarray(x, jnp.float32)))
    want = _oracle(mat, x)
    assert np.abs(got - want).max() / (np.abs(want).max() + 1e-9) < 1e-5


def test_k_shifts_engine_choice_when_gather_dominates():
    """The point of k-aware tuning: once the matrix stream is amortized and
    the gather line-overage is shared across the k-tile, a padded format
    can lose its k=1 win (or vice versa). Use a synthetic feature vector
    where the shift is provable rather than hunting for a generator."""
    feat = {"m": 4096, "n": 4096, "nnz": 32768, "row_nnz_max": 9,
            "row_nnz_cv": 0.1, "avg_row_bandwidth": 700.0,
            "block_fill": 0.05, "nonempty_blocks": 3000,
            "block_row_max": 12, "num_block_rows": 512}
    c_csr = {k: candidate_cost(feat, "csr", k=k) for k in (1, 64)}
    c_ell = {k: candidate_cost(feat, "ell", k=k) for k in (1, 64)}
    # csr (no padding, heavy gather) vs ell (padding, same gather model):
    # relative gap must move toward the low-footprint engine as k grows
    gap1 = c_ell[1] / c_csr[1]
    gap64 = c_ell[64] / c_csr[64]
    assert gap1 != pytest.approx(gap64), "k must reshape the ranking"


def test_probe_mode_with_k():
    mat = G.banded(256, 4, 0)
    op = build_operator(mat, "auto", probe=True, k=4)
    assert op.plan.source == "probe" and op.plan.k == 4
    assert op.plan.probe_ms and all(v > 0 for v in op.plan.probe_ms.values())


# --------------------------------------------------------------------------
# Micro-batching service
# --------------------------------------------------------------------------
def _service_mats():
    return {"banded": G.banded(256, 4, seed=1),
            "powerlaw": G.power_law(512, alpha=1.9, seed=6)}


def test_service_results_match_unbatched(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path))
    mats = _service_mats()
    rng = np.random.default_rng(0)
    with SpmvService(max_batch=8, window_ms=100.0) as svc:
        for key, m in mats.items():
            svc.register(key, m)
        pending = []
        for _ in range(24):
            key = ("banded", "powerlaw")[rng.integers(2)]
            x = rng.standard_normal(mats[key].n)
            pending.append((key, x, svc.submit(key, x)))
        svc.flush()
        stats = svc.stats()
        for key, x, fut in pending:
            got = np.asarray(fut.result(timeout=10))
            # identical to unbatched execution through the same operator
            alone = np.asarray(svc.operator(key)(jnp.asarray(x, jnp.float32)))
            scale = np.abs(alone).max() + 1e-9
            assert np.abs(got - alone).max() / scale < 1e-5, key
            # and correct vs the numpy oracle
            want = mats[key].spmv(x)
            assert np.abs(got - want).max() / (np.abs(want).max() + 1e-9) < 1e-4
    assert stats["requests"] == 24
    assert stats["batches"] < 24, "burst must coalesce"
    assert stats["batch_size_max"] > 1


def test_service_batches_cap_and_window():
    mats = _service_mats()
    with SpmvService(max_batch=4, window_ms=150.0, engine="csr",
                     cache=False) as svc:
        svc.register("banded", mats["banded"])
        rng = np.random.default_rng(1)
        futs = [svc.submit("banded", rng.standard_normal(mats["banded"].n))
                for _ in range(11)]
        svc.flush()
        for f in futs:
            f.result(timeout=10)
        s = svc.stats()
    # 11 requests, cap 4 -> at least ceil(11/4) = 3 dispatches and the cap
    # is never exceeded; the exact split may vary if a CI scheduler stall
    # expires a window early, so only the invariants are asserted
    assert s["batch_size_sum"] == 11
    assert s["batch_size_max"] <= 4
    assert 3 <= s["batches"] < 11          # cap respected, coalescing real


def test_service_rejects_unknown_key_and_closed():
    svc = SpmvService(max_batch=2, window_ms=1.0)
    svc.register("banded", _service_mats()["banded"])
    with pytest.raises(KeyError):
        svc.submit("nope", np.zeros(4))
    # malformed x is rejected at submit — it must never poison a batch
    with pytest.raises(ValueError):
        svc.submit("banded", np.zeros(255))
    with pytest.raises(ValueError):
        svc.submit("banded", np.zeros((256, 2)))
    svc.close()
    with pytest.raises(RuntimeError):
        svc.submit("banded", np.zeros(256))


def test_service_reregister_invalidates_operator():
    """Re-registering a key (after flush) must drop the memoized operator —
    requests after the swap are answered from the NEW matrix; a swap while
    requests are pending is refused."""
    a = G.banded(256, 4, seed=1)
    b = G.banded(256, 4, seed=9)
    x = np.random.default_rng(5).standard_normal(256)
    with SpmvService(max_batch=2, window_ms=1.0, engine="csr",
                     cache=False) as svc:
        svc.register("m", a)
        fut = svc.submit("m", x)
        ya = fut.result(timeout=10)
        svc.flush()
        svc.register("m", b)
        yb = svc.submit("m", x).result(timeout=10)
    assert np.abs(ya - a.spmv(x)).max() / (np.abs(ya).max() + 1e-9) < 1e-5
    assert np.abs(yb - b.spmv(x)).max() / (np.abs(yb).max() + 1e-9) < 1e-5
    assert not np.allclose(ya, yb)


def test_service_refuses_reregister_with_pending_requests():
    a = G.banded(256, 4, seed=1)
    b = G.banded(256, 4, seed=9)
    svc = SpmvService(max_batch=8, window_ms=5000.0, engine="csr",
                      cache=False)
    svc.register("m", a)
    svc.submit("m", np.zeros(256))   # parked in the (huge) batch window
    with pytest.raises(RuntimeError, match="pending"):
        svc.register("m", b)
    with svc._cv:
        svc._queues["m"].clear()
        svc._stop = True
        svc._cv.notify_all()
    svc._worker.join(timeout=10)


def test_service_backpressure_bounds_queue():
    mats = _service_mats()
    # max_queue < max_batch and a huge window: the dispatcher keeps waiting
    # for a full batch, so the queue deterministically fills to max_queue
    # and the next submit must be rejected with backpressure
    svc = SpmvService(max_batch=8, window_ms=5000.0, engine="csr",
                      cache=False, max_queue=4)
    svc.register("banded", mats["banded"])
    x = np.zeros(256)
    futs = [svc.submit("banded", x) for _ in range(4)]
    with pytest.raises(RuntimeError, match="backpressure"):
        svc.submit("banded", x)
    with svc._cv:
        svc._queues["banded"].clear()   # drop pending so close() is instant
        svc._stop = True
        svc._cv.notify_all()
    svc._worker.join(timeout=10)
    assert all(not f.done() for f in futs)  # dropped, never mis-resolved


def test_service_uses_k_specialized_plan(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path))
    mats = _service_mats()
    with SpmvService(max_batch=16, window_ms=1.0) as svc:
        svc.register("powerlaw", mats["powerlaw"])
        op = svc.operator("powerlaw")
    assert op.plan.k == 16


def test_serve_sim_end_to_end(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path))
    from repro.launch.spmv_bench import run_serve_sim

    rec = run_serve_sim(matrices=("smoke_banded", "smoke_powerlaw"),
                        requests=12, max_batch=4, window_ms=50.0,
                        engine="csr", write_results=False)
    assert rec["ok"] and rec["batches"] <= 12
    assert rec["coalesce_ratio"] >= 1.0


# --------------------------------------------------------------------------
# Block CG — the solver consumer of the SpMM path
# --------------------------------------------------------------------------
def test_block_cg_matches_per_column_cg():
    mat = G.banded(256, 4, seed=1)       # diagonally dominant -> SPD
    op = build_operator(mat, "csr")
    b = jnp.asarray(np.random.default_rng(0).standard_normal((mat.n, 4)),
                    jnp.float32)
    res = block_cg_solve(op.matmul, b, max_iter=200, tol=1e-6)
    assert np.all(np.asarray(res.residual) < 1e-5)
    for j in range(4):
        single = cg_solve(op, b[:, j], max_iter=200, tol=1e-6)
        dx = np.abs(np.asarray(res.x[:, j]) - np.asarray(single.x)).max()
        assert dx < 1e-3, j


def test_block_cg_freezes_converged_columns():
    """A column whose RHS is zero converges at iteration 0 and must stay
    exactly zero while the others keep iterating."""
    mat = G.banded(128, 3, seed=2)
    op = build_operator(mat, "csr")
    rng = np.random.default_rng(3)
    b = np.asarray(rng.standard_normal((mat.n, 3)), np.float32)
    b[:, 1] = 0.0
    res = block_cg_solve(op.matmul, jnp.asarray(b), max_iter=200, tol=1e-6)
    assert np.array_equal(np.asarray(res.x[:, 1]), np.zeros(mat.n))
    assert np.all(np.asarray(res.residual) < 1e-5)
