"""Optimizer, checkpointing (incl. crash/resume), data pipeline, and a
short end-to-end training run (loss decreases)."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.training import checkpoint as CKPT
from repro.training import data as DATA
from repro.training import optimizer as OPT


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        cfg = OPT.OptConfig(peak_lr=0.1, warmup_steps=5, total_steps=100)
        params = {"w": jnp.array([3.0, -2.0, 1.0])}
        state = OPT.init_opt_state(params)
        for _ in range(100):
            grads = {"w": 2 * params["w"]}
            params, state, m = OPT.adamw_update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_grad_clip(self):
        cfg = OPT.OptConfig(grad_clip=1.0, warmup_steps=0, total_steps=10)
        params = {"w": jnp.zeros(3)}
        state = OPT.init_opt_state(params)
        _, _, m = OPT.adamw_update(cfg, params, {"w": jnp.full(3, 1e6)}, state)
        assert float(m["grad_norm"]) > 1e5  # raw norm reported

    def test_wsd_schedule_shape(self):
        cfg = OPT.OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                            schedule="wsd", wsd_decay_frac=0.2,
                            min_lr_frac=0.1)
        lrs = [float(OPT.lr_at(cfg, s)) for s in range(101)]
        assert lrs[5] < lrs[10]                       # warmup
        assert abs(lrs[50] - 1.0) < 1e-6              # stable plateau
        assert lrs[99] < 0.2                          # decay tail
        # plateau really is flat (the WSD signature)
        assert abs(lrs[40] - lrs[70]) < 1e-6

    def test_cosine_schedule(self):
        cfg = OPT.OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                            schedule="cosine", min_lr_frac=0.1)
        lrs = [float(OPT.lr_at(cfg, s)) for s in range(101)]
        assert lrs[30] > lrs[60] > lrs[95]


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        ck = CKPT.Checkpointer(str(tmp_path), async_save=False)
        tree = {"a": np.arange(5.0), "b": {"c": np.ones((2, 3))}}
        ck.save(7, tree, extra={"foo": 1}, cfg_hash="h")
        got, extra = ck.restore(7, tree, cfg_hash="h")
        assert np.array_equal(got["a"], tree["a"])
        assert extra == {"foo": 1}

    def test_latest_and_gc(self, tmp_path):
        ck = CKPT.Checkpointer(str(tmp_path), keep=2, async_save=False)
        tree = {"a": np.zeros(2)}
        for s in (1, 2, 3, 4):
            ck.save(s, tree)
        assert ck.latest_step() == 4
        steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step"))
        assert len(steps) == 2  # gc kept last 2

    def test_config_hash_mismatch_refuses(self, tmp_path):
        ck = CKPT.Checkpointer(str(tmp_path), async_save=False)
        tree = {"a": np.zeros(2)}
        ck.save(1, tree, cfg_hash="AAA")
        with pytest.raises(ValueError, match="hash"):
            ck.restore(1, tree, cfg_hash="BBB")

    def test_partial_tmp_ignored(self, tmp_path):
        ck = CKPT.Checkpointer(str(tmp_path), async_save=False)
        ck.save(1, {"a": np.zeros(2)})
        os.makedirs(tmp_path / "step_00000002.tmp")  # crashed mid-write
        ck2 = CKPT.Checkpointer(str(tmp_path), async_save=False)
        assert ck2.latest_step() == 1

    def test_async_save(self, tmp_path):
        ck = CKPT.Checkpointer(str(tmp_path), async_save=True)
        ck.save(3, {"a": np.arange(4.0)})
        ck.wait()
        got, _ = ck.restore(3, {"a": np.zeros(4)})
        assert np.array_equal(got["a"], np.arange(4.0))


class TestData:
    def test_deterministic(self):
        cfg = DATA.DataConfig(vocab=100, seq_len=32, global_batch=4)
        d1, d2 = DATA.SyntheticLM(cfg), DATA.SyntheticLM(cfg)
        assert np.array_equal(d1.batch(5)["tokens"], d2.batch(5)["tokens"])

    def test_steps_differ(self):
        cfg = DATA.DataConfig(vocab=100, seq_len=32, global_batch=4)
        d = DATA.SyntheticLM(cfg)
        assert not np.array_equal(d.batch(1)["tokens"], d.batch(2)["tokens"])

    def test_tokens_in_range(self):
        cfg = DATA.DataConfig(vocab=50, seq_len=16, global_batch=2)
        t = DATA.SyntheticLM(cfg).batch(0)["tokens"]
        assert t.min() >= 0 and t.max() < 50


class TestEndToEnd:
    def test_train_loss_decreases_and_resumes(self, tmp_path):
        from repro.launch.train import train
        cfg = ModelConfig(name="tiny", family="dense", n_layers=2,
                          d_model=64, n_heads=4, kv_heads=2, d_ff=128,
                          vocab=256, head_dim=16)
        # crash at step 30, then auto-resume to 60
        out1 = train(cfg, 60, str(tmp_path), batch=4, seq=64,
                     ckpt_every=10, crash_at=30, log_every=100)
        assert out1["crashed_at"] == 30
        out2 = train(cfg, 60, str(tmp_path), batch=4, seq=64,
                     ckpt_every=10, log_every=100)
        assert out2["steps"] == 60
        assert out2["final_loss"] < out1["losses"][0] - 0.3
