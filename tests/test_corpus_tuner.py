"""Corpus manifest/registry + the closed-loop learned tuner.

The full loop under test: corpus:// names resolve through the suite
registry, offline stand-ins are deterministic first-class artifacts, a
probed campaign seeds the advisor's knowledge base as a side effect, and
`plan(probe="learned")` then shortlists strictly fewer candidates than
either probing mode — with the hit/miss/fallback counters and the
per-plan confidence auditable throughout.
"""
import json

import numpy as np
import pytest

from repro import obs
from repro.corpus import manifest
from repro.corpus.advisor import (FEATURE_AXES, TuneAdvisor, advisor_reset,
                                  default_advisor, embed)
from repro.experiments import ExperimentSpec, MeasurePolicy, Runner
from repro.matrices import generators as G
from repro.matrices import suite

FAST = MeasurePolicy(iters=1, warmup=0, with_yax=False, with_parallel=False,
                     with_metrics=False)


@pytest.fixture()
def stores(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RESULT_STORE", str(tmp_path / "results"))
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    monkeypatch.setenv("REPRO_REORDER_CACHE", str(tmp_path / "reorder"))
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path / "opcache"))
    monkeypatch.setenv("REPRO_CORPUS_CACHE", str(tmp_path / "corpus"))
    monkeypatch.setenv("REPRO_CORPUS_OFFLINE", "1")
    advisor_reset()
    yield tmp_path
    advisor_reset()


def _policy(probe, iters=2):
    return MeasurePolicy(iters=iters, warmup=0, probe=probe, with_yax=False,
                         with_parallel=False, with_metrics=False)


# -------------------------------------------------------------------------
# manifest
# -------------------------------------------------------------------------
class TestManifest:
    def test_bundled_manifest_loads_and_validates(self):
        entries = manifest.load_manifest()
        assert len(entries) >= 15
        fixtures = [e for e in entries.values() if e.fixture]
        remote = [e for e in entries.values() if e.url]
        assert len(fixtures) >= 5 and len(remote) >= 10
        # the scale campaign depends on >=100k-row entries existing
        assert any(e.m >= 100_000 for e in remote)

    def test_get_entry_accepts_both_name_forms(self):
        a = manifest.get_entry("fix_bcsstk")
        b = manifest.get_entry("corpus://fix_bcsstk")
        assert a == b and a.qualified == "corpus://fix_bcsstk"

    def test_get_entry_unknown_lists_known(self):
        with pytest.raises(KeyError, match="fix_bcsstk"):
            manifest.get_entry("no_such_matrix")

    def test_corpus_names_are_qualified_once(self):
        names = manifest.corpus_names()
        assert names and all(n.startswith("corpus://") for n in names)
        assert not any(n.count("corpus://") > 1 for n in names)

    @pytest.mark.parametrize("rec,match", [
        ({"name": "fix_bcsstk", "m": 1, "n": 1, "nnz": 1,
          "symmetric": True, "kind": "fixture", "fixture": "x.mtx"},
         "duplicate"),
        ({"name": "z", "m": 1, "n": 1, "nnz": 1, "symmetric": False,
          "kind": "banana", "url": "http://x"}, "unknown kind"),
        ({"name": "z", "m": 1, "n": 1, "nnz": 1, "symmetric": False,
          "kind": "mesh"}, "neither url nor"),
        ({"name": "z", "m": 0, "n": 1, "nnz": 1, "symmetric": False,
          "kind": "mesh", "url": "http://x"}, "non-positive"),
    ])
    def test_manifest_validation_rejects(self, tmp_path, rec, match):
        with open(manifest.MANIFEST_PATH) as f:
            raw = json.load(f)
        raw["matrices"].append(rec)
        bad = tmp_path / "manifest.json"
        bad.write_text(json.dumps(raw))
        with pytest.raises(ValueError, match=match):
            manifest.load_manifest(str(bad))


# -------------------------------------------------------------------------
# resolution: fixtures, stand-ins, suite registry
# -------------------------------------------------------------------------
class TestResolution:
    def test_fixture_resolves_through_suite(self, stores):
        e = manifest.get_entry("fix_bcsstk")
        mat = suite.get("corpus://fix_bcsstk")
        assert (mat.m, mat.n, mat.nnz) == (e.m, e.n, e.nnz)

    def test_offline_standin_deterministic_and_flagged(self, stores):
        cold = manifest.ensure("bcsstk17")
        assert not cold.cache_hit
        assert cold.meta.get("standin") is True
        assert cold.mat.m == manifest.get_entry("bcsstk17").m
        warm = manifest.ensure("bcsstk17")
        assert warm.cache_hit  # second resolve: first-class .csrz artifact
        np.testing.assert_array_equal(warm.mat.vals, cold.mat.vals)
        np.testing.assert_array_equal(warm.mat.cols, cold.mat.cols)
        rep = manifest.verify_entry("bcsstk17")
        assert rep["ok"] and rep["standin"]

    def test_verify_entry_fixture(self, stores):
        rep = manifest.verify_entry("fix_general")
        assert rep["ok"] and not rep["standin"]
        assert rep["artifact"].endswith(".csrz")

    def test_suite_catalog_uniform(self, stores):
        assert "corpus" in suite.TIERS
        assert set(suite.smoke_names()) <= set(suite.names())
        assert suite.names("smoke") == suite.smoke_names()
        got = suite.corpus_names()
        assert "corpus://fix_bcsstk" in got
        with pytest.raises(KeyError, match="corpus://"):
            suite.get("definitely_not_registered")
        with pytest.raises(ValueError, match="already registered"):
            suite.register_matrix(suite.names()[0], "smoke",
                                  lambda: G.banded(8, 1))
        suite.register_matrix("tmp_test_matrix", "smoke",
                              lambda: G.banded(8, 1, seed=3), cached=False,
                              override=True)
        try:
            assert suite.get("tmp_test_matrix").m == 8
        finally:
            del suite._CATALOG["tmp_test_matrix"]

    def test_runner_resolves_corpus_names(self, stores):
        spec = ExperimentSpec(name="corpus_rt",
                              matrices=("corpus://fix_bcsstk",),
                              schemes=("baseline",), engines=("auto",),
                              policy=FAST)
        rep = Runner(spec, verbose=False).run()
        rec = rep.cell("corpus://fix_bcsstk", "baseline")
        assert rec["m"] == 96
        # every measured cell now carries the advisor's training pair
        assert set(rec["tuner_decision"]) == {"engine", "block_shape",
                                              "sell_sigma"}
        assert rec["features"]["nnz"] > 0
        assert rec["tuner_candidates"] >= 1


# -------------------------------------------------------------------------
# probe modes + plan keys
# -------------------------------------------------------------------------
class TestProbeModes:
    def test_policy_resolve_keeps_probe_mode(self):
        for probe, want in ((False, False), (True, True),
                            ("learned", "learned"),
                            ("exhaustive", "exhaustive")):
            pol = _policy(probe).resolve("*")
            assert pol["probe"] == want

    def test_plan_keys_distinct_per_mode(self, stores):
        from repro.api import SpmvProblem
        from repro.core.spmv.plan import plan_key

        pr = SpmvProblem(G.banded(64, 2, seed=1), k=1, dtype="float32")
        keys = {plan_key(pr, "baseline", "auto", mode, 0)
                for mode in (False, True, "learned", "exhaustive")}
        assert len(keys) == 4

    def test_bogus_probe_mode_rejected(self, stores):
        from repro.api import SpmvProblem, plan

        with pytest.raises(ValueError, match="probe"):
            plan(SpmvProblem(G.banded(32, 1, seed=1), k=1, dtype="float32"),
                 reorder="baseline", probe="telepathic")


# -------------------------------------------------------------------------
# the learned tuner loop
# -------------------------------------------------------------------------
class TestLearnedTuner:
    def test_embed_covers_all_axes(self):
        from repro.core.spmv.tune import matrix_features

        v = embed(matrix_features(G.power_law(128, alpha=2.0, seed=2)))
        assert v.shape == (len(FEATURE_AXES),)
        assert np.all(np.isfinite(v))
        assert embed({}).shape == v.shape  # pre-schema records degrade to 0s

    def test_fallback_on_empty_store(self, stores):
        from repro.api import SpmvProblem, plan

        before = obs.snapshot()["counters"].get("advisor.fallbacks", 0)
        pl = plan(SpmvProblem(G.banded(64, 2, seed=4), k=1, dtype="float32"),
                  reorder="baseline", probe="learned")
        after = obs.snapshot()["counters"].get("advisor.fallbacks", 0)
        assert after == before + 1
        assert pl.advisor_confidence == 0.0
        assert pl.tune.source == "probe"  # model ranking still probed

    def test_seeded_kb_shortlists_strictly_fewer(self, stores):
        from repro.core.spmv.tune import PROBE_TOP_K

        mats = ("corpus://fix_banded_1k", "corpus://fix_plaw_1k")
        seed = ExperimentSpec(name="tseed", matrices=mats,
                              schemes=("baseline",), engines=("auto",),
                              policy=_policy("exhaustive"))
        store_rep = Runner(seed, verbose=False).run()
        n_ex = {m: store_rep.cell(m, "baseline")["probed_candidates"]
                for m in mats}
        assert all(v > PROBE_TOP_K for v in n_ex.values())

        advisor_reset()  # the learned phase must see the cells just written
        assert default_advisor().knowledge_size() == len(mats)
        before = obs.snapshot()["counters"]
        learned = ExperimentSpec(name="tlearn", matrices=mats,
                                 schemes=("baseline",), engines=("auto",),
                                 policy=_policy("learned"))
        rep = Runner(learned, verbose=False).run()
        after = obs.snapshot()["counters"]

        for m in mats:
            rec = rep.cell(m, "baseline")
            n_ln = rec["probed_candidates"]
            assert 0 < n_ln <= 2 < PROBE_TOP_K + 1
            assert n_ln < n_ex[m]
            assert rec["advisor_confidence"] > 0
        consulted = sum(after.get(k, 0) - before.get(k, 0)
                        for k in ("advisor.hits", "advisor.misses"))
        assert consulted == len(mats)
        assert after.get("advisor.fallbacks", 0) == before.get(
            "advisor.fallbacks", 0)

    def test_shortlist_maps_decisions_onto_candidates(self):
        adv = TuneAdvisor.__new__(TuneAdvisor)  # no store: drive _match only
        cands = [
            {"engine": "csr", "block_shape": (8, 128), "sigma": None},
            {"engine": "sell", "block_shape": (8, 128), "sigma": 64},
            {"engine": "sell", "block_shape": (8, 128), "sigma": 256},
        ]
        exact = adv._match({"engine": "sell", "block_shape": [8, 128],
                            "sell_sigma": 256}, cands)
        assert exact is cands[2]
        shape_only = adv._match({"engine": "sell", "block_shape": [8, 128],
                                 "sell_sigma": 999}, cands)
        assert shape_only is cands[1]
        assert adv._match({"engine": "gone", "block_shape": [8, 128],
                           "sell_sigma": None}, cands) is None


# -------------------------------------------------------------------------
# CLI
# -------------------------------------------------------------------------
class TestCli:
    def test_list(self, stores, capsys):
        from repro.corpus.__main__ import main

        assert main(["list"]) == 0
        out = capsys.readouterr().out
        assert "corpus://fix_bcsstk" in out and "fixture" in out
        assert main(["list", "--json"]) == 0
        rows = json.loads(capsys.readouterr().out)
        assert any(r["name"] == "corpus://cage12" for r in rows)

    def test_ingest_then_expect_cached(self, stores, capsys):
        from repro.corpus.__main__ import main

        # cold cache: fixtures parse, so --expect-cached must fail...
        assert main(["ingest", "--fixtures", "--offline",
                     "--expect-cached"]) == 1
        capsys.readouterr()
        # ...and once artifacts exist, re-ingest is a 100% hit
        assert main(["ingest", "--fixtures", "--offline",
                     "--expect-cached"]) == 0
        out = capsys.readouterr().out
        assert "cache-hit" in out and "0 parse(s)" in out

    def test_verify_fixtures_and_unknown_name(self, stores, capsys):
        from repro.corpus.__main__ import main

        assert main(["verify", "--fixtures"]) == 0
        assert "ok" in capsys.readouterr().out
        with pytest.raises(SystemExit):
            main(["ingest"])  # no selection
        with pytest.raises(KeyError):
            main(["ingest", "no_such_matrix"])
