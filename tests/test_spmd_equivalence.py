"""SPMD correctness: the sharded (mesh) forward/loss equals the
single-device one — including the MoE shard_map path (sorted dispatch +
all_to_all) and the sharding-constraint hints.

Runs in a subprocess (needs 8 fake devices before jax init)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.configs import registry
    from repro.configs.base import smoke_config, MoEConfig
    from repro.models import model as MDL
    from repro.distributed import sharding as SH

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    for arch in ["qwen2-7b", "qwen3-moe-30b-a3b", "gemma2-27b", "rwkv6-7b"]:
        cfg = smoke_config(registry.get(arch))
        if cfg.moe:
            # high capacity so no tokens drop (dispatch differs per shard
            # layout; with zero drops the math is permutation-invariant)
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=8.0))
        params = MDL.init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 64), 0, cfg.vocab)}

        ref_loss, _ = jax.jit(
            lambda p, b: MDL.loss_fn(p, b, cfg, train=False))(params, batch)

        specs = SH.validate_specs(params, SH.param_specs(params), mesh)
        psh = SH.named_shardings(specs, mesh)
        with mesh:
            params_sh = jax.device_put(params, psh)
            batch_sh = jax.device_put(
                batch, NamedSharding(mesh, P("data", None)))
            loss_sh, _ = jax.jit(
                lambda p, b: MDL.loss_fn(p, b, cfg, mesh=mesh,
                                         dp_axes=("data",), train=False)
            )(params_sh, batch_sh)
        err = abs(float(ref_loss) - float(loss_sh))
        assert err < 5e-3, (arch, float(ref_loss), float(loss_sh))
        print(f"EQ_OK {arch} {float(ref_loss):.5f} {float(loss_sh):.5f}")
""")


def test_spmd_matches_single_device():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=1200,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert r.stdout.count("EQ_OK") == 4, r.stdout
