"""SPMD correctness: the sharded (mesh) execution equals the single-device
one — the model forward/loss (MoE shard_map path: sorted dispatch +
all_to_all, sharding-constraint hints) AND the SpMV facade (a topology-
aware plan's ShardedOperator vs the same scheme's single-device Operator
vs the simulated fallback — the paper's cross-machine consistency story
applied to our own execution paths).

Runs in subprocesses (needs 8 fake devices before jax init)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.configs import registry
    from repro.configs.base import smoke_config, MoEConfig
    from repro.models import model as MDL
    from repro.distributed import sharding as SH

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    for arch in ["qwen2-7b", "qwen3-moe-30b-a3b", "gemma2-27b", "rwkv6-7b"]:
        cfg = smoke_config(registry.get(arch))
        if cfg.moe:
            # high capacity so no tokens drop (dispatch differs per shard
            # layout; with zero drops the math is permutation-invariant)
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=8.0))
        params = MDL.init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 64), 0, cfg.vocab)}

        ref_loss, _ = jax.jit(
            lambda p, b: MDL.loss_fn(p, b, cfg, train=False))(params, batch)

        specs = SH.validate_specs(params, SH.param_specs(params), mesh)
        psh = SH.named_shardings(specs, mesh)
        with mesh:
            params_sh = jax.device_put(params, psh)
            batch_sh = jax.device_put(
                batch, NamedSharding(mesh, P("data", None)))
            loss_sh, _ = jax.jit(
                lambda p, b: MDL.loss_fn(p, b, cfg, mesh=mesh,
                                         dp_axes=("data",), train=False)
            )(params_sh, batch_sh)
        err = abs(float(ref_loss) - float(loss_sh))
        assert err < 5e-3, (arch, float(ref_loss), float(loss_sh))
        print(f"EQ_OK {arch} {float(ref_loss):.5f} {float(loss_sh):.5f}")
""")


def test_spmd_matches_single_device():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=1200,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert r.stdout.count("EQ_OK") == 4, r.stdout


SCRIPT_SPMV_FACADE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.api import SpmvProblem, Topology, plan
    from repro.matrices import generators as G

    mat = G.shuffle(G.sbm(512, 8, 0.08, 0.002, seed=4), seed=5)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(mat.n).astype(np.float64)

    # single-device reference through the same facade, same scheme
    ref_op = plan(SpmvProblem(mat, dtype=np.float64), reorder="rcm",
                  engine="csr", cache=False).build(cache=False)
    want = np.asarray(ref_op(x))

    for layout, shape in (("1d_rows", ()), ("2d_panels", (4, 2))):
        for eng in ("bell", "csr"):
            topo = Topology(devices=8, layout=layout, mesh_shape=shape)
            pl = plan(SpmvProblem(mat, dtype=np.float64), reorder="rcm",
                      engine=eng, topology=topo, partition="nnz_balanced",
                      cache=False)
            op = pl.build(cache=False)
            assert not op.simulated
            got_mesh = np.asarray(op(x))
            err = np.abs(got_mesh - want).max() / np.abs(want).max()
            assert err < 1e-12, (layout, eng, "mesh", err)
            # the simulated fallback must agree with the mesh execution
            op.force_simulated = True
            got_sim = np.asarray(op(x))
            op.force_simulated = False
            errs = np.abs(got_sim - got_mesh).max() / np.abs(want).max()
            assert errs < 1e-12, (layout, eng, "sim", errs)
            print(f"SPMV_EQ_OK {layout} {eng} {err:.2e} {errs:.2e}")
""")


def test_sharded_spmv_facade_matches_single_device():
    """ShardedOperator (both layouts x both panel engines, mesh AND
    simulated paths) == the single-device facade operator to fp64
    tolerance on the same reordered problem."""
    r = subprocess.run([sys.executable, "-c", SCRIPT_SPMV_FACADE],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu", "JAX_ENABLE_X64": "1",
                            "REPRO_REORDER_CACHE": "/tmp/spmd_eq_reorder",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert r.stdout.count("SPMV_EQ_OK") == 4, r.stdout
