"""SELL-C-σ engine, OSKI-style autotuner, and persistent operator cache.

Covers the PR's acceptance criteria:
  * cross-engine equivalence (csr/ell/sell/bell/bcsr vs the dense numpy
    oracle) over the generator suites — including the power-law row-skew
    generator — under every reorder scheme in PAPER_SCHEMES
  * SELL beats padded-ELL storage by >= 2x on power-law skew
  * build_operator(mat, engine="auto") returns a tuned operator with a plan
  * the second spmv_bench invocation on the same (matrix, scheme) hits the
    operator cache (no reconversion / re-tune)
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.reorder import api as reorder_api
from repro.core.sparse.csr import CSRMatrix
from repro.core.sparse.sell import (pick_chunk_width, sell_padded_nnz,
                                    sell_to_dense, to_sell)
from repro.core.spmv.ops import DeviceELL, build_operator
from repro.matrices import generators as G

ENGINES = ["csr", "ell", "sell", "bell", "bcsr"]

MATS = {
    "banded": lambda: G.banded(64, 3, 0),
    "stencil": lambda: G.stencil_2d(8, seed=1),
    "rmat": lambda: G.rmat(6, 4, 2),
    "powerlaw": lambda: G.power_law(96, alpha=1.8, seed=3),
    "sbm": lambda: G.shuffle(G.sbm(96, 4, 0.2, 0.01, seed=4), seed=5),
}


def _check_engine(mat, engine, x, want, tol=1e-5):
    kw = {"block_shape": (4, 4)} if engine in ("bell", "bcsr", "sell") else {}
    op = build_operator(mat, engine, **kw)
    got = np.asarray(op(jnp.asarray(x, jnp.float32)))
    scale = np.abs(want).max() + 1e-9
    assert np.abs(got - want).max() / scale < tol, engine


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("matname", list(MATS))
@pytest.mark.parametrize("scheme", ["baseline"] + reorder_api.PAPER_SCHEMES)
def test_cross_engine_equivalence(engine, matname, scheme):
    """Every engine x matrix family x paper scheme must match the oracle."""
    mat = MATS[matname]()
    if scheme != "baseline":
        perm = reorder_api.reorder(mat, scheme, cache=False)
        mat = mat.permute(perm)
    x = np.random.default_rng(0).standard_normal(mat.n)
    want = mat.spmv(x)  # numpy oracle == dense oracle (test_sparse_formats)
    _check_engine(mat, engine, x, want)


@given(st.integers(8, 80), st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_property_sell_matches_oracle_on_skew(m, seed):
    mat = G.power_law(max(m, 8), alpha=1.8, seed=seed)
    x = np.random.default_rng(seed).standard_normal(mat.n)
    want = mat.spmv(x)
    for c, sigma, w in [(4, 8, 8), (8, 64, 16), (8, 1, 4)]:
        op = build_operator(mat, "sell", block_shape=(c, w), sell_sigma=sigma)
        got = np.asarray(op(jnp.asarray(x, jnp.float32)))
        scale = np.abs(want).max() + 1e-9
        assert np.abs(got - want).max() / scale < 1e-5, (c, sigma, w)


def test_sell_roundtrip_and_perm():
    mat = G.power_law(200, alpha=1.9, seed=7)
    s = to_sell(mat, c=8, sigma=64, w=16)
    assert np.allclose(sell_to_dense(s), mat.to_dense())
    # row_perm restricted to real rows is a permutation of [0, m)
    real = s.row_perm[s.row_perm < mat.m]
    assert np.array_equal(np.sort(real), np.arange(mat.m))
    assert s.padded_nnz == sell_padded_nnz(mat, 8, 64, 16)


def test_sell_interpret_kernel_matches_ref():
    mat = G.power_law(128, alpha=1.9, seed=8)
    x = np.random.default_rng(8).standard_normal(mat.n)
    ops = [build_operator(mat, "sell", block_shape=(8, 16), use_kernel=uk)
           for uk in ("ref", "interpret")]
    outs = [np.asarray(op(jnp.asarray(x, jnp.float32))) for op in ops]
    assert np.allclose(outs[0], outs[1], atol=1e-5 * (np.abs(outs[0]).max() + 1))


def test_sell_beats_ell_padding_2x_on_power_law():
    """Acceptance: >= 2x fewer stored elements than padded ELL on skew."""
    mat = G.power_law(4096, alpha=1.9, seed=0)
    ell_pad = DeviceELL(mat).padded_nnz
    w = pick_chunk_width(mat)
    sell_pad = sell_padded_nnz(mat, c=8, sigma=mat.m, w=w)
    assert sell_pad * 2 <= ell_pad, (sell_pad, ell_pad)
    # and the actual built format agrees with the prediction
    op = build_operator(mat, "sell", block_shape=(8, w), sell_sigma=mat.m)
    assert op.padded_nnz == sell_pad


def test_auto_engine_returns_tuned_operator():
    mat = G.power_law(512, alpha=1.9, seed=1)
    op = build_operator(mat, "auto")
    assert hasattr(op, "plan")
    assert op.plan.engine in ENGINES + ["dense"]
    assert op.plan.source == "model"
    assert op.plan.costs  # every candidate was scored
    x = np.random.default_rng(1).standard_normal(mat.n)
    want = mat.spmv(x)
    got = np.asarray(op(jnp.asarray(x, jnp.float32)))
    assert np.abs(got - want).max() / (np.abs(want).max() + 1e-9) < 1e-5


def test_auto_engine_probe_mode():
    mat = G.banded(256, 4, 0)
    op = build_operator(mat, "auto", probe=True)
    assert op.plan.source == "probe"
    assert op.plan.probe_ms and all(v > 0 for v in op.plan.probe_ms.values())


def test_tuner_prefers_ell_on_uniform_rows_and_not_on_skew():
    from repro.core.spmv.tune import tune

    banded = tune(G.banded(2048, 8, 0))
    skew = tune(G.power_law(2048, alpha=1.8, seed=0))
    assert banded.engine == "ell"
    # on heavy skew padded-ELL must never win
    assert skew.engine != "ell"


def test_operator_cache_hit(tmp_path, monkeypatch):
    """Acceptance: second spmv_bench invocation on the same (matrix, scheme)
    reloads the tuned operator — no reconversion, no re-tune. use_store=False
    (--fresh) forces the re-MEASURE so the plan-store reload is what's
    exercised; with the result store on, the second invocation skips even
    the measurement (store_hit)."""
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path / "opcache"))
    monkeypatch.setenv("REPRO_REORDER_CACHE", str(tmp_path / "reorder"))
    from repro.launch.spmv_bench import run_single

    r1 = run_single("smoke_powerlaw", "rcm", iters=2, write_results=False,
                    use_store=False)
    r2 = run_single("smoke_powerlaw", "rcm", iters=2, write_results=False,
                    use_store=False)
    assert not r1["cache_hit"]
    assert r2["cache_hit"]
    assert r2["tune_ms"] == 0.0 and r2["build_ms"] == 0.0
    assert r2["engine"] == r1["engine"]
    # a different scheme is a different cache entry
    r3 = run_single("smoke_powerlaw", "baseline", iters=2,
                    write_results=False, use_store=False)
    assert not r3["cache_hit"]
    # result-store layer: the same cell measured above is now served
    # without any new measurement
    r4 = run_single("smoke_powerlaw", "rcm", iters=2, write_results=False)
    assert r4["store_hit"] and not r2["store_hit"]
    assert r4["spmv_ios_ms"] == r2["spmv_ios_ms"]


def test_operator_cache_roundtrip_all_engines(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path))
    from repro.core.spmv.opcache import build_cached

    mat = G.power_law(256, alpha=1.9, seed=2)
    x = np.random.default_rng(2).standard_normal(mat.n)
    want = mat.spmv(x)
    for eng in ENGINES + ["dense", "auto"]:
        kw = {"block_shape": (4, 4)} if eng in ("bell", "bcsr", "sell") else {}
        _, i1 = build_cached(mat, eng, **kw)
        op, i2 = build_cached(mat, eng, **kw)
        assert not i1["cache_hit"] and i2["cache_hit"], eng
        got = np.asarray(op(jnp.asarray(x, jnp.float32)))
        assert np.abs(got - want).max() / (np.abs(want).max() + 1e-9) < 1e-4, eng


def test_cache_disabled_via_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", "off")
    from repro.core.spmv.opcache import build_cached

    mat = G.banded(64, 2, 0)
    _, i1 = build_cached(mat, "csr")
    _, i2 = build_cached(mat, "csr")
    assert not i1["cache_hit"] and not i2["cache_hit"]


def test_power_law_generator_is_skewed_and_symmetric():
    mat = G.power_law(2048, alpha=1.8, seed=0)
    # duplicate edges sum in different orders for (i,j) vs (j,i): structure
    # is exactly symmetric, values only to fp addition order
    assert mat.is_symmetric(tol=1e-9)
    counts = mat.row_nnz()
    assert counts.max() >= 8 * np.median(counts)  # genuine hub rows
