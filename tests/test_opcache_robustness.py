"""Operator-cache robustness: corruption fallback, write atomicity, k plans.

The cache is persistent across processes AND code versions, so every
defensive property matters:
  * a corrupt / truncated / schema-stale entry must be treated as a miss
    and rebuilt, never crash or serve garbage;
  * writers must publish entries atomically (tmp file + rename, with the
    .json gate renamed last) so a concurrent reader never observes a
    half-written entry;
  * k-specialized plans (tuned for an SpMM batch width) round-trip: the
    reloaded operator carries the same plan, and different k means a
    different entry.
"""
import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.spmv import opcache
from repro.core.spmv.opcache import build_cached, content_key
from repro.matrices import generators as G


@pytest.fixture()
def cache_dir(tmp_path, monkeypatch):
    d = tmp_path / "opcache"
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(d))
    return d


def _mat():
    return G.power_law(256, alpha=1.9, seed=11)


def _check(op, mat):
    x = np.random.default_rng(0).standard_normal(mat.n)
    want = mat.spmv(x)
    got = np.asarray(op(jnp.asarray(x, jnp.float32)))
    assert np.abs(got - want).max() / (np.abs(want).max() + 1e-9) < 1e-4


@pytest.mark.parametrize("damage", ["npz_garbage", "npz_truncated",
                                    "json_garbage", "json_bad_schema",
                                    "npz_missing"])
def test_corrupt_entry_falls_back_to_rebuild(cache_dir, damage):
    mat = _mat()
    _, i1 = build_cached(mat, "auto")
    assert not i1["cache_hit"]
    key = i1["key"]
    npz, js = cache_dir / f"{key}.npz", cache_dir / f"{key}.json"
    assert npz.exists() and js.exists()
    if damage == "npz_garbage":
        npz.write_bytes(b"not an npz at all")
    elif damage == "npz_truncated":
        npz.write_bytes(npz.read_bytes()[: npz.stat().st_size // 2])
    elif damage == "json_garbage":
        js.write_text("{this is not json")
    elif damage == "json_bad_schema":
        js.write_text(json.dumps({"cls": "NoSuchOperator", "meta": {},
                                  "plan": None}))
    elif damage == "npz_missing":
        npz.unlink()
    op, i2 = build_cached(mat, "auto")
    assert not i2["cache_hit"], "damaged entry must be a miss"
    _check(op, mat)
    # and the rebuild repaired the entry
    op3, i3 = build_cached(mat, "auto")
    assert i3["cache_hit"]
    _check(op3, mat)


def test_store_is_write_then_rename_json_last(cache_dir, monkeypatch):
    """Atomicity contract: both files are written to tmp names and renamed,
    npz first, the .json gate LAST — a concurrent reader either sees no
    entry (json missing -> miss) or a complete one."""
    events = []
    real_replace = os.replace

    def spy_replace(src, dst):
        # the tmp file must be fully written before publication
        assert os.path.exists(src) and src.endswith(".tmp")
        events.append(os.path.basename(dst))
        return real_replace(src, dst)

    monkeypatch.setattr(opcache.os, "replace", spy_replace)
    mat = _mat()
    _, info = build_cached(mat, "csr")
    key = info["key"]
    assert events == [f"{key}.npz", f"{key}.json"]
    # no tmp litter left behind
    assert not [f for f in os.listdir(cache_dir) if f.endswith(".tmp")]


def test_reader_treats_json_missing_as_miss(cache_dir):
    """The in-between state of an interrupted writer (npz published, json
    not yet) must read as a clean miss."""
    mat = _mat()
    _, i1 = build_cached(mat, "csr")
    (cache_dir / f"{i1['key']}.json").unlink()
    op, i2 = build_cached(mat, "csr")
    assert not i2["cache_hit"]
    _check(op, mat)


def test_cache_hit_with_k_specialized_plan(cache_dir):
    mat = _mat()
    op1, i1 = build_cached(mat, "auto", k=8)
    op2, i2 = build_cached(mat, "auto", k=8)
    assert not i1["cache_hit"] and i2["cache_hit"]
    assert op2.plan.k == 8 and i2["plan"]["k"] == 8
    assert op2.plan.engine == op1.plan.engine
    _check(op2, mat)
    # a different batch width is a different entry (different plan)
    op3, i3 = build_cached(mat, "auto", k=1)
    assert not i3["cache_hit"] and op3.plan.k == 1
    assert i3["key"] != i1["key"]
    dt = jnp.dtype(jnp.float32).name
    assert content_key(mat, "auto", dt, k=8) != content_key(mat, "auto", dt)
    # for a FIXED engine k never changes the stored format: one entry
    assert content_key(mat, "csr", dt, k=8) == content_key(mat, "csr", dt)
    _, j1 = build_cached(mat, "csr", k=1)
    _, j2 = build_cached(mat, "csr", k=8)
    assert j2["cache_hit"] and j1["key"] == j2["key"]


def test_legacy_plan_without_k_still_loads(cache_dir):
    """Entries written before k-aware tuning have no 'k' in the plan json;
    they must load with the default k=1."""
    mat = _mat()
    _, i1 = build_cached(mat, "auto")
    js = cache_dir / f"{i1['key']}.json"
    rec = json.loads(js.read_text())
    rec["plan"].pop("k")
    js.write_text(json.dumps(rec))
    op, i2 = build_cached(mat, "auto")
    assert i2["cache_hit"] and op.plan.k == 1
    _check(op, mat)
