"""IOS/YAX harness + CG solver + profile analytics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.measure import cg, ios, profiles
from repro.core.spmv.ops import build_operator
from repro.matrices import generators as G


@pytest.fixture(scope="module")
def spd_op():
    mat = G.stencil_2d(16, seed=0)  # diag-dominant -> SPD
    return mat, build_operator(mat, "csr")


class TestHarness:
    def test_yax_returns_times(self, spd_op):
        mat, op = spd_op
        x = jnp.ones(mat.n, jnp.float32)
        t = ios.run_yax(op, x, iters=4, warmup=1)
        assert t.shape == (4,) and (t > 0).all()

    def test_ios_swaps(self, spd_op):
        mat, op = spd_op
        x = jnp.ones(mat.n, jnp.float32)
        t = ios.run_ios(op, x, iters=4, warmup=1)
        assert t.shape == (4,) and (t > 0).all()

    def test_gflops(self):
        assert np.isclose(ios.gflops(500_000, np.array([1.0])), 1.0)


class TestCG:
    def test_solves_spd_system(self, spd_op):
        mat, op = spd_op
        rng = np.random.default_rng(1)
        x_true = rng.standard_normal(mat.n)
        b = jnp.asarray(mat.spmv(x_true), jnp.float32)
        res = cg.cg_solve(op, b, max_iter=200, tol=1e-6)
        got = np.asarray(res.x)
        assert np.abs(mat.spmv(got) - np.asarray(b)).max() < 1e-2

    def test_measured_cg_times(self, spd_op):
        mat, op = spd_op
        b = jnp.ones(mat.n, jnp.float32)
        t = cg.cg_measured(op, b, iters=3, warmup=1)
        assert t.shape == (3,) and (t > 0).all()


class TestProfiles:
    def test_performance_profile_best_is_one_at_tau1(self):
        perf = np.array([[2.0, 1.0], [1.0, 2.0]])
        prof = profiles.performance_profile(perf, np.array([1.0, 2.0]))
        assert np.allclose(prof[:, 0], [0.5, 0.5])
        assert np.allclose(prof[:, 1], [1.0, 1.0])

    def test_buckets_sum_to_matrices(self):
        sp = np.array([[0.5, 1.05, 1.2, 3.0]])
        counts = profiles.speedup_buckets(sp)
        assert counts.sum() == 4
        assert counts[0, 0] == 1 and counts[0, -1] == 1

    def test_pairwise_winrate_antisymmetric_no_ties(self):
        perf = np.array([[1.0, 3.0], [2.0, 2.0]])
        win = profiles.pairwise_win_rates(perf)
        assert np.isclose(win[0, 1] + win[1, 0], 1.0)

    def test_consistency_ratio(self):
        # m0 speeds up both matrices; m1 slows down matrix 1
        s = np.array([[1.5, 1.5], [1.2, 0.8]])
        cons, n = profiles.consistency_ratio(s, tau=1.1)
        assert n == 2 and np.isclose(cons, 0.5)

    def test_consistency_empty_ccs(self):
        s = np.array([[1.0, 1.0]])
        cons, n = profiles.consistency_ratio(s, tau=2.0)
        assert n == 0 and cons == 1.0
