"""CSR/Block-ELL/BCSR containers: roundtrips, permutation, conversions."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sparse.csr import CSRMatrix
from repro.core.sparse import bell, metrics, partition
from repro.matrices import generators as G


def random_sym(m, density, seed):
    rng = np.random.default_rng(seed)
    d = (rng.random((m, m)) < density) * rng.standard_normal((m, m))
    d = d + d.T
    return d, CSRMatrix.from_dense(d)


class TestCSR:
    def test_dense_roundtrip(self):
        d, a = random_sym(50, 0.1, 0)
        assert np.allclose(a.to_dense(), d)

    def test_scipy_roundtrip(self):
        d, a = random_sym(40, 0.15, 1)
        assert np.allclose(CSRMatrix.from_scipy(a.to_scipy()).to_dense(), d)

    def test_spmv_oracle(self):
        d, a = random_sym(64, 0.1, 2)
        x = np.random.default_rng(3).standard_normal(64)
        assert np.allclose(a.spmv(x), d @ x)

    def test_permute_matches_dense(self):
        d, a = random_sym(33, 0.2, 4)
        perm = np.random.default_rng(5).permutation(33)
        assert np.allclose(a.permute(perm).to_dense(), d[np.ix_(perm, perm)])

    def test_permute_keeps_symmetry(self):
        _, a = random_sym(29, 0.2, 6)
        perm = np.random.default_rng(7).permutation(29)
        assert a.permute(perm).is_symmetric(tol=1e-12)

    def test_transpose_symmetric(self):
        _, a = random_sym(21, 0.3, 8)
        t = a.transpose()
        assert np.allclose(t.to_dense(), a.to_dense().T)

    @given(st.integers(5, 40), st.integers(0, 10))
    @settings(max_examples=20, deadline=None)
    def test_property_permute_spmv_commutes(self, m, seed):
        """(PAP^T)(Px) == P(Ax) — the algebra every reordering relies on."""
        rng = np.random.default_rng(seed)
        d = (rng.random((m, m)) < 0.3) * rng.standard_normal((m, m))
        d = d + d.T
        a = CSRMatrix.from_dense(d)
        perm = rng.permutation(m)
        x = rng.standard_normal(m)
        inv = np.empty(m, dtype=np.int64)
        inv[perm] = np.arange(m)
        lhs = a.permute(perm).spmv(x[perm])
        rhs = a.spmv(x)[perm]
        assert np.allclose(lhs, rhs, atol=1e-10)


class TestBlockFormats:
    @pytest.mark.parametrize("bm,bn", [(4, 4), (8, 8), (8, 16), (16, 8)])
    def test_bell_roundtrip(self, bm, bn):
        d, a = random_sym(50, 0.12, 9)
        be = bell.to_block_ell(a, bm, bn)
        assert np.allclose(bell.bell_to_dense(be), d)

    def test_bcsr_blocks_match_bell(self):
        _, a = random_sym(40, 0.15, 10)
        be = bell.to_block_ell(a, 8, 8)
        bc = bell.to_bcsr(a, 8, 8)
        assert bc.total_blocks == int(be.nblocks.sum())

    def test_bell_k_cap_raises(self):
        _, a = random_sym(32, 0.5, 11)
        with pytest.raises(ValueError):
            bell.to_block_ell(a, 8, 8, k=1)


class TestPartition:
    def test_static_covers_rows(self):
        a = G.banded(100, 3)
        s = partition.static_partition(a, 7)
        assert s[0] == 0 and s[-1] == 100
        assert (np.diff(s) >= 0).all()

    def test_nnz_balanced_reduces_li(self):
        a = G.rmat(10, 8, seed=0)
        li_s = metrics.load_imbalance(a, partition.static_partition(a, 8))
        li_b = metrics.load_imbalance(a, partition.nnz_balanced_partition(a, 8))
        assert li_b <= li_s
        assert li_b < 1.5

    @given(st.integers(10, 200), st.integers(2, 16), st.integers(0, 5))
    @settings(max_examples=25, deadline=None)
    def test_property_partitions_valid(self, m, p, seed):
        a = G.random_uniform(max(m, p), 4, seed=seed)
        for starts in (partition.static_partition(a, p),
                       partition.nnz_balanced_partition(a, p)):
            assert starts[0] == 0 and starts[-1] == a.m
            assert (np.diff(starts) >= 0).all()
            assert len(starts) == p + 1

    def test_chunked_cyclic_covers(self):
        panels = partition.chunked_cyclic_panels(100, 4, 16)
        allrows = np.sort(np.concatenate(panels))
        assert np.array_equal(allrows, np.arange(100))


class TestMetrics:
    def test_bandwidth_banded(self):
        assert metrics.bandwidth(G.banded(64, 5)) == 5

    def test_block_fill_banded_better_than_shuffled(self):
        b = G.banded(512, 4, 0)
        s = G.shuffle(b, 1)
        assert metrics.block_fill_ratio(b, 8, 8) > metrics.block_fill_ratio(s, 8, 8)

    def test_cut_volume_zero_for_block_diagonal(self):
        d = np.kron(np.eye(4), np.ones((8, 8)))
        a = CSRMatrix.from_dense(d)
        s = partition.static_partition(a, 4)
        assert metrics.cut_volume(a, s) == 0

    def test_li_lower_bound(self):
        a = G.rmat(9, 6, 1)
        assert metrics.load_imbalance(a, partition.static_partition(a, 4)) >= 1.0
