"""Observability layer (repro.obs): spans, metrics registry, exporters,
and the perf-regression gate.

Covers the PR's correctness contract:

* span nesting within a thread and across threads (distinct tids, each
  thread its own tree);
* exception safety — a span exited by an unwinding exception records an
  ``error`` attribute, and a child left open by a raise is force-closed
  (``unclosed``) when its parent exits;
* deterministic ``TraceBuffer.flush()`` ordering;
* the disabled path stays near-free (micro-benchmark bound);
* Chrome-trace export validates (B/E balance per tid, pid/tid present)
  and round-trips through the CLI gate;
* ``MeasurePolicy.resolve()`` key stability — the ``trace`` knob is
  absent unless set, so untraced campaigns keep their cell keys;
* ``SpmvService.stats()`` reconciles with the obs registry (legacy keys
  preserved, counters are the same objects);
* ``regress.compare``: pass, fail on an injected 2x slowdown, and the
  cross-scale refusal.
"""
from __future__ import annotations

import copy
import json
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.experiments.regress import compare, main as regress_main
from repro.experiments.spec import MeasurePolicy


@pytest.fixture(autouse=True)
def _no_leaked_sinks():
    yield
    assert not obs.enabled(), "a test leaked an installed trace sink"


# ---------------------------------------------------------------- spans

def test_span_nesting_single_thread():
    with obs.tracing() as buf:
        with obs.span("outer", layer="test"):
            with obs.span("inner") as sp:
                sp.set(k=3)
    evs = buf.flush()
    by_name = {e["name"]: e for e in evs}
    assert set(by_name) == {"outer", "inner"}
    inner, outer = by_name["inner"], by_name["outer"]
    assert inner["parent"] == outer["id"]
    assert outer["parent"] is None
    assert inner["args"] == {"k": 3}
    assert outer["args"] == {"layer": "test"}
    # containment: inner starts no earlier and ends no later than outer
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert evs[0]["pid"] == evs[1]["pid"]


def test_span_nesting_across_threads():
    def worker(i):
        with obs.span("worker", idx=i):
            with obs.span("child", idx=i):
                time.sleep(0.001)

    with obs.tracing() as buf:
        with obs.span("main_root"):
            ts = [threading.Thread(target=worker, args=(i,), daemon=True)
                  for i in range(3)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
    evs = buf.flush()
    tids = {e["tid"] for e in evs}
    assert len(tids) == 4          # main + 3 workers
    root = next(e for e in evs if e["name"] == "main_root")
    for e in evs:
        if e["name"] == "worker":
            # each thread owns its own tree: workers are roots on their
            # tid, never children of another thread's span
            assert e["parent"] is None
            assert e["tid"] != root["tid"]
        if e["name"] == "child":
            parent = next(x for x in evs if x["id"] == e["parent"])
            assert parent["name"] == "worker"
            assert parent["tid"] == e["tid"]
            assert parent["args"]["idx"] == e["args"]["idx"]


def test_span_exception_records_error():
    with obs.tracing() as buf:
        with pytest.raises(ValueError):
            with obs.span("boom"):
                raise ValueError("nope")
    (ev,) = buf.flush()
    assert ev["name"] == "boom"
    assert ev["args"]["error"] == "ValueError"


def test_dangling_child_force_closed():
    # A span entered but never exited (raise between enter and manual
    # bookkeeping) must still export when its parent closes.
    with obs.tracing() as buf:
        with obs.span("parent"):
            sp = obs.span("left_open", stage="probe")
            sp.__enter__()
            # ... probe raises here; nobody calls sp.__exit__ ...
    evs = buf.flush()
    by_name = {e["name"]: e for e in evs}
    assert set(by_name) == {"parent", "left_open"}
    assert by_name["left_open"]["args"]["unclosed"] is True
    assert by_name["left_open"]["parent"] == by_name["parent"]["id"]
    # stack is clean afterwards: a new root really is a root
    with obs.tracing() as buf2:
        with obs.span("fresh_root"):
            pass
    assert buf2.flush()[0]["parent"] is None


def test_flush_order_deterministic():
    with obs.tracing() as buf:
        for i in range(5):
            with obs.span(f"s{i}"):
                pass
    order1 = [e["id"] for e in buf.flush()]
    order2 = [e["id"] for e in buf.flush()]
    assert order1 == order2
    assert order1 == sorted(order1)    # sequential spans: ts-ordered


def test_disabled_span_is_near_noop():
    assert not obs.enabled()
    sp = obs.span("hot", a=1)
    assert sp is obs.span("hot2")      # shared singleton, no allocation
    n = 20000
    best = float("inf")
    for _ in range(5):                 # best-of-5 derisks CI noise
        t0 = time.perf_counter_ns()
        for _ in range(n):
            with obs.span("hot.path", key="k"):
                pass
        best = min(best, (time.perf_counter_ns() - t0) / n)
    assert best < 1000, f"disabled span costs {best:.0f}ns (>1us)"


def test_multiple_sinks_and_enabled_flag():
    b1, b2 = obs.TraceBuffer(), obs.TraceBuffer()
    obs.install_sink(b1)
    try:
        obs.install_sink(b2)
        try:
            with obs.span("both"):
                pass
        finally:
            obs.remove_sink(b2)
        assert obs.enabled()           # b1 still installed
        with obs.span("one"):
            pass
    finally:
        obs.remove_sink(b1)
    assert not obs.enabled()
    assert [e["name"] for e in b1.flush()] == ["both", "one"]
    assert [e["name"] for e in b2.flush()] == ["both"]


# -------------------------------------------------------------- metrics

def test_registry_counters_labels_total_snapshot():
    reg = obs.Registry() if hasattr(obs, "Registry") else None
    # module-level registry API (what the instrumentation uses)
    obs.counter("t.hits", shard="a").inc()
    obs.counter("t.hits", shard="a").inc(2)
    obs.counter("t.hits", shard="b").inc()
    obs.gauge("t.resident").set(10)
    obs.gauge("t.resident").max(7)     # no-op, 7 < 10
    obs.histogram("t.wait").observe(2.0)
    obs.histogram("t.wait").observe(4.0)
    try:
        snap = obs.snapshot()
        assert snap["counters"]["t.hits{shard=a}"] == 3
        assert snap["counters"]["t.hits{shard=b}"] == 1
        assert obs.REGISTRY.total("t.hits") == 4
        assert snap["gauges"]["t.resident"] == 10
        h = snap["histograms"]["t.wait"]
        assert h == {"count": 2, "sum": 6.0, "min": 2.0, "max": 4.0,
                     "avg": 3.0}
    finally:
        obs.reset()
    assert reg is None or isinstance(reg, object)


def test_registry_get_or_create_identity():
    try:
        c1 = obs.counter("t.same", x="1")
        c2 = obs.counter("t.same", x="1")
        assert c1 is c2
        assert obs.counter("t.same", x="2") is not c1
    finally:
        obs.reset()


# ------------------------------------------------------------ exporters

def _sample_events():
    with obs.tracing() as buf:
        with obs.span("a", k=1):
            with obs.span("b"):
                pass
    return buf.flush()


def test_chrome_trace_export_and_validate(tmp_path):
    evs = _sample_events()
    trace = obs.to_chrome_trace(evs)
    dur = obs.validate_chrome_trace(trace)
    bs = [e for e in dur if e["ph"] == "B"]
    es = [e for e in dur if e["ph"] == "E"]
    assert len(bs) == len(es) == 2
    assert all("pid" in e and "tid" in e for e in trace["traceEvents"])
    # metadata names the thread
    ms = [e for e in trace["traceEvents"] if e["ph"] == "M"]
    assert ms and ms[0]["name"] == "thread_name"
    # file round-trip + CLI gate
    p = tmp_path / "t.json"
    obs.write_trace(str(p), evs)
    assert obs.validate_chrome_trace(str(p))
    from repro.obs.export import main as export_main

    assert export_main([str(p), "--require-span", "a",
                        "--require-span", "b"]) == 0
    assert export_main([str(p), "--require-span", "zzz"]) == 1


def test_chrome_trace_zero_duration_stays_balanced():
    evs = _sample_events()
    for e in evs:
        e["dur"] = 0.0                 # degenerate: all spans collapse
    obs.validate_chrome_trace(obs.to_chrome_trace(evs))


def test_validate_rejects_unbalanced():
    trace = {"traceEvents": [
        {"ph": "B", "name": "x", "ts": 0, "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError, match="unbalanced"):
        obs.validate_chrome_trace(trace)
    with pytest.raises(ValueError, match="pid/tid"):
        obs.validate_chrome_trace({"traceEvents": [{"ph": "B", "name": "x",
                                                    "ts": 0}]})


def test_jsonl_export(tmp_path):
    evs = _sample_events()
    p = tmp_path / "t.jsonl"
    obs.write_trace(str(p), evs)       # extension-dispatched
    lines = [json.loads(ln) for ln in p.read_text().splitlines()]
    assert [ln["name"] for ln in lines] == [e["name"] for e in evs]
    assert all("id" in ln and "args" in ln for ln in lines)


# ---------------------------------------------- policy key stability

def test_measure_policy_trace_key_stability():
    off = MeasurePolicy().resolve("")
    assert "trace" not in off          # untraced campaigns keep their keys
    on = MeasurePolicy(trace=True).resolve("")
    assert on["trace"] is True
    assert {k: v for k, v in on.items() if k != "trace"} == off


# ------------------------------------------- service stats reconciliation

def test_service_stats_reconciles_with_registry():
    from repro.matrices import suite
    from repro.serving.spmv_service import SpmvService

    mat = suite.get("smoke_banded")
    rng = np.random.default_rng(0)
    with SpmvService(engine="csr", max_batch=4, window_ms=5.0) as svc:
        svc.register("m", mat)
        futs = [svc.submit("m", rng.standard_normal(mat.n))
                for _ in range(6)]
        svc.flush()
        for f in futs:
            f.result(timeout=10)
        sid = svc.sid
    # read both views after shutdown: the dispatcher no longer ticks
    # time-driven counters (wakeups), so the cut is stable
    stats = svc.stats()
    snap = obs.snapshot()["counters"]
    # legacy keys preserved, and each is a view over the labelled counter
    for key in ("requests", "batches", "dispatches", "results", "sheds",
                "errors", "wakeups", "op_builds", "evictions"):
        assert stats[key] == snap[f"service.{key}{{service={sid}}}"], key
    assert stats["requests"] == 6 and stats["results"] == 6
    assert obs.REGISTRY.total("service.requests") >= 6
    # derived legacy fields still present
    assert "avg_batch" in stats and "slo" in stats
    assert isinstance(stats["batch_hist"], dict)


def test_plan_store_counters_move(tmp_path, monkeypatch):
    """Planning twice through the facade moves the unified cache
    counters (plan_store + opcache) — the scattered ad-hoc fields are
    gone, the registry is the single source. Fresh store dirs so the
    first plan is a guaranteed write and the second a guaranteed hit."""
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    monkeypatch.setenv("REPRO_REORDER_CACHE", str(tmp_path / "reorder"))
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path / "opcache"))
    from repro import api
    from repro.matrices import suite

    before = {n: obs.REGISTRY.total(n)
              for n in ("plan_store.writes", "plan_store.hits")}
    mat = suite.get("smoke_stencil")
    problem = api.SpmvProblem(mat)
    pl1 = api.plan(problem, reorder="baseline", engine="csr")
    pl1.build()
    pl2 = api.plan(problem, reorder="baseline", engine="csr")
    pl2.build()
    assert obs.REGISTRY.total("plan_store.writes") > before["plan_store.writes"]
    assert obs.REGISTRY.total("plan_store.hits") > before["plan_store.hits"]


def test_service_dispatcher_spans_nest_on_own_thread():
    """serve.dispatch/execute come from the dispatcher thread (its own
    tid, its own span tree): execute nests under dispatch, and neither
    parents onto the submitting thread's spans."""
    from repro.matrices import suite
    from repro.serving.spmv_service import SpmvService

    mat = suite.get("smoke_banded")
    rng = np.random.default_rng(1)
    with obs.tracing() as buf:
        with obs.span("caller"):
            with SpmvService(engine="csr", max_batch=4,
                             window_ms=5.0) as svc:
                svc.register("m", mat)
                futs = [svc.submit("m", rng.standard_normal(mat.n))
                        for _ in range(4)]
                svc.flush()
                for f in futs:
                    f.result(timeout=10)
    evs = buf.flush()
    by_id = {e["id"]: e for e in evs}
    caller = next(e for e in evs if e["name"] == "caller")
    dispatches = [e for e in evs if e["name"] == "serve.dispatch"]
    executes = [e for e in evs if e["name"] == "serve.execute"]
    submits = [e for e in evs if e["name"] == "serve.submit"]
    assert dispatches and executes and len(submits) == 4
    for e in submits:                  # submit runs on the caller thread
        assert e["tid"] == caller["tid"]
        assert e["parent"] == caller["id"]
    for e in dispatches:               # dispatcher owns its own tree
        assert e["tid"] != caller["tid"]
        assert e["parent"] is None
    for e in executes:
        parent = by_id[e["parent"]]
        assert parent["name"] == "serve.dispatch"
        assert parent["tid"] == e["tid"]


# ------------------------------------------------------------ regression

def _summary(geo_base=0.06, geo_rcm=0.05, run_ms=0.14, iters=3):
    return {
        "schema": 1, "campaign": "smoke", "field": "seq_ios_gflops",
        "geomean": {"baseline": geo_base, "rcm": geo_rcm},
        "speedup_vs_baseline": {"rcm": geo_rcm / geo_base},
        "scale": {"matrices": ["a", "b"], "max_m": 1024, "iters": iters,
                  "warmup": 1, "use_kernel": "interpret",
                  "representative": False},
        "plan_run": {"median_plan_ms": 4.0, "median_run_ms": run_ms,
                     "median_amortized_ms": 0.2, "amortize_iters": 100},
        "phases": {"median_tune_ms": 1.0},
    }


def test_regress_pass_and_improvement():
    res = compare(_summary(), _summary(geo_base=0.07))
    assert res["comparable"] and not res["regressions"]
    assert res["checks"] >= 4
    assert any("geomean[baseline]" in s for s in res["improvements"])


def test_regress_fails_on_2x_slowdown():
    cur = _summary(geo_base=0.03, geo_rcm=0.025, run_ms=0.28)
    res = compare(_summary(), cur)
    assert res["comparable"]
    names = " ".join(res["regressions"])
    assert "geomean[baseline]" in names
    assert "plan_run.median_run_ms" in names


def test_regress_portable_gates_only_ratios():
    # uniform 2x slowdown preserves speedup ratios: portable mode (for a
    # baseline committed from another machine) must NOT fail on it...
    cur = _summary(geo_base=0.03, geo_rcm=0.025, run_ms=0.28)
    res = compare(_summary(), cur, portable=True)
    assert res["comparable"] and not res["regressions"]
    assert any("machine-bound" in s for s in res["notes"])
    # ...but a collapsed rcm speedup still fails portable mode
    bad = _summary(geo_rcm=0.02)       # speedup 0.33 vs baseline 0.83
    res = compare(_summary(), bad, portable=True)
    assert any("speedup_vs_baseline" in s for s in res["regressions"])


def test_regress_refuses_cross_scale():
    res = compare(_summary(), _summary(iters=50))
    assert not res["comparable"]
    assert any("scale.iters" in s for s in res["scale_mismatch"])
    # a stamp-less (pre-gate) summary is incomparable, not silently passed
    old = _summary()
    del old["scale"]
    assert not compare(old, _summary())["comparable"]


def test_regress_cli_exit_codes(tmp_path):
    base, cur = _summary(), _summary()
    slow = copy.deepcopy(cur)
    slow["geomean"] = {k: v / 2 for k, v in slow["geomean"].items()}
    xscale = copy.deepcopy(cur)
    xscale["scale"]["iters"] = 99
    paths = {}
    for name, obj in [("base", base), ("cur", cur), ("slow", slow),
                      ("xscale", xscale)]:
        p = tmp_path / f"{name}.json"
        p.write_text(json.dumps(obj))
        paths[name] = str(p)
    argv = ["--baseline", paths["base"], "--current"]
    assert regress_main(argv + [paths["cur"]]) == 0
    assert regress_main(argv + [paths["slow"]]) == 1
    assert regress_main(argv + [paths["xscale"]]) == 2
    assert regress_main(argv + [str(tmp_path / "missing.json")]) == 2
