"""Per-kernel allclose vs ref.py oracles, sweeping shapes & dtypes
(interpret=True executes the Pallas kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sparse.bell import to_bcsr, to_block_ell
from repro.core.sparse.csr import CSRMatrix
from repro.kernels.bcsr_spmv.kernel import bcsr_spmm
from repro.kernels.bcsr_spmv.ops import BcsrOperator, pad_empty_rows
from repro.kernels.bcsr_spmv.ref import bcsr_spmm_ref
from repro.kernels.bell_spmv.kernel import bell_spmm
from repro.kernels.bell_spmv.ops import BellOperator
from repro.kernels.bell_spmv.ref import bell_spmm_ref
from repro.matrices import generators as G


def _mat(kind, seed):
    if kind == "banded":
        return G.banded(72, 3, seed)
    if kind == "rmat":
        return G.rmat(6, 4, seed)
    return G.stencil_2d(9, seed=seed)


@pytest.mark.parametrize("kind", ["banded", "rmat", "stencil"])
@pytest.mark.parametrize("bm,bn", [(4, 4), (8, 8), (4, 16), (16, 4)])
@pytest.mark.parametrize("nv", [1, 3])
def test_bell_kernel_shape_sweep(kind, bm, bn, nv):
    mat = _mat(kind, 0)
    host = to_block_ell(mat, bm, bn)
    rng = np.random.default_rng(1)
    ncb = (mat.n + bn - 1) // bn
    x2d = jnp.asarray(rng.standard_normal((ncb, bn, nv)), jnp.float32)
    blocks = jnp.asarray(host.blocks, jnp.float32)
    cols = jnp.asarray(host.block_cols)
    got = bell_spmm(blocks, cols, x2d, interpret=True)
    want = bell_spmm_ref(blocks, cols, x2d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", ["banded", "rmat", "stencil"])
@pytest.mark.parametrize("bm,bn", [(4, 4), (8, 8), (8, 16)])
def test_bcsr_kernel_shape_sweep(kind, bm, bn):
    mat = _mat(kind, 2)
    host = pad_empty_rows(to_bcsr(mat, bm, bn))
    rng = np.random.default_rng(3)
    ncb = (mat.n + bn - 1) // bn
    x2d = jnp.asarray(rng.standard_normal((ncb, bn, 1)), jnp.float32)
    blocks = jnp.asarray(host.blocks, jnp.float32)
    got = bcsr_spmm(blocks, jnp.asarray(host.block_rows), jnp.asarray(host.block_cols),
                    x2d, host.num_block_rows, interpret=True)
    want = bcsr_spmm_ref(blocks, jnp.asarray(host.block_rows),
                         jnp.asarray(host.block_cols), x2d, host.num_block_rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 0.05)])
def test_bell_kernel_dtypes(dtype, tol):
    mat = _mat("stencil", 4)
    host = to_block_ell(mat, 8, 8)
    rng = np.random.default_rng(5)
    ncb = (mat.n + 7) // 8
    x2d = jnp.asarray(rng.standard_normal((ncb, 8, 1)), dtype)
    blocks = jnp.asarray(host.blocks, dtype)
    cols = jnp.asarray(host.block_cols)
    got = np.asarray(bell_spmm(blocks, cols, x2d, interpret=True), np.float64)
    want = np.asarray(bell_spmm_ref(blocks, cols, x2d), np.float64)
    scale = np.abs(want).max() + 1e-9
    assert np.abs(got - want).max() / scale < tol


def test_bcsr_empty_row_padding():
    """Matrix with an all-zero row band: kernel must still define y there."""
    d = np.zeros((24, 24))
    d[0, 0] = 1.0
    d[20, 4] = 2.0  # rows 8..15 empty -> empty block row at bm=8
    mat = CSRMatrix.from_dense(d)
    op = BcsrOperator(to_bcsr(mat, 8, 8), use_kernel="interpret")
    x = jnp.asarray(np.arange(24, dtype=np.float32))
    got = np.asarray(op(x))
    assert np.allclose(got, d @ np.arange(24.0), atol=1e-5)


@given(st.integers(8, 48), st.integers(0, 5))
@settings(max_examples=8, deadline=None)
def test_property_bell_vs_numpy(m, seed):
    mat = G.random_uniform(m, 3, seed=seed)
    x = np.random.default_rng(seed).standard_normal(mat.n)
    op = BellOperator(to_block_ell(mat, 4, 4), use_kernel="interpret")
    got = np.asarray(op(jnp.asarray(x, jnp.float32)))
    want = mat.spmv(x)
    assert np.abs(got - want).max() < 1e-4 * (np.abs(want).max() + 1)
