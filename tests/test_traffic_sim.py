"""Open-loop traffic simulator + the "serve" experiment cell kind.

The schedule pieces (arrival_times / zipf_keys / update_mask) are pure,
seeded functions — tested without a service. run_open_loop is then
exercised end-to-end against a small budgeted service, and the "serve"
cell kind is driven through ExperimentSpec → Runner → ResultStore with
the same resumability contract every other kind honors.
"""
import numpy as np
import pytest

from repro.matrices import generators as G
from repro.serving import traffic
from repro.serving.traffic import TrafficPattern, arrival_times, \
    run_open_loop, update_mask, zipf_keys


@pytest.fixture()
def stores(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    monkeypatch.setenv("REPRO_REORDER_CACHE", str(tmp_path / "reorder"))
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path / "opcache"))
    monkeypatch.setenv("REPRO_RESULT_STORE", str(tmp_path / "results"))
    return tmp_path


# -- schedule determinism & statistics -------------------------------------
@pytest.mark.parametrize("arrival", traffic.ARRIVALS)
def test_arrival_times_deterministic_ascending_mean_rate(arrival):
    p = TrafficPattern(arrival=arrival, rate_rps=500.0, requests=400,
                      seed=7)
    t1, t2 = arrival_times(p), arrival_times(p)
    assert np.array_equal(t1, t2), "same seed must give same schedule"
    assert t1.shape == (400,)
    assert np.all(np.diff(t1) >= 0) and t1[0] > 0
    # open-loop mean rate ~ rate_rps (bursty has the same MEAN rate)
    achieved = p.requests / t1[-1]
    assert 0.6 * p.rate_rps < achieved < 1.6 * p.rate_rps
    if arrival != "uniform":        # uniform is seed-independent
        assert not np.array_equal(
            t1, arrival_times(TrafficPattern(arrival=arrival,
                                             rate_rps=500.0,
                                             requests=400, seed=8)))


def test_uniform_arrivals_are_evenly_spaced():
    p = TrafficPattern(arrival="uniform", rate_rps=100.0, requests=10)
    t = arrival_times(p)
    assert np.allclose(np.diff(t), 1.0 / 100.0)


def test_bursty_has_heavier_interarrival_tail_than_uniform():
    p = TrafficPattern(arrival="bursty", rate_rps=1000.0, requests=2000,
                      seed=3)
    gaps = np.diff(arrival_times(p))
    # on/off modulation: the largest gaps dwarf the median
    assert gaps.max() > 5 * np.median(gaps)


def test_zipf_keys_skew_toward_key_zero():
    p = TrafficPattern(rate_rps=1.0, requests=2000, n_keys=8, zipf_s=1.5,
                      seed=1)
    k = zipf_keys(p)
    assert k.min() >= 0 and k.max() < 8
    counts = np.bincount(k, minlength=8)
    assert counts[0] > counts[-1] * 2, "key 0 must be the hot key"
    # zipf_s=0 degenerates to uniform: far flatter
    flat = np.bincount(zipf_keys(TrafficPattern(
        rate_rps=1.0, requests=2000, n_keys=8, zipf_s=0.0, seed=1)),
        minlength=8)
    assert flat[0] < counts[0]


def test_update_mask_matches_fraction():
    p = TrafficPattern(rate_rps=1.0, requests=5000, update_frac=0.3,
                      seed=2)
    m = update_mask(p)
    assert m.dtype == np.bool_ and m.shape == (5000,)
    assert 0.25 < m.mean() < 0.35
    assert not update_mask(TrafficPattern(rate_rps=1.0, requests=50)).any()


def test_pattern_validation():
    with pytest.raises(ValueError, match="arrival"):
        TrafficPattern(arrival="lognormal")
    with pytest.raises(ValueError):
        TrafficPattern(rate_rps=0.0)
    with pytest.raises(ValueError):
        TrafficPattern(requests=0)
    with pytest.raises(ValueError, match="update_frac"):
        TrafficPattern(update_frac=1.0)


# -- variant round-trip ----------------------------------------------------
def test_serve_variant_roundtrips_and_elides_defaults():
    from repro.experiments.cells import _parse_serve_variant, serve_variant

    assert serve_variant() == "poisson"
    v = serve_variant(arrival="bursty", rate_rps=2000.0, requests=120,
                      n_keys=3, update_frac=0.25, budget_mb=0.02,
                      max_queue=16, window_ms=1.0,
                      overload="degrade-to-k1")
    cfg = _parse_serve_variant(v)
    assert cfg["arrival"] == "bursty" and cfg["rate_rps"] == 2000.0
    assert cfg["requests"] == 120 and cfg["n_keys"] == 3
    assert cfg["update_frac"] == 0.25 and cfg["budget_mb"] == 0.02
    assert cfg["max_queue"] == 16 and cfg["window_ms"] == 1.0
    assert cfg["overload"] == "degrade-to-k1"
    # untouched axes stay at defaults
    assert cfg["zipf_s"] == 1.1
    # equal scenarios encode identically (cell identity)
    assert v == serve_variant(arrival="bursty", rate_rps=2000.0,
                              requests=120, n_keys=3, update_frac=0.25,
                              budget_mb=0.02, max_queue=16, window_ms=1.0,
                              overload="degrade-to-k1")
    with pytest.raises(ValueError, match="unknown serve-variant"):
        _parse_serve_variant("poisson,x9")


# -- end-to-end open loop --------------------------------------------------
def test_run_open_loop_accounts_every_arrival(stores):
    from repro.serving.spmv_service import SpmvService

    mats = {f"k{i}": G.banded(128, 3, seed=i) for i in range(2)}
    p = TrafficPattern(arrival="poisson", rate_rps=2000.0, requests=60,
                      n_keys=2, update_frac=0.2, seed=0)
    with SpmvService(max_batch=8, window_ms=1.0, engine="csr",
                     use_kernel="interpret", max_queue=16,
                     overload="reject") as svc:
        for k, m in mats.items():
            svc.register(k, m)
        summary = run_open_loop(svc, mats, p)
        svc.flush(timeout=60)
    assert summary["offered"] == 60
    assert (summary["submitted"] + summary["rejected"]
            + summary["updates"] + summary["update_conflicts"]
            + summary["update_errors"]) == 60
    assert (summary["ok"] + summary["shed"] + summary["errors"]
            + summary["unresolved"]) == summary["submitted"]
    assert summary["unresolved"] == 0
    assert summary["errors"] == 0
    assert summary["retry_after_positive"]
    assert summary["budget_ok"]
    assert summary["stats"]["requests"] == summary["submitted"]


def test_run_open_loop_requires_enough_matrices():
    p = TrafficPattern(rate_rps=1.0, requests=1, n_keys=3)
    with pytest.raises(ValueError, match="3 keys"):
        run_open_loop(None, {"only": None}, p)


# -- the "serve" experiment cell kind --------------------------------------
def test_serve_cell_kind_campaign_resumes(stores):
    from repro.experiments import (ExperimentSpec, MeasurePolicy,
                                   ResultStore, Runner)
    from repro.experiments.cells import serve_variant

    spec = ExperimentSpec(
        name="t_serve", matrices=("smoke_banded",),
        schemes=("baseline",), engines=("csr",), ks=(4,),
        kind="serve",
        variants=(serve_variant(rate_rps=1500.0, requests=50, n_keys=2,
                                budget_mb=0.02, max_queue=8,
                                window_ms=1.0, overload="shed-oldest"),),
        policy=MeasurePolicy(iters=1, warmup=0, with_yax=False,
                             with_parallel=False, with_metrics=False,
                             use_kernel="interpret"))
    store = ResultStore()
    rep = Runner(spec, store=store, verbose=False).run()
    assert rep.measured == 1 and rep.reused == 0
    rec = rep.records[0]
    assert rec["offered"] == 50
    assert rec["unresolved"] == 0
    assert rec["errors"] == 0
    assert rec["counters_balanced"]
    assert rec["budget_ok"]
    assert rec["memory_budget_bytes"] == int(0.02 * (1 << 20))
    assert rec["p50_ms"] <= rec["p95_ms"] <= rec["p99_ms"]
    if rec["shed"] or rec["rejected"]:
        assert rec["retry_after_positive"]
    # records must be store-serializable scalars
    for v in rec.values():
        assert isinstance(v, (int, float, bool, str))
    # resumability: identical spec re-run measures nothing
    rep2 = Runner(spec, store=store, verbose=False).run()
    assert rep2.measured == 0 and rep2.reused == 1
    assert rep2.records[0]["store_reused"]
