"""Incremental structure deltas (ISSUE 10 satellite): StructureDelta /
delta_between / Plan.apply_delta edge cases, plus the amortization
acceptance — a gnn drift stream expressed as small rewires replans ZERO
times under use_deltas while delta.applies does the work.
"""
import numpy as np
import pytest

from repro import obs
from repro.core.spmv.delta import (BadDelta, DeltaTooLarge, MAX_CHURN,
                                   StructureDelta, delta_between)
from repro.core.spmv.plan import (SpmvProblem, plan, structure_key,
                                  values_key)
from repro.matrices import generators as G


@pytest.fixture
def stores(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    monkeypatch.setenv("REPRO_REORDER_CACHE", str(tmp_path / "reorder"))
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path / "ops"))
    monkeypatch.setenv("REPRO_RESULT_STORE", str(tmp_path / "results"))


def _entries(mat):
    rows = np.repeat(np.arange(mat.shape[0], dtype=np.int64),
                     np.diff(mat.rowptr.astype(np.int64)))
    return rows, mat.cols.astype(np.int64)


def _counters():
    return (obs.counter("delta.applies").value,
            obs.counter("delta.fallbacks").value)


# -- StructureDelta mechanics ---------------------------------------------

def test_apply_to_delete_and_add_roundtrip():
    mat = G.banded(64, 3, seed=0)
    rows, cols = _entries(mat)
    d = StructureDelta(del_rows=rows[:4], del_cols=cols[:4])
    out = d.apply_to(mat)
    assert out.nnz == mat.nnz - 4 and out.shape == mat.shape
    # add them back: structurally identical to the original
    vals = mat.vals[:4]
    d2 = StructureDelta(add_rows=rows[:4], add_cols=cols[:4], add_vals=vals)
    back = d2.apply_to(out)
    assert structure_key(back) == structure_key(mat)


def test_apply_to_validates_edits():
    mat = G.banded(32, 2, seed=1)
    rows, cols = _entries(mat)
    with pytest.raises(BadDelta):      # delete a hole
        StructureDelta(del_rows=[0], del_cols=[31]).apply_to(mat)
    with pytest.raises(BadDelta):      # add onto an existing entry
        StructureDelta(add_rows=rows[:1], add_cols=cols[:1],
                       add_vals=[1.0]).apply_to(mat)
    with pytest.raises(BadDelta):      # out of range
        StructureDelta(del_rows=[99], del_cols=[0]).apply_to(mat)
    with pytest.raises(BadDelta):      # ragged arrays
        StructureDelta(add_rows=[0, 1], add_cols=[5], add_vals=[1.0])


def test_delta_between_recovers_edit():
    old = G.banded(64, 3, seed=2)
    rows, cols = _entries(old)
    edit = StructureDelta(del_rows=rows[10:13], del_cols=cols[10:13])
    new = edit.apply_to(old)
    d = delta_between(old, new)
    assert d is not None and d.churn_nnz == 3
    assert structure_key(d.apply_to(old)) == structure_key(new)
    # shrunk shape is inexpressible
    assert delta_between(new, G.banded(32, 3, seed=2)) is None
    # identical structures produce an empty delta
    same = delta_between(old, old)
    assert same is not None and same.is_empty


# -- Plan.apply_delta edge cases ------------------------------------------

def test_empty_delta_is_noop_and_moves_no_counters(stores):
    mat = G.banded(128, 4, seed=3)
    pl = plan(SpmvProblem(mat), reorder="rcm", cache=False)
    before = _counters()
    out = pl.apply_delta(StructureDelta())
    assert out is pl                       # the SAME plan object
    assert _counters() == before           # neither applies nor fallbacks


def test_over_churn_delta_falls_back_exactly_once(stores):
    mat = G.banded(128, 4, seed=4)
    rows, cols = _entries(mat)
    k = int(mat.nnz * MAX_CHURN) + 1       # one entry past the threshold
    d = StructureDelta(del_rows=rows[:k], del_cols=cols[:k])
    pl = plan(SpmvProblem(mat), reorder="rcm", cache=False)
    applies0, fallbacks0 = _counters()
    with pytest.raises(DeltaTooLarge):
        pl.apply_delta(d)
    applies1, fallbacks1 = _counters()
    assert fallbacks1 == fallbacks0 + 1    # exactly one fallback
    assert applies1 == applies0            # and no apply


def test_keys_consistent_after_apply_delta(stores):
    mat = G.banded(128, 4, seed=5)
    rows, cols = _entries(mat)
    d = StructureDelta(del_rows=rows[5:9], del_cols=cols[5:9])
    pl = plan(SpmvProblem(mat), reorder="rcm", cache=False)
    applies0, _ = _counters()
    pl2 = pl.apply_delta(d)
    assert obs.counter("delta.applies").value == applies0 + 1
    new_mat = d.apply_to(mat)
    # the delta'd plan carries exactly the edited structure and values
    assert structure_key(pl2._mat) == structure_key(new_mat)
    assert values_key(pl2._mat) == values_key(new_mat)
    assert pl2.key != pl.key               # delta-chained plan key
    assert tuple(pl2.mat_shape) == tuple(new_mat.shape)
    assert pl2.mat_nnz == new_mat.nnz
    # frozen decision survives; the operator built from it is correct
    assert pl2.scheme == pl.scheme and pl2.tune.engine == pl.tune.engine
    op = pl2.build(cache=False)
    x = np.random.default_rng(0).standard_normal(new_mat.shape[1])
    want = new_mat.to_dense() @ x
    got = np.asarray(op(x), dtype=np.float64)
    assert np.abs(got - want).max() <= 1e-3 * max(np.abs(want).max(), 1.0)


def test_append_rows_extends_perm_with_identity_tail(stores):
    mat = G.banded(64, 3, seed=6)
    # appended entries hug the diagonal so bandwidth stays legal
    d = StructureDelta(append_rows=2,
                       add_rows=[64, 65], add_cols=[63, 65],
                       add_vals=[1.0, 2.0])
    pl = plan(SpmvProblem(mat), reorder="rcm", cache=False)
    pl2 = pl.apply_delta(d)
    assert pl2.mat_shape == (66, 66)       # square grows both dims
    assert pl2.perm is not None and pl2.perm.size == 66
    assert list(pl2.perm[-2:]) == [64, 65]


def test_sharded_plan_refuses_append(stores):
    from repro.core.spmv.topology import Topology

    mat = G.banded(128, 4, seed=7)
    pl = plan(SpmvProblem(mat), reorder="baseline", cache=False,
              topology=Topology(devices=2), partition="static")
    d = StructureDelta(append_rows=1, add_rows=[128], add_cols=[0],
                       add_vals=[1.0])
    _, fallbacks0 = _counters()
    with pytest.raises(DeltaTooLarge):
        pl.apply_delta(d)
    assert obs.counter("delta.fallbacks").value == fallbacks0 + 1
    # same-shape deltas ARE accepted on sharded plans
    rows, cols = _entries(mat)
    pl2 = pl.apply_delta(StructureDelta(del_rows=rows[:2],
                                        del_cols=cols[:2]))
    assert pl2.topology is not None and pl2.mat_nnz == mat.nnz - 2


# -- the amortization acceptance ------------------------------------------

def test_gnn_drift_with_deltas_pins_zero_replans(stores):
    """A drifting gnn stream whose steps are small rewires: with
    use_deltas the session expresses every structure move as a
    StructureDelta (replans == 0, deltas == steps - 1) — the cost that
    had to amortize is GONE, not merely amortized."""
    from repro.workloads import DynamicSparseProblem, WorkloadSession
    from repro.workloads.dynamic import run_stream

    prob = DynamicSparseProblem("workload://gnn-m128-deg6-n5-rw0.02",
                                scenario="drift", seed=0)
    session = WorkloadSession(prob, use_deltas=True)
    applies0 = obs.counter("delta.applies").value
    out = run_stream(prob, session, iters=1, compare_dense=True)
    assert out["replans"] == 0
    assert out["plans"] == 1
    assert out["deltas"] == out["steps"] - 1 > 0
    assert obs.counter("delta.applies").value >= applies0 + out["deltas"]
    assert out["verify_ok"]                # delta'd operators stay correct
    # the un-delta'd session on the SAME stream replans every drift step
    # (the baseline the router/session amortization is measured against)
    base = run_stream(prob, WorkloadSession(prob), iters=1,
                      compare_dense=False)
    assert base["replans"] == out["steps"] - 1 and base["deltas"] == 0
