"""SpMV engines vs oracles: csr/ell/bell/bcsr/dense, dtypes, SpMM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sparse.csr import CSRMatrix
from repro.core.spmv.ops import build_operator
from repro.matrices import generators as G

ENGINES = ["csr", "ell", "dense", "bell", "bcsr"]

MATS = {
    "banded": lambda: G.banded(96, 3, 0),
    "rmat": lambda: G.rmat(7, 4, 1),
    "stencil": lambda: G.stencil_2d(10, seed=2),
    "singleton": lambda: CSRMatrix.from_dense(np.diag([1.0, 2.0, 3.0])),
}


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("matname", list(MATS))
def test_engine_matches_numpy(engine, matname):
    mat = MATS[matname]()
    x = np.random.default_rng(0).standard_normal(mat.n)
    want = mat.spmv(x)
    kw = {"block_shape": (4, 4)} if engine in ("bell", "bcsr") else {}
    op = build_operator(mat, engine, **kw)
    got = np.asarray(op(jnp.asarray(x, jnp.float32)))
    scale = np.abs(want).max() + 1e-9
    assert np.abs(got - want).max() / scale < 1e-5, (engine, matname)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    mat = G.stencil_2d(8, seed=1)
    x = np.random.default_rng(1).standard_normal(mat.n)
    op = build_operator(mat, "bell", dtype=dtype, block_shape=(4, 4))
    got = np.asarray(op(jnp.asarray(x, dtype)), dtype=np.float64)
    want = mat.spmv(x)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    scale = np.abs(want).max() + 1e-9
    assert np.abs(got - want).max() / scale < tol


@given(st.integers(8, 64), st.sampled_from([2, 3, 5]), st.integers(0, 6))
@settings(max_examples=15, deadline=None)
def test_property_engines_agree(m, deg, seed):
    mat = G.random_uniform(m, deg, seed=seed)
    x = np.random.default_rng(seed).standard_normal(mat.n)
    outs = []
    for engine in ["csr", "ell", "bell"]:
        kw = {"block_shape": (4, 4)} if engine == "bell" else {}
        op = build_operator(mat, engine, **kw)
        outs.append(np.asarray(op(jnp.asarray(x, jnp.float32))))
    for o in outs[1:]:
        assert np.allclose(o, outs[0], atol=1e-3 * (np.abs(outs[0]).max() + 1))


def test_reordered_spmv_same_result():
    """Reordering must never change the math: P^T (PAP^T) (Px) == Ax."""
    from repro.core.reorder import api

    mat = G.shuffle(G.banded(256, 4, 0), 1)
    x = np.random.default_rng(2).standard_normal(mat.n)
    want = mat.spmv(x)
    perm = api.reorder(mat, "rcm", cache=False)
    rmat = mat.permute(perm)
    op = build_operator(rmat, "csr")
    y_perm = np.asarray(op(jnp.asarray(x[perm], jnp.float32)))
    got = np.empty_like(y_perm)
    got[perm] = y_perm  # scatter back: y = P^T y'
    assert np.abs(got - want).max() < 1e-3
