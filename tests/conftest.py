"""Test-environment shims.

The container image does not ship `hypothesis`; rather than fork every
property test, install a minimal deterministic stand-in that supports the
subset this suite uses (`given`, `settings`, `strategies.integers`,
`strategies.sampled_from`, `strategies.booleans`, `strategies.floats`).

The stub enumerates a fixed, seeded sample of the strategy space
(`max_examples` draws), so property tests stay deterministic across runs —
weaker than real shrinking/search, but sufficient as a regression net and
it keeps the suite green without network installs. If the real package is
present it is used untouched.
"""
from __future__ import annotations

import functools
import itertools
import random
import sys
import types


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def integers(min_value=None, max_value=None):
        lo = -(2**31) if min_value is None else int(min_value)
        hi = 2**31 - 1 if max_value is None else int(max_value)

        def draw(rng, lo=lo, hi=hi):
            # bias toward boundaries the way hypothesis does
            pick = rng.random()
            if pick < 0.15:
                return lo
            if pick < 0.3:
                return hi
            return rng.randint(lo, hi)

        return _Strategy(draw)

    def sampled_from(seq):
        seq = list(seq)

        def draw(rng, seq=seq):
            return rng.choice(seq)

        return _Strategy(draw)

    def booleans():
        return sampled_from([False, True])

    def floats(min_value=0.0, max_value=1.0, **_kw):
        def draw(rng, lo=float(min_value), hi=float(max_value)):
            return lo + (hi - lo) * rng.random()

        return _Strategy(draw)

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    def given(*strategies, **kw_strategies):
        def deco(fn):
            max_examples = getattr(fn, "_stub_max_examples", 20)

            # NB: no functools.wraps — pytest must not see the original
            # signature (it would treat the drawn params as fixtures).
            # *args only carries `self` when the test is a method.
            def wrapper(*args):
                rng = random.Random(0xC0FFEE)
                for i in range(max_examples):
                    drawn = tuple(s.example(rng) for s in strategies)
                    drawn_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **drawn_kw)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.hypothesis_stub = True
            return wrapper

        return deco

    mod = types.ModuleType("hypothesis")
    strategies_mod = types.ModuleType("hypothesis.strategies")
    strategies_mod.integers = integers
    strategies_mod.sampled_from = sampled_from
    strategies_mod.booleans = booleans
    strategies_mod.floats = floats
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies_mod
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    mod.__is_stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies_mod


_install_hypothesis_stub()
