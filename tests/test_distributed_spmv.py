"""Distributed shard_map SpMV vs numpy oracle, on 8 fake CPU devices.

Runs in a subprocess because xla_force_host_platform_device_count must be
set before jax initializes (the main pytest process keeps 1 device).
"""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.spmv import distributed as D
    from repro.matrices import generators as G

    mat = G.rmat(9, 6, seed=0)   # 512 rows, skewed
    rng = np.random.default_rng(1)
    x = rng.standard_normal(mat.n)
    want = mat.spmv(x)

    # ---- 1-D layout (8 panels over a flat mesh) ----
    devs = np.array(jax.devices()).reshape(8)
    mesh = Mesh(devs, ("data",))
    plan = D.plan_1d(mat, 8, bm=4, bn=16, balanced=True)
    f = D.spmv_1d(mesh, ("data",))
    # x panels: pad x to 8 * panel_n segments aligned with row panels
    pm = plan.panel_rows
    xp = np.zeros((8, pm))
    for p in range(8):
        r0 = plan.row_offset[p]
        r1 = plan.row_offset[p + 1] if p < 7 else mat.m
        xp[p, : r1 - r0] = x[r0:r1]
    n_pad = 8 * pm
    assert n_pad >= mat.n or True
    # all_gather(tiled) of panels gives a vector in PANEL layout; the plan's
    # block_cols refer to ORIGINAL column ids. For the test keep layout
    # consistent: run with x in panel-padded layout by rebuilding the matrix
    # in that layout (columns remapped to padded positions).
    colmap = np.zeros(mat.n, dtype=np.int64)
    for p in range(8):
        r0 = plan.row_offset[p]
        r1 = plan.row_offset[p + 1] if p < 7 else mat.m
        colmap[r0:r1] = p * pm + np.arange(r1 - r0)
    from repro.core.sparse.csr import CSRMatrix
    src = np.repeat(np.arange(mat.m), mat.row_nnz())
    rows_padded = colmap[src]
    cols_padded = colmap[mat.cols]
    mat_p = CSRMatrix.from_coo(rows_padded, cols_padded, mat.vals, (n_pad, n_pad))
    plan_p = D.plan_1d(mat_p, 8, bm=4, bn=16, balanced=False)
    xp_flat = np.zeros(n_pad); xp_flat[colmap] = x
    y = f(jnp.asarray(plan_p.blocks, jnp.float32),
          jnp.asarray(plan_p.block_cols),
          jnp.asarray(xp_flat.reshape(8, pm), jnp.float32))
    got = np.asarray(y).reshape(-1)[colmap]
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 1e-4, ("1d", err)
    print("1D OK", err)

    # ---- 2-D layout (4 x 2 mesh) ----
    mesh2 = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    blocks, bcols, seg_n, h_pad, starts = D.plan_2d(mat_p, 4, 2, bm=4, bn=16,
                                                    balanced=False)
    f2 = D.spmv_2d(mesh2)
    xs = xp_flat.copy()
    xs = np.pad(xs, (0, max(0, 2 * seg_n - xs.size))).reshape(2, seg_n)
    y2 = f2(jnp.asarray(blocks, jnp.float32), jnp.asarray(bcols),
            jnp.asarray(xs, jnp.float32))
    got2 = np.asarray(y2).reshape(-1)
    # rows: 4 panels each h_pad tall, starts gives true offsets
    out = np.zeros(n_pad)
    for p in range(4):
        r0, r1 = starts[p], starts[p + 1]
        out[r0:r1] = got2[p * h_pad : p * h_pad + (r1 - r0)]
    got2 = out[colmap]
    err2 = np.abs(got2 - want).max() / (np.abs(want).max() + 1e-9)
    assert err2 < 1e-4, ("2d", err2)
    print("2D OK", err2)
""")


def test_distributed_spmv_8dev():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "1D OK" in r.stdout and "2D OK" in r.stdout


SCRIPT_HALO = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.spmv import distributed as D
    from repro.core.reorder import api as reorder_api
    from repro.matrices import generators as G

    # shuffled banded matrix; RCM recovers small bandwidth -> halo legal
    raw = G.shuffle(G.banded(1024, 6, seed=0), seed=1)
    perm = reorder_api.reorder(raw, "rcm", cache=False)
    mat = raw.permute(perm)

    rng = np.random.default_rng(1)
    x = rng.standard_normal(mat.n)
    want = mat.spmv(x)

    blocks, bcols, halo, panel_n = D.plan_halo_1d(mat, 8, bm=4, bn=16)
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
    f = D.spmv_halo_1d(mesh, ("data",), halo)
    y = f(jnp.asarray(blocks, jnp.float32), jnp.asarray(bcols),
          jnp.asarray(x.reshape(8, panel_n), jnp.float32))
    got = np.asarray(y).reshape(-1)[: mat.m]
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 1e-4, err
    # comm accounting: halo exchange is 2*halo floats vs n*(P-1)/P all-gather
    assert 2 * halo < mat.n * 7 / 8 / 10, (halo, mat.n)
    print("HALO OK", err, "halo =", halo, "vs gather", mat.n * 7 // 8)
""")


def test_halo_exchange_spmv():
    r = subprocess.run([sys.executable, "-c", SCRIPT_HALO],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "HALO OK" in r.stdout
