"""Distributed SpMV through the topology-aware facade, on 8 fake CPU
devices (XLA_FLAGS host-device simulation), plus one bitwise legacy-parity
test for the pre-PR-5 shims.

The facade test runs in a subprocess because
xla_force_host_platform_device_count must be set before jax initializes
(the main pytest process keeps 1 device). It pins the PR's acceptance
criterion: a sharded plan (p=8, nnz_balanced, reordered) saved via
Plan.save reloads with ZERO re-tune and ShardedOperator(x) matches the
dense oracle in the ORIGINAL index space to fp64 tolerance for both
1d_rows and 2d_panels layouts.
"""
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest


def _run(script: str, tmp_path) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu", "HOME": "/root",
             "JAX_ENABLE_X64": "1",
             "REPRO_OPERATOR_CACHE": str(tmp_path / "opcache"),
             "REPRO_PLAN_CACHE": str(tmp_path / "plans"),
             "REPRO_REORDER_CACHE": str(tmp_path / "reorder")})


SCRIPT_FACADE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.api import Plan, ShardedOperator, SpmvProblem, Topology, plan
    from repro.matrices import generators as G

    mat = G.rmat(9, 6, seed=0)   # 512 rows, skewed
    rng = np.random.default_rng(1)
    x = rng.standard_normal(mat.n)
    want = mat.to_dense() @ x    # fp64 dense oracle
    X = rng.standard_normal((mat.n, 3))
    wantX = mat.to_dense() @ X

    for layout in ("1d_rows", "2d_panels"):
        topo = Topology(devices=8, layout=layout)
        pl = plan(SpmvProblem(mat, dtype=np.float64), reorder="rcm",
                  topology=topo, partition="nnz_balanced")
        assert pl.partitioner == "nnz_balanced" and pl.scheme == "rcm"
        assert pl.panel_starts is not None and pl.panel_starts.size == \\
            topo.row_devices + 1
        op = pl.build()
        assert isinstance(op, ShardedOperator) and not op.simulated
        got = np.asarray(op(x))
        err = np.abs(got - want).max() / (np.abs(want).max() + 1e-300)
        assert err < 1e-12, (layout, err)
        gotX = np.asarray(op.matmul(X))
        errX = np.abs(gotX - wantX).max() / (np.abs(wantX).max() + 1e-300)
        assert errX < 1e-12, (layout, errX)

        # store round-trip: reload pays zero plan time, operator arrays
        # restore from the entry (no re-partition, no re-conversion)
        pl2 = Plan.load(pl.key, mat=mat)
        assert pl2 is not None and pl2.cache_hit
        assert pl2.plan_ms == 0.0 and pl2.tune_ms == 0.0
        assert pl2.partitioner == pl.partitioner
        assert np.array_equal(pl2.panel_starts, pl.panel_starts)
        assert pl2.topology == pl.topology
        op2 = pl2.build()
        assert op2.build_info["cache_hit"], layout
        assert np.array_equal(np.asarray(op2(x)), got)
        print(f"{layout} OK", err)

    # CG through the sharded operator, original index space end-to-end
    from repro.core.measure import cg
    spd = G.banded(512, 4, seed=2)     # diagonally-dominant SPD-ish band
    d = spd.to_dense(); d = (d + d.T) / 2 + 8.0 * np.eye(512)
    r, c = np.nonzero(d)
    from repro.core.sparse.csr import CSRMatrix
    spd = CSRMatrix.from_coo(r, c, d[r, c], (512, 512))
    b = rng.standard_normal(512)
    res, op = cg.solve_problem(SpmvProblem(spd, dtype=np.float64), b,
                               reorder="rcm", engine="auto", max_iter=200,
                               tol=1e-10,
                               topology=Topology(devices=8),
                               partition="nnz_balanced")
    xsol = np.asarray(res.x)
    assert np.abs(spd.spmv(xsol) - b).max() < 1e-6, float(res.residual)
    print("CG OK", float(res.residual))
""")


def test_sharded_facade_8dev(tmp_path):
    r = _run(SCRIPT_FACADE, tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "1d_rows OK" in r.stdout and "2d_panels OK" in r.stdout
    assert "CG OK" in r.stdout


SCRIPT_HALO = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.api import SpmvProblem, Topology, plan
    from repro.matrices import generators as G

    # shuffled banded matrix; RCM recovers small bandwidth, so the comm
    # model switches the 1-D collective from all-gather to halo exchange
    raw = G.shuffle(G.banded(2048, 6, seed=0), seed=1)
    pl = plan(SpmvProblem(raw, dtype=np.float64), reorder="rcm",
              topology=Topology(devices=8), partition="static")
    assert pl.comm["schedule"] == "halo", pl.comm
    assert pl.comm["bytes_per_spmv"] < pl.comm["gather_bytes"] / 4, pl.comm
    op = pl.build()
    assert not op.simulated
    rng = np.random.default_rng(1)
    x = rng.standard_normal(raw.n)
    want = raw.to_dense() @ x
    got = np.asarray(op(x))
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-300)
    assert err < 1e-12, err
    print("HALO OK", err, pl.comm["halo"], "vs gather",
          pl.comm["gather_bytes"])
""")


def test_halo_schedule_8dev(tmp_path):
    r = _run(SCRIPT_HALO, tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "HALO OK" in r.stdout


# -- legacy parity (the pre-PR-5 shims) ------------------------------------

def _bell_panels_to_dense(blocks, bcols, bm, bn, n):
    """Reassemble a [P, nbr, K, bm, bn] panel stack into dense rows."""
    p_, nbr, k = blocks.shape[:3]
    out = np.zeros((p_ * nbr * bm, n))
    for p in range(p_):
        for r in range(nbr):
            for j in range(k):
                c0 = int(bcols[p, r, j]) * bn
                r0 = (p * nbr + r) * bm
                out[r0:r0 + bm, c0:c0 + bn] += blocks[p, r, j]
    return out


def test_legacy_shims_bitwise_parity():
    """The deprecated plan_1d / plan_2d / plan_halo_1d shims still emit
    bitwise-exact layouts: reassembling their bricks reproduces the dense
    matrix EXACTLY (pure data movement, no arithmetic), and they warn."""
    from repro.core.reorder import api as reorder_api
    from repro.core.spmv import distributed as D
    from repro.matrices import generators as G

    mat = G.rmat(7, 5, seed=0)          # 128 rows
    with pytest.warns(DeprecationWarning):
        p1 = D.plan_1d(mat, 4, bm=4, bn=16, balanced=True)
    dense = np.zeros(mat.shape)
    h = p1.panel_rows
    rebuilt = _bell_panels_to_dense(p1.blocks, p1.block_cols, 4, 16, h * 4)
    for p in range(4):
        r0 = int(p1.row_offset[p])
        r1 = int(p1.row_offset[p + 1]) if p < 3 else mat.m
        dense[r0:r1] = rebuilt[p * h: p * h + (r1 - r0), :mat.n]
    assert np.array_equal(dense, mat.to_dense())

    with pytest.warns(DeprecationWarning):
        blocks, bcols, seg_n, h_pad, starts = D.plan_2d(
            mat, 2, 2, bm=4, bn=16, balanced=False)
    dense2 = np.zeros((2 * h_pad, 2 * seg_n))
    for q in range(2):
        seg = _bell_panels_to_dense(blocks[:, q], bcols[:, q], 4, 16, seg_n)
        dense2[:, q * seg_n:(q + 1) * seg_n] = seg
    want = np.zeros((2 * h_pad, 2 * seg_n))
    d = mat.to_dense()
    for p in range(2):
        r0, r1 = int(starts[p]), int(starts[p + 1])
        want[p * h_pad: p * h_pad + (r1 - r0), :mat.n] = d[r0:r1]
    assert np.array_equal(dense2, want)

    banded = G.shuffle(G.banded(256, 3, seed=0), seed=1)
    rmat = banded.permute(reorder_api.reorder(banded, "rcm", cache=False))
    with pytest.warns(DeprecationWarning):
        hblocks, hbcols, halo, panel_n = D.plan_halo_1d(rmat, 4, bm=4, bn=16)
    win = panel_n + 2 * halo
    dense3 = np.zeros((rmat.m, win))
    reb = _bell_panels_to_dense(hblocks, hbcols, 4, 16, win)
    nbr = (panel_n + 3) // 4
    for p in range(4):
        dense3[p * panel_n:(p + 1) * panel_n] = \
            reb[p * nbr * 4: p * nbr * 4 + panel_n]
    d3 = rmat.to_dense()
    for p in range(4):
        for i in range(panel_n):
            row = d3[p * panel_n + i]
            lo = p * panel_n - halo
            wrow = np.zeros(win)
            for c in np.nonzero(row)[0]:
                wrow[c - lo] = row[c]
            assert np.array_equal(dense3[p * panel_n + i], wrow)


def test_shim_step_builders_warn():
    """The mesh-step shims warn without needing a mesh to be built."""
    from unittest import mock

    from repro.core.spmv import distributed as D

    with pytest.warns(DeprecationWarning):
        with mock.patch.object(D, "_legacy_spmv_1d", return_value=None):
            D.spmv_1d(None, ("data",))
    with pytest.warns(DeprecationWarning):
        with mock.patch.object(D, "_legacy_spmv_2d", return_value=None):
            D.spmv_2d(None)
    with pytest.warns(DeprecationWarning):
        with mock.patch.object(D, "_legacy_spmv_halo_1d", return_value=None):
            D.spmv_halo_1d(None, ("data",), 16)


def test_no_in_src_shim_callers():
    """src/ never calls the deprecated distributed entry points (the
    facade path runs clean with DeprecationWarning promoted to error)."""
    import jax.numpy as jnp

    from repro.api import SpmvProblem, Topology, plan
    from repro.matrices import generators as G

    mat = G.banded(128, 3, seed=0)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        pl = plan(SpmvProblem(mat), reorder="baseline", engine="csr",
                  topology=Topology(devices=2), partition="static",
                  cache=False)
        op = pl.build(cache=False)
        op(jnp.ones(mat.n, jnp.float32))
        op.matmul(jnp.ones((mat.n, 2), jnp.float32))
