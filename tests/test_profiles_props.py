"""Property tests for the paper-statistics kernels in measure/profiles.py
(Dolan-Moré profiles, speedup buckets, cross-machine consistency).

Runs under real hypothesis when installed, else the deterministic stub in
conftest.py."""
from __future__ import annotations

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.measure import profiles

SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _perf(seed, s, m, low=0.1, high=10.0):
    rng = np.random.default_rng(seed)
    return low + (high - low) * rng.random((s, m))


# -- performance_profile ----------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(SEEDS, st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=12))
def test_profile_bounds_monotone_and_best_covers(seed, s, m):
    perf = _perf(seed, s, m)
    taus = np.array([1.0, 1.1, 1.5, 2.0, 1e9])
    prof = profiles.performance_profile(perf, taus)
    assert prof.shape == (s, len(taus))
    assert ((prof >= 0) & (prof <= 1)).all()
    # nondecreasing in tau, and every scheme reaches 1 at tau -> inf
    assert (np.diff(prof, axis=1) >= -1e-12).all()
    assert np.allclose(prof[:, -1], 1.0)
    # at tau=1 every matrix has at least one winning scheme
    assert prof[:, 0].sum() >= 1.0 - 1e-12


@settings(max_examples=15, deadline=None)
@given(SEEDS, st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=12))
def test_profile_all_schemes_tied(seed, s, m):
    """Ties: when every scheme performs identically, each is 'within tau
    of the best' everywhere — the profile is 1.0 for all schemes at every
    tau >= 1 (no winner is crowned arbitrarily)."""
    row = _perf(seed, 1, m)
    perf = np.repeat(row, s, axis=0)
    prof = profiles.performance_profile(perf, np.array([1.0, 2.0]))
    assert np.allclose(prof, 1.0)


@settings(max_examples=15, deadline=None)
@given(SEEDS, st.integers(min_value=1, max_value=12))
def test_profile_single_scheme_is_identically_one(seed, m):
    """A single scheme is trivially the best on every matrix."""
    perf = _perf(seed, 1, m)
    prof = profiles.performance_profile(perf, np.array([1.0, 1.5]))
    assert np.allclose(prof, 1.0)


# -- consistency_ratio ------------------------------------------------------

def test_consistency_empty_candidate_set_is_vacuously_consistent():
    # no matrix exceeds tau on any machine -> |CCS| = 0, Consistent% = 1
    s = np.array([[1.0, 0.9], [1.05, 1.0]])
    cons, n = profiles.consistency_ratio(s, tau=1.5)
    assert (cons, n) == (1.0, 0)
    # degenerate shapes
    cons, n = profiles.consistency_ratio(np.ones((1, 0)), tau=1.1)
    assert (cons, n) == (1.0, 0)


@settings(max_examples=25, deadline=None)
@given(SEEDS, st.integers(min_value=1, max_value=5),
       st.integers(min_value=1, max_value=15))
def test_consistency_ccs_monotone_in_tau(seed, machines, m):
    """tau ordering: raising tau can only SHRINK the candidate set
    (speedup > tau is a stricter filter), and Consistent% stays in
    [0, 1] throughout."""
    rng = np.random.default_rng(seed)
    sp = 0.25 + 3.0 * rng.random((machines, m))
    last_n = None
    for tau in (1.05, 1.1, 1.25, 1.5, 2.0, 3.0):
        cons, n = profiles.consistency_ratio(sp, tau)
        assert 0.0 <= cons <= 1.0
        if last_n is not None:
            assert n <= last_n
        last_n = n


@settings(max_examples=25, deadline=None)
@given(SEEDS, st.integers(min_value=1, max_value=5),
       st.integers(min_value=1, max_value=15),
       st.sampled_from([1.1, 1.25, 1.5, 2.0]))
def test_consistency_matches_definition(seed, machines, m, tau):
    """Eq. 1 re-derived independently: IS ⊆ CCS and
    Consistent% = 1 - |IS|/|CCS|."""
    rng = np.random.default_rng(seed)
    sp = 0.25 + 3.0 * rng.random((machines, m))
    cons, n = profiles.consistency_ratio(sp, tau)
    ccs = [j for j in range(m) if (sp[:, j] > tau).any()]
    is_ = [j for j in ccs if (sp[:, j] < 1.0).any()]
    assert n == len(ccs)
    if ccs:
        assert np.isclose(cons, 1.0 - len(is_) / len(ccs))
    else:
        assert cons == 1.0


# -- speedup_buckets --------------------------------------------------------

def test_bucket_boundary_values_land_left_inclusive():
    """Each boundary belongs to the bucket it opens (histogram bins are
    left-inclusive): 1.0 is '1-1.1', 1.1 is '1.1-1.25', ..., 2.0 is '>=2'."""
    boundaries = [1.0, 1.1, 1.25, 1.5, 2.0]
    counts = profiles.speedup_buckets(np.array([boundaries]))
    # bucket 0 is '<1': empty; each boundary value fills exactly the
    # bucket it opens
    assert counts[0].tolist() == [0, 1, 1, 1, 1, 1]
    assert counts.sum() == len(boundaries)
    # just below each boundary falls one bucket lower
    eps = 1e-9
    below = [b - eps for b in boundaries]
    counts2 = profiles.speedup_buckets(np.array([below]))
    assert counts2[0].tolist() == [1, 1, 1, 1, 1, 0]


@settings(max_examples=25, deadline=None)
@given(SEEDS, st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=20))
def test_buckets_partition_all_matrices(seed, s, m):
    """Buckets partition the speedup axis: every matrix lands in exactly
    one bucket per scheme."""
    rng = np.random.default_rng(seed)
    sp = 0.1 + 4.0 * rng.random((s, m))
    counts = profiles.speedup_buckets(sp)
    assert counts.shape == (s, len(profiles.BUCKET_LABELS))
    assert (counts.sum(axis=1) == m).all()
