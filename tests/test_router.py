"""Multi-shard serving router (ISSUE 10): placement policies, the
routing-table ledger, per-device operator accounting + budget
enforcement, RoutedElsewhere on the plain service, non-stalling
background shard replans (siblings keep serving), routed delta applies,
and the 'route' cell-kind variant grammar.
"""
import numpy as np
import pytest

from repro import obs
from repro.core.spmv import opcache
from repro.core.spmv.plan import SpmvProblem, plan
from repro.core.spmv.topology import Topology
from repro.matrices import generators as G
from repro.router import (MeshSpec, PLACEMENT_REGISTRY, RoutedSpmvService,
                          RoutingTable, estimate_nbytes, get_placement,
                          register_placement)
from repro.serving.errors import BadRequest, RoutedElsewhere
from repro.serving.spmv_service import SpmvService


@pytest.fixture
def stores(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    monkeypatch.setenv("REPRO_REORDER_CACHE", str(tmp_path / "reorder"))
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path / "ops"))


def _x(n, seed=0):
    return np.random.default_rng(seed).standard_normal(n)


def _close(got, mat, x):
    want = mat.to_dense() @ x
    return np.abs(np.asarray(got, np.float64) - want).max() \
        <= 1e-3 * max(np.abs(want).max(), 1.0)


# -- satellite 1: per-device operator accounting ---------------------------

def test_operator_nbytes_per_device(stores):
    mat = G.banded(256, 4, seed=0)
    op1 = plan(SpmvProblem(mat), cache=False).build(cache=False)
    # non-sharded: the whole operator lives on one device
    assert opcache.operator_nbytes_per_device(op1) \
        == [opcache.operator_nbytes(op1)]
    pl = plan(SpmvProblem(mat), cache=False,
              topology=Topology(devices=2), partition="static")
    op = pl.build(cache=False)
    per = opcache.operator_nbytes_per_device(op)
    assert len(per) == 2 and all(b > 0 for b in per)
    # the replicated gather/scatter index maps are charged to EVERY
    # device, so no device's share can be smaller than they are alone
    idx_bytes = sum(
        np.asarray(getattr(op, a)).nbytes
        for a in ("_in_idx", "_in_idx_r", "_out_idx", "_out_idx_r")
        if getattr(op, a, None) is not None)
    assert idx_bytes > 0 and min(per) >= idx_bytes


# -- satellite 2: the plain service refuses sharded updates ----------------

def test_routed_elsewhere_hierarchy():
    assert issubclass(RoutedElsewhere, BadRequest)
    assert issubclass(RoutedElsewhere, ValueError)   # legacy catch intact


def test_plain_service_update_on_sharded_key_raises(stores):
    mat = G.banded(128, 4, seed=1)
    with SpmvService(use_kernel="interpret", window_ms=1.0,
                     topology=Topology(devices=2)) as svc:
        svc.register("s", mat)
        assert _close(svc.submit("s", _x(mat.n)).result(timeout=60),
                      mat, _x(mat.n))                # serving itself works
        with pytest.raises(RoutedElsewhere):
            svc.update_values("s", mat.vals * 2.0)
        with pytest.raises(RoutedElsewhere):
            svc.update_structure("s", mat=G.banded(128, 5, seed=2))


# -- placement policies ----------------------------------------------------

def _loads(meshes):
    return {m.name: {"keys": 0, "nnz": 0, "est_bytes": 0} for m in meshes}


def test_bin_pack_best_fit_prefers_tightest_budget():
    mat = G.banded(256, 4, seed=3)
    est = estimate_nbytes(mat)
    meshes = [MeshSpec("big", Topology(devices=2),
                       budget_per_device=16 << 20),
              MeshSpec("tight", Topology(devices=1),
                       budget_per_device=est + 1024)]
    table = RoutingTable(meshes, policy="bin_pack")
    assert table.assign("k0", mat).name == "tight"   # best (smallest) fit
    assert table.assign("k1", mat).name == "big"     # tight is now full


def test_bin_pack_falls_back_to_unbounded_mesh():
    mat = G.banded(256, 4, seed=3)
    meshes = [MeshSpec("full", Topology(devices=1), budget_per_device=1),
              MeshSpec("open", Topology(devices=1))]
    spec = get_placement("bin_pack")
    assert spec.fn("k", mat, meshes, _loads(meshes)) == "open"


def test_nnz_balance_spreads_equal_meshes():
    mat = G.banded(256, 4, seed=4)
    meshes = [MeshSpec("m0", Topology(devices=2)),
              MeshSpec("m1", Topology(devices=2))]
    table = RoutingTable(meshes, policy="nnz_balance")
    got = {table.assign(f"k{i}", mat).name for i in range(2)}
    assert got == {"m0", "m1"}


def test_comm_aware_scores_every_mesh():
    mat = G.power_law(256, alpha=1.8, seed=5)
    meshes = [MeshSpec("wide", Topology(devices=4)),
              MeshSpec("solo", Topology(devices=1))]
    spec = get_placement("comm_aware")
    loads = _loads(meshes)
    first = spec.fn("k", mat, meshes, loads)
    assert first in {"wide", "solo"}
    assert spec.fn("k", mat, meshes, loads) == first   # pure in the ledger


def test_register_placement_and_registry_errors():
    name = "always_first_TEST"
    try:
        @register_placement(name, "test-only")
        def always_first(key, mat, meshes, loads):
            return meshes[0].name

        mat = G.banded(64, 2, seed=6)
        table = RoutingTable([MeshSpec("a", Topology(devices=1)),
                              MeshSpec("b", Topology(devices=1))],
                             policy=name)
        assert table.assign("k", mat).name == "a"
        with pytest.raises(ValueError):          # duplicate registration
            register_placement(name)(always_first)
    finally:
        PLACEMENT_REGISTRY.pop(name, None)
    with pytest.raises(KeyError):
        get_placement("no_such_policy")


def test_routing_table_ledger():
    mat = G.banded(64, 2, seed=7)
    meshes = [MeshSpec("a", Topology(devices=1)),
              MeshSpec("b", Topology(devices=1))]
    table = RoutingTable(meshes, policy="nnz_balance")
    spec = table.assign("k", mat, mesh="b")          # explicit pin
    assert spec.name == "b" and table.mesh_of("k").name == "b"
    with pytest.raises(ValueError):                  # no silent re-place
        table.assign("k", mat)
    with pytest.raises(KeyError):
        table.assign("k2", mat, mesh="nope")
    snap = table.snapshot()
    assert snap["assignments"] == {"k": "b"}
    assert snap["loads"]["b"]["nnz"] == mat.nnz
    table.remove("k", mat)
    assert snap["loads"]["b"]["keys"] == 1           # snapshot is a copy
    assert table.snapshot()["loads"]["b"] \
        == {"keys": 0, "nnz": 0, "est_bytes": 0}
    with pytest.raises(KeyError):
        table.mesh_of("k")
    with pytest.raises(ValueError):
        RoutingTable([], policy="bin_pack")
    with pytest.raises(ValueError):
        RoutingTable([meshes[0], meshes[0]])         # duplicate names


# -- per-device budgets (tentpole pillar 1) --------------------------------

def test_per_device_budget_bounds_every_device(stores):
    mats = {"a": G.banded(256, 4, seed=8), "b": G.banded(256, 4, seed=9)}
    kw = dict(use_kernel="interpret", window_ms=1.0, max_batch=4)
    with RoutedSpmvService([MeshSpec("m", Topology(devices=2))],
                           **kw) as rt:
        rt.register("a", mats["a"])
        rt.operator("a")
        need = max(rt.stats()["per_mesh"]["m"]["per_device_bytes"])
    budget = int(need * 1.5)                 # one operator fits, two don't
    mesh = MeshSpec("m", Topology(devices=2), budget_per_device=budget)
    with RoutedSpmvService([mesh], **kw) as rt:
        for k, m in mats.items():
            rt.register(k, m)
        for k in mats:
            assert _close(rt.submit(k, _x(256)).result(timeout=60),
                          mats[k], _x(256))
        st = rt.stats()
        assert st["evictions"] >= 1          # the LRU had to make room
        assert st["per_device_ok"]
        assert all(b <= budget for b
                   in st["per_mesh"]["m"]["per_device_bytes"])
        # the evicted key still serves (zero-re-tune reload)
        for k in mats:
            assert _close(rt.submit(k, _x(256, 1)).result(timeout=60),
                          mats[k], _x(256, 1))


# -- non-stalling shard replans (pillar 2) + routed deltas (pillar 3) ------

def test_background_replan_keeps_siblings_serving(stores):
    a, b = G.banded(128, 4, seed=10), G.banded(128, 4, seed=11)
    b2 = G.banded(128, 6, seed=12)           # new structure for b
    mesh = MeshSpec("m", Topology(devices=2))
    with RoutedSpmvService([mesh], use_kernel="interpret",
                           window_ms=1.0, max_batch=4) as rt:
        rt.register("a", a, mesh="m")
        rt.register("b", b, mesh="m")
        rt.operator("a")
        rt.operator("b")
        fut = rt.update_structure("b", mat=b2)
        # the sibling keeps serving while b replans in the background
        assert _close(rt.submit("a", _x(128)).result(timeout=60),
                      a, _x(128))
        gen = fut.result(timeout=120)
        assert isinstance(gen, int)
        st = rt.stats()
        assert st["replans"] == 1 and st["replan_errors"] == 0
        # b now serves the NEW structure
        assert _close(rt.submit("b", _x(128, 2)).result(timeout=60),
                      b2, _x(128, 2))
        # and a was never touched
        assert _close(rt.submit("a", _x(128, 3)).result(timeout=60),
                      a, _x(128, 3))


def test_routed_delta_applies_without_full_replan(stores):
    from repro.core.spmv.delta import StructureDelta

    mat = G.banded(128, 4, seed=13)
    rows = np.repeat(np.arange(128, dtype=np.int64),
                     np.diff(mat.rowptr.astype(np.int64)))
    d = StructureDelta(del_rows=rows[:3],
                       del_cols=mat.cols.astype(np.int64)[:3])
    new_mat = d.apply_to(mat)
    mesh = MeshSpec("m", Topology(devices=2))
    with RoutedSpmvService([mesh], use_kernel="interpret",
                           window_ms=1.0, max_batch=4) as rt:
        rt.register("k", mat)
        rt.operator("k")
        applies0 = obs.counter("delta.applies").value
        rt.update_structure("k", delta=d).result(timeout=120)
        assert obs.counter("delta.applies").value == applies0 + 1
        assert rt.stats()["replans"] == 1
        assert _close(rt.submit("k", _x(128, 4)).result(timeout=60),
                      new_mat, _x(128, 4))
    with pytest.raises(BadRequest):          # exactly one of mat=/delta=
        rt2 = RoutedSpmvService([MeshSpec("m", Topology(devices=1))],
                                use_kernel="interpret")
        try:
            rt2.register("k", mat)
            rt2.update_structure("k")
        finally:
            rt2.close()


def test_unrouted_key_raises(stores):
    from repro.serving.errors import UnregisteredKey

    with RoutedSpmvService([MeshSpec("m", Topology(devices=1))],
                           use_kernel="interpret") as rt:
        with pytest.raises(UnregisteredKey):
            rt.operator("ghost")
        with pytest.raises(KeyError):
            rt.submit("ghost", _x(8))


# -- the 'route' cell-kind variant grammar ---------------------------------

def test_route_variant_roundtrips_and_elides_defaults():
    from repro.experiments.cells import _parse_route_variant, route_variant

    assert route_variant() == "poisson"      # all defaults elided
    v = route_variant(rate_rps=600, requests=120, n_keys=4,
                      structure_frac=0.08, devices=4, policy="comm_aware",
                      budget_mb=2.0, window_ms=1.0)
    cfg = _parse_route_variant(v)
    assert cfg["rate_rps"] == 600 and cfg["requests"] == 120
    assert cfg["n_keys"] == 4 and cfg["structure_frac"] == 0.08
    assert cfg["devices"] == 4 and cfg["policy"] == "comm_aware"
    assert cfg["budget_mb"] == 2.0 and cfg["window_ms"] == 1.0
    assert cfg["meshes"] == 2 and cfg["layout"] == "1d_rows"  # defaults
    with pytest.raises(ValueError):
        _parse_route_variant("poisson,q17")
