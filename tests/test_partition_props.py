"""Property tests: partitioning invariants + vectorized metrics.

Satellites of the SpMM and topology PRs:
  * nnz_balanced_partition must survive p > m, a single giant row that
    swallows several nnz targets, and empty trailing panels — always
    returning monotone offsets that cover every row exactly once.
  * chunked_cyclic_panels must assign every row to exactly one thread
    (coverage + disjointness), each thread's row list strictly
    increasing, with clean degeneration when m < p * chunk.
  * partition_to_owner must be the exact inverse view of a covering
    partition: nondecreasing owners, counts == panel heights, loud
    rejection of non-covering input.
  * Every registered PARTITIONER plugin honors the (perm, starts)
    contract on arbitrary skewed matrices.
  * The vectorized metrics (profile / distinct_col_blocks / cut_volume /
    halo_width) must be BIT-identical to the straightforward per-row /
    per-panel loops they replaced.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.registry import PARTITIONER_REGISTRY
from repro.core.sparse import metrics
from repro.core.sparse.csr import CSRMatrix
from repro.core.sparse.partition import (chunked_cyclic_panels,
                                         nnz_balanced_partition,
                                         partition_to_owner,
                                         resolve_partitioner,
                                         static_partition)
from repro.matrices import generators as G


def _skewed(m: int, seed: int) -> CSRMatrix:
    return G.power_law(max(m, 8), alpha=1.8, seed=seed)


def _check_invariants(mat: CSRMatrix, p: int, starts: np.ndarray) -> None:
    assert starts.shape == (p + 1,)
    assert starts[0] == 0 and starts[-1] == mat.m
    assert np.all(np.diff(starts) >= 0), "panel offsets must be monotone"
    loads = metrics.panel_loads(mat, starts)
    assert int(loads.sum()) == mat.nnz, "panels must cover every nnz once"
    if mat.nnz and p > 1:
        # greedy-splitter guarantee: no panel exceeds fair share + one row
        max_row = int(mat.row_nnz().max())
        assert loads.max() <= mat.nnz / p + max_row + 1e-9


@given(st.integers(8, 200), st.integers(1, 64), st.integers(0, 5))
@settings(max_examples=25, deadline=None)
def test_property_nnz_balanced_invariants(m, p, seed):
    """Random skewed matrices x panel counts (including p > m)."""
    mat = _skewed(m, seed)
    _check_invariants(mat, p, nnz_balanced_partition(mat, p))


def test_nnz_balanced_p_greater_than_m():
    mat = _skewed(16, 0)
    starts = nnz_balanced_partition(mat, 64)
    _check_invariants(mat, 64, starts)
    # exactly m nonempty panels at most
    assert int(np.count_nonzero(np.diff(starts))) <= mat.m


def test_nnz_balanced_giant_row_swallows_targets():
    """One row holding ~90% of nnz overruns several targets at once."""
    m, p = 64, 8
    counts = np.ones(m, dtype=np.int64)
    counts[3] = 600
    rowptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    nnz = int(rowptr[-1])
    rng = np.random.default_rng(0)
    mat = CSRMatrix(rowptr=rowptr,
                    cols=rng.integers(0, m, nnz).astype(np.int32),
                    vals=np.ones(nnz), shape=(m, m))
    starts = nnz_balanced_partition(mat, p)
    _check_invariants(mat, p, starts)
    # the giant row sits alone in its panel; the overtaken cuts collapse
    giant_panel = int(np.searchsorted(starts, 3, side="right")) - 1
    assert starts[giant_panel] <= 3 < starts[giant_panel + 1]


def test_nnz_balanced_degenerate_inputs():
    empty = CSRMatrix(rowptr=np.zeros(9, np.int32),
                      cols=np.empty(0, np.int32), vals=np.empty(0),
                      shape=(8, 8))
    starts = nnz_balanced_partition(empty, 4)  # nnz == 0 -> equal rows
    assert np.array_equal(starts, static_partition(empty, 4))
    zero_rows = CSRMatrix(rowptr=np.zeros(1, np.int32),
                          cols=np.empty(0, np.int32), vals=np.empty(0),
                          shape=(0, 0))
    assert np.array_equal(nnz_balanced_partition(zero_rows, 3),
                          np.zeros(4, np.int64))
    with pytest.raises(ValueError):
        nnz_balanced_partition(empty, 0)


# --------------------------------------------------------------------------
# chunked_cyclic_panels: coverage / disjointness / monotone threads
# --------------------------------------------------------------------------
@given(st.integers(0, 300), st.integers(1, 16), st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_property_chunked_cyclic_cover_disjoint(m, p, chunk):
    panels = chunked_cyclic_panels(m, p, chunk)
    assert len(panels) == p
    allids = np.concatenate(panels) if panels else np.empty(0, np.int64)
    # coverage + disjointness: the union is exactly [0, m), each once
    assert allids.size == m
    assert np.array_equal(np.sort(allids), np.arange(m))
    for ids in panels:
        # each thread's row set is strictly increasing (stride order)
        assert np.all(np.diff(ids) > 0) if ids.size > 1 else True
        # and is a union of <=chunk-length runs starting at multiples of
        # chunk owned by this thread
        if ids.size:
            assert ids.min() >= 0 and ids.max() < m


def test_chunked_cyclic_degenerate_small_m():
    """m < p * chunk: the leading threads each get (at most) one partial
    chunk, trailing threads come out EMPTY — never an index error."""
    panels = chunked_cyclic_panels(10, 4, 16)     # one chunk covers all
    assert [len(x) for x in panels] == [10, 0, 0, 0]
    panels = chunked_cyclic_panels(20, 4, 16)
    assert [len(x) for x in panels] == [16, 4, 0, 0]
    assert np.array_equal(panels[1], np.arange(16, 20))
    panels = chunked_cyclic_panels(0, 3, 8)
    assert [len(x) for x in panels] == [0, 0, 0]


def test_chunked_cyclic_round_robin_order():
    panels = chunked_cyclic_panels(64, 2, 16)
    assert np.array_equal(panels[0],
                          np.r_[np.arange(0, 16), np.arange(32, 48)])
    assert np.array_equal(panels[1],
                          np.r_[np.arange(16, 32), np.arange(48, 64)])


# --------------------------------------------------------------------------
# partition_to_owner: inverse-view invariants
# --------------------------------------------------------------------------
@given(st.integers(8, 200), st.integers(1, 64), st.integers(0, 5))
@settings(max_examples=25, deadline=None)
def test_property_partition_to_owner(m, p, seed):
    mat = _skewed(m, seed)
    starts = nnz_balanced_partition(mat, p)
    owner = partition_to_owner(starts, mat.m)
    assert owner.shape == (mat.m,)
    # owner-monotonicity: contiguous panels => nondecreasing owner ids
    assert np.all(np.diff(owner) >= 0)
    assert owner.min() >= 0 and owner.max() <= p - 1
    # counts are exactly the panel heights
    assert np.array_equal(np.bincount(owner, minlength=p),
                          np.diff(starts))


def test_partition_to_owner_rejects_non_covering():
    with pytest.raises(ValueError):
        partition_to_owner(np.array([1, 4, 8]), 8)     # doesn't start at 0
    with pytest.raises(ValueError):
        partition_to_owner(np.array([0, 4]), 8)        # doesn't reach m
    with pytest.raises(ValueError):
        partition_to_owner(np.empty(0, np.int64), 8)


# --------------------------------------------------------------------------
# partitioner plugin contract (what plan(topology=...) relies on)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(PARTITIONER_REGISTRY)
                         + ["chunked_cyclic_c4"])
def test_partitioner_contract(name):
    """(perm, starts) from every registered partitioner: perm is a valid
    permutation (or None), starts covers [0, m] monotonically with
    exactly p panels."""
    mat = _skewed(100, 3)
    for p in (1, 4, 7):
        _, fn = resolve_partitioner(name)
        perm, starts = fn(mat, p, 0)
        assert starts.shape == (p + 1,)
        assert starts[0] == 0 and starts[-1] == mat.m
        assert np.all(np.diff(starts) >= 0)
        if perm is not None:
            assert np.array_equal(np.sort(perm), np.arange(mat.m))


def test_resolve_partitioner_unknown():
    with pytest.raises(KeyError):
        resolve_partitioner("nope")
    with pytest.raises(KeyError):
        resolve_partitioner("nope_c16")


def test_partition_to_owner_matches_loop():
    mat = _skewed(100, 1)
    for p in (1, 3, 8, 200):
        starts = nnz_balanced_partition(mat, p)
        want = np.zeros(mat.m, dtype=np.int32)
        for pnl in range(len(starts) - 1):
            want[starts[pnl]:starts[pnl + 1]] = pnl
        assert np.array_equal(partition_to_owner(starts, mat.m), want)


# --------------------------------------------------------------------------
# Vectorized metrics == the loops they replaced (bit-identical)
# --------------------------------------------------------------------------
def _profile_loop(mat):
    total = 0
    rp = mat.rowptr.astype(np.int64)
    for i in np.flatnonzero(np.diff(rp) > 0):
        cmin = mat.cols[rp[i]:rp[i + 1]].min()
        if cmin < i:
            total += int(i - cmin)
    return total


def _distinct_loop(mat, panel_starts, block_n):
    rp = mat.rowptr.astype(np.int64)
    out = np.zeros(len(panel_starts) - 1, dtype=np.int64)
    blocks = mat.cols.astype(np.int64) // block_n
    for p in range(len(panel_starts) - 1):
        s, e = rp[panel_starts[p]], rp[panel_starts[p + 1]]
        out[p] = np.unique(blocks[s:e]).size
    return out


def _cut_loop(mat, panel_starts):
    owner = np.zeros(mat.m, dtype=np.int64)
    for p in range(len(panel_starts) - 1):
        owner[panel_starts[p]:panel_starts[p + 1]] = p
    r = np.repeat(np.arange(mat.m), mat.row_nnz()).astype(np.int64)
    return int(np.count_nonzero(owner[r] != owner[mat.cols.astype(np.int64)]))


def _halo_loop(mat, panel_starts):
    rp = mat.rowptr.astype(np.int64)
    worst = 0
    for p in range(len(panel_starts) - 1):
        r0, r1 = panel_starts[p], panel_starts[p + 1]
        s, e = rp[r0], rp[r1]
        if e > s:
            seg = mat.cols[s:e].astype(np.int64)
            worst = max(worst,
                        int(max(r0 - seg.min(), seg.max() - (r1 - 1), 0)))
    return worst


_MATS = [
    lambda: G.power_law(150, alpha=1.8, seed=0),
    lambda: G.banded(96, 5, seed=1),
    lambda: G.shuffle(G.sbm(128, 4, 0.15, 0.01, seed=2), seed=3),
    # rows 10..19 empty: exercises the reduceat empty-segment argument
    lambda: _with_empty_rows(),
]


def _with_empty_rows():
    mat = G.banded(64, 3, seed=4)
    dense = mat.to_dense()
    dense[10:20, :] = 0.0
    rows, cols = np.nonzero(dense)
    return CSRMatrix.from_coo(rows, cols, dense[rows, cols], mat.shape)


@pytest.mark.parametrize("mk", range(len(_MATS)))
@pytest.mark.parametrize("p", [1, 3, 7, 64])
def test_vectorized_metrics_bit_identical(mk, p):
    mat = _MATS[mk]()
    for starts in (static_partition(mat, p), nnz_balanced_partition(mat, p)):
        assert metrics.profile(mat) == _profile_loop(mat)
        assert np.array_equal(metrics.distinct_col_blocks(mat, starts, 16),
                              _distinct_loop(mat, starts, 16))
        assert metrics.cut_volume(mat, starts) == _cut_loop(mat, starts)
        assert metrics.halo_width(mat, starts) == _halo_loop(mat, starts)


def test_vectorized_metrics_non_covering_partition():
    """A partition spanning only a sub-range of rows must behave exactly
    like the old loops: out-of-panel nonzeros are simply ignored."""
    mat = G.power_law(150, alpha=1.8, seed=5)
    starts = np.array([10, 40, 90], dtype=np.int64)
    assert np.array_equal(metrics.distinct_col_blocks(mat, starts, 16),
                          _distinct_loop(mat, starts, 16))
    assert metrics.cut_volume(mat, starts) == _cut_loop(mat, starts)
    assert metrics.halo_width(mat, starts) == _halo_loop(mat, starts)


def test_vectorized_metrics_empty_matrix():
    empty = CSRMatrix(rowptr=np.zeros(17, np.int32),
                      cols=np.empty(0, np.int32), vals=np.empty(0),
                      shape=(16, 16))
    starts = static_partition(empty, 4)
    assert metrics.profile(empty) == 0
    assert np.array_equal(metrics.distinct_col_blocks(empty, starts, 8),
                          np.zeros(4, np.int64))
    assert metrics.cut_volume(empty, starts) == 0
    assert metrics.halo_width(empty, starts) == 0
